//! Wall-clock measurement used by the Fig.-7 speed comparison and the bench
//! harness (criterion is not in the offline crate set, so `bench_fn`
//! implements the warmup + repeated-measurement loop itself).

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating named phases.
#[derive(Debug, Default)]
pub struct Stopwatch {
    phases: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start timing a named phase (ends any running phase first).
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Stop the running phase, if any.
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.phases.push((name, t0.elapsed()));
        }
    }

    /// Total accumulated time of all phases with this name.
    pub fn total(&self, name: &str) -> Duration {
        self.phases
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }

    pub fn total_secs(&self, name: &str) -> f64 {
        self.total(name).as_secs_f64()
    }

    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }
}

/// Result of a [`bench_fn`] run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  min {:>12}  max {:>12}",
            self.name,
            self.iters,
            fmt_duration(self.mean_s),
            fmt_duration(self.min_s),
            fmt_duration(self.max_s)
        )
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Criterion-lite: warm up, then measure `f` repeatedly until `budget`
/// wall time or `max_iters` is spent, and report mean/min/max.
pub fn bench_fn<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warmup: one call (also triggers lazy init / JIT caches)
    f();
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && times.len() < 10_000 {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() >= 3 && start.elapsed() > budget {
            break;
        }
    }
    let n = times.len().max(1);
    let mean = times.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.start("b");
        std::thread::sleep(Duration::from_millis(1));
        sw.stop();
        assert!(sw.total("a") >= Duration::from_millis(2));
        assert!(sw.total("b") >= Duration::from_millis(1));
        assert_eq!(sw.total("missing"), Duration::ZERO);
    }

    #[test]
    fn bench_fn_runs() {
        let mut count = 0usize;
        let r = bench_fn("noop", Duration::from_millis(5), || count += 1);
        assert!(r.iters >= 1);
        assert!(count >= r.iters); // warmup adds one
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s + 1e-12);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.5).ends_with(" s"));
        assert!(fmt_duration(2.5e-3).ends_with("ms"));
        assert!(fmt_duration(2.5e-6).ends_with("µs"));
        assert!(fmt_duration(2.5e-9).ends_with("ns"));
    }
}
