//! Minimal JSON parser/writer. The offline crate set has no serde_json; the
//! runtime needs to read `artifacts/manifest.json` and the dataset tools
//! (de)serialize clip sets. Supports the full JSON grammar minus `\u` escapes
//! beyond BMP pairs (we never emit those).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — experiment artifacts diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }
    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    // ---- serialization ---------------------------------------------------
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }
    pub fn dump_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry the byte offset.
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u")?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(format!("expected ',' or '}}' at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.dump()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":{"e":[]}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.dump()).unwrap(), v);
        assert_eq!(parse(&v.dump_pretty()).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n":4,"s":"x","a":[10,20]}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(4));
        assert_eq!(v.get("s").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(1).as_i64(), Some(20));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"config":{"vocab_size":512},"variants":{"capsim":
            {"param_size":190721,"params":[{"name":"embed","shape":[512,64],
            "offset":0}],"files":{"init":"capsim_init.hlo.txt"}}}}"#;
        let v = parse(src).unwrap();
        assert_eq!(
            v.get("variants").get("capsim").get("param_size").as_usize(),
            Some(190721)
        );
        assert_eq!(
            v.get("variants").get("capsim").get("params").idx(0)
                .get("shape").idx(0).as_usize(),
            Some(512)
        );
    }
}
