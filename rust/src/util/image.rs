//! `CPIM` — the shared on-disk **image container** behind the mmap
//! persistence of the clip cache and the attention weights (no `unsafe`
//! here; the mapping and pointer casts live in [`crate::util::mmap`]).
//!
//! Layout (all little-endian), designed so a reader can go from open to
//! serving in O(1): a fixed 96-byte header + a small kind-specific meta
//! blob, both covered by a checksum, then two segment-aligned sections —
//! fixed-stride records (sorted by key, binary-searchable in place) and a
//! raw payload (f32 data for weights). Section starts are padded to
//! [`SEG_ALIGN`] so any mmap base (page-aligned by definition) gives
//! aligned in-memory views.
//!
//! ```text
//! off  size field
//!   0    u32 magic            "CPIM"
//!   4    u32 container version (1)
//!   8    u32 kind             (1 = clip cache, 2 = attention weights)
//!  12    u32 meta_len
//!  16    u64 fingerprint      Predictor::fingerprint the image is keyed by
//!  24    u64 kernel_contract  KERNEL_CONTRACT_VERSION at save time
//!  32    u32 time_scale bits  (0 where not applicable)
//!  36    u32 record_stride
//!  40    u64 n_records
//!  48    u64 records_off      SEG_ALIGN-aligned
//!  56    u64 records_len      == n_records * record_stride
//!  64    u64 payload_off      SEG_ALIGN-aligned
//!  72    u64 payload_len
//!  80    u64 data_digest      digest64 over records ++ payload
//!  88    u64 header_checksum  digest64 over bytes [0, 88) ++ meta
//!  96    meta bytes, zero padding, records, zero padding, payload
//! ```
//!
//! Verification is two-phase by design: [`ImageView::parse`] checks the
//! header checksum plus every bound/alignment/stride invariant in O(1),
//! which is what makes warm start size-independent; [`ImageView::verify_data`]
//! recomputes the O(data) digest and is run eagerly for the small weights
//! payload but deferred to first use for the cache (see
//! `coordinator::cache`), so corruption is always caught before any byte
//! is trusted, without putting an O(entries) scan on the open path.

use std::io::Write;

/// Header magic "CPIM" (CaPsim IMage).
pub const IMAGE_MAGIC: u32 = 0x4D49_5043;
/// Bump on any incompatible container change; old images then cold-start.
pub const IMAGE_VERSION: u32 = 1;
/// Image kind: clip cache (16-byte `key,f64` records, empty payload).
pub const KIND_CLIP_CACHE: u32 = 1;
/// Image kind: attention weights (24-byte tensor records, f32 payload).
pub const KIND_WEIGHTS: u32 = 2;
/// Section alignment. 4096 divides every real page size, so an offset
/// aligned to it is at least 4096-aligned in any mapping.
pub const SEG_ALIGN: usize = 4096;
/// Fixed header size (everything before the meta blob).
pub const HEADER_LEN: usize = 96;
/// Upper bound on the kind-specific meta blob — parse refuses beyond it,
/// so a hostile `meta_len` can never drive a large read or allocation.
pub const MAX_META_LEN: u32 = 1 << 16;

/// FNV-1a over 8-byte little-endian words (tail zero-padded), seeded with
/// the section lengths. Word-wise rather than byte-wise so verifying the
/// weights payload runs at memcpy-like speed, and the same function
/// serves both the O(1) header checksum and the O(data) segment digest.
pub fn digest64(sections: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    let mut mix = |v: u64| {
        h = (h ^ v).wrapping_mul(0x0000_0100_0000_01B3);
    };
    for s in sections {
        mix(s.len() as u64);
        let mut chunks = s.chunks_exact(8);
        for c in &mut chunks {
            mix(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            mix(u64::from_le_bytes(tail));
        }
    }
    h
}

/// Everything an image writer must supply; offsets, padding, digests and
/// the checksum are derived here so every writer shares one layout.
pub struct ImageSpec<'a> {
    pub kind: u32,
    pub fingerprint: u64,
    pub kernel_contract: u64,
    pub time_scale_bits: u32,
    pub meta: &'a [u8],
    pub record_stride: u32,
    pub records: &'a [u8],
    pub payload: &'a [u8],
}

/// Serialize `spec` as one complete image. The caller owns durability
/// (unique temp file + fsync + rename); this only produces bytes.
pub fn write_image(w: &mut impl Write, spec: &ImageSpec<'_>) -> std::io::Result<()> {
    assert!(spec.record_stride > 0, "record stride must be non-zero");
    assert_eq!(
        spec.records.len() % spec.record_stride as usize,
        0,
        "records must be whole strides"
    );
    assert!(spec.meta.len() <= MAX_META_LEN as usize, "meta blob too large");
    let n_records = (spec.records.len() / spec.record_stride as usize) as u64;
    let records_off = align_up(HEADER_LEN + spec.meta.len());
    let payload_off = align_up(records_off + spec.records.len());

    let mut head = Vec::with_capacity(HEADER_LEN);
    head.extend_from_slice(&IMAGE_MAGIC.to_le_bytes());
    head.extend_from_slice(&IMAGE_VERSION.to_le_bytes());
    head.extend_from_slice(&spec.kind.to_le_bytes());
    head.extend_from_slice(&(spec.meta.len() as u32).to_le_bytes());
    head.extend_from_slice(&spec.fingerprint.to_le_bytes());
    head.extend_from_slice(&spec.kernel_contract.to_le_bytes());
    head.extend_from_slice(&spec.time_scale_bits.to_le_bytes());
    head.extend_from_slice(&spec.record_stride.to_le_bytes());
    head.extend_from_slice(&n_records.to_le_bytes());
    head.extend_from_slice(&(records_off as u64).to_le_bytes());
    head.extend_from_slice(&(spec.records.len() as u64).to_le_bytes());
    head.extend_from_slice(&(payload_off as u64).to_le_bytes());
    head.extend_from_slice(&(spec.payload.len() as u64).to_le_bytes());
    head.extend_from_slice(&digest64(&[spec.records, spec.payload]).to_le_bytes());
    let checksum = digest64(&[&head, spec.meta]);
    head.extend_from_slice(&checksum.to_le_bytes());
    debug_assert_eq!(head.len(), HEADER_LEN);

    w.write_all(&head)?;
    w.write_all(spec.meta)?;
    w.write_all(&vec![0u8; records_off - HEADER_LEN - spec.meta.len()])?;
    w.write_all(spec.records)?;
    w.write_all(&vec![0u8; payload_off - records_off - spec.records.len()])?;
    w.write_all(spec.payload)
}

fn align_up(off: usize) -> usize {
    off.div_ceil(SEG_ALIGN) * SEG_ALIGN
}

/// A parsed, bounds- and checksum-verified view into an image's bytes.
/// Constructing one is O(1) + O(meta); it borrows, never copies.
pub struct ImageView<'a> {
    pub kind: u32,
    pub fingerprint: u64,
    pub kernel_contract: u64,
    pub time_scale_bits: u32,
    pub record_stride: u32,
    pub n_records: u64,
    pub meta: &'a [u8],
    pub records: &'a [u8],
    pub payload: &'a [u8],
    pub data_digest: u64,
}

impl<'a> ImageView<'a> {
    /// Parse and validate a header. Anything short of a fully coherent
    /// image — wrong magic/version, bad checksum, out-of-bounds or
    /// misaligned sections, stride/length mismatch, oversized meta —
    /// returns `Err` so the caller cold-starts. Every arithmetic step is
    /// overflow-checked; hostile headers can neither panic nor allocate.
    pub fn parse(bytes: &'a [u8]) -> Result<ImageView<'a>, String> {
        let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
        if bytes.len() < HEADER_LEN {
            return Err(format!("{} bytes is too short for an image header", bytes.len()));
        }
        if u32_at(0) != IMAGE_MAGIC {
            return Err("not a CPIM image".into());
        }
        if u32_at(4) != IMAGE_VERSION {
            return Err(format!("unsupported image version {}", u32_at(4)));
        }
        let meta_len = u32_at(12);
        if meta_len > MAX_META_LEN {
            return Err(format!("oversized meta blob ({meta_len} bytes)"));
        }
        let meta_end = HEADER_LEN
            .checked_add(meta_len as usize)
            .filter(|&e| e <= bytes.len())
            .ok_or("meta blob out of bounds")?;
        let meta = &bytes[HEADER_LEN..meta_end];
        let stored = u64_at(88);
        if digest64(&[&bytes[..88], meta]) != stored {
            return Err("header checksum mismatch (torn or corrupt header)".into());
        }
        // From here the header is internally consistent *as written*; the
        // remaining checks pin it to this file's actual size and layout.
        let record_stride = u32_at(36);
        let n_records = u64_at(40);
        let section = |off: u64, len: u64, align: usize, what: &str| -> Result<&'a [u8], String> {
            let end = off.checked_add(len).ok_or_else(|| format!("{what} length overflow"))?;
            if end > bytes.len() as u64 {
                return Err(format!("{what} section out of bounds"));
            }
            if off as usize % align != 0 {
                return Err(format!("{what} section misaligned"));
            }
            if len > 0 && (off as usize) < meta_end {
                return Err(format!("{what} section overlaps the header"));
            }
            Ok(&bytes[off as usize..end as usize])
        };
        let records_len = u64_at(56);
        if record_stride == 0
            || record_stride as usize > SEG_ALIGN
            || n_records.checked_mul(record_stride as u64) != Some(records_len)
        {
            return Err("record stride/count/length disagree".into());
        }
        let records = section(u64_at(48), records_len, SEG_ALIGN, "records")?;
        let payload = section(u64_at(64), u64_at(72), SEG_ALIGN, "payload")?;
        Ok(ImageView {
            kind: u32_at(8),
            fingerprint: u64_at(16),
            kernel_contract: u64_at(24),
            time_scale_bits: u32_at(32),
            record_stride,
            n_records,
            meta,
            records,
            payload,
            data_digest: u64_at(80),
        })
    }

    /// Recompute the data digest over records ++ payload. O(data) — the
    /// one intentionally non-O(1) check; see the module docs for when
    /// each caller runs it.
    pub fn verify_data(&self) -> bool {
        digest64(&[self.records, self.payload]) == self.data_digest
    }

    /// Record `i`'s bytes (panics if out of range — callers index within
    /// `n_records`, which `parse` proved in-bounds).
    pub fn record(&self, i: usize) -> &'a [u8] {
        let s = self.record_stride as usize;
        &self.records[i * s..(i + 1) * s]
    }
}

/// Monotonic per-process sequence for unique temp-file names.
static SAVE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Shared durable-publish discipline for every persisted format: write to
/// a uniquely-named sibling temp file (pid + sequence — a fixed
/// `with_extension("tmp")` name would let two concurrent savers
/// interleave writes and rename a torn file over the good one), fsync,
/// then atomically rename into place; the temp is unlinked on error.
/// fsync before rename matters: without it a crash shortly after the
/// rename can leave a file whose *name* is durable but whose bytes are
/// not — exactly the torn image [`ImageView::parse`] exists to refuse.
pub fn persist_atomic(
    path: &std::path::Path,
    write_body: impl FnOnce(&mut std::io::BufWriter<std::fs::File>) -> std::io::Result<()>,
) -> std::io::Result<()> {
    let seq = SAVE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut tmp_name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    tmp_name.push(format!(".{}.{}.tmp", std::process::id(), seq));
    let tmp = path.with_file_name(tmp_name);
    let write = (|| -> std::io::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        write_body(&mut w)?;
        w.into_inner().map_err(|e| e.into_error())?.sync_all()?;
        std::fs::rename(&tmp, path)
    })();
    if write.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    write
}

/// Peek a file's leading magic/version without loading it — powers the
/// `capsim backends` persistence report. Returns `(magic, version)`.
pub fn peek_format(path: &std::path::Path) -> std::io::Result<(u32, u32)> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let mut head = [0u8; 8];
    f.read_exact(&mut head)?;
    Ok((
        u32::from_le_bytes(head[0..4].try_into().unwrap()),
        u32::from_le_bytes(head[4..8].try_into().unwrap()),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(records: &[u8], payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_image(
            &mut out,
            &ImageSpec {
                kind: KIND_CLIP_CACHE,
                fingerprint: 0xFEED,
                kernel_contract: 2,
                time_scale_bits: 40.0f32.to_bits(),
                meta: b"meta!",
                record_stride: 16,
                records,
                payload,
            },
        )
        .unwrap();
        out
    }

    #[test]
    fn write_parse_roundtrip() {
        let records: Vec<u8> = (0..64u8).collect(); // 4 records of 16
        let payload = [7u8; 12];
        let img = sample(&records, &payload);
        let v = ImageView::parse(&img).unwrap();
        assert_eq!(v.kind, KIND_CLIP_CACHE);
        assert_eq!(v.fingerprint, 0xFEED);
        assert_eq!(v.kernel_contract, 2);
        assert_eq!(v.time_scale_bits, 40.0f32.to_bits());
        assert_eq!(v.meta, b"meta!");
        assert_eq!(v.n_records, 4);
        assert_eq!(v.records, &records[..]);
        assert_eq!(v.payload, &payload[..]);
        assert_eq!(v.record(2), &records[32..48]);
        assert!(v.verify_data());
    }

    #[test]
    fn sections_are_seg_aligned() {
        let img = sample(&[0u8; 32], &[1u8; 8]);
        let v = ImageView::parse(&img).unwrap();
        let base = img.as_ptr() as usize;
        assert_eq!((v.records.as_ptr() as usize - base) % SEG_ALIGN, 0);
        assert_eq!((v.payload.as_ptr() as usize - base) % SEG_ALIGN, 0);
    }

    #[test]
    fn every_single_byte_truncation_is_refused_or_intact() {
        let img = sample(&[3u8; 48], &[9u8; 4]);
        for cut in 0..img.len() {
            let t = &img[..cut];
            if let Ok(v) = ImageView::parse(t) {
                // a parseable truncation may only drop trailing padding —
                // the data itself must still be whole and verified
                assert!(v.verify_data(), "truncation at {cut} parsed but data is torn");
                assert_eq!(v.records, &[3u8; 48][..]);
                assert_eq!(v.payload, &[9u8; 4][..]);
            }
        }
        assert!(ImageView::parse(&[]).is_err());
    }

    #[test]
    fn every_header_byte_flip_is_caught() {
        let img = sample(&[5u8; 16], b"");
        for pos in 0..HEADER_LEN + 5 {
            for bit in [1u8, 0x80] {
                let mut m = img.clone();
                m[pos] ^= bit;
                assert!(
                    ImageView::parse(&m).is_err(),
                    "header/meta flip at byte {pos} went unnoticed"
                );
            }
        }
    }

    #[test]
    fn data_flips_fail_digest_not_parse() {
        let img = sample(&[5u8; 32], &[6u8; 8]);
        let v = ImageView::parse(&img).unwrap();
        let records_start = v.records.as_ptr() as usize - img.as_ptr() as usize;
        let mut m = img.clone();
        m[records_start + 7] ^= 0x10;
        let v = ImageView::parse(&m).expect("header still coherent");
        assert!(!v.verify_data(), "record flip must fail the digest");
    }

    #[test]
    fn hostile_headers_cannot_panic() {
        // all-zero, all-ones, and a sweep of single-field extremes
        assert!(ImageView::parse(&[0u8; HEADER_LEN]).is_err());
        assert!(ImageView::parse(&[0xFF; HEADER_LEN * 2]).is_err());
        let img = sample(&[1u8; 16], b"");
        for field_off in [12usize, 36, 40, 48, 56, 64, 72] {
            for val in [u64::MAX, u64::MAX / 2, 1 << 32] {
                if field_off == 12 && val == 1 << 32 {
                    // low u32 is 0: a *smaller* meta_len re-sealed with a
                    // fresh checksum is a coherent (if odd) image, not a
                    // hostile one — skip it
                    continue;
                }
                let mut m = img.clone();
                m[field_off..field_off + 8.min(HEADER_LEN - field_off)]
                    .copy_from_slice(&val.to_le_bytes()[..8.min(HEADER_LEN - field_off)]);
                // re-seal the checksum so the size checks themselves run
                let meta_len = u32::from_le_bytes(m[12..16].try_into().unwrap()) as usize;
                let meta_end = (HEADER_LEN + meta_len).min(m.len());
                let meta = m[HEADER_LEN.min(meta_end)..meta_end].to_vec();
                let sum = digest64(&[&m[..88], &meta]);
                m[88..96].copy_from_slice(&sum.to_le_bytes());
                assert!(ImageView::parse(&m).is_err(), "extreme field at {field_off} accepted");
            }
        }
    }

    #[test]
    fn digest64_is_order_and_boundary_sensitive() {
        assert_ne!(digest64(&[b"ab", b"c"]), digest64(&[b"a", b"bc"]));
        assert_ne!(digest64(&[b"abc"]), digest64(&[b"acb"]));
        assert_ne!(digest64(&[b""]), digest64(&[b"\0"]));
        // deterministic across calls
        assert_eq!(digest64(&[b"stable"]), digest64(&[b"stable"]));
    }

    #[test]
    fn peek_format_reads_magic_and_version() {
        let dir = std::env::temp_dir().join("capsim_image_peek");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("img.bin");
        std::fs::write(&p, sample(&[0u8; 16], b"")).unwrap();
        assert_eq!(peek_format(&p).unwrap(), (IMAGE_MAGIC, IMAGE_VERSION));
        let _ = std::fs::remove_file(&p);
    }
}
