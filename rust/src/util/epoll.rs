//! Readiness polling for the serve session layer — **all `unsafe` in the
//! event-driven serving stack lives in this module**, nowhere else (same
//! confinement rule as [`super::mmap`] for the persistence stack).
//!
//! [`Poller`] wraps a Linux `epoll` instance plus an `eventfd` wakeup
//! channel, bound `extern "C"` against the libc `std` already links — the
//! zero-dependency rule means no `libc`/`mio` crate. The API is the small
//! readiness core an event loop needs: register/modify/deregister a fd
//! with a `u64` token, block in [`Poller::wait`] with a timeout, and poke
//! the loop from any thread through a cloneable [`Waker`] (the predict
//! loops use this to signal completed batches).
//!
//! On non-Linux targets the module still compiles: [`available`] reports
//! `false`, [`Poller::new`] returns `ErrorKind::Unsupported`, and the
//! serve layer falls back to thread-per-connection sessions. Forcing
//! `--session-layer epoll` on such a host is an error, not a silent
//! fallback — same convention as forcing an unavailable kernel tier.
//!
//! Safety argument for the Linux path: every fd we pass to the kernel is
//! either owned by the `Poller` (epoll fd, eventfd — closed exactly once
//! in `Drop`) or borrowed from a caller-owned socket that the event loop
//! keeps alive for the registration's lifetime; `epoll_event` uses the
//! kernel's ABI layout (packed on x86_64, naturally aligned elsewhere);
//! and the wait buffer is sized/valid for the `maxevents` we report.
//! Tokens are plain data to the kernel — stale events after a `delete`
//! are possible in principle and the event loop treats unknown tokens as
//! no-ops.

use std::io;

/// Readiness delivered by [`Poller::wait`]. `readable`/`writable` follow
/// the registered interest; `hangup` covers `EPOLLHUP`/`EPOLLERR`, which
/// the kernel reports regardless of interest.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

/// `true` when this host has a real readiness backend (Linux epoll).
pub fn available() -> bool {
    cfg!(target_os = "linux")
}

#[cfg(target_os = "linux")]
mod imp {
    use super::Event;
    use std::io;
    use std::sync::Arc;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;

    const EPOLL_CLOEXEC: i32 = 0x80000;
    const EFD_NONBLOCK: i32 = 0x800;
    const EFD_CLOEXEC: i32 = 0x80000;

    /// The kernel's `struct epoll_event`. On x86_64 Linux it is packed to
    /// 12 bytes (a 32-bit-era ABI fossil); everywhere else it has natural
    /// alignment. Getting this wrong silently corrupts `data` for every
    /// event after the first, so the layout is pinned by a test below.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
        fn close(fd: i32) -> i32;
        fn read(fd: i32, buf: *mut std::ffi::c_void, count: usize) -> isize;
        fn write(fd: i32, buf: *const std::ffi::c_void, count: usize) -> isize;
    }

    /// An owned kernel fd, closed exactly once on drop.
    struct OwnedFd(i32);

    impl Drop for OwnedFd {
        fn drop(&mut self) {
            // The fd came from a successful create/eventfd call and nothing
            // else closes it; a failure here has no recovery.
            unsafe { close(self.0) };
        }
    }

    /// Token the internal eventfd is registered under. Caller tokens must
    /// stay below this; the event loop's slab indices trivially do.
    const WAKE_TOKEN: u64 = u64::MAX;

    /// A Linux epoll instance plus an eventfd wake channel.
    pub struct Poller {
        epfd: OwnedFd,
        wake: Arc<OwnedFd>,
        buf: Vec<EpollEvent>,
    }

    /// Cross-thread handle that makes a blocked [`Poller::wait`] return.
    /// Cloneable, `Send + Sync`; wakes coalesce (the eventfd is a counter).
    #[derive(Clone)]
    pub struct Waker {
        wake: Arc<OwnedFd>,
    }

    impl Waker {
        pub fn wake(&self) {
            let one: u64 = 1;
            // A full counter (EAGAIN) still leaves the fd readable, so a
            // lost increment cannot lose the wakeup; ignore the result.
            unsafe {
                write(self.wake.0, (&one as *const u64).cast(), 8);
            }
        }
    }

    fn interest_bits(read: bool, write: bool) -> u32 {
        (if read { EPOLLIN } else { 0 }) | (if write { EPOLLOUT } else { 0 })
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            let epfd = OwnedFd(epfd);
            let wfd = unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
            if wfd < 0 {
                return Err(io::Error::last_os_error());
            }
            let wake = Arc::new(OwnedFd(wfd));
            let poller = Poller { epfd, wake, buf: vec![EpollEvent { events: 0, data: 0 }; 256] };
            poller.ctl(EPOLL_CTL_ADD, poller.wake.0, EPOLLIN, WAKE_TOKEN)?;
            Ok(poller)
        }

        /// A cloneable cross-thread wake handle for this poller.
        pub fn waker(&self) -> Waker {
            Waker { wake: Arc::clone(&self.wake) }
        }

        fn ctl(&self, op: i32, fd: i32, events: u32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.epfd.0, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Register `fd` under `token` with the given interest. The caller
        /// keeps `fd` open until [`Poller::delete`] (or the fd's close,
        /// which deregisters implicitly).
        pub fn add(&self, fd: i32, token: u64, read: bool, write: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, interest_bits(read, write), token)
        }

        /// Replace the interest set of an already-registered `fd`.
        pub fn modify(&self, fd: i32, token: u64, read: bool, write: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, interest_bits(read, write), token)
        }

        /// Deregister `fd`. Events already queued for it may still be
        /// delivered by an in-flight `wait`; callers match on token.
        pub fn delete(&self, fd: i32) -> io::Result<()> {
            // Pre-2.6.9 kernels required a non-null event for DEL; passing
            // one is free and keeps the call portable.
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Block until readiness, a wake, or the timeout; `None` blocks
        /// indefinitely. Appends caller events to `events` (wake events are
        /// drained internally and not reported) and returns how many were
        /// appended — `0` means timeout or a bare wake.
        pub fn wait(
            &mut self,
            events: &mut Vec<Event>,
            timeout: Option<std::time::Duration>,
        ) -> io::Result<usize> {
            let timeout_ms: i32 = match timeout {
                // Round up so a 0.4 ms deadline doesn't spin at timeout 0.
                Some(d) => d.as_millis().saturating_add(1).min(i32::MAX as u128) as i32,
                None => -1,
            };
            let n = loop {
                let rc = unsafe {
                    epoll_wait(
                        self.epfd.0,
                        self.buf.as_mut_ptr(),
                        self.buf.len() as i32,
                        timeout_ms,
                    )
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            let mut appended = 0;
            for i in 0..n {
                let ev = self.buf[i];
                let (bits, token) = (ev.events, ev.data);
                if token == WAKE_TOKEN {
                    let mut counter: u64 = 0;
                    // Drain the coalesced counter; EAGAIN (already empty) is
                    // fine — the next wake re-arms it.
                    unsafe { read(self.wake.0, (&mut counter as *mut u64).cast(), 8) };
                    continue;
                }
                events.push(Event {
                    token,
                    readable: bits & EPOLLIN != 0,
                    writable: bits & EPOLLOUT != 0,
                    hangup: bits & (EPOLLHUP | EPOLLERR) != 0,
                });
                appended += 1;
            }
            Ok(appended)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::io::Write as _;
        use std::net::{TcpListener, TcpStream};
        use std::os::unix::io::AsRawFd;
        use std::time::Duration;

        #[test]
        fn epoll_event_layout_matches_kernel_abi() {
            let expect = if cfg!(target_arch = "x86_64") { 12 } else { 16 };
            assert_eq!(std::mem::size_of::<EpollEvent>(), expect);
        }

        #[test]
        fn empty_poller_times_out_with_no_events() {
            let mut p = Poller::new().unwrap();
            let mut evs = Vec::new();
            let n = p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap();
            assert_eq!(n, 0);
            assert!(evs.is_empty());
        }

        #[test]
        fn listener_becomes_readable_on_connect() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let mut p = Poller::new().unwrap();
            p.add(listener.as_raw_fd(), 7, true, false).unwrap();
            let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let mut evs = Vec::new();
            p.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
            assert!(evs.iter().any(|e| e.token == 7 && e.readable));
        }

        #[test]
        fn connected_stream_reports_writable_then_modify_masks_it() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (server, _) = listener.accept().unwrap();
            let mut p = Poller::new().unwrap();
            p.add(server.as_raw_fd(), 3, false, true).unwrap();
            let mut evs = Vec::new();
            p.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
            assert!(evs.iter().any(|e| e.token == 3 && e.writable));

            // Drop write interest, gain read interest: quiet until data.
            p.modify(server.as_raw_fd(), 3, true, false).unwrap();
            evs.clear();
            let n = p.wait(&mut evs, Some(Duration::from_millis(20))).unwrap();
            assert_eq!(n, 0, "no data yet, write interest masked");
            (&client).write_all(b"x").unwrap();
            p.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
            assert!(evs.iter().any(|e| e.token == 3 && e.readable));
        }

        #[test]
        fn waker_unblocks_wait_from_another_thread() {
            let mut p = Poller::new().unwrap();
            let waker = p.waker();
            let t = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                waker.wake();
                waker.wake(); // coalesces with the first
            });
            let mut evs = Vec::new();
            let n = p.wait(&mut evs, Some(Duration::from_secs(10))).unwrap();
            t.join().unwrap();
            assert_eq!(n, 0, "a bare wake reports no caller events");
            // Drained: the next wait times out instead of spinning on the
            // still-readable eventfd.
            let n = p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap();
            assert_eq!(n, 0);
        }

        #[test]
        fn delete_stops_event_delivery() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let mut p = Poller::new().unwrap();
            p.add(listener.as_raw_fd(), 1, true, false).unwrap();
            p.delete(listener.as_raw_fd()).unwrap();
            let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let mut evs = Vec::new();
            let n = p.wait(&mut evs, Some(Duration::from_millis(30))).unwrap();
            assert_eq!(n, 0, "deregistered fd stays silent");
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    use super::Event;
    use std::io;

    /// Stub poller for hosts without epoll: construction fails with
    /// `Unsupported` and the serve layer uses threaded sessions instead.
    pub struct Poller {
        _priv: (),
    }

    /// Stub waker (unreachable in practice — no `Poller` can exist).
    #[derive(Clone)]
    pub struct Waker {
        _priv: (),
    }

    impl Waker {
        pub fn wake(&self) {}
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "epoll session layer requires Linux; use --session-layer threads",
            ))
        }

        pub fn waker(&self) -> Waker {
            Waker { _priv: () }
        }

        pub fn add(&self, _fd: i32, _token: u64, _read: bool, _write: bool) -> io::Result<()> {
            unreachable!("stub Poller cannot be constructed")
        }

        pub fn modify(&self, _fd: i32, _token: u64, _read: bool, _write: bool) -> io::Result<()> {
            unreachable!("stub Poller cannot be constructed")
        }

        pub fn delete(&self, _fd: i32) -> io::Result<()> {
            unreachable!("stub Poller cannot be constructed")
        }

        pub fn wait(
            &mut self,
            _events: &mut Vec<Event>,
            _timeout: Option<std::time::Duration>,
        ) -> io::Result<usize> {
            unreachable!("stub Poller cannot be constructed")
        }
    }
}

pub use imp::{Poller, Waker};
