//! Miniature property-testing harness (proptest is not in the offline crate
//! set). A property runs over `N` random cases generated from a seeded
//! [`Rng`]; on failure the failing seed is reported so the case replays
//! deterministically.

use super::rng::Rng;

/// Number of cases per property (kept modest; properties run in `cargo test`).
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` over `cases` random inputs produced by `gen`. Panics with the
/// failing case seed on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n{input:#?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result` with a reason.
pub fn check_res<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {reason}\n{input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 32, |r| (r.below(100), r.below(100)),
              |(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics() {
        check("always-false", 4, |r| r.below(10), |_| false);
    }

    #[test]
    fn deterministic_cases() {
        let mut seen1 = Vec::new();
        check("collect1", 8, |r| r.next_u64(), |x| {
            seen1.push(*x);
            true
        });
        let mut seen2 = Vec::new();
        check("collect2", 8, |r| r.next_u64(), |x| {
            seen2.push(*x);
            true
        });
        assert_eq!(seen1, seen2);
    }
}
