//! Small statistics helpers shared by evaluation and the bench harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean of positive values; 0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64)
        .exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// p-th percentile (0..=100) with linear interpolation.
///
/// NaN samples are ignored: a latency harness that records one poisoned
/// measurement must not panic mid-report (the old
/// `partial_cmp(..).unwrap()` sort did exactly that) or smear NaN into
/// every percentile. An input of only NaNs behaves like an empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Mean Absolute Percentage Error (paper Eq. 11), in [0, inf).
pub fn mape(pred: &[f64], fact: &[f64]) -> f64 {
    assert_eq!(pred.len(), fact.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(fact)
        .map(|(p, f)| (p - f).abs() / f.max(1e-9))
        .sum::<f64>()
        / pred.len() as f64
}

/// Accuracy in the paper's reporting convention: `100 * (1 - MAPE)` (%).
pub fn accuracy_pct(pred: &[f64], fact: &[f64]) -> f64 {
    100.0 * (1.0 - mape(pred, fact))
}

/// Weighted mean with matching weights.
pub fn weighted_mean(xs: &[f64], ws: &[f64]) -> f64 {
    assert_eq!(xs.len(), ws.len());
    let tot: f64 = ws.iter().sum();
    if tot == 0.0 {
        return 0.0;
    }
    xs.iter().zip(ws).map(|(x, w)| x * w).sum::<f64>() / tot
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        // regression: one NaN latency used to panic the sort unwrap in
        // BurstReport::p50_ms / p99_ms and the fig7 serve-latency table
        let xs = [10.0, f64::NAN, 20.0, 30.0, f64::NAN, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0, "all-NaN acts like empty");
        // infinities still sort (total_cmp), they are not filtered
        assert_eq!(percentile(&[f64::INFINITY, 1.0], 0.0), 1.0);
    }

    #[test]
    fn mape_matches_eq11() {
        assert!((mape(&[110.0], &[100.0]) - 0.1).abs() < 1e-12);
        assert!((mape(&[90.0, 110.0], &[100.0, 100.0]) - 0.1).abs() < 1e-12);
        assert_eq!(mape(&[5.0], &[5.0]), 0.0);
    }

    #[test]
    fn accuracy_complement() {
        assert!((accuracy_pct(&[88.0], &[100.0]) - 88.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_mean_basic() {
        assert_eq!(weighted_mean(&[1.0, 3.0], &[1.0, 1.0]), 2.0);
        assert_eq!(weighted_mean(&[1.0, 3.0], &[3.0, 1.0]), 1.5);
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[5.0]), 0.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }
}
