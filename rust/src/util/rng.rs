//! Deterministic PRNG (xoshiro256**) — used by workload generators, k-means
//! seeding, dataset splits and the property-test harness. Determinism
//! matters: every experiment in EXPERIMENTS.md must be reproducible from a
//! seed.

/// xoshiro256** by Blackman & Vigna (public domain reference constants).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)` (n > 0), unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a reference to a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Rng::new(0);
        let mut b = Rng::new(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
