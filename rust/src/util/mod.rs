//! Offline-friendly utilities: the vendored crate set has no serde / rand /
//! criterion / proptest, so the small pieces we need live here, tested.

pub mod epoll;
pub mod image;
pub mod json;
pub mod mmap;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
