//! Read-only file mapping — **all `unsafe` in the persistence stack lives
//! in this module**, nowhere else.
//!
//! [`Mmap::open`] maps a file `PROT_READ`/`MAP_SHARED` so N processes that
//! open the same image share one set of physical pages (the kernel page
//! cache) with zero copies and O(1) setup time. The raw `mmap`/`munmap`
//! bindings are declared `extern "C"` against the platform libc that `std`
//! already links — the zero-dependency rule means no `libc` crate.
//!
//! On non-unix targets, or when the syscall fails, [`Mmap::open`] degrades
//! to an 8-byte-aligned heap read of the whole file (the portable
//! fallback). Callers observe the same `&[u8]`; [`Mmap::is_mapped`] says
//! which path was taken so tooling can report "mmap-frozen" vs
//! "heap-loaded" truthfully.
//!
//! Safety argument for the mapped path: the mapping is `PROT_READ`, the
//! pointer/length pair comes straight from a successful `mmap` of `len`
//! bytes and is unmapped exactly once in `Drop`, and the struct is
//! `Send + Sync` because a read-only mapping has no writers to race.
//! A concurrent `rename(2)` over the file swaps the directory entry, not
//! the mapped inode, so a mapping taken before an atomic re-save keeps
//! reading the old, complete image — never a torn mix. The one hazard
//! mmap cannot rule out is another process *truncating* the mapped inode
//! (reads past EOF then fault); image files are only ever replaced whole
//! via rename, never truncated in place, so this stays outside the
//! supported contract.

use std::io::Read;
use std::path::Path;

/// A read-only view of a file's bytes: memory-mapped where possible,
/// heap-read otherwise.
pub struct Mmap {
    inner: Inner,
}

enum Inner {
    #[cfg(unix)]
    Mapped { ptr: *const u8, len: usize },
    /// Portable fallback: the file copied into an 8-byte-aligned heap
    /// buffer (u64 backing), so offset alignment within the buffer
    /// matches the mapped case for every scalar type the formats use.
    Heap { buf: Vec<u64>, len: usize },
}

// A PROT_READ mapping (or an owned immutable buffer) has no interior
// mutability and no writers; sharing it across threads is sound.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_SHARED: i32 = 1;

    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }

    // Bound against the libc std already links. `off_t` is 64-bit on
    // every unix target we build for; we only ever pass offset 0 anyway.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

impl Mmap {
    /// Map `path` read-only. Falls back to a heap read when mapping is
    /// unavailable (non-unix, empty file, or a refused syscall); only a
    /// real I/O failure is an error.
    pub fn open(path: &Path) -> std::io::Result<Mmap> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = std::fs::File::open(path)?;
            let len = file.metadata()?.len();
            if len > 0 && len <= usize::MAX as u64 {
                let len = len as usize;
                let ptr = unsafe {
                    sys::mmap(
                        std::ptr::null_mut(),
                        len,
                        sys::PROT_READ,
                        sys::MAP_SHARED,
                        file.as_raw_fd(),
                        0,
                    )
                };
                if ptr != sys::map_failed() {
                    // fd can close now; the mapping keeps the inode alive
                    return Ok(Mmap { inner: Inner::Mapped { ptr: ptr as *const u8, len } });
                }
            }
            Self::open_heap_from(file)
        }
        #[cfg(not(unix))]
        {
            Self::open_heap_from(std::fs::File::open(path)?)
        }
    }

    /// The portable fallback, also used directly by tests: read the whole
    /// file into an 8-byte-aligned buffer.
    fn open_heap_from(mut file: std::fs::File) -> std::io::Result<Mmap> {
        let len = file.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "file too large to load",
            ));
        }
        let len = len as usize;
        let mut buf = vec![0u64; len.div_ceil(8)];
        {
            // View the u64 backing as bytes for the read — u64 has no
            // invalid bit patterns, so writing arbitrary bytes is sound.
            let dst = unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len) };
            file.read_exact(dst)?;
        }
        Ok(Mmap { inner: Inner::Heap { buf, len } })
    }

    /// The file's bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.inner {
            #[cfg(unix)]
            Inner::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Inner::Heap { buf, len } => {
                unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len) }
            }
        }
    }

    /// `true` when the bytes are a real shared mapping (zero-copy across
    /// processes), `false` on the heap-read fallback.
    pub fn is_mapped(&self) -> bool {
        match &self.inner {
            #[cfg(unix)]
            Inner::Mapped { .. } => true,
            Inner::Heap { .. } => false,
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Inner::Mapped { ptr, len } = &self.inner {
            // Failure here would mean the pointer/length pair we minted in
            // `open` went bad, which the type makes impossible; ignore.
            unsafe { sys::munmap(*ptr as *mut std::ffi::c_void, *len) };
        }
    }
}

/// Reinterpret `bytes` as little-endian `f32`s without copying. Returns
/// `None` unless the slice is 4-byte aligned and a whole number of f32s —
/// the caller degrades (cold start / heap copy) instead of hitting UB.
/// Only meaningful on little-endian hosts, which is all this project
/// builds for; the on-disk format is explicitly little-endian.
pub fn f32_view(bytes: &[u8]) -> Option<&[f32]> {
    if bytes.as_ptr() as usize % std::mem::align_of::<f32>() != 0 || bytes.len() % 4 != 0 {
        return None;
    }
    // Alignment and length are checked above; f32 accepts all bit
    // patterns, and the source is an immutable borrow of the same bytes.
    Some(unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("capsim_mmap_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn mapped_bytes_match_file() {
        let data: Vec<u8> = (0..10_000u32).flat_map(|v| v.to_le_bytes()).collect();
        let p = tmp("roundtrip.bin", &data);
        let m = Mmap::open(&p).unwrap();
        assert_eq!(m.bytes(), &data[..]);
        #[cfg(unix)]
        assert!(m.is_mapped(), "unix should take the real mmap path");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn empty_file_maps_to_empty_bytes() {
        let p = tmp("empty.bin", b"");
        let m = Mmap::open(&p).unwrap();
        assert!(m.bytes().is_empty());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn missing_file_is_an_error() {
        assert!(Mmap::open(Path::new("/nonexistent/capsim.img")).is_err());
    }

    #[test]
    fn heap_fallback_is_8_aligned_and_identical() {
        let data: Vec<u8> = (0..999u32).flat_map(|v| v.to_le_bytes()).collect();
        let p = tmp("heap.bin", &data);
        let m = Mmap::open_heap_from(std::fs::File::open(&p).unwrap()).unwrap();
        assert!(!m.is_mapped());
        assert_eq!(m.bytes(), &data[..]);
        assert_eq!(m.bytes().as_ptr() as usize % 8, 0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn f32_view_checks_alignment_and_length() {
        let mut backing = vec![0u8; 64];
        let base = backing.as_mut_ptr() as usize;
        // find a 4-aligned window inside the buffer
        let off = (4 - base % 4) % 4;
        let aligned = &backing[off..off + 16];
        let v = f32_view(aligned).expect("aligned whole-f32 slice");
        assert_eq!(v.len(), 4);
        assert!(f32_view(&aligned[..15]).is_none(), "ragged length refused");
        assert!(f32_view(&backing[off + 1..off + 13]).is_none(), "misaligned refused");
    }

    #[test]
    fn mapping_survives_rename_replacement() {
        let p = tmp("swap.bin", &[1u8; 4096]);
        let m = Mmap::open(&p).unwrap();
        // atomically replace the file; the old inode stays mapped
        let p2 = tmp("swap_new.bin", &[2u8; 4096]);
        std::fs::rename(&p2, &p).unwrap();
        assert!(m.bytes().iter().all(|&b| b == 1), "mapping reads the pre-rename image");
        let fresh = Mmap::open(&p).unwrap();
        assert!(fresh.bytes().iter().all(|&b| b == 2));
        let _ = std::fs::remove_file(&p);
    }
}
