//! PISA binary encoding — 32-bit fixed width.
//!
//! Layout (bit 31 = MSB):
//!
//! ```text
//! [31:24] opcode (u8, index into ALL_OPCODES)
//! [23:19] rd
//! [18:14] ra
//! [13:0]  imm14 (signed)  -- immediate / displacement forms
//! [13:9]  rb              -- register-register forms
//! ```
//!
//! Branches `b`/`bl` use a 24-bit signed offset in [23:0] (in instructions);
//! conditional branches use imm14. `li`/`lis` use a 19-bit signed immediate
//! in [18:0] so that 32-bit constants compose as `lis; ori`.

use super::inst::{Inst, Opcode, ALL_OPCODES, NUM_OPCODES};

/// Signed immediate range of imm14 forms.
pub const IMM14_MIN: i32 = -(1 << 13);
pub const IMM14_MAX: i32 = (1 << 13) - 1;
/// Signed immediate range of li/lis (imm19).
pub const IMM19_MIN: i32 = -(1 << 18);
pub const IMM19_MAX: i32 = (1 << 18) - 1;
/// Signed branch offset range of b/bl (off24, in instructions).
pub const OFF24_MIN: i32 = -(1 << 23);
pub const OFF24_MAX: i32 = (1 << 23) - 1;

#[derive(Debug, PartialEq, Eq)]
pub enum EncodeError {
    ImmOutOfRange { op: Opcode, imm: i32 },
    RegOutOfRange { op: Opcode, reg: u8 },
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::ImmOutOfRange { op, imm } => {
                write!(f, "immediate {imm} out of range for {op:?}")
            }
            EncodeError::RegOutOfRange { op, reg } => {
                write!(f, "register {reg} out of range for {op:?}")
            }
        }
    }
}

impl std::error::Error for EncodeError {}

fn uses_imm14(op: Opcode) -> bool {
    use Opcode::*;
    matches!(
        op,
        Addi | Andi | Ori | Xori | Sldi | Srdi | Sradi | Cmpi | Cmpli
            | Lbz | Lhz | Lwz | Ld | Lwzu | Lfd | Stb | Sth | Stw | Std
            | Stwu | Stfd | Beq | Bne | Blt | Bge | Bgt | Ble | Bdnz
    )
}

fn uses_imm19(op: Opcode) -> bool {
    matches!(op, Opcode::Li | Opcode::Lis)
}

fn uses_off24(op: Opcode) -> bool {
    matches!(op, Opcode::B | Opcode::Bl)
}

/// Encode one instruction to its 32-bit word.
pub fn encode(i: &Inst) -> Result<u32, EncodeError> {
    if i.rd > 31 {
        return Err(EncodeError::RegOutOfRange { op: i.op, reg: i.rd });
    }
    if i.ra > 31 {
        return Err(EncodeError::RegOutOfRange { op: i.op, reg: i.ra });
    }
    if i.rb > 31 {
        return Err(EncodeError::RegOutOfRange { op: i.op, reg: i.rb });
    }
    let opbits = (i.op as u32) << 24;
    if uses_off24(i.op) {
        if i.imm < OFF24_MIN || i.imm > OFF24_MAX {
            return Err(EncodeError::ImmOutOfRange { op: i.op, imm: i.imm });
        }
        return Ok(opbits | (i.imm as u32 & 0x00FF_FFFF));
    }
    if uses_imm19(i.op) {
        if i.imm < IMM19_MIN || i.imm > IMM19_MAX {
            return Err(EncodeError::ImmOutOfRange { op: i.op, imm: i.imm });
        }
        return Ok(opbits
            | ((i.rd as u32) << 19)
            | (i.imm as u32 & 0x0007_FFFF));
    }
    if uses_imm14(i.op) {
        if i.imm < IMM14_MIN || i.imm > IMM14_MAX {
            return Err(EncodeError::ImmOutOfRange { op: i.op, imm: i.imm });
        }
        return Ok(opbits
            | ((i.rd as u32) << 19)
            | ((i.ra as u32) << 14)
            | (i.imm as u32 & 0x3FFF));
    }
    // register-register form (imm must be 0)
    Ok(opbits
        | ((i.rd as u32) << 19)
        | ((i.ra as u32) << 14)
        | ((i.rb as u32) << 9))
}

#[derive(Debug, PartialEq, Eq)]
pub struct DecodeError(pub u32);

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid instruction word {:#010x}", self.0)
    }
}

impl std::error::Error for DecodeError {}

#[inline]
fn sext(v: u32, bits: u32) -> i32 {
    let sh = 32 - bits;
    ((v << sh) as i32) >> sh
}

/// Decode one 32-bit word.
pub fn decode(word: u32) -> Result<Inst, DecodeError> {
    let idx = (word >> 24) as usize;
    if idx >= NUM_OPCODES {
        return Err(DecodeError(word));
    }
    let op = ALL_OPCODES[idx];
    if uses_off24(op) {
        return Ok(Inst::new(op, 0, 0, 0, sext(word & 0x00FF_FFFF, 24)));
    }
    let rd = ((word >> 19) & 0x1F) as u8;
    if uses_imm19(op) {
        return Ok(Inst::new(op, rd, 0, 0, sext(word & 0x0007_FFFF, 19)));
    }
    let ra = ((word >> 14) & 0x1F) as u8;
    if uses_imm14(op) {
        return Ok(Inst::new(op, rd, ra, 0, sext(word & 0x3FFF, 14)));
    }
    let rb = ((word >> 9) & 0x1F) as u8;
    Ok(Inst::new(op, rd, ra, rb, 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_inst(r: &mut Rng) -> Inst {
        let op = ALL_OPCODES[r.range(0, NUM_OPCODES)];
        let rd = r.range(0, 32) as u8;
        let ra = r.range(0, 32) as u8;
        let rb = r.range(0, 32) as u8;
        let imm = if uses_off24(op) {
            r.range(0, (OFF24_MAX - OFF24_MIN) as usize) as i32 + OFF24_MIN
        } else if uses_imm19(op) {
            r.range(0, (IMM19_MAX - IMM19_MIN) as usize) as i32 + IMM19_MIN
        } else if uses_imm14(op) {
            r.range(0, (IMM14_MAX - IMM14_MIN) as usize) as i32 + IMM14_MIN
        } else {
            0
        };
        // off24/imm19 forms don't carry all regs; normalize unused fields
        if uses_off24(op) {
            Inst::new(op, 0, 0, 0, imm)
        } else if uses_imm19(op) {
            Inst::new(op, rd, 0, 0, imm)
        } else if uses_imm14(op) {
            Inst::new(op, rd, ra, 0, imm)
        } else {
            Inst::new(op, rd, ra, rb, 0)
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        prop::check_res("encode/decode roundtrip", 512, random_inst, |i| {
            let w = encode(i).map_err(|e| e.to_string())?;
            let back = decode(w).map_err(|e| e.to_string())?;
            if back == *i {
                Ok(())
            } else {
                Err(format!("{back:?} != {i:?}"))
            }
        });
    }

    #[test]
    fn imm_range_checked() {
        let i = Inst::new(Opcode::Addi, 1, 2, 0, 40_000);
        assert!(matches!(encode(&i), Err(EncodeError::ImmOutOfRange { .. })));
        let i = Inst::new(Opcode::Addi, 1, 2, 0, IMM14_MAX);
        assert!(encode(&i).is_ok());
    }

    #[test]
    fn reg_range_checked() {
        let i = Inst::new(Opcode::Add, 32, 0, 0, 0);
        assert!(matches!(encode(&i), Err(EncodeError::RegOutOfRange { .. })));
    }

    #[test]
    fn negative_offsets_roundtrip() {
        for imm in [-1, -100, OFF24_MIN] {
            let i = Inst::new(Opcode::B, 0, 0, 0, imm);
            assert_eq!(decode(encode(&i).unwrap()).unwrap().imm, imm);
        }
        for imm in [-1, -8000, IMM14_MIN] {
            let i = Inst::new(Opcode::Bdnz, 0, 0, 0, imm);
            assert_eq!(decode(encode(&i).unwrap()).unwrap().imm, imm);
        }
    }

    #[test]
    fn invalid_opcode_rejected() {
        assert!(decode(0xFF00_0000).is_err());
    }
}
