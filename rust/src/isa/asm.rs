//! A small structured assembler — the workload suite (`workloads/`) writes
//! its 24 SPEC-analog benchmarks against this builder API.
//!
//! Features: forward/backward labels, every PISA opcode as a method, and
//! `load_imm64` pseudo-expansion for wide constants. `finish()` resolves
//! labels into instruction-count offsets and encodes the program.

use super::encode::encode;
use super::inst::{Inst, Opcode};
use super::INST_BYTES;

/// A label handle; bind with [`Assembler::bind`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Label(usize);

/// An assembled program image.
#[derive(Clone, Debug)]
pub struct Program {
    /// Entry point (address of the first instruction).
    pub entry: u64,
    /// Decoded instructions in order.
    pub insts: Vec<Inst>,
    /// Encoded 32-bit words (same order).
    pub words: Vec<u32>,
    /// Initial data segments: (address, bytes).
    pub data: Vec<(u64, Vec<u8>)>,
}

impl Program {
    pub fn code_bytes(&self) -> Vec<u8> {
        self.words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    pub fn end_addr(&self) -> u64 {
        self.entry + self.insts.len() as u64 * INST_BYTES
    }
}

enum Pending {
    Done(Inst),
    /// Branch whose imm is an instruction-offset to a label.
    Branch(Opcode, Label),
}

/// The builder.
pub struct Assembler {
    entry: u64,
    items: Vec<Pending>,
    labels: Vec<Option<usize>>, // instruction index
    data: Vec<(u64, Vec<u8>)>,
}

impl Assembler {
    pub fn new(entry: u64) -> Self {
        Assembler { entry, items: Vec::new(), labels: Vec::new(), data: Vec::new() }
    }

    /// Create an unbound label.
    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    /// Bind `l` to the current position.
    pub fn bind(&mut self, l: Label) {
        assert!(self.labels[l.0].is_none(), "label bound twice");
        self.labels[l.0] = Some(self.items.len());
    }

    /// Convenience: create and immediately bind.
    pub fn here(&mut self) -> Label {
        let l = self.label();
        self.bind(l);
        l
    }

    /// Current instruction index.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Attach an initial data segment.
    pub fn data(&mut self, addr: u64, bytes: Vec<u8>) {
        self.data.push((addr, bytes));
    }

    /// Attach a data segment of little-endian u64 words.
    pub fn data_u64(&mut self, addr: u64, vals: &[u64]) {
        self.data
            .push((addr, vals.iter().flat_map(|v| v.to_le_bytes()).collect()));
    }

    /// Attach a data segment of f64 values.
    pub fn data_f64(&mut self, addr: u64, vals: &[f64]) {
        self.data.push((
            addr,
            vals.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect(),
        ));
    }

    fn push(&mut self, i: Inst) {
        self.items.push(Pending::Done(i));
    }

    // ---- integer reg-reg ---------------------------------------------
    pub fn add(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Add, rd, ra, rb, 0));
    }
    pub fn sub(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Sub, rd, ra, rb, 0));
    }
    pub fn mullw(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Mullw, rd, ra, rb, 0));
    }
    pub fn divd(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Divd, rd, ra, rb, 0));
    }
    pub fn neg(&mut self, rd: u8, ra: u8) {
        self.push(Inst::new(Opcode::Neg, rd, ra, 0, 0));
    }
    pub fn and(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::And, rd, ra, rb, 0));
    }
    pub fn or(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Or, rd, ra, rb, 0));
    }
    pub fn xor(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Xor, rd, ra, rb, 0));
    }
    pub fn sld(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Sld, rd, ra, rb, 0));
    }
    pub fn srd(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Srd, rd, ra, rb, 0));
    }
    pub fn srad(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Srad, rd, ra, rb, 0));
    }

    // ---- integer immediate ---------------------------------------------
    pub fn addi(&mut self, rd: u8, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Addi, rd, ra, 0, imm));
    }
    pub fn andi(&mut self, rd: u8, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Andi, rd, ra, 0, imm));
    }
    pub fn ori(&mut self, rd: u8, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Ori, rd, ra, 0, imm));
    }
    pub fn xori(&mut self, rd: u8, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Xori, rd, ra, 0, imm));
    }
    pub fn sldi(&mut self, rd: u8, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Sldi, rd, ra, 0, imm));
    }
    pub fn srdi(&mut self, rd: u8, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Srdi, rd, ra, 0, imm));
    }
    pub fn sradi(&mut self, rd: u8, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Sradi, rd, ra, 0, imm));
    }
    pub fn li(&mut self, rd: u8, imm: i32) {
        self.push(Inst::new(Opcode::Li, rd, 0, 0, imm));
    }
    pub fn lis(&mut self, rd: u8, imm: i32) {
        self.push(Inst::new(Opcode::Lis, rd, 0, 0, imm));
    }

    /// Load an arbitrary 64-bit constant (pseudo; expands to up to 9 insts:
    /// `li` of the top chunk followed by `sldi`+`ori` pairs of 13-bit
    /// chunks, since `ori`'s immediate is 14-bit signed).
    pub fn load_imm64(&mut self, rd: u8, val: u64) {
        // li (19-bit signed) covers small values directly
        if (val as i64) >= -(1 << 18) && (val as i64) < (1 << 18) {
            self.li(rd, val as i64 as i32);
            return;
        }
        // choose the fewest 13-bit chunks that cover the value
        let bits = 64 - val.leading_zeros() as usize;
        let chunks = bits.div_ceil(13);
        let top = (chunks - 1) * 13;
        self.li(rd, (val >> top) as i32); // < 2^13, fits imm19
        for c in (0..chunks - 1).rev() {
            self.sldi(rd, rd, 13);
            let piece = (val >> (c * 13)) & 0x1FFF;
            if piece != 0 {
                self.ori(rd, rd, piece as i32);
            }
        }
    }

    // ---- compares --------------------------------------------------------
    pub fn cmp(&mut self, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Cmp, 0, ra, rb, 0));
    }
    pub fn cmpl(&mut self, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Cmpl, 0, ra, rb, 0));
    }
    pub fn cmpi(&mut self, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Cmpi, 0, ra, 0, imm));
    }
    pub fn cmpli(&mut self, ra: u8, imm: i32) {
        self.push(Inst::new(Opcode::Cmpli, 0, ra, 0, imm));
    }

    // ---- memory ------------------------------------------------------
    pub fn lbz(&mut self, rd: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Lbz, rd, ra, 0, disp));
    }
    pub fn lhz(&mut self, rd: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Lhz, rd, ra, 0, disp));
    }
    pub fn lwz(&mut self, rd: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Lwz, rd, ra, 0, disp));
    }
    pub fn ld(&mut self, rd: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Ld, rd, ra, 0, disp));
    }
    pub fn lwzu(&mut self, rd: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Lwzu, rd, ra, 0, disp));
    }
    pub fn ldx(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Ldx, rd, ra, rb, 0));
    }
    pub fn lfd(&mut self, fd: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Lfd, fd, ra, 0, disp));
    }
    pub fn lfdx(&mut self, fd: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Lfdx, fd, ra, rb, 0));
    }
    pub fn stb(&mut self, rs: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Stb, rs, ra, 0, disp));
    }
    pub fn sth(&mut self, rs: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Sth, rs, ra, 0, disp));
    }
    pub fn stw(&mut self, rs: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Stw, rs, ra, 0, disp));
    }
    pub fn std(&mut self, rs: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Std, rs, ra, 0, disp));
    }
    pub fn stwu(&mut self, rs: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Stwu, rs, ra, 0, disp));
    }
    pub fn stdx(&mut self, rs: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Stdx, rs, ra, rb, 0));
    }
    pub fn stfd(&mut self, fs: u8, disp: i32, ra: u8) {
        self.push(Inst::new(Opcode::Stfd, fs, ra, 0, disp));
    }
    pub fn stfdx(&mut self, fs: u8, ra: u8, rb: u8) {
        self.push(Inst::new(Opcode::Stfdx, fs, ra, rb, 0));
    }

    // ---- floating point ------------------------------------------------
    pub fn fadd(&mut self, fd: u8, fa: u8, fb: u8) {
        self.push(Inst::new(Opcode::Fadd, fd, fa, fb, 0));
    }
    pub fn fsub(&mut self, fd: u8, fa: u8, fb: u8) {
        self.push(Inst::new(Opcode::Fsub, fd, fa, fb, 0));
    }
    pub fn fmul(&mut self, fd: u8, fa: u8, fb: u8) {
        self.push(Inst::new(Opcode::Fmul, fd, fa, fb, 0));
    }
    pub fn fdiv(&mut self, fd: u8, fa: u8, fb: u8) {
        self.push(Inst::new(Opcode::Fdiv, fd, fa, fb, 0));
    }
    /// fmadd fd, fa, fb: fd += fa * fb (accumulator form).
    pub fn fmadd(&mut self, fd: u8, fa: u8, fb: u8) {
        self.push(Inst::new(Opcode::Fmadd, fd, fa, fb, 0));
    }
    pub fn fneg(&mut self, fd: u8, fa: u8) {
        self.push(Inst::new(Opcode::Fneg, fd, fa, 0, 0));
    }
    pub fn fmr(&mut self, fd: u8, fa: u8) {
        self.push(Inst::new(Opcode::Fmr, fd, fa, 0, 0));
    }
    pub fn fcmp(&mut self, fa: u8, fb: u8) {
        self.push(Inst::new(Opcode::Fcmp, 0, fa, fb, 0));
    }
    pub fn fcfid(&mut self, fd: u8, ra: u8) {
        self.push(Inst::new(Opcode::Fcfid, fd, ra, 0, 0));
    }
    pub fn fctid(&mut self, fd: u8, fa: u8) {
        self.push(Inst::new(Opcode::Fctid, fd, fa, 0, 0));
    }

    // ---- branches --------------------------------------------------------
    fn branch(&mut self, op: Opcode, l: Label) {
        self.items.push(Pending::Branch(op, l));
    }
    pub fn b(&mut self, l: Label) {
        self.branch(Opcode::B, l);
    }
    pub fn bl(&mut self, l: Label) {
        self.branch(Opcode::Bl, l);
    }
    pub fn blr(&mut self) {
        self.push(Inst::new(Opcode::Blr, 0, 0, 0, 0));
    }
    pub fn bctr(&mut self) {
        self.push(Inst::new(Opcode::Bctr, 0, 0, 0, 0));
    }
    pub fn beq(&mut self, l: Label) {
        self.branch(Opcode::Beq, l);
    }
    pub fn bne(&mut self, l: Label) {
        self.branch(Opcode::Bne, l);
    }
    pub fn blt(&mut self, l: Label) {
        self.branch(Opcode::Blt, l);
    }
    pub fn bge(&mut self, l: Label) {
        self.branch(Opcode::Bge, l);
    }
    pub fn bgt(&mut self, l: Label) {
        self.branch(Opcode::Bgt, l);
    }
    pub fn ble(&mut self, l: Label) {
        self.branch(Opcode::Ble, l);
    }
    pub fn bdnz(&mut self, l: Label) {
        self.branch(Opcode::Bdnz, l);
    }

    // ---- SPR moves -------------------------------------------------------
    pub fn mtlr(&mut self, ra: u8) {
        self.push(Inst::new(Opcode::Mtlr, 0, ra, 0, 0));
    }
    pub fn mflr(&mut self, rd: u8) {
        self.push(Inst::new(Opcode::Mflr, rd, 0, 0, 0));
    }
    pub fn mtctr(&mut self, ra: u8) {
        self.push(Inst::new(Opcode::Mtctr, 0, ra, 0, 0));
    }
    pub fn mfctr(&mut self, rd: u8) {
        self.push(Inst::new(Opcode::Mfctr, rd, 0, 0, 0));
    }

    // ---- misc --------------------------------------------------------
    pub fn nop(&mut self) {
        self.push(Inst::new(Opcode::Nop, 0, 0, 0, 0));
    }
    pub fn halt(&mut self) {
        self.push(Inst::new(Opcode::Halt, 0, 0, 0, 0));
    }

    /// Resolve labels and encode.
    ///
    /// Panics on unbound labels — a workload construction bug, not a
    /// runtime condition.
    pub fn finish(self) -> Program {
        let insts: Vec<Inst> = self
            .items
            .iter()
            .enumerate()
            .map(|(idx, it)| match it {
                Pending::Done(i) => *i,
                Pending::Branch(op, l) => {
                    let target = self.labels[l.0]
                        .unwrap_or_else(|| panic!("unbound label {l:?}"));
                    let off = target as i64 - idx as i64;
                    Inst::new(*op, 0, 0, 0, off as i32)
                }
            })
            .collect();
        let words = insts
            .iter()
            .map(|i| encode(i).expect("assembled instruction must encode"))
            .collect();
        Program { entry: self.entry, insts, words, data: self.data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::decode;

    #[test]
    fn label_resolution_forward_and_backward() {
        let mut a = Assembler::new(0x1000);
        let top = a.here(); // idx 0
        a.addi(1, 1, 1); // idx 0 is this addi (here() binds before push)
        let out = a.label();
        a.beq(out); // idx 1 -> forward
        a.b(top); // idx 2 -> backward to 0
        a.bind(out);
        a.halt(); // idx 3
        let p = a.finish();
        assert_eq!(p.insts[1].imm, 2); // 3 - 1
        assert_eq!(p.insts[2].imm, -2); // 0 - 2
    }

    #[test]
    fn program_words_decode_back() {
        let mut a = Assembler::new(0x1000);
        a.li(3, 100);
        a.addi(3, 3, -1);
        a.cmpi(3, 0);
        let top = a.label();
        a.bind(top);
        a.halt();
        let p = a.finish();
        for (inst, word) in p.insts.iter().zip(&p.words) {
            assert_eq!(&decode(*word).unwrap(), inst);
        }
        assert_eq!(p.end_addr(), 0x1000 + 16);
    }

    #[test]
    #[should_panic(expected = "unbound label")]
    fn unbound_label_panics() {
        let mut a = Assembler::new(0);
        let l = a.label();
        a.b(l);
        let _ = a.finish();
    }

    #[test]
    fn load_imm64_sizes() {
        for val in [0u64, 5, 0x3_FFFF, 0x4_0000, 0xDEAD_BEEF,
                    0x1234_5678_9ABC_DEF0, u64::MAX] {
            let mut a = Assembler::new(0);
            a.load_imm64(9, val);
            a.halt();
            let p = a.finish();
            assert!(p.insts.len() <= 10);
            // verify by executing on the functional simulator
            let mut cpu = crate::functional::AtomicCpu::load(&p);
            cpu.run_trace(32);
            assert_eq!(cpu.regs.gpr[9], val, "load_imm64({val:#x})");
        }
    }

    #[test]
    fn data_segments_recorded() {
        let mut a = Assembler::new(0);
        a.data_u64(0x10000, &[1, 2, 3]);
        a.data_f64(0x20000, &[1.5]);
        a.halt();
        let p = a.finish();
        assert_eq!(p.data[0].1.len(), 24);
        assert_eq!(p.data[1].1, 1.5f64.to_bits().to_le_bytes().to_vec());
    }
}
