//! Decoded PISA instructions + the static metadata every downstream layer
//! consumes: functional semantics class, O3 functional-unit class, and the
//! explicit/implicit register reads & writes that drive both dependence
//! tracking (O3) and the Fig.-5 standardization (tokenizer).

/// Every PISA opcode. Mnemonics follow Power where an analogue exists.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Opcode {
    // ---- integer register-register ----
    Add,
    Sub,
    Mullw,
    Divd,
    Neg,
    And,
    Or,
    Xor,
    Sld,
    Srd,
    Srad,
    // ---- integer immediate ----
    Addi,
    Andi,
    Ori,
    Xori,
    Sldi,
    Srdi,
    Sradi,
    Li,
    Lis,
    // ---- compares (write CR field 0; paper Fig. 5c) ----
    Cmp,
    Cmpl,
    Cmpi,
    Cmpli,
    // ---- loads ----
    Lbz,
    Lhz,
    Lwz,
    Ld,
    Lwzu,
    Ldx,
    Lfd,
    Lfdx,
    // ---- stores ----
    Stb,
    Sth,
    Stw,
    Std,
    Stwu,
    Stdx,
    Stfd,
    Stfdx,
    // ---- floating point ----
    Fadd,
    Fsub,
    Fmul,
    Fdiv,
    Fmadd,
    Fneg,
    Fmr,
    Fcmp,
    Fcfid,
    Fctid,
    // ---- branches ----
    B,
    Bl,
    Blr,
    Bctr,
    Beq,
    Bne,
    Blt,
    Bge,
    Bgt,
    Ble,
    Bdnz,
    // ---- SPR moves ----
    Mtlr,
    Mflr,
    Mtctr,
    Mfctr,
    // ---- misc ----
    Nop,
    Halt,
}

pub const NUM_OPCODES: usize = Opcode::Halt as usize + 1;

/// All opcodes in declaration order (vocab construction, decode table).
pub const ALL_OPCODES: [Opcode; NUM_OPCODES] = [
    Opcode::Add, Opcode::Sub, Opcode::Mullw, Opcode::Divd, Opcode::Neg,
    Opcode::And, Opcode::Or, Opcode::Xor, Opcode::Sld, Opcode::Srd,
    Opcode::Srad, Opcode::Addi, Opcode::Andi, Opcode::Ori, Opcode::Xori,
    Opcode::Sldi, Opcode::Srdi, Opcode::Sradi, Opcode::Li, Opcode::Lis,
    Opcode::Cmp, Opcode::Cmpl, Opcode::Cmpi, Opcode::Cmpli, Opcode::Lbz,
    Opcode::Lhz, Opcode::Lwz, Opcode::Ld, Opcode::Lwzu, Opcode::Ldx,
    Opcode::Lfd, Opcode::Lfdx, Opcode::Stb, Opcode::Sth, Opcode::Stw,
    Opcode::Std, Opcode::Stwu, Opcode::Stdx, Opcode::Stfd, Opcode::Stfdx,
    Opcode::Fadd, Opcode::Fsub, Opcode::Fmul, Opcode::Fdiv, Opcode::Fmadd,
    Opcode::Fneg, Opcode::Fmr, Opcode::Fcmp, Opcode::Fcfid, Opcode::Fctid,
    Opcode::B, Opcode::Bl, Opcode::Blr, Opcode::Bctr, Opcode::Beq,
    Opcode::Bne, Opcode::Blt, Opcode::Bge, Opcode::Bgt, Opcode::Ble,
    Opcode::Bdnz, Opcode::Mtlr, Opcode::Mflr, Opcode::Mtctr, Opcode::Mfctr,
    Opcode::Nop, Opcode::Halt,
];

impl Opcode {
    pub fn mnemonic(self) -> &'static str {
        use Opcode::*;
        match self {
            Add => "add", Sub => "sub", Mullw => "mullw", Divd => "divd",
            Neg => "neg", And => "and", Or => "or", Xor => "xor",
            Sld => "sld", Srd => "srd", Srad => "srad", Addi => "addi",
            Andi => "andi", Ori => "ori", Xori => "xori", Sldi => "sldi",
            Srdi => "srdi", Sradi => "sradi", Li => "li", Lis => "lis",
            Cmp => "cmp", Cmpl => "cmpl", Cmpi => "cmpi", Cmpli => "cmpli",
            Lbz => "lbz", Lhz => "lhz", Lwz => "lwz", Ld => "ld",
            Lwzu => "lwzu", Ldx => "ldx", Lfd => "lfd", Lfdx => "lfdx",
            Stb => "stb", Sth => "sth", Stw => "stw", Std => "std",
            Stwu => "stwu", Stdx => "stdx", Stfd => "stfd", Stfdx => "stfdx",
            Fadd => "fadd", Fsub => "fsub", Fmul => "fmul", Fdiv => "fdiv",
            Fmadd => "fmadd", Fneg => "fneg", Fmr => "fmr", Fcmp => "fcmp",
            Fcfid => "fcfid", Fctid => "fctid", B => "b", Bl => "bl",
            Blr => "blr", Bctr => "bctr", Beq => "beq", Bne => "bne",
            Blt => "blt", Bge => "bge", Bgt => "bgt", Ble => "ble",
            Bdnz => "bdnz", Mtlr => "mtlr", Mflr => "mflr", Mtctr => "mtctr",
            Mfctr => "mfctr", Nop => "nop", Halt => "halt",
        }
    }
}

/// Memory access width in bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemWidth {
    B1 = 1,
    B2 = 2,
    B4 = 4,
    B8 = 8,
}

/// Functional-unit class for the O3 model (latency/occupancy per `o3::config`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FuClass {
    IntAlu,
    IntMul,
    IntDiv,
    Load,
    Store,
    FpAdd,
    FpMul,
    FpDiv,
    FpFma,
    Branch,
    Nop,
}

/// An architectural register reference — explicit or implicit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RegRef {
    Gpr(u8),
    Fpr(u8),
    Cr,
    Lr,
    Ctr,
    Xer,
}

/// A decoded instruction. `imm` meaning depends on the opcode: immediate
/// operand, memory displacement, or branch offset in *instructions*.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Inst {
    pub op: Opcode,
    pub rd: u8,
    pub ra: u8,
    pub rb: u8,
    pub imm: i32,
}

impl Inst {
    pub fn new(op: Opcode, rd: u8, ra: u8, rb: u8, imm: i32) -> Self {
        Inst { op, rd, ra, rb, imm }
    }

    /// Functional-unit class (drives O3 latency and issue-port selection).
    pub fn fu_class(&self) -> FuClass {
        use Opcode::*;
        match self.op {
            Mullw => FuClass::IntMul,
            Divd => FuClass::IntDiv,
            Lbz | Lhz | Lwz | Ld | Lwzu | Ldx | Lfd | Lfdx => FuClass::Load,
            Stb | Sth | Stw | Std | Stwu | Stdx | Stfd | Stfdx => FuClass::Store,
            Fadd | Fsub | Fneg | Fmr | Fcmp | Fcfid | Fctid => FuClass::FpAdd,
            Fmul => FuClass::FpMul,
            Fdiv => FuClass::FpDiv,
            Fmadd => FuClass::FpFma,
            B | Bl | Blr | Bctr | Beq | Bne | Blt | Bge | Bgt | Ble | Bdnz => {
                FuClass::Branch
            }
            Nop | Halt => FuClass::Nop,
            _ => FuClass::IntAlu,
        }
    }

    pub fn is_branch(&self) -> bool {
        self.fu_class() == FuClass::Branch
    }

    /// Conditional branches (prediction-relevant; `bdnz` counts: its
    /// direction depends on CTR).
    pub fn is_cond_branch(&self) -> bool {
        use Opcode::*;
        matches!(self.op, Beq | Bne | Blt | Bge | Bgt | Ble | Bdnz)
    }

    /// Indirect branches (target from LR/CTR).
    pub fn is_indirect_branch(&self) -> bool {
        matches!(self.op, Opcode::Blr | Opcode::Bctr)
    }

    pub fn is_load(&self) -> bool {
        self.fu_class() == FuClass::Load
    }

    pub fn is_store(&self) -> bool {
        self.fu_class() == FuClass::Store
    }

    pub fn is_mem(&self) -> bool {
        self.is_load() || self.is_store()
    }

    pub fn mem_width(&self) -> Option<MemWidth> {
        use Opcode::*;
        Some(match self.op {
            Lbz | Stb => MemWidth::B1,
            Lhz | Sth => MemWidth::B2,
            Lwz | Lwzu | Stw | Stwu => MemWidth::B4,
            Ld | Ldx | Std | Stdx | Lfd | Lfdx | Stfd | Stfdx => MemWidth::B8,
            _ => return None,
        })
    }

    /// Update-form memory ops also write back the effective address to `ra`.
    pub fn is_update_form(&self) -> bool {
        matches!(self.op, Opcode::Lwzu | Opcode::Stwu)
    }

    /// Indexed-form memory ops compute EA = ra + rb (no displacement).
    pub fn is_indexed_mem(&self) -> bool {
        matches!(self.op, Opcode::Ldx | Opcode::Stdx | Opcode::Lfdx | Opcode::Stfdx)
    }

    /// Destination registers, implicit ones included (Fig. 5c: `cmpi`
    /// writes CR even though no destination appears in the assembly).
    pub fn dsts(&self) -> Vec<RegRef> {
        use Opcode::*;
        use RegRef::*;
        let mut v = Vec::with_capacity(2);
        match self.op {
            Add | Sub | Mullw | Divd | Neg | And | Or | Xor | Sld | Srd
            | Srad | Addi | Andi | Ori | Xori | Sldi | Srdi | Sradi | Li
            | Lis => v.push(Gpr(self.rd)),
            Cmp | Cmpl | Cmpi | Cmpli | Fcmp => v.push(Cr),
            Lbz | Lhz | Lwz | Ld | Ldx => v.push(Gpr(self.rd)),
            Lwzu => {
                v.push(Gpr(self.rd));
                v.push(Gpr(self.ra));
            }
            Stwu => v.push(Gpr(self.ra)),
            Lfd | Lfdx => v.push(Fpr(self.rd)),
            Stb | Sth | Stw | Std | Stdx | Stfd | Stfdx => {}
            Fadd | Fsub | Fmul | Fdiv | Fmadd | Fneg | Fmr | Fcfid
            | Fctid => v.push(Fpr(self.rd)),
            B => {}
            Bl => v.push(Lr),
            Blr | Bctr | Beq | Bne | Blt | Bge | Bgt | Ble => {}
            Bdnz => v.push(Ctr),
            Mtlr => v.push(Lr),
            Mflr => v.push(Gpr(self.rd)),
            Mtctr => v.push(Ctr),
            Mfctr => v.push(Gpr(self.rd)),
            Nop | Halt => {}
        }
        v
    }

    /// Source registers, implicit ones included (`beq` reads CR, `blr`
    /// reads LR, `bdnz` reads CTR).
    pub fn srcs(&self) -> Vec<RegRef> {
        use Opcode::*;
        use RegRef::*;
        let mut v = Vec::with_capacity(3);
        match self.op {
            Add | Sub | Mullw | Divd | And | Or | Xor | Sld | Srd | Srad => {
                v.push(Gpr(self.ra));
                v.push(Gpr(self.rb));
            }
            Neg => v.push(Gpr(self.ra)),
            Addi | Andi | Ori | Xori | Sldi | Srdi | Sradi => {
                v.push(Gpr(self.ra))
            }
            Li | Lis => {}
            Cmp | Cmpl => {
                v.push(Gpr(self.ra));
                v.push(Gpr(self.rb));
            }
            Cmpi | Cmpli => v.push(Gpr(self.ra)),
            Lbz | Lhz | Lwz | Ld | Lwzu | Lfd => v.push(Gpr(self.ra)),
            Ldx | Lfdx => {
                v.push(Gpr(self.ra));
                v.push(Gpr(self.rb));
            }
            Stb | Sth | Stw | Std | Stwu => {
                v.push(Gpr(self.rd));
                v.push(Gpr(self.ra));
            }
            Stdx => {
                v.push(Gpr(self.rd));
                v.push(Gpr(self.ra));
                v.push(Gpr(self.rb));
            }
            Stfd => {
                v.push(Fpr(self.rd));
                v.push(Gpr(self.ra));
            }
            Stfdx => {
                v.push(Fpr(self.rd));
                v.push(Gpr(self.ra));
                v.push(Gpr(self.rb));
            }
            Fadd | Fsub | Fmul | Fdiv | Fcmp => {
                v.push(Fpr(self.ra));
                v.push(Fpr(self.rb));
            }
            Fmadd => {
                v.push(Fpr(self.ra));
                v.push(Fpr(self.rb));
                v.push(Fpr(self.rd)); // accumulator convention: rd += ra*rb
            }
            Fneg | Fmr | Fctid => v.push(Fpr(self.ra)),
            Fcfid => v.push(Gpr(self.ra)),
            B | Bl => {}
            Blr => v.push(Lr),
            Bctr => v.push(Ctr),
            Beq | Bne | Blt | Bge | Bgt | Ble => v.push(Cr),
            Bdnz => v.push(Ctr),
            Mtlr | Mtctr => v.push(Gpr(self.ra)),
            Mflr => v.push(Lr),
            Mfctr => v.push(Ctr),
            Nop | Halt => {}
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_opcodes_table_is_consistent() {
        assert_eq!(ALL_OPCODES.len(), NUM_OPCODES);
        for (i, op) in ALL_OPCODES.iter().enumerate() {
            assert_eq!(*op as usize, i, "{op:?} out of order");
        }
    }

    #[test]
    fn mnemonics_unique() {
        let mut seen = std::collections::HashSet::new();
        for op in ALL_OPCODES {
            assert!(seen.insert(op.mnemonic()), "dup {}", op.mnemonic());
        }
    }

    #[test]
    fn cmpi_writes_cr_implicitly() {
        // Fig. 5c: the destination is not in the assembly text but must be
        // tracked (and tokenized) anyway.
        let i = Inst::new(Opcode::Cmpi, 0, 5, 0, 3);
        assert_eq!(i.dsts(), vec![RegRef::Cr]);
        assert_eq!(i.srcs(), vec![RegRef::Gpr(5)]);
    }

    #[test]
    fn bl_writes_lr_and_blr_reads_it() {
        assert_eq!(Inst::new(Opcode::Bl, 0, 0, 0, 4).dsts(), vec![RegRef::Lr]);
        assert_eq!(Inst::new(Opcode::Blr, 0, 0, 0, 0).srcs(), vec![RegRef::Lr]);
    }

    #[test]
    fn bdnz_reads_and_writes_ctr() {
        let i = Inst::new(Opcode::Bdnz, 0, 0, 0, -4);
        assert_eq!(i.srcs(), vec![RegRef::Ctr]);
        assert_eq!(i.dsts(), vec![RegRef::Ctr]);
        assert!(i.is_cond_branch());
    }

    #[test]
    fn update_form_writes_base() {
        let i = Inst::new(Opcode::Lwzu, 3, 4, 0, 8);
        assert!(i.dsts().contains(&RegRef::Gpr(4)));
        assert!(i.dsts().contains(&RegRef::Gpr(3)));
    }

    #[test]
    fn store_reads_value_and_base() {
        let i = Inst::new(Opcode::Std, 7, 1, 0, 16);
        assert_eq!(i.dsts(), vec![]);
        assert!(i.srcs().contains(&RegRef::Gpr(7)));
        assert!(i.srcs().contains(&RegRef::Gpr(1)));
    }

    #[test]
    fn fu_classes_cover_mem_and_branch() {
        assert_eq!(Inst::new(Opcode::Ld, 0, 0, 0, 0).fu_class(), FuClass::Load);
        assert_eq!(Inst::new(Opcode::Stw, 0, 0, 0, 0).fu_class(), FuClass::Store);
        assert!(Inst::new(Opcode::Beq, 0, 0, 0, 0).is_cond_branch());
        assert!(Inst::new(Opcode::Blr, 0, 0, 0, 0).is_indirect_branch());
        assert!(!Inst::new(Opcode::B, 0, 0, 0, 0).is_cond_branch());
    }

    #[test]
    fn mem_width_matches_opcode() {
        assert_eq!(Inst::new(Opcode::Lbz, 0, 0, 0, 0).mem_width(),
                   Some(MemWidth::B1));
        assert_eq!(Inst::new(Opcode::Std, 0, 0, 0, 0).mem_width(),
                   Some(MemWidth::B8));
        assert_eq!(Inst::new(Opcode::Add, 0, 0, 0, 0).mem_width(), None);
    }

    #[test]
    fn fmadd_reads_accumulator() {
        let i = Inst::new(Opcode::Fmadd, 2, 3, 4, 0);
        assert!(i.srcs().contains(&RegRef::Fpr(2)));
        assert_eq!(i.dsts(), vec![RegRef::Fpr(2)]);
    }
}
