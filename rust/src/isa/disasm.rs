//! Textual disassembly, Power-flavoured. This is also documentation-grade
//! ground truth for the Fig.-5 standardization examples in the tests.

use super::inst::{Inst, Opcode};

/// Render one instruction the way the paper's Fig. 5 shows raw assembly.
pub fn disasm(i: &Inst) -> String {
    use Opcode::*;
    let m = i.op.mnemonic();
    match i.op {
        Add | Sub | Mullw | Divd | And | Or | Xor | Sld | Srd | Srad => {
            format!("{m} r{}, r{}, r{}", i.rd, i.ra, i.rb)
        }
        Neg => format!("{m} r{}, r{}", i.rd, i.ra),
        Addi | Andi | Ori | Xori | Sldi | Srdi | Sradi => {
            format!("{m} r{}, r{}, {}", i.rd, i.ra, i.imm)
        }
        Li | Lis => format!("{m} r{}, {}", i.rd, i.imm),
        Cmp | Cmpl => format!("{m} r{}, r{}", i.ra, i.rb),
        Cmpi | Cmpli => format!("{m} r{}, {}", i.ra, i.imm),
        Lbz | Lhz | Lwz | Ld | Lwzu => {
            format!("{m} r{}, {}(r{})", i.rd, i.imm, i.ra)
        }
        Lfd => format!("{m} f{}, {}(r{})", i.rd, i.imm, i.ra),
        Ldx => format!("{m} r{}, r{}, r{}", i.rd, i.ra, i.rb),
        Lfdx => format!("{m} f{}, r{}, r{}", i.rd, i.ra, i.rb),
        Stb | Sth | Stw | Std | Stwu => {
            format!("{m} r{}, {}(r{})", i.rd, i.imm, i.ra)
        }
        Stfd => format!("{m} f{}, {}(r{})", i.rd, i.imm, i.ra),
        Stdx => format!("{m} r{}, r{}, r{}", i.rd, i.ra, i.rb),
        Stfdx => format!("{m} f{}, r{}, r{}", i.rd, i.ra, i.rb),
        Fadd | Fsub | Fmul | Fdiv => {
            format!("{m} f{}, f{}, f{}", i.rd, i.ra, i.rb)
        }
        Fmadd => format!("{m} f{}, f{}, f{}", i.rd, i.ra, i.rb),
        Fneg | Fmr | Fctid => format!("{m} f{}, f{}", i.rd, i.ra),
        Fcfid => format!("{m} f{}, r{}", i.rd, i.ra),
        Fcmp => format!("{m} f{}, f{}", i.ra, i.rb),
        B | Bl => format!("{m} {}", i.imm),
        Blr | Bctr => m.to_string(),
        Beq | Bne | Blt | Bge | Bgt | Ble | Bdnz => format!("{m} {}", i.imm),
        Mtlr | Mtctr => format!("{m} r{}", i.ra),
        Mflr | Mfctr => format!("{m} r{}", i.rd),
        Nop | Halt => m.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::inst::Inst;

    #[test]
    fn formats_match_power_style() {
        assert_eq!(
            disasm(&Inst::new(Opcode::Addi, 3, 4, 0, 8)),
            "addi r3, r4, 8"
        );
        assert_eq!(
            disasm(&Inst::new(Opcode::Lwz, 5, 9, 0, -16)),
            "lwz r5, -16(r9)"
        );
        assert_eq!(disasm(&Inst::new(Opcode::Cmpi, 0, 7, 0, 3)), "cmpi r7, 3");
        assert_eq!(disasm(&Inst::new(Opcode::Blr, 0, 0, 0, 0)), "blr");
        assert_eq!(
            disasm(&Inst::new(Opcode::Fmadd, 1, 2, 3, 0)),
            "fmadd f1, f2, f3"
        );
    }

    #[test]
    fn every_opcode_disassembles() {
        for op in crate::isa::inst::ALL_OPCODES {
            let text = disasm(&Inst::new(op, 1, 2, 3, 4));
            assert!(text.starts_with(op.mnemonic()));
        }
    }
}
