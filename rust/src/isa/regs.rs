//! PISA architectural register file — the Table-I set.
//!
//! | Class  | Count | Width | Paper role                                  |
//! |--------|-------|-------|---------------------------------------------|
//! | GPR    | 32    | 64    | principal integer registers                 |
//! | FPR    | 32    | 64    | floating point (paper: VSR used as FPR)     |
//! | CR     | 1     | 32    | condition register (field 0 used: LT/GT/EQ/SO) |
//! | LR     | 1     | 64    | link register (branch-and-link target)      |
//! | CTR    | 1     | 64    | count register (`bdnz` loop idiom)          |
//! | XER    | 1     | 64    | fixed-point exception bits                  |
//! | FPSCR  | 1     | 32    | FP status/control                           |
//! | CIA    | 1     | 64    | current instruction address                 |
//! | NIA    | 1     | 64    | next instruction address                    |

/// CR field-0 bit masks (within the 4-bit field).
pub const CR_LT: u32 = 0b1000;
pub const CR_GT: u32 = 0b0100;
pub const CR_EQ: u32 = 0b0010;
pub const CR_SO: u32 = 0b0001;

/// Condition register: 8 four-bit fields, field 0 in the top nibble
/// (Power numbering).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Cr(pub u32);

impl Cr {
    /// Read field `f` (0..8) as a 4-bit value.
    #[inline]
    pub fn field(&self, f: usize) -> u32 {
        (self.0 >> (28 - 4 * f)) & 0xF
    }

    /// Write field `f`.
    #[inline]
    pub fn set_field(&mut self, f: usize, v: u32) {
        let sh = 28 - 4 * f;
        self.0 = (self.0 & !(0xF << sh)) | ((v & 0xF) << sh);
    }

    /// Set field 0 from a signed comparison result.
    #[inline]
    pub fn compare_signed(&mut self, a: i64, b: i64) {
        let v = if a < b {
            CR_LT
        } else if a > b {
            CR_GT
        } else {
            CR_EQ
        };
        self.set_field(0, v);
    }

    /// Set field 0 from an unsigned comparison result.
    #[inline]
    pub fn compare_unsigned(&mut self, a: u64, b: u64) {
        let v = if a < b {
            CR_LT
        } else if a > b {
            CR_GT
        } else {
            CR_EQ
        };
        self.set_field(0, v);
    }
}

/// The full architectural state (excluding memory).
#[derive(Clone, Debug, PartialEq)]
pub struct RegFile {
    pub gpr: [u64; 32],
    pub fpr: [f64; 32],
    pub cr: Cr,
    pub lr: u64,
    pub ctr: u64,
    pub xer: u64,
    pub fpscr: u32,
    /// Current instruction address.
    pub cia: u64,
    /// Next instruction address (computed by execute).
    pub nia: u64,
}

impl Default for RegFile {
    fn default() -> Self {
        RegFile {
            gpr: [0; 32],
            fpr: [0.0; 32],
            cr: Cr(0),
            lr: 0,
            ctr: 0,
            xer: 0,
            fpscr: 0,
            cia: 0,
            nia: 0,
        }
    }
}

impl RegFile {
    pub fn new(entry: u64) -> Self {
        RegFile { cia: entry, nia: entry, ..Default::default() }
    }

    /// Raw 64-bit view of an FPR (for context-matrix byte tokens).
    #[inline]
    pub fn fpr_bits(&self, i: usize) -> u64 {
        self.fpr[i].to_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cr_field_layout_is_power_ordering() {
        let mut cr = Cr(0);
        cr.set_field(0, 0xA);
        assert_eq!(cr.0, 0xA000_0000);
        cr.set_field(7, 0x5);
        assert_eq!(cr.field(7), 0x5);
        assert_eq!(cr.field(0), 0xA);
    }

    #[test]
    fn signed_compare_sets_exactly_one_of_lt_gt_eq() {
        for (a, b) in [(-5i64, 3i64), (3, -5), (7, 7)] {
            let mut cr = Cr(0);
            cr.compare_signed(a, b);
            let f = cr.field(0);
            let bits = (f & CR_LT != 0) as u32
                + (f & CR_GT != 0) as u32
                + (f & CR_EQ != 0) as u32;
            assert_eq!(bits, 1);
        }
        let mut cr = Cr(0);
        cr.compare_signed(-1, 1);
        assert_ne!(cr.field(0) & CR_LT, 0);
    }

    #[test]
    fn unsigned_compare_differs_from_signed() {
        let mut s = Cr(0);
        let mut u = Cr(0);
        s.compare_signed(-1, 1);
        u.compare_unsigned(u64::MAX, 1);
        assert_ne!(s.field(0) & CR_LT, 0);
        assert_ne!(u.field(0) & CR_GT, 0);
    }

    #[test]
    fn fpr_bits_roundtrip() {
        let mut rf = RegFile::default();
        rf.fpr[3] = -1.5;
        assert_eq!(f64::from_bits(rf.fpr_bits(3)), -1.5);
    }
}
