//! **PISA** — a Power-inspired 32-bit fixed-width RISC ISA.
//!
//! The paper builds its gem5 model for the Power ISA; PISA reproduces every
//! feature the CAPSim pipeline actually observes:
//!
//! * the Table-I register file — 32 GPRs, 32 FPRs (standing in for the
//!   VSRs), CR, LR, CTR, XER, FPSCR, CIA/NIA;
//! * implicit control-register effects (compares write CR, `bl` writes LR,
//!   `bdnz` decrements CTR) that the Fig.-5 standardization must surface;
//! * update-form memory accesses and indexed accesses;
//! * a 32-bit fixed encoding so fetch groups and I-cache behaviour are
//!   well-defined for the O3 model.
//!
//! Submodules: [`inst`] (decoded form + semantics metadata), [`encode`]
//! (binary encode/decode), [`asm`] (program builder used by `workloads`),
//! [`disasm`] (textual form, also the tokenizer's ground truth).

pub mod asm;
pub mod disasm;
pub mod encode;
pub mod inst;
pub mod regs;

pub use asm::Assembler;
pub use encode::{decode, encode};
pub use inst::{Inst, MemWidth, Opcode};
pub use regs::{Cr, RegFile, CR_EQ, CR_GT, CR_LT, CR_SO};

/// Instruction width in bytes (fixed, Power-style).
pub const INST_BYTES: u64 = 4;
