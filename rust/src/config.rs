//! Configuration system: a TOML-subset parser (the offline crate set has no
//! toml crate) plus the typed `PipelineConfig` the launcher and benches use.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string /
//! integer / float / boolean / flat-array values, `#` comments. That covers
//! every config in `configs/`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::o3::O3Config;
use crate::runtime::{Backend, KernelTier};
use crate::sampler::SamplerConfig;
use crate::simpoint::SimpointConfig;
use crate::workloads::Scale;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key -> value` map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Toml {
    pub entries: BTreeMap<String, TomlValue>,
}

impl Toml {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn int(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn float(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    let clean = s.replace('_', "");
    if let Ok(v) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(format!("cannot parse value: {s}"))
}

/// Parse a TOML-subset document.
pub fn parse_toml(src: &str) -> Result<Toml, String> {
    let mut out = Toml::default();
    let mut section = String::new();
    for (lineno, raw) in src.lines().enumerate() {
        // strip the first '#' that sits outside a quoted string
        let mut in_quotes = false;
        let mut cut = raw.len();
        for (i, c) in raw.char_indices() {
            match c {
                '"' => in_quotes = !in_quotes,
                '#' if !in_quotes => {
                    cut = i;
                    break;
                }
                _ => {}
            }
        }
        let line = raw[..cut].trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.entries.insert(
            key,
            parse_value(v).map_err(|e| format!("line {}: {e}", lineno + 1))?,
        );
    }
    Ok(out)
}

/// How training clips are delimited (see `slicer`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainSlicing {
    /// Algorithm 1 (paper §IV-A): boundaries on commit-time changes.
    Algo1,
    /// Fixed `l_min` windows with telescoping labels — matches the
    /// inference-time slicing distribution exactly (used by the Fig.-7
    /// end-to-end runs; see DESIGN.md §7).
    Fixed,
}

/// Everything one experiment run needs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub scale: Scale,
    pub simpoint: SimpointConfig,
    pub o3: O3Config,
    pub sampler: SamplerConfig,
    /// Predictor backend (`pipeline.backend` TOML / `--backend` CLI;
    /// `pjrt | native | attention`, default `pjrt`). The registry in
    /// [`runtime::backend`](crate::runtime::backend) resolves it to a
    /// constructed predictor; unknown TOML values fall back to `pjrt`,
    /// matching this parser's defaults-for-absent-keys convention (the
    /// CLI flag is strict).
    pub backend: Backend,
    /// SIMD kernel tier of the kernel-executing backends
    /// (`pipeline.kernel_tier` TOML / `--kernel-tier` CLI;
    /// `auto | scalar | avx2 | neon`, default `auto`). `Auto` consults
    /// the `CAPSIM_KERNEL_TIER` env var, then auto-detects (precedence:
    /// CLI > TOML > env > detect; see
    /// [`PipelineConfig::effective_kernel_tier`]). All tiers are
    /// bit-identical, so this only changes throughput; unknown TOML
    /// values fall back to `auto`, matching the backend key's
    /// convention (the CLI flag is strict).
    pub kernel_tier: KernelTier,
    /// Worker threads for the sharded engine (per-interval and
    /// per-benchmark fan-out). `0` means auto — the `CAPSIM_THREADS`
    /// env var if set, else one per available core (precedence:
    /// `--threads` CLI > `pipeline.threads` TOML > `CAPSIM_THREADS` >
    /// autodetect; see `coordinator::pool::default_threads`). Results
    /// are bit-identical for every value.
    pub threads: usize,
    /// Capacity of the bounded scan→merge channel of the streaming
    /// engine (`coordinator::stream`): how many finished interval scans
    /// may wait, unmerged, before scan workers block. `0` = auto
    /// (2 × worker threads).
    pub queue_depth: usize,
    /// Capacity of the bounded merge→predict channel: how many ready
    /// inference batches may wait before the merge stage blocks. `0` =
    /// auto (2).
    pub batch_depth: usize,
    /// Directory holding the persistent clip cache (`--cache-dir` /
    /// `pipeline.cache_dir`); empty = no persistence.
    pub cache_dir: String,
    /// Upper bound on resident `ClipCache` entries
    /// (`--cache-max-entries` / `pipeline.cache_max_entries`; `0` =
    /// unbounded). When full, the oldest-inserted entries are evicted on
    /// insert (and before `save`). The default is far above what current
    /// suites produce, so eviction only engages on long-lived persistent
    /// caches.
    pub cache_max_entries: usize,
    /// Residency of a warm-started clip-cache image
    /// (`pipeline.cache_mmap`, default `true`): serve lookups straight
    /// from the mmap-frozen image (zero-copy, shared across processes),
    /// or copy entries onto the heap when `false` (`--cache-heap`).
    pub cache_mmap: bool,
    /// Listen address of the `capsim serve` daemon (`--listen` /
    /// `serve.listen`); port `0` picks a free port.
    pub serve_listen: String,
    /// How long (µs) a serve predict loop lets a partial batch wait for
    /// more requests before flushing (`--linger-us` /
    /// `serve.linger_us`). Larger values trade first-clip latency for
    /// fuller cross-request batches. Clamped to
    /// [`serve::MAX_LINGER_US`](crate::serve::MAX_LINGER_US) (60 s) at
    /// parse time so the `Busy` retry hint derived from it stays sane.
    pub serve_linger_us: u64,
    /// Replicated predict loops of the serve daemon (`--predict-loops`
    /// / `serve.predict_loops`). Each loop owns private
    /// accumulator/runner state over one shared read-only weight set;
    /// row-locality keeps answers bit-identical for every value. `0` =
    /// auto (a small multiple of the cores, see
    /// [`PipelineConfig::effective_predict_loops`]).
    pub serve_predict_loops: usize,
    /// Session tier of the serve daemon (`--session-layer` /
    /// `serve.session_layer`): `auto` (default) resolves to the epoll
    /// event loop on Linux and thread-per-connection elsewhere. Forcing
    /// `epoll` on a host without it errors at daemon start. Unknown
    /// TOML values fall back to `auto`; the CLI flag is strict.
    pub serve_session_layer: crate::serve::SessionLayer,
    /// Reap a serve connection after this many ms without traffic
    /// (`--idle-timeout-ms` / `serve.idle_timeout_ms`, `0` = never) so
    /// half-open clients cannot pin session state forever.
    pub serve_idle_timeout_ms: u64,
    /// Slicer minimum clip length (paper L_min).
    pub l_min: usize,
    /// Training-label slicing policy.
    pub train_slicing: TrainSlicing,
    /// Training settings.
    pub train_steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Artifact directory.
    pub artifacts: String,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            scale: Scale::Test,
            simpoint: SimpointConfig::default(),
            o3: O3Config::default(),
            sampler: SamplerConfig::default(),
            backend: Backend::Pjrt,
            kernel_tier: KernelTier::Auto,
            threads: 0,
            queue_depth: 0,
            batch_depth: 0,
            cache_dir: String::new(),
            cache_max_entries: 1_000_000,
            cache_mmap: true,
            serve_listen: "127.0.0.1:4650".to_string(),
            serve_linger_us: 2_000,
            serve_predict_loops: 0,
            serve_session_layer: crate::serve::SessionLayer::Auto,
            serve_idle_timeout_ms: 60_000,
            l_min: 24,
            train_slicing: TrainSlicing::Algo1,
            train_steps: 300,
            lr: 1e-3,
            seed: 42,
            artifacts: "artifacts".to_string(),
        }
    }
}

impl PipelineConfig {
    /// Build from a parsed TOML document, using defaults for absent keys.
    pub fn from_toml(t: &Toml) -> Self {
        let mut c = PipelineConfig::default();
        c.scale = match t.str("pipeline.scale", "test").as_str() {
            "full" => Scale::Full,
            _ => Scale::Test,
        };
        c.backend = match t.str("pipeline.backend", "pjrt").as_str() {
            "native" => Backend::Native,
            "attention" => Backend::Attention,
            _ => Backend::Pjrt,
        };
        // unknown values fall back to auto, like the backend key
        c.kernel_tier =
            t.str("pipeline.kernel_tier", "auto").parse().unwrap_or(KernelTier::Auto);
        // negative values mean "auto" rather than wrapping to usize::MAX
        c.threads = t.int("pipeline.threads", c.threads as i64).max(0) as usize;
        c.queue_depth = t.int("pipeline.queue_depth", c.queue_depth as i64).max(0) as usize;
        c.batch_depth = t.int("pipeline.batch_depth", c.batch_depth as i64).max(0) as usize;
        c.cache_dir = t.str("pipeline.cache_dir", &c.cache_dir);
        c.cache_max_entries = t
            .int("pipeline.cache_max_entries", c.cache_max_entries as i64)
            .max(0) as usize;
        c.cache_mmap = t.bool("pipeline.cache_mmap", c.cache_mmap);
        c.serve_listen = t.str("serve.listen", &c.serve_listen);
        c.serve_linger_us = (t.int("serve.linger_us", c.serve_linger_us as i64).max(0) as u64)
            .min(crate::serve::MAX_LINGER_US);
        c.serve_predict_loops =
            t.int("serve.predict_loops", c.serve_predict_loops as i64).max(0) as usize;
        c.serve_session_layer =
            crate::serve::SessionLayer::parse(&t.str("serve.session_layer", "auto"))
                .unwrap_or(c.serve_session_layer);
        c.serve_idle_timeout_ms =
            t.int("serve.idle_timeout_ms", c.serve_idle_timeout_ms as i64).max(0) as u64;
        c.l_min = t.int("pipeline.l_min", c.l_min as i64) as usize;
        c.train_slicing = match t.str("pipeline.train_slicing", "algo1").as_str() {
            "fixed" => TrainSlicing::Fixed,
            _ => TrainSlicing::Algo1,
        };
        c.train_steps = t.int("train.steps", c.train_steps as i64) as usize;
        c.lr = t.float("train.lr", c.lr as f64) as f32;
        c.seed = t.int("pipeline.seed", c.seed as i64) as u64;
        c.artifacts = t.str("pipeline.artifacts", &c.artifacts);

        c.simpoint.interval_insts =
            t.int("simpoint.interval_insts", c.simpoint.interval_insts as i64) as u64;
        c.simpoint.warmup_insts =
            t.int("simpoint.warmup_insts", c.simpoint.warmup_insts as i64) as u64;
        c.simpoint.max_k = t.int("simpoint.max_k", c.simpoint.max_k as i64) as usize;

        c.sampler.threshold =
            t.int("sampler.threshold", c.sampler.threshold as i64) as u64;
        c.sampler.coefficient = t.float("sampler.coefficient", c.sampler.coefficient);

        c.o3.fetch_width = t.int("o3.fetch_width", c.o3.fetch_width as i64) as usize;
        c.o3.issue_width = t.int("o3.issue_width", c.o3.issue_width as i64) as usize;
        c.o3.commit_width = t.int("o3.commit_width", c.o3.commit_width as i64) as usize;
        c.o3.rob_entries = t.int("o3.rob_entries", c.o3.rob_entries as i64) as usize;
        c
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let src = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Ok(Self::from_toml(&parse_toml(&src)?))
    }

    /// The worker-thread count the engine should actually use
    /// (resolves the `0 = auto` convention).
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            crate::coordinator::pool::default_threads()
        } else {
            self.threads
        }
    }

    /// The concrete kernel tier kernel-executing backends should run
    /// on. Resolution order: an explicit `kernel_tier` (CLI flag or
    /// TOML key) wins outright; `auto` consults the
    /// `CAPSIM_KERNEL_TIER` env var (unparseable values are ignored,
    /// like any malformed env override); whatever is still `auto` after
    /// that resolves to the best detected tier. A tier that is forced —
    /// by config or env — but unavailable on this host is an error, not
    /// a silent fallback.
    pub fn effective_kernel_tier(&self) -> anyhow::Result<KernelTier> {
        let mut tier = self.kernel_tier;
        if tier == KernelTier::Auto {
            if let Ok(v) = std::env::var("CAPSIM_KERNEL_TIER") {
                tier = v.parse().unwrap_or(KernelTier::Auto);
            }
        }
        tier.resolve()
    }

    /// Scan→merge channel capacity for the streaming engine (resolves
    /// `0 = auto`: twice the worker count, so the merge always has work
    /// queued without unbounded buffering).
    pub fn effective_queue_depth(&self) -> usize {
        if self.queue_depth == 0 {
            (2 * self.effective_threads()).max(2)
        } else {
            self.queue_depth
        }
    }

    /// Merge→predict channel capacity (resolves `0 = auto` to 2: one
    /// batch in flight to the predictor plus one being filled keeps the
    /// stages overlapped without hoarding memory).
    pub fn effective_batch_depth(&self) -> usize {
        if self.batch_depth == 0 {
            2
        } else {
            self.batch_depth
        }
    }

    /// Predict-loop replicas the serve daemon should spawn (resolves
    /// `0 = auto`: one per core up to 4 — the forward pass already
    /// parallelizes within a batch, so a handful of loops saturates the
    /// admission side long before weight-sharing stops paying).
    pub fn effective_predict_loops(&self) -> usize {
        if self.serve_predict_loops == 0 {
            crate::coordinator::pool::default_threads().min(4).max(1)
        } else {
            self.serve_predict_loops
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = parse_toml(
            r#"
            # comment
            top = 1
            [o3]
            fetch_width = 4
            name = "wide"   # trailing comment
            ratio = 0.5
            flag = true
            widths = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(t.int("top", 0), 1);
        assert_eq!(t.int("o3.fetch_width", 0), 4);
        assert_eq!(t.str("o3.name", ""), "wide");
        assert_eq!(t.float("o3.ratio", 0.0), 0.5);
        assert!(t.bool("o3.flag", false));
        assert_eq!(
            t.get("o3.widths"),
            Some(&TomlValue::Array(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
    }

    #[test]
    fn underscored_numbers() {
        let t = parse_toml("n = 5_000_000").unwrap();
        assert_eq!(t.int("n", 0), 5_000_000);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("just a line").is_err());
        assert!(parse_toml("x = @@").is_err());
    }

    #[test]
    fn pipeline_config_from_toml_overrides() {
        let t = parse_toml(
            r#"
            [pipeline]
            scale = "full"
            backend = "attention"
            l_min = 48
            threads = 4
            queue_depth = 16
            batch_depth = 3
            cache_dir = "warm"
            cache_max_entries = 500
            cache_mmap = false
            [serve]
            listen = "127.0.0.1:9999"
            linger_us = 750
            predict_loops = 3
            session_layer = "threads"
            idle_timeout_ms = 2500
            [o3]
            rob_entries = 128
            [train]
            steps = 10
            lr = 0.01
            [sampler]
            threshold = 99
            "#,
        )
        .unwrap();
        let c = PipelineConfig::from_toml(&t);
        assert_eq!(c.scale, Scale::Full);
        assert_eq!(c.l_min, 48);
        assert_eq!(c.threads, 4);
        assert_eq!(c.effective_threads(), 4);
        assert_eq!(c.queue_depth, 16);
        assert_eq!(c.effective_queue_depth(), 16);
        assert_eq!(c.batch_depth, 3);
        assert_eq!(c.effective_batch_depth(), 3);
        assert_eq!(c.cache_dir, "warm");
        assert_eq!(c.backend, Backend::Attention);
        assert_eq!(c.cache_max_entries, 500);
        assert!(!c.cache_mmap, "cache_mmap = false forces the heap tier");
        assert_eq!(c.serve_listen, "127.0.0.1:9999");
        assert_eq!(c.serve_linger_us, 750);
        assert_eq!(c.serve_predict_loops, 3);
        assert_eq!(c.effective_predict_loops(), 3);
        assert_eq!(c.serve_session_layer, crate::serve::SessionLayer::Threads);
        assert_eq!(c.serve_idle_timeout_ms, 2500);
        assert_eq!(c.o3.rob_entries, 128);
        assert_eq!(c.o3.fetch_width, 8, "default preserved");
        assert_eq!(c.train_steps, 10);
        assert_eq!(c.sampler.threshold, 99);
        assert!((c.lr - 0.01).abs() < 1e-9);
    }

    #[test]
    fn negative_threads_means_auto() {
        let t = parse_toml("[pipeline]\nthreads = -1").unwrap();
        let c = PipelineConfig::from_toml(&t);
        assert_eq!(c.threads, 0, "negative clamps to auto");
    }

    #[test]
    fn defaults_without_file() {
        let c = PipelineConfig::default();
        assert_eq!(c.l_min, 24);
        assert_eq!(c.o3.fetch_width, 8);
        assert_eq!(c.threads, 0, "0 = auto");
        assert!(c.effective_threads() >= 1);
        assert_eq!(c.queue_depth, 0, "0 = auto");
        assert!(c.effective_queue_depth() >= 2);
        assert_eq!(c.effective_batch_depth(), 2);
        assert!(c.cache_dir.is_empty(), "persistence off by default");
        assert_eq!(c.backend, Backend::Pjrt, "pjrt is the default backend");
        assert_eq!(c.cache_max_entries, 1_000_000, "bound far above suite sizes");
        assert!(c.cache_mmap, "mmap residency is the default");
        assert_eq!(c.serve_listen, "127.0.0.1:4650");
        assert_eq!(c.serve_linger_us, 2_000);
        assert_eq!(c.serve_predict_loops, 0, "0 = auto");
        assert_eq!(c.serve_session_layer, crate::serve::SessionLayer::Auto);
        assert_eq!(c.serve_idle_timeout_ms, 60_000, "idle reaping is on by default");
        let loops = c.effective_predict_loops();
        assert!((1..=4).contains(&loops), "auto picks 1..=4 loops, got {loops}");
    }

    #[test]
    fn serve_linger_and_predict_loops_are_clamped_at_parse_time() {
        // an absurd linger_us clamps to MAX_LINGER_US instead of later
        // truncating the u32 retry hint; negative loop counts mean auto
        let t = parse_toml("[serve]\nlinger_us = 999_999_999_999\npredict_loops = -2").unwrap();
        let c = PipelineConfig::from_toml(&t);
        assert_eq!(c.serve_linger_us, crate::serve::MAX_LINGER_US);
        assert_eq!(c.serve_predict_loops, 0, "negative clamps to auto");
    }

    #[test]
    fn serve_session_layer_and_idle_timeout_parse_with_fallbacks() {
        use crate::serve::SessionLayer;
        for (s, want) in [
            ("auto", SessionLayer::Auto),
            ("epoll", SessionLayer::Epoll),
            ("threads", SessionLayer::Threads),
            ("kqueue", SessionLayer::Auto), // unknown TOML value → default
        ] {
            let t = parse_toml(&format!("[serve]\nsession_layer = \"{s}\"")).unwrap();
            assert_eq!(PipelineConfig::from_toml(&t).serve_session_layer, want, "{s}");
        }
        // negative idle timeout clamps to 0 (= never reap)
        let t = parse_toml("[serve]\nidle_timeout_ms = -5").unwrap();
        assert_eq!(PipelineConfig::from_toml(&t).serve_idle_timeout_ms, 0);
    }

    #[test]
    fn backend_values_parse_and_unknown_falls_back() {
        for (s, want) in [
            ("pjrt", Backend::Pjrt),
            ("native", Backend::Native),
            ("attention", Backend::Attention),
            ("mystery", Backend::Pjrt),
        ] {
            let t = parse_toml(&format!("[pipeline]\nbackend = \"{s}\"")).unwrap();
            assert_eq!(PipelineConfig::from_toml(&t).backend, want, "{s}");
        }
    }

    #[test]
    fn negative_cache_max_entries_means_unbounded() {
        let t = parse_toml("[pipeline]\ncache_max_entries = -5").unwrap();
        assert_eq!(PipelineConfig::from_toml(&t).cache_max_entries, 0);
    }

    #[test]
    fn kernel_tier_values_parse_and_unknown_falls_back() {
        // the env-override path is pinned in tests/prop_kernel_tiers.rs
        // (integration binary, so the env mutation cannot race other
        // unit tests)
        assert_eq!(PipelineConfig::default().kernel_tier, KernelTier::Auto);
        for (s, want) in [
            ("auto", KernelTier::Auto),
            ("scalar", KernelTier::Scalar),
            ("avx2", KernelTier::Avx2),
            ("neon", KernelTier::Neon),
            ("sse9", KernelTier::Auto),
        ] {
            let t = parse_toml(&format!("[pipeline]\nkernel_tier = \"{s}\"")).unwrap();
            assert_eq!(PipelineConfig::from_toml(&t).kernel_tier, want, "{s}");
        }
    }

    #[test]
    fn forced_scalar_tier_resolves_to_scalar() {
        let mut c = PipelineConfig::default();
        c.kernel_tier = KernelTier::Scalar;
        // an explicit tier ignores the env override entirely, so this
        // holds regardless of CAPSIM_KERNEL_TIER in the test environment
        assert_eq!(c.effective_kernel_tier().unwrap(), KernelTier::Scalar);
    }
}
