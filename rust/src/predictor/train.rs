//! The training driver: Rust orchestrates SGD through the AOT train step
//! (paper §VI-B: SGD, lr 1e-3, momentum 0.9, MAPE loss; the Fig. 9 loss
//! curves come straight out of [`TrainLog`]).

use anyhow::Result;

use crate::dataset::Dataset;
use crate::runtime::ModelHandle;
use crate::util::Rng;

use super::batcher::build_batches;
use super::eval::evaluate;

/// Training hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct TrainParams {
    /// Total SGD steps (minibatches, not epochs).
    pub steps: usize,
    pub lr: f32,
    /// Evaluate on the validation split every this many steps.
    pub eval_every: usize,
    pub seed: u64,
    /// Stop early if validation MAPE fails to improve this many evals.
    pub patience: usize,
}

impl Default for TrainParams {
    fn default() -> Self {
        TrainParams { steps: 300, lr: 1e-3, eval_every: 25, seed: 7, patience: 1_000 }
    }
}

/// The Fig.-9 record: training and validation loss over steps.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    /// (step, minibatch training loss)
    pub train_loss: Vec<(usize, f32)>,
    /// (step, validation MAPE)
    pub val_loss: Vec<(usize, f64)>,
    pub time_scale: f32,
    pub steps_run: usize,
}

impl TrainLog {
    /// Smoothed (windowed mean) training loss — the plotted Fig. 9 curve.
    pub fn smoothed_train(&self, window: usize) -> Vec<(usize, f64)> {
        let w = window.max(1);
        self.train_loss
            .chunks(w)
            .map(|c| {
                let step = c.last().unwrap().0;
                let mean = c.iter().map(|p| p.1 as f64).sum::<f64>() / c.len() as f64;
                (step, mean)
            })
            .collect()
    }
}

/// Train `model` on `train_idx` of `ds`, validating on `val_idx`.
pub fn train(
    model: &mut ModelHandle,
    ds: &Dataset,
    train_idx: &[usize],
    val_idx: &[usize],
    p: &TrainParams,
) -> Result<TrainLog> {
    let tb = model
        .train_batch()
        .ok_or_else(|| anyhow::anyhow!("variant has no train step"))?;
    let g = model.geometry.clone();
    let time_scale = ds.subset(train_idx).mean_time() as f32;

    anyhow::ensure!(!train_idx.is_empty(), "empty training split");
    let mut log = TrainLog { time_scale, ..Default::default() };
    let mut rng = Rng::new(p.seed);
    let mut order: Vec<usize> = train_idx.to_vec();
    let mut cursor = order.len(); // force initial shuffle
    let mut best_val = f64::INFINITY;
    let mut bad_evals = 0usize;

    for step in 0..p.steps {
        // draw exactly `tb` indices, reshuffling at epoch boundaries so
        // every batch is full (partial batches would let the zero-padding
        // rows pollute the MAPE gradient)
        let mut chunk = Vec::with_capacity(tb);
        while chunk.len() < tb {
            if cursor >= order.len() {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            let take = (tb - chunk.len()).min(order.len() - cursor);
            chunk.extend_from_slice(&order[cursor..cursor + take]);
            cursor += take;
        }
        let batch = build_batches(ds, &chunk, tb, &g).pop().unwrap();
        let loss = model.train_step(&batch, p.lr, time_scale)?;
        log.train_loss.push((step, loss));
        log.steps_run = step + 1;

        if !val_idx.is_empty() && (step + 1) % p.eval_every == 0 {
            let ev = evaluate(&*model, ds, val_idx, time_scale)?;
            log.val_loss.push((step, ev.mape));
            if ev.mape < best_val - 1e-4 {
                best_val = ev.mape;
                bad_evals = 0;
            } else {
                bad_evals += 1;
                if bad_evals >= p.patience {
                    break;
                }
            }
        }
    }
    Ok(log)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothing_windows() {
        let log = TrainLog {
            train_loss: (0..10).map(|i| (i, i as f32)).collect(),
            ..Default::default()
        };
        let s = log.smoothed_train(5);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0], (4, 2.0));
        assert_eq!(s[1], (9, 7.0));
    }
}
