//! The traditional-ML baseline: linear regression over hand-crafted clip
//! features (paper §II-C cites regression CPI models [20][21][22]; this is
//! the natively-implemented comparator for the Fig. 10 discussion).
//!
//! Features per clip: instruction-class mix (share of loads, stores, FP,
//! branches, mul/div), clip length, and distinct-register pressure — the
//! classic ingredients of regression CPI models. Fit by ridge-regularized
//! normal equations (Gaussian elimination; no LAPACK offline).

use crate::dataset::{ClipSample, Dataset};
use crate::isa::inst::FuClass;
use crate::tokenizer::Vocab;

const NUM_FEATURES: usize = 9;

/// Extract the feature vector of one clip from its *tokens* (the baseline
/// sees exactly the same standardized input as the neural predictors).
fn features(s: &ClipSample, l_token: usize) -> [f64; NUM_FEATURES] {
    let n = s.len as usize;
    let mut loads = 0.0;
    let mut stores = 0.0;
    let mut fp = 0.0;
    let mut branches = 0.0;
    let mut muldiv = 0.0;
    let mut regs = std::collections::HashSet::new();
    for i in 0..n {
        // token 2 of each standardized row is the opcode token
        let op_tok = s.tokens[i * l_token + 2];
        if let Some(op) = opcode_of_token(op_tok) {
            let inst = crate::isa::Inst::new(op, 0, 0, 0, 0);
            match inst.fu_class() {
                FuClass::Load => loads += 1.0,
                FuClass::Store => stores += 1.0,
                FuClass::FpAdd | FuClass::FpMul | FuClass::FpDiv | FuClass::FpFma => {
                    fp += 1.0
                }
                FuClass::Branch => branches += 1.0,
                FuClass::IntMul | FuClass::IntDiv => muldiv += 1.0,
                _ => {}
            }
        }
        for t in 0..l_token {
            let tok = s.tokens[i * l_token + t];
            // register tokens sit between the opcodes and the byte values
            if !Vocab::name(tok).starts_with('<') {
                regs.insert(tok);
            }
        }
    }
    let nf = n as f64;
    [
        1.0, // intercept
        nf,
        loads / nf,
        stores / nf,
        fp / nf,
        branches / nf,
        muldiv / nf,
        regs.len() as f64 / 16.0,
        (loads + stores) / nf * branches / nf, // mem-control interaction
    ]
}

fn opcode_of_token(tok: u16) -> Option<crate::isa::Opcode> {
    use crate::isa::inst::ALL_OPCODES;
    for op in ALL_OPCODES {
        if Vocab::opcode(op) == tok {
            return Some(op);
        }
    }
    None
}

/// The fitted model.
#[derive(Clone, Debug)]
pub struct LinRegBaseline {
    pub weights: [f64; NUM_FEATURES],
    l_token: usize,
}

impl LinRegBaseline {
    /// Fit on `idx` of `ds` with ridge parameter `lambda`.
    pub fn fit(ds: &Dataset, idx: &[usize], lambda: f64) -> LinRegBaseline {
        let mut xtx = [[0.0f64; NUM_FEATURES]; NUM_FEATURES];
        let mut xty = [0.0f64; NUM_FEATURES];
        for &i in idx {
            let x = features(&ds.samples[i], ds.l_token);
            let y = ds.samples[i].time as f64;
            for a in 0..NUM_FEATURES {
                for b in 0..NUM_FEATURES {
                    xtx[a][b] += x[a] * x[b];
                }
                xty[a] += x[a] * y;
            }
        }
        for (a, row) in xtx.iter_mut().enumerate() {
            row[a] += lambda;
        }
        let weights = solve(xtx, xty);
        LinRegBaseline { weights, l_token: ds.l_token }
    }

    pub fn predict(&self, s: &ClipSample) -> f64 {
        let x = features(s, self.l_token);
        let y: f64 = x.iter().zip(&self.weights).map(|(a, b)| a * b).sum();
        y.max(1.0)
    }

    pub fn mape(&self, ds: &Dataset, idx: &[usize]) -> f64 {
        let pred: Vec<f64> = idx.iter().map(|&i| self.predict(&ds.samples[i])).collect();
        let fact: Vec<f64> = idx.iter().map(|&i| ds.samples[i].time as f64).collect();
        crate::util::stats::mape(&pred, &fact)
    }
}

/// Gaussian elimination with partial pivoting for the small normal system.
fn solve(
    mut a: [[f64; NUM_FEATURES]; NUM_FEATURES],
    mut b: [f64; NUM_FEATURES],
) -> [f64; NUM_FEATURES] {
    let n = NUM_FEATURES;
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() < 1e-12 {
            continue; // singular direction; ridge term normally prevents this
        }
        for r in col + 1..n {
            let f = a[r][col] / d;
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = [0.0; NUM_FEATURES];
    for col in (0..n).rev() {
        let mut s = b[col];
        for c in col + 1..n {
            s -= a[col][c] * x[c];
        }
        x[col] = if a[col][col].abs() < 1e-12 { 0.0 } else { s / a[col][col] };
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ClipSample;
    use crate::isa::{Inst, Opcode};
    use crate::tokenizer::standardize::{has_const, standardize};

    const LT: usize = 16;

    fn clip_of(ops: &[Opcode], time: f32) -> ClipSample {
        let mut tokens = Vec::new();
        for &op in ops {
            let inst = Inst::new(op, 1, 2, 3, 0);
            tokens.extend(standardize(&inst, has_const(&inst), LT));
        }
        ClipSample {
            len: ops.len() as u16,
            tokens,
            ctx: vec![0; 90],
            time,
            key: 0,
            bench: 0,
        }
    }

    fn toy_dataset() -> Dataset {
        // ground truth: time = 5 + 3*loads + 1*alu (learnable linearly)
        let mut ds = Dataset::new(LT, 32, 90);
        for loads in 0..6u32 {
            for alus in 1..6u32 {
                let mut ops = vec![Opcode::Ld; loads as usize];
                ops.extend(vec![Opcode::Add; alus as usize]);
                let t = 5.0 + 3.0 * loads as f32 + alus as f32;
                ds.push(clip_of(&ops, t));
            }
        }
        ds
    }

    #[test]
    fn fits_linear_ground_truth() {
        let ds = toy_dataset();
        let idx: Vec<usize> = (0..ds.len()).collect();
        let m = LinRegBaseline::fit(&ds, &idx, 1e-6);
        let mape = m.mape(&ds, &idx);
        assert!(mape < 0.08, "linear target should fit well, MAPE {mape}");
    }

    #[test]
    fn predicts_monotone_in_loads() {
        let ds = toy_dataset();
        let idx: Vec<usize> = (0..ds.len()).collect();
        let m = LinRegBaseline::fit(&ds, &idx, 1e-6);
        let few = m.predict(&clip_of(&[Opcode::Ld, Opcode::Add, Opcode::Add], 0.0));
        let many = m.predict(&clip_of(
            &[Opcode::Ld, Opcode::Ld, Opcode::Ld, Opcode::Ld, Opcode::Add, Opcode::Add],
            0.0,
        ));
        assert!(many > few);
    }

    #[test]
    fn solver_handles_identity() {
        let mut a = [[0.0; NUM_FEATURES]; NUM_FEATURES];
        let mut b = [0.0; NUM_FEATURES];
        for i in 0..NUM_FEATURES {
            a[i][i] = 2.0;
            b[i] = 4.0 * i as f64;
        }
        let x = solve(a, b);
        for (i, v) in x.iter().enumerate() {
            assert!((v - 2.0 * i as f64).abs() < 1e-9);
        }
    }
}
