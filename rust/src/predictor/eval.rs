//! Evaluation: batched inference over a dataset subset, MAPE / accuracy
//! (paper Eq. 11 and the "accuracy = 1 − MAPE" convention of §VI-D).
//!
//! Both entry points are generic over [`Predictor`], so they run
//! identically against every registered backend (`pjrt`, `native`,
//! `attention` — see [`runtime::Backend`](crate::runtime::Backend)); the
//! tests below pin that down for the two dependency-free ones.

use anyhow::Result;

use crate::dataset::{ClipSample, Dataset};
use crate::runtime::Predictor;
use crate::util::stats;

use super::batcher::{build_batch, BatchRunner};

/// Evaluation result over a subset.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub mape: f64,
    pub accuracy_pct: f64,
    pub n: usize,
    pub predictions: Vec<f64>,
    pub targets: Vec<f64>,
}

/// Predict every sample in `idx` (batched with the largest compiled fwd).
pub fn predict_all<P: Predictor + ?Sized>(
    model: &P,
    ds: &Dataset,
    idx: &[usize],
    time_scale: f32,
) -> Result<Vec<f64>> {
    let g = model.geometry().clone();
    let b = model.max_fwd_batch();
    let mut out = Vec::with_capacity(idx.len());
    // one BatchRunner (workspace + prediction buffer) across the chunks
    let mut runner = BatchRunner::new();
    for chunk in idx.chunks(b) {
        let refs: Vec<&ClipSample> = chunk.iter().map(|&i| &ds.samples[i]).collect();
        let cap = model.pick_fwd_batch(refs.len());
        let batch = build_batch(&refs, cap, &g);
        let preds = runner.forward(model, &batch, time_scale)?;
        out.extend(preds.iter().map(|&p| p as f64));
    }
    Ok(out)
}

/// Evaluate MAPE/accuracy of `model` over `idx`.
pub fn evaluate<P: Predictor + ?Sized>(
    model: &P,
    ds: &Dataset,
    idx: &[usize],
    time_scale: f32,
) -> Result<EvalResult> {
    let predictions = predict_all(model, ds, idx, time_scale)?;
    let targets: Vec<f64> = idx.iter().map(|&i| ds.samples[i].time as f64).collect();
    let mape = stats::mape(&predictions, &targets);
    Ok(EvalResult {
        mape,
        accuracy_pct: 100.0 * (1.0 - mape),
        n: idx.len(),
        predictions,
        targets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{AttentionPredictor, NativePredictor};

    fn tiny_dataset(g: &crate::runtime::ModelGeometry) -> Dataset {
        let mut ds = Dataset::new(g.l_token, g.l_clip, g.m_rows);
        for i in 0..10u16 {
            let len = 2 + (i % 4);
            ds.push(ClipSample {
                tokens: (0..len as usize * g.l_token)
                    .map(|t| if t % g.l_token == 0 { 1 } else { 3 + i })
                    .collect(),
                len,
                ctx: vec![20 + i; g.m_rows],
                time: 5.0 + i as f32,
                key: i as u64 + 1,
                bench: 0,
            });
        }
        ds
    }

    /// `evaluate`/`predict_all` are backend-agnostic: both
    /// dependency-free backends produce finite, positive, row-count
    /// preserving results through the exact same call path the PJRT
    /// model uses.
    #[test]
    fn evaluate_runs_on_every_dependency_free_backend() {
        let native = NativePredictor::with_defaults();
        let attention = AttentionPredictor::with_defaults();
        let models: [&dyn Predictor; 2] = [&native, &attention];
        for model in models {
            let ds = tiny_dataset(model.geometry());
            let idx: Vec<usize> = (0..ds.len()).collect();
            let ev = evaluate(model, &ds, &idx, 9.5).unwrap();
            assert_eq!(ev.n, 10);
            assert_eq!(ev.predictions.len(), 10);
            assert!(ev.predictions.iter().all(|p| p.is_finite() && *p > 0.0));
            assert!(ev.mape.is_finite() && ev.mape >= 0.0);
            assert_eq!(ev.targets[3], 8.0);
        }
    }

    /// Chunked `predict_all` equals per-sample prediction bit-for-bit on
    /// the row-local backends (the padding/batch invariance the engine
    /// depends on, exercised through the eval path).
    #[test]
    fn predict_all_chunking_matches_per_sample_prediction() {
        let attention = AttentionPredictor::with_defaults();
        let ds = tiny_dataset(attention.geometry());
        let idx: Vec<usize> = (0..ds.len()).collect();
        let chunked = predict_all(&attention, &ds, &idx, 7.0).unwrap();
        for (i, &p) in chunked.iter().enumerate() {
            let solo = predict_all(&attention, &ds, &idx[i..i + 1], 7.0).unwrap();
            assert_eq!(solo[0].to_bits(), p.to_bits(), "sample {i}");
        }
    }
}
