//! Evaluation: batched inference over a dataset subset, MAPE / accuracy
//! (paper Eq. 11 and the "accuracy = 1 − MAPE" convention of §VI-D).
//!
//! Both entry points are generic over [`Predictor`], so they run
//! identically against the PJRT backend and the native analytic backend.

use anyhow::Result;

use crate::dataset::{ClipSample, Dataset};
use crate::runtime::Predictor;
use crate::util::stats;

use super::batcher::build_batch;

/// Evaluation result over a subset.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub mape: f64,
    pub accuracy_pct: f64,
    pub n: usize,
    pub predictions: Vec<f64>,
    pub targets: Vec<f64>,
}

/// Predict every sample in `idx` (batched with the largest compiled fwd).
pub fn predict_all<P: Predictor + ?Sized>(
    model: &P,
    ds: &Dataset,
    idx: &[usize],
    time_scale: f32,
) -> Result<Vec<f64>> {
    let g = model.geometry().clone();
    let b = model.max_fwd_batch();
    let mut out = Vec::with_capacity(idx.len());
    for chunk in idx.chunks(b) {
        let refs: Vec<&ClipSample> = chunk.iter().map(|&i| &ds.samples[i]).collect();
        let cap = model.pick_fwd_batch(refs.len());
        let batch = build_batch(&refs, cap, &g);
        let pred = model.forward(&batch, time_scale)?;
        out.extend(pred.iter().map(|&p| p as f64));
    }
    Ok(out)
}

/// Evaluate MAPE/accuracy of `model` over `idx`.
pub fn evaluate<P: Predictor + ?Sized>(
    model: &P,
    ds: &Dataset,
    idx: &[usize],
    time_scale: f32,
) -> Result<EvalResult> {
    let predictions = predict_all(model, ds, idx, time_scale)?;
    let targets: Vec<f64> = idx.iter().map(|&i| ds.samples[i].time as f64).collect();
    let mape = stats::mape(&predictions, &targets);
    Ok(EvalResult {
        mape,
        accuracy_pct: 100.0 * (1.0 - mape),
        n: idx.len(),
        predictions,
        targets,
    })
}
