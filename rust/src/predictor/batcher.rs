//! Padding clip samples into the fixed-shape batches the AOT model expects,
//! plus the [`BatchAccumulator`] the sharded engine uses to fill batches
//! *across* intervals and benchmarks (and, in `capsim serve`, across
//! client requests) instead of flushing ragged per-interval remainders,
//! and the [`BatchRunner`] that owns the per-driving-thread forward
//! state every predict loop shares.

use anyhow::Result;

use crate::dataset::{ClipSample, Dataset};
use crate::runtime::{Batch, ModelGeometry, Predictor, Workspace};

/// Assemble one batch of capacity `b` from `samples` (at most `b` of them).
/// Rows beyond `samples.len()` stay zero-masked padding.
pub fn build_batch(samples: &[&ClipSample], b: usize, g: &ModelGeometry) -> Batch {
    assert!(samples.len() <= b);
    let mut batch = Batch::zeroed(b, g);
    batch.live = samples.len();
    let row_tokens = g.l_clip * g.l_token;
    for (r, s) in samples.iter().enumerate() {
        let n = s.len as usize;
        debug_assert!(n <= g.l_clip);
        // tokens + token mask (a token is live unless it's <PAD>=0)
        for i in 0..n {
            for t in 0..g.l_token {
                let tok = s.tokens[i * g.l_token + t];
                batch.tokens[r * row_tokens + i * g.l_token + t] = tok as i32;
                if t == 0 || tok != 0 {
                    batch.tok_mask[r * row_tokens + i * g.l_token + t] = 1.0;
                }
            }
            batch.clip_mask[r * g.l_clip + i] = 1.0;
        }
        for (m, &t) in s.ctx.iter().enumerate() {
            batch.ctx[r * g.m_rows + m] = t as i32;
        }
        batch.target[r] = s.time.max(1.0);
    }
    batch
}

/// Split `idx` (indices into `ds`) into batches of capacity `b`.
pub fn build_batches(ds: &Dataset, idx: &[usize], b: usize, g: &ModelGeometry) -> Vec<Batch> {
    idx.chunks(b)
        .map(|chunk| {
            let refs: Vec<&ClipSample> = chunk.iter().map(|&i| &ds.samples[i]).collect();
            build_batch(&refs, b, g)
        })
        .collect()
}

/// Accumulates keyed clips until a full batch of capacity `cap` is ready.
///
/// The engine feeds every *new unique* clip it discovers — across all
/// intervals of a benchmark, and across benchmarks when driven by
/// `coordinator::engine::capsim_suite` — into one accumulator, so the
/// predictor almost always sees full batches; only the final
/// [`flush`](BatchAccumulator::flush) can be partial (and is still padded
/// to `cap`, which must be a compiled batch size).
///
/// The key type `T` is generic so the same accumulator serves both the
/// engine (plain `u64` content keys, the default) and the serving daemon,
/// whose keys carry routing tags — `(request id, slot, content key)` —
/// that thread each batched clip back to the client request it came from
/// (cross-request batching).
///
/// Emission order is exactly push order, which is what keeps the engine
/// deterministic across thread counts.
pub struct BatchAccumulator<T = u64> {
    cap: usize,
    g: ModelGeometry,
    keys: Vec<T>,
    samples: Vec<ClipSample>,
}

impl<T> BatchAccumulator<T> {
    pub fn new(cap: usize, g: ModelGeometry) -> BatchAccumulator<T> {
        assert!(cap > 0, "batch capacity must be positive");
        BatchAccumulator {
            cap,
            g,
            keys: Vec::with_capacity(cap),
            samples: Vec::with_capacity(cap),
        }
    }

    /// Clips pushed but not yet emitted.
    pub fn pending(&self) -> usize {
        self.samples.len()
    }

    /// Add one clip; returns a full `(keys, batch)` pair once `cap` clips
    /// have accumulated.
    pub fn push(&mut self, key: T, sample: ClipSample) -> Option<(Vec<T>, Batch)> {
        self.keys.push(key);
        self.samples.push(sample);
        if self.samples.len() == self.cap {
            self.emit(self.cap)
        } else {
            None
        }
    }

    /// Emit whatever is pending as a final (possibly partial) batch,
    /// padded to `tail_cap` — pass the smallest *compiled* batch size
    /// that fits `pending()` (i.e. `model.pick_fwd_batch(pending())`) so
    /// the tail doesn't burn a full-capacity forward on a few rows.
    pub fn flush(&mut self, tail_cap: usize) -> Option<(Vec<T>, Batch)> {
        if self.samples.is_empty() {
            None
        } else {
            assert!(
                tail_cap >= self.samples.len(),
                "tail capacity {} below {} pending clips",
                tail_cap,
                self.samples.len()
            );
            self.emit(tail_cap)
        }
    }

    /// Take every pending `(key, sample)` pair out of the accumulator
    /// without building a batch — the streaming engine's merge stage
    /// hands its tail downstream raw, and the predict stage (which knows
    /// the compiled batch sizes) pads it with `pick_fwd_batch` (see
    /// [`BatchRunner::forward_tail`]).
    pub fn drain(&mut self) -> Vec<(T, ClipSample)> {
        let keys = std::mem::take(&mut self.keys);
        let samples = std::mem::take(&mut self.samples);
        keys.into_iter().zip(samples).collect()
    }

    fn emit(&mut self, cap: usize) -> Option<(Vec<T>, Batch)> {
        let keys = std::mem::take(&mut self.keys);
        let samples = std::mem::take(&mut self.samples);
        let refs: Vec<&ClipSample> = samples.iter().collect();
        let batch = build_batch(&refs, cap, &self.g);
        Some((keys, batch))
    }
}

/// The per-driving-thread forward state — a [`Workspace`] scratch arena
/// plus a reusable prediction buffer — behind every predict loop in the
/// tree (stream stage 3, `DedupState::predict`, the eval loop, the serve
/// daemon). One `BatchRunner` per driving thread keeps the steady-state
/// forward allocation-free, exactly as the kernel contract in
/// [`runtime`](crate::runtime) requires; centralizing it here means the
/// workspace + buffer + tail-padding idiom exists once instead of being
/// re-derived at each call site.
#[derive(Default)]
pub struct BatchRunner {
    ws: Workspace,
    preds: Vec<f32>,
}

impl BatchRunner {
    pub fn new() -> BatchRunner {
        BatchRunner { ws: Workspace::new(), preds: Vec::new() }
    }

    /// Run one prepared batch through [`Predictor::forward_into`] and
    /// return the live-row predictions (length `batch.live`, borrowed
    /// from the runner's buffer until the next call).
    pub fn forward<P: Predictor + ?Sized>(
        &mut self,
        model: &P,
        batch: &Batch,
        time_scale: f32,
    ) -> Result<&[f32]> {
        model.forward_into(batch, time_scale, &mut self.ws, &mut self.preds)?;
        Ok(&self.preds)
    }

    /// Pad-and-forward a raw accumulator tail (the output of
    /// [`BatchAccumulator::drain`]): picks the smallest compiled capacity
    /// that fits via [`Predictor::pick_fwd_batch`], builds the padded
    /// batch, and forwards it. Predictions come back in `clips` order; an
    /// empty tail returns an empty slice without touching the model.
    pub fn forward_tail<P: Predictor + ?Sized, T>(
        &mut self,
        model: &P,
        clips: &[(T, ClipSample)],
        time_scale: f32,
    ) -> Result<&[f32]> {
        if clips.is_empty() {
            self.preds.clear();
            return Ok(&self.preds);
        }
        let cap = model.pick_fwd_batch(clips.len());
        let refs: Vec<&ClipSample> = clips.iter().map(|(_, sample)| sample).collect();
        let batch = build_batch(&refs, cap, model.geometry());
        self.forward(model, &batch, time_scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geometry() -> ModelGeometry {
        ModelGeometry {
            vocab_size: 512,
            embed_dim: 64,
            l_token: 4,
            l_clip: 8,
            m_rows: 9,
            train_batch: 4,
            fwd_batch_sizes: vec![1, 4],
        }
    }

    fn sample(len: u16, fill: u16) -> ClipSample {
        ClipSample {
            tokens: (0..len as usize * 4)
                .map(|i| if i % 4 == 3 { 0 } else { fill })
                .collect(),
            len,
            ctx: vec![9; 9],
            time: 42.0,
            key: 1,
            bench: 0,
        }
    }

    #[test]
    fn masks_follow_shape() {
        let g = geometry();
        let s = sample(3, 5);
        let b = build_batch(&[&s], 4, &g);
        assert_eq!(b.live, 1);
        // 3 live instructions
        let cm: f32 = b.clip_mask[..8].iter().sum();
        assert_eq!(cm, 3.0);
        // row 0 inst 0: tokens [5,5,5,0] -> mask [1,1,1,0]... except t==0 always 1
        assert_eq!(&b.tok_mask[..4], &[1.0, 1.0, 1.0, 0.0]);
        // padding rows all zero
        assert!(b.clip_mask[8..].iter().all(|&x| x == 0.0));
        assert!(b.tokens[3 * 8 * 4..].iter().all(|&x| x == 0));
    }

    #[test]
    fn rep_position_always_live() {
        let g = geometry();
        // token 0 at position 0 should still be masked-in (it's <REP>'s slot;
        // standardize always puts <REP>=1 there, but the mask rule protects
        // even degenerate rows)
        let mut s = sample(1, 0);
        s.tokens = vec![0, 0, 0, 0];
        let b = build_batch(&[&s], 1, &g);
        assert_eq!(b.tok_mask[0], 1.0);
    }

    #[test]
    fn target_clamped_positive() {
        let g = geometry();
        let mut s = sample(2, 3);
        s.time = 0.0;
        let b = build_batch(&[&s], 1, &g);
        assert_eq!(b.target[0], 1.0);
    }

    #[test]
    fn accumulator_emits_full_batches_in_push_order() {
        let g = geometry();
        let mut acc = BatchAccumulator::new(4, g.clone());
        let mut emitted: Vec<Vec<u64>> = Vec::new();
        for i in 0..10u64 {
            if let Some((keys, batch)) = acc.push(i, sample(2, i as u16 + 1)) {
                assert_eq!(batch.live, 4);
                assert_eq!(batch.b, 4);
                emitted.push(keys);
            }
        }
        assert_eq!(emitted, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        assert_eq!(acc.pending(), 2);
        // the tail flushes into a smaller compiled capacity
        let (keys, batch) = acc.flush(2).unwrap();
        assert_eq!(keys, vec![8, 9]);
        assert_eq!(batch.live, 2);
        assert_eq!(batch.b, 2, "tail uses the caller-picked capacity");
        assert!(acc.flush(4).is_none());
        assert_eq!(acc.pending(), 0);
    }

    #[test]
    fn accumulator_supports_tagged_keys() {
        // the serve daemon threads (request id, slot) routing tags
        // through the same accumulator the engine uses with plain keys
        let g = geometry();
        let mut acc: BatchAccumulator<(u64, usize)> = BatchAccumulator::new(2, g);
        assert!(acc.push((7, 0), sample(2, 1)).is_none());
        let (tags, batch) = acc.push((9, 1), sample(2, 2)).unwrap();
        assert_eq!(tags, vec![(7, 0), (9, 1)]);
        assert_eq!(batch.live, 2);
        assert_eq!(acc.pending(), 0);
        assert!(acc.drain().is_empty());
    }

    #[test]
    fn batch_runner_tail_matches_single_row_forwards() {
        use crate::runtime::{NativePredictor, Predictor};
        let model = NativePredictor::with_defaults();
        let g = model.geometry().clone();
        let clips: Vec<(u64, ClipSample)> = (0..3u64)
            .map(|i| {
                let len = 2 + i as u16;
                let tokens = (0..len as usize * g.l_token)
                    .map(|t| 1 + ((t as u16 + i as u16) % 7))
                    .collect();
                ClipSample {
                    tokens,
                    len,
                    ctx: vec![3; g.m_rows],
                    time: 10.0,
                    key: i,
                    bench: 0,
                }
            })
            .map(|s| (s.key, s))
            .collect();
        let mut runner = BatchRunner::new();
        let batched: Vec<f32> =
            runner.forward_tail(&model, &clips, 40.0).unwrap().to_vec();
        assert_eq!(batched.len(), 3);
        // the backend is row-local, so one-row tails reproduce each
        // batched prediction bit-exactly (dirty runner reuse included)
        let mut solo = BatchRunner::new();
        for (i, pair) in clips.iter().enumerate() {
            let p = solo
                .forward_tail(&model, std::slice::from_ref(pair), 40.0)
                .unwrap();
            assert_eq!(p.len(), 1);
            assert_eq!(p[0].to_bits(), batched[i].to_bits(), "clip {i}");
        }
        let none: &[(u64, ClipSample)] = &[];
        assert!(runner.forward_tail(&model, none, 40.0).unwrap().is_empty());
    }

    #[test]
    fn batches_cover_all_indices() {
        let g = geometry();
        let mut ds = Dataset::new(4, 8, 9);
        for i in 0..10 {
            ds.push(sample(2 + (i % 3) as u16, i as u16 + 1));
        }
        let idx: Vec<usize> = (0..10).collect();
        let bs = build_batches(&ds, &idx, 4, &g);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[0].live, 4);
        assert_eq!(bs[2].live, 2);
    }
}
