//! The predictor driver: batching clips into the AOT entry points, the
//! SGD training loop (paper §VI-B), evaluation (MAPE / accuracy), and a
//! native linear-regression CPI baseline (the "traditional ML" comparison
//! the related-work section describes [20][21]).

pub mod batcher;
pub mod eval;
pub mod linreg;
pub mod train;

pub use batcher::{build_batch, build_batches, BatchAccumulator, BatchRunner};
pub use eval::{evaluate, predict_all, EvalResult};
pub use linreg::LinRegBaseline;
pub use train::{train, TrainLog, TrainParams};
