//! The atomic functional CPU: decode + execute, one instruction per step.

use crate::isa::asm::Program;
use crate::isa::{decode, Inst, Opcode, RegFile, INST_BYTES};
use crate::mem::Memory;

use super::trace::TraceRecord;

/// Outcome of one [`AtomicCpu::step`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepOutcome {
    /// Executed a normal instruction.
    Ok(TraceRecord),
    /// Executed `halt`; the program is finished.
    Halted(TraceRecord),
}

impl StepOutcome {
    pub fn record(&self) -> &TraceRecord {
        match self {
            StepOutcome::Ok(r) | StepOutcome::Halted(r) => r,
        }
    }
}

/// The functional simulator state.
#[derive(Clone, Debug)]
pub struct AtomicCpu {
    pub regs: RegFile,
    pub mem: Memory,
    pub halted: bool,
    /// Dynamic instruction count.
    pub icount: u64,
}

impl AtomicCpu {
    /// Load a program image (code + data) and point CIA at its entry.
    pub fn load(program: &Program) -> Self {
        let mut mem = Memory::new();
        mem.write_bytes(program.entry, &program.code_bytes());
        for (addr, bytes) in &program.data {
            mem.write_bytes(*addr, bytes);
        }
        AtomicCpu {
            regs: RegFile::new(program.entry),
            mem,
            halted: false,
            icount: 0,
        }
    }

    /// Construct from an existing architectural state + memory (checkpoint
    /// restore path).
    pub fn from_state(regs: RegFile, mem: Memory) -> Self {
        AtomicCpu { regs, mem, halted: false, icount: 0 }
    }

    /// Execute one instruction.
    ///
    /// Panics on undecodable words — programs come from our assembler, so
    /// that is a construction bug, not an input condition.
    pub fn step(&mut self) -> StepOutcome {
        debug_assert!(!self.halted);
        let pc = self.regs.cia;
        let word = self.mem.read_u32(pc);
        let inst = decode(word).expect("functional sim fetched invalid word");
        let (mem_addr, taken) = self.execute(&inst, pc);
        self.icount += 1;
        let rec = TraceRecord { pc, inst, mem_addr, taken, next_pc: self.regs.nia };
        self.regs.cia = self.regs.nia;
        if inst.op == Opcode::Halt {
            self.halted = true;
            StepOutcome::Halted(rec)
        } else {
            StepOutcome::Ok(rec)
        }
    }

    /// Run until halt or `max_insts`, collecting the trace.
    pub fn run_trace(&mut self, max_insts: u64) -> Vec<TraceRecord> {
        let mut out = Vec::new();
        while !self.halted && (out.len() as u64) < max_insts {
            out.push(*self.step().record());
        }
        out
    }

    /// Run without collecting (profiling / fast-forward), invoking `f` per
    /// record. Stops at halt or after `max_insts`.
    pub fn run_with(&mut self, max_insts: u64, mut f: impl FnMut(&TraceRecord)) -> u64 {
        let mut n = 0;
        while !self.halted && n < max_insts {
            let o = self.step();
            f(o.record());
            n += 1;
        }
        n
    }

    #[inline]
    fn ea(&self, inst: &Inst) -> u64 {
        if inst.is_indexed_mem() {
            self.regs.gpr[inst.ra as usize].wrapping_add(self.regs.gpr[inst.rb as usize])
        } else {
            self.regs.gpr[inst.ra as usize].wrapping_add(inst.imm as i64 as u64)
        }
    }

    /// Execute semantics; returns (mem_addr, branch_taken). Sets `nia`.
    fn execute(&mut self, inst: &Inst, pc: u64) -> (Option<u64>, bool) {
        use Opcode::*;
        let g = |r: &RegFile, i: u8| r.gpr[i as usize];
        let mut mem_addr = None;
        let mut taken = false;
        let mut nia = pc.wrapping_add(INST_BYTES);
        let rd = inst.rd as usize;
        let ra = inst.ra as usize;
        let rb = inst.rb as usize;
        match inst.op {
            Add => self.regs.gpr[rd] = g(&self.regs, inst.ra).wrapping_add(g(&self.regs, inst.rb)),
            Sub => self.regs.gpr[rd] = g(&self.regs, inst.ra).wrapping_sub(g(&self.regs, inst.rb)),
            Mullw => {
                self.regs.gpr[rd] =
                    g(&self.regs, inst.ra).wrapping_mul(g(&self.regs, inst.rb))
            }
            Divd => {
                let a = g(&self.regs, inst.ra) as i64;
                let b = g(&self.regs, inst.rb) as i64;
                self.regs.gpr[rd] = if b == 0 || (a == i64::MIN && b == -1) {
                    self.regs.xer |= 1; // overflow/invalid sticky bit
                    0
                } else {
                    (a / b) as u64
                };
            }
            Neg => self.regs.gpr[rd] = (g(&self.regs, inst.ra) as i64).wrapping_neg() as u64,
            And => self.regs.gpr[rd] = g(&self.regs, inst.ra) & g(&self.regs, inst.rb),
            Or => self.regs.gpr[rd] = g(&self.regs, inst.ra) | g(&self.regs, inst.rb),
            Xor => self.regs.gpr[rd] = g(&self.regs, inst.ra) ^ g(&self.regs, inst.rb),
            Sld => {
                let sh = g(&self.regs, inst.rb) & 63;
                self.regs.gpr[rd] = g(&self.regs, inst.ra) << sh;
            }
            Srd => {
                let sh = g(&self.regs, inst.rb) & 63;
                self.regs.gpr[rd] = g(&self.regs, inst.ra) >> sh;
            }
            Srad => {
                let sh = g(&self.regs, inst.rb) & 63;
                self.regs.gpr[rd] = ((g(&self.regs, inst.ra) as i64) >> sh) as u64;
            }
            Addi => {
                self.regs.gpr[rd] =
                    g(&self.regs, inst.ra).wrapping_add(inst.imm as i64 as u64)
            }
            Andi => self.regs.gpr[rd] = g(&self.regs, inst.ra) & (inst.imm as i64 as u64),
            Ori => self.regs.gpr[rd] = g(&self.regs, inst.ra) | (inst.imm as i64 as u64),
            Xori => self.regs.gpr[rd] = g(&self.regs, inst.ra) ^ (inst.imm as i64 as u64),
            Sldi => self.regs.gpr[rd] = g(&self.regs, inst.ra) << (inst.imm & 63),
            Srdi => self.regs.gpr[rd] = g(&self.regs, inst.ra) >> (inst.imm & 63),
            Sradi => {
                self.regs.gpr[rd] = ((g(&self.regs, inst.ra) as i64) >> (inst.imm & 63)) as u64
            }
            Li => self.regs.gpr[rd] = inst.imm as i64 as u64,
            Lis => self.regs.gpr[rd] = (inst.imm as i64 as u64) << 16,
            Cmp => {
                let (a, b) = (g(&self.regs, inst.ra) as i64, g(&self.regs, inst.rb) as i64);
                self.regs.cr.compare_signed(a, b);
            }
            Cmpl => {
                let (a, b) = (g(&self.regs, inst.ra), g(&self.regs, inst.rb));
                self.regs.cr.compare_unsigned(a, b);
            }
            Cmpi => {
                let a = g(&self.regs, inst.ra) as i64;
                self.regs.cr.compare_signed(a, inst.imm as i64);
            }
            Cmpli => {
                let a = g(&self.regs, inst.ra);
                self.regs.cr.compare_unsigned(a, inst.imm as i64 as u64);
            }
            Lbz | Lhz | Lwz | Ld | Lwzu | Ldx => {
                let ea = self.ea(inst);
                mem_addr = Some(ea);
                let w = inst.mem_width().unwrap() as usize;
                self.regs.gpr[rd] = self.mem.read_le(ea, w);
                if inst.is_update_form() {
                    self.regs.gpr[ra] = ea;
                }
            }
            Lfd | Lfdx => {
                let ea = self.ea(inst);
                mem_addr = Some(ea);
                self.regs.fpr[rd] = self.mem.read_f64(ea);
            }
            Stb | Sth | Stw | Std | Stwu | Stdx => {
                let ea = self.ea(inst);
                mem_addr = Some(ea);
                let w = inst.mem_width().unwrap() as usize;
                self.mem.write_le(ea, w, g(&self.regs, inst.rd));
                if inst.is_update_form() {
                    self.regs.gpr[ra] = ea;
                }
            }
            Stfd | Stfdx => {
                let ea = self.ea(inst);
                mem_addr = Some(ea);
                self.mem.write_f64(ea, self.regs.fpr[rd]);
            }
            Fadd => self.regs.fpr[rd] = self.regs.fpr[ra] + self.regs.fpr[rb],
            Fsub => self.regs.fpr[rd] = self.regs.fpr[ra] - self.regs.fpr[rb],
            Fmul => self.regs.fpr[rd] = self.regs.fpr[ra] * self.regs.fpr[rb],
            Fdiv => self.regs.fpr[rd] = self.regs.fpr[ra] / self.regs.fpr[rb],
            Fmadd => {
                self.regs.fpr[rd] += self.regs.fpr[ra] * self.regs.fpr[rb];
            }
            Fneg => self.regs.fpr[rd] = -self.regs.fpr[ra],
            Fmr => self.regs.fpr[rd] = self.regs.fpr[ra],
            Fcmp => {
                let (a, b) = (self.regs.fpr[ra], self.regs.fpr[rb]);
                if a.is_nan() || b.is_nan() {
                    self.regs.cr.set_field(0, crate::isa::CR_SO);
                    self.regs.fpscr |= 1;
                } else if a < b {
                    self.regs.cr.set_field(0, crate::isa::CR_LT);
                } else if a > b {
                    self.regs.cr.set_field(0, crate::isa::CR_GT);
                } else {
                    self.regs.cr.set_field(0, crate::isa::CR_EQ);
                }
            }
            Fcfid => self.regs.fpr[rd] = g(&self.regs, inst.ra) as i64 as f64,
            Fctid => {
                let v = self.regs.fpr[ra];
                self.regs.fpr[rd] = f64::from_bits(if v.is_nan() {
                    0
                } else {
                    (v as i64) as u64
                });
            }
            B => {
                taken = true;
                nia = pc.wrapping_add((inst.imm as i64 * INST_BYTES as i64) as u64);
            }
            Bl => {
                taken = true;
                self.regs.lr = pc.wrapping_add(INST_BYTES);
                nia = pc.wrapping_add((inst.imm as i64 * INST_BYTES as i64) as u64);
            }
            Blr => {
                taken = true;
                nia = self.regs.lr;
            }
            Bctr => {
                taken = true;
                nia = self.regs.ctr;
            }
            Beq | Bne | Blt | Bge | Bgt | Ble => {
                let f = self.regs.cr.field(0);
                let cond = match inst.op {
                    Beq => f & crate::isa::CR_EQ != 0,
                    Bne => f & crate::isa::CR_EQ == 0,
                    Blt => f & crate::isa::CR_LT != 0,
                    Bge => f & crate::isa::CR_LT == 0,
                    Bgt => f & crate::isa::CR_GT != 0,
                    Ble => f & crate::isa::CR_GT == 0,
                    _ => unreachable!(),
                };
                if cond {
                    taken = true;
                    nia = pc.wrapping_add((inst.imm as i64 * INST_BYTES as i64) as u64);
                }
            }
            Bdnz => {
                self.regs.ctr = self.regs.ctr.wrapping_sub(1);
                if self.regs.ctr != 0 {
                    taken = true;
                    nia = pc.wrapping_add((inst.imm as i64 * INST_BYTES as i64) as u64);
                }
            }
            Mtlr => self.regs.lr = g(&self.regs, inst.ra),
            Mflr => self.regs.gpr[rd] = self.regs.lr,
            Mtctr => self.regs.ctr = g(&self.regs, inst.ra),
            Mfctr => self.regs.gpr[rd] = self.regs.ctr,
            Nop | Halt => {}
        }
        self.regs.nia = nia;
        (mem_addr, taken)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Assembler;

    fn run(prog: Program, max: u64) -> AtomicCpu {
        let mut cpu = AtomicCpu::load(&prog);
        cpu.run_trace(max);
        cpu
    }

    #[test]
    fn arith_basics() {
        let mut a = Assembler::new(0x1000);
        a.li(1, 6);
        a.li(2, 7);
        a.mullw(3, 1, 2);
        a.addi(3, 3, 1);
        a.halt();
        let cpu = run(a.finish(), 100);
        assert_eq!(cpu.regs.gpr[3], 43);
        assert!(cpu.halted);
        assert_eq!(cpu.icount, 5);
    }

    #[test]
    fn division_by_zero_sets_xer() {
        let mut a = Assembler::new(0x1000);
        a.li(1, 5);
        a.li(2, 0);
        a.divd(3, 1, 2);
        a.halt();
        let cpu = run(a.finish(), 10);
        assert_eq!(cpu.regs.gpr[3], 0);
        assert_eq!(cpu.regs.xer & 1, 1);
    }

    #[test]
    fn loop_with_bdnz() {
        // sum 1..=10 using CTR loop
        let mut a = Assembler::new(0x1000);
        a.li(3, 0); // acc
        a.li(4, 10); // i
        a.mtctr(4);
        let top = a.here();
        a.add(3, 3, 4);
        a.addi(4, 4, -1);
        a.bdnz(top);
        a.halt();
        let cpu = run(a.finish(), 1000);
        assert_eq!(cpu.regs.gpr[3], 55);
    }

    #[test]
    fn memory_roundtrip_and_update_form() {
        let mut a = Assembler::new(0x1000);
        a.load_imm64(1, 0x10000);
        a.li(2, 0x1234);
        a.stw(2, 0, 1);
        a.lwz(3, 0, 1);
        a.lwzu(4, 4, 1); // loads from 0x10004, r1 <- 0x10004
        a.halt();
        let cpu = run(a.finish(), 100);
        assert_eq!(cpu.regs.gpr[3], 0x1234);
        assert_eq!(cpu.regs.gpr[4], 0);
        assert_eq!(cpu.regs.gpr[1], 0x10004);
    }

    #[test]
    fn conditional_branches_follow_cr() {
        let mut a = Assembler::new(0x1000);
        a.li(1, 5);
        a.cmpi(1, 5);
        let eq = a.label();
        a.beq(eq);
        a.li(9, 111); // skipped
        a.bind(eq);
        a.li(10, 222);
        a.halt();
        let cpu = run(a.finish(), 100);
        assert_eq!(cpu.regs.gpr[9], 0);
        assert_eq!(cpu.regs.gpr[10], 222);
    }

    #[test]
    fn call_and_return_via_lr() {
        let mut a = Assembler::new(0x1000);
        let f = a.label();
        a.li(3, 1);
        a.bl(f);
        a.addi(3, 3, 100); // after return
        a.halt();
        a.bind(f);
        a.addi(3, 3, 10);
        a.blr();
        let cpu = run(a.finish(), 100);
        assert_eq!(cpu.regs.gpr[3], 111);
    }

    #[test]
    fn fp_pipeline() {
        let mut a = Assembler::new(0x1000);
        a.data_f64(0x20000, &[1.5, 2.5]);
        a.load_imm64(1, 0x20000);
        a.lfd(1, 0, 1);
        a.lfd(2, 8, 1);
        a.fadd(3, 1, 2); // 4.0
        a.fmul(4, 3, 2); // 10.0
        a.fmadd(4, 1, 2); // 10 + 1.5*2.5 = 13.75
        a.stfd(4, 16, 1);
        a.halt();
        let cpu = run(a.finish(), 100);
        assert_eq!(cpu.mem.read_f64(0x20010), 13.75);
    }

    #[test]
    fn trace_records_memory_and_branches() {
        let mut a = Assembler::new(0x1000);
        a.load_imm64(1, 0x10000);
        a.lwz(2, 8, 1);
        let skip = a.label();
        a.cmpi(2, 99);
        a.bne(skip);
        a.nop();
        a.bind(skip);
        a.halt();
        let mut cpu = AtomicCpu::load(&a.finish());
        let trace = cpu.run_trace(100);
        let load = trace.iter().find(|r| r.inst.is_load()).unwrap();
        assert_eq!(load.mem_addr, Some(0x10008));
        let br = trace.iter().find(|r| r.inst.is_cond_branch()).unwrap();
        assert!(br.taken); // r2==0 != 99
        assert_eq!(br.next_pc, br.pc + 2 * 4);
    }

    #[test]
    fn indexed_and_indirect() {
        let mut a = Assembler::new(0x1000);
        a.data_u64(0x30000, &[77]);
        a.load_imm64(1, 0x30000);
        a.li(2, 0);
        a.ldx(3, 1, 2);
        // computed branch via CTR
        a.load_imm64(5, 0x1000); // patched below: jump to halt
        a.halt(); // placeholder to compute addresses easily
        let p = a.finish();
        let mut cpu = AtomicCpu::load(&p);
        cpu.run_trace(100);
        assert_eq!(cpu.regs.gpr[3], 77);
    }
}
