//! Dynamic instruction trace records — the interchange between the
//! functional simulator, the O3 timing model, and the slicer.

use crate::isa::Inst;

/// One dynamically executed instruction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    /// Instruction address.
    pub pc: u64,
    /// Decoded instruction.
    pub inst: Inst,
    /// Effective address of the memory access, if any.
    pub mem_addr: Option<u64>,
    /// Branch outcome (false for non-branches).
    pub taken: bool,
    /// Address of the next dynamically executed instruction.
    pub next_pc: u64,
}

impl TraceRecord {
    /// Whether this record ends a basic block (taken or not, control flow
    /// instructions delimit blocks for BBV profiling).
    pub fn ends_block(&self) -> bool {
        self.inst.is_branch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Inst, Opcode};

    #[test]
    fn branch_ends_block() {
        let rec = TraceRecord {
            pc: 0x1000,
            inst: Inst::new(Opcode::B, 0, 0, 0, -2),
            mem_addr: None,
            taken: true,
            next_pc: 0x0FF8,
        };
        assert!(rec.ends_block());
        let rec2 = TraceRecord { inst: Inst::new(Opcode::Add, 1, 2, 3, 0), ..rec };
        assert!(!rec2.ends_block());
    }
}
