//! The fast functional simulator — CAPSim's analogue of gem5's
//! `AtomicSimpleCPU` (paper Fig. 1, right side).
//!
//! "Atomic" means each instruction executes completely in one step with no
//! timing model; it is an order of magnitude faster than the O3 model and
//! produces exactly two things the predictor pipeline needs:
//!
//! 1. the dynamic **instruction trace** ([`TraceRecord`]: decoded
//!    instruction, effective address, branch outcome);
//! 2. **register snapshots** (the architectural state that becomes the
//!    Fig.-6 context matrix at clip boundaries).

pub mod cpu;
pub mod trace;

pub use cpu::{AtomicCpu, StepOutcome};
pub use trace::TraceRecord;
