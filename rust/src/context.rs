//! The context matrix (paper §V-B, Fig. 6, Table I): the CPU state before
//! a trace clip executes, rendered as embedding-table tokens.
//!
//! Each selected register contributes one *name* token followed by its
//! value split into byte tokens, most-significant byte first (the paper
//! splits 128-bit VSR values into 16 hex-pair groups; our 64-bit registers
//! split into 8). The register list is configurable; the default is the
//! `ctx_regs = 10` prefix declared in `model_config.json`, mirroring the
//! Table-I classes that matter most on PISA workloads (argument/stack GPRs,
//! CR, LR, CTR, XER, CIA).

use crate::isa::RegFile;
use crate::tokenizer::{RegName, Vocab};

/// One context register: its name token and how to read its value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CtxReg {
    Gpr(u8),
    Fpr(u8),
    Cr,
    Lr,
    Ctr,
    Xer,
    Cia,
    Nia,
}

impl CtxReg {
    pub fn name(&self) -> RegName {
        match self {
            CtxReg::Gpr(i) => RegName::Gpr(*i),
            CtxReg::Fpr(i) => RegName::Fpr(*i),
            CtxReg::Cr => RegName::Cr,
            CtxReg::Lr => RegName::Lr,
            CtxReg::Ctr => RegName::Ctr,
            CtxReg::Xer => RegName::Xer,
            CtxReg::Cia => RegName::Cia,
            CtxReg::Nia => RegName::Nia,
        }
    }

    pub fn value(&self, regs: &RegFile) -> u64 {
        match self {
            CtxReg::Gpr(i) => regs.gpr[*i as usize],
            CtxReg::Fpr(i) => regs.fpr_bits(*i as usize),
            CtxReg::Cr => regs.cr.0 as u64,
            CtxReg::Lr => regs.lr,
            CtxReg::Ctr => regs.ctr,
            CtxReg::Xer => regs.xer,
            CtxReg::Cia => regs.cia,
            CtxReg::Nia => regs.nia,
        }
    }
}

/// The default register set (must stay consistent with
/// `model_config.json`'s `ctx_regs`): working GPRs the kernels use for
/// cursors/counters, plus the control registers of Table I.
pub const REGISTER_SPEC: [CtxReg; 10] = [
    CtxReg::Gpr(1),
    CtxReg::Gpr(3),
    CtxReg::Gpr(4),
    CtxReg::Gpr(5),
    CtxReg::Gpr(31),
    CtxReg::Cr,
    CtxReg::Lr,
    CtxReg::Ctr,
    CtxReg::Xer,
    CtxReg::Cia,
];

/// Tokens contributed per register: 1 name + 8 value bytes.
pub const TOKENS_PER_REG: usize = 9;

/// Total context rows with the default spec (the model's `M`).
pub const M_ROWS: usize = REGISTER_SPEC.len() * TOKENS_PER_REG;

/// Build the context matrix token row for one register snapshot (Fig. 6b).
pub fn context_tokens(regs: &RegFile, spec: &[CtxReg]) -> Vec<u16> {
    let mut out = Vec::with_capacity(spec.len() * TOKENS_PER_REG);
    for r in spec {
        out.push(Vocab::reg(r.name()));
        let v = r.value(regs);
        for byte in v.to_be_bytes() {
            out.push(Vocab::byte(byte));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_register_layout() {
        // R10 = 0x0123_4567_89ab_cdef -> name token + 8 byte tokens MSB-first
        let mut regs = RegFile::default();
        regs.gpr[10] = 0x0123_4567_89AB_CDEF;
        let t = context_tokens(&regs, &[CtxReg::Gpr(10)]);
        assert_eq!(t.len(), TOKENS_PER_REG);
        assert_eq!(t[0], Vocab::reg(RegName::Gpr(10)));
        assert_eq!(t[1], Vocab::byte(0x01));
        assert_eq!(t[2], Vocab::byte(0x23));
        assert_eq!(t[8], Vocab::byte(0xEF));
    }

    #[test]
    fn default_spec_matches_model_m() {
        // model_config.json: ctx_regs=10, ctx_value_tokens=8 -> M=90
        assert_eq!(M_ROWS, 90);
        let regs = RegFile::default();
        assert_eq!(context_tokens(&regs, &REGISTER_SPEC).len(), 90);
    }

    #[test]
    fn values_flow_into_tokens() {
        let mut a = RegFile::default();
        let b = {
            let mut b = RegFile::default();
            b.ctr = 500; // a loop counter difference must show in context
            b
        };
        a.ctr = 2;
        let ta = context_tokens(&a, &REGISTER_SPEC);
        let tb = context_tokens(&b, &REGISTER_SPEC);
        assert_ne!(ta, tb);
        // but only in the CTR row's byte tokens
        let diff = ta.iter().zip(&tb).filter(|(x, y)| x != y).count();
        assert!(diff <= 8);
    }

    #[test]
    fn fpr_uses_raw_bits() {
        let mut regs = RegFile::default();
        regs.fpr[2] = 1.0; // 0x3FF0_0000_0000_0000
        let t = context_tokens(&regs, &[CtxReg::Fpr(2)]);
        assert_eq!(t[1], Vocab::byte(0x3F));
        assert_eq!(t[2], Vocab::byte(0xF0));
    }
}
