//! The `capsim serve` daemon: weights loaded once, clips predicted for
//! many clients over the [`wire`](super::wire) protocol.
//!
//! ```text
//!  client sessions (1 thread each)        predict loop (caller thread)
//!  ┌─────────────────────────────┐   admission   ┌──────────────────────┐
//!  │ read frame → validate clips │──sync_channel─▶ cache lookups        │
//!  │ try_send  (Busy when full)  │  (bounded by  │ BatchAccumulator     │
//!  │ block on per-request reply ◀│─ queue_depth) │   (cross-request)    │
//!  └─────────────────────────────┘               │ flush: full batch or │
//!                                                │   linger deadline    │
//!                                                │ settle → route rows  │
//!                                                │   back per request   │
//!                                                └──────────────────────┘
//! ```
//!
//! One model, one [`BatchRunner`], one predict loop: requests from
//! different clients fill **one shared accumulator**, so concurrent
//! small requests ride full batches (`StatsReply::cross_batches`,
//! `mean_fill`). Because every registered backend is row-local (the
//! batch-invariance contract pinned by the runtime tests), a clip's
//! prediction is bit-identical whether its batch was filled by one
//! client or five — serving changes throughput, never answers.
//!
//! Backpressure is the bounded admission channel: when `queue_depth`
//! requests are already waiting, new ones bounce immediately with
//! [`Response::Busy`] carrying a retry hint, so daemon memory stays
//! bounded no matter how many clients pile on. Shutdown drains: accepted
//! work is finished, the tail batch flushed, and the clip cache saved
//! before [`Server::run`] returns.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::coordinator::ClipCache;
use crate::dataset::ClipSample;
use crate::predictor::{BatchAccumulator, BatchRunner};
use crate::runtime::{ModelGeometry, Predictor};

use super::wire::{
    read_frame, write_frame, Request, Response, StatsReply, WireClip, FLAG_USE_CACHE,
};

/// Daemon configuration (CLI flags + `[serve]` TOML keys).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Listen address (`--listen`); port 0 picks a free port.
    pub listen: String,
    /// How long a partial batch may wait for more requests (`--linger-us`).
    pub linger_us: u64,
    /// Admission-queue bound (`--queue-depth`): requests waiting for the
    /// predict loop beyond this bounce with `Busy`.
    pub queue_depth: usize,
    /// Prediction time scale — part of the cache key.
    pub time_scale: f32,
    /// Warm-start / save path for the persistent clip cache.
    pub cache_path: Option<PathBuf>,
    /// Entry bound for the persistent cache (`0` = unbounded).
    pub cache_max_entries: usize,
    /// Serve warm-start entries straight from the mmap-frozen image
    /// (`true`, the default) or copy them onto the heap
    /// (`cache_mmap = false` / `--cache-heap`).
    pub cache_mmap: bool,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            listen: "127.0.0.1:4650".into(),
            linger_us: 2_000,
            queue_depth: 16,
            time_scale: 40.0,
            cache_path: None,
            cache_max_entries: 1_000_000,
            cache_mmap: true,
        }
    }
}

/// What the daemon did, reported after a graceful drain.
#[derive(Debug)]
pub struct ServeSummary {
    pub stats: StatsReply,
    /// Entries persisted on shutdown (None without a cache path).
    pub cache_saved: Option<usize>,
    /// Whether the cache warm-started from disk.
    pub warm_start: bool,
}

#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    rejected: AtomicU64,
    predicted_clips: AtomicU64,
    batches: AtomicU64,
    cross_batches: AtomicU64,
}

fn snapshot(counters: &Counters, cache: &ClipCache) -> StatsReply {
    let cs = cache.stats();
    StatsReply {
        requests: counters.requests.load(Ordering::Relaxed),
        rejected: counters.rejected.load(Ordering::Relaxed),
        predicted_clips: counters.predicted_clips.load(Ordering::Relaxed),
        batches: counters.batches.load(Ordering::Relaxed),
        cross_batches: counters.cross_batches.load(Ordering::Relaxed),
        cache_hits: cs.hits,
        cache_misses: cs.misses,
        cache_len: cache.len() as u64,
        cache_evictions: cs.evictions,
        cache_frozen_len: cache.frozen_len() as u64,
        cache_source: cache.source().code(),
    }
}

/// One admitted predict request, queued for the predict loop.
struct Job {
    clips: Vec<(u64, ClipSample)>,
    use_cache: bool,
    reply: SyncSender<Vec<f64>>,
}

/// Routing tag threaded through the shared accumulator:
/// `(request id, slot in that request, clip content key)`.
type Tag = (u64, usize, u64);

/// A request whose rows are still spread across pending batches.
struct Inflight {
    reply: SyncSender<Vec<f64>>,
    out: Vec<f64>,
    remaining: usize,
    use_cache: bool,
}

/// A bound listener, ready to [`run`](Server::run). Binding is split
/// from running so callers (tests, the bench) can learn the actual
/// port of a `:0` bind before the daemon blocks.
pub struct Server {
    listener: TcpListener,
    opts: ServeOptions,
}

impl Server {
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(&opts.listen)
            .with_context(|| format!("binding {}", opts.listen))?;
        Ok(Server { listener, opts })
    }

    /// The bound address (resolves a `:0` port).
    pub fn addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local address")
    }

    /// Serve until a `Shutdown` request (or a fatal model error), then
    /// drain, save the cache, and report. Blocks the calling thread —
    /// the predict loop runs here so the model never has to be `Send`.
    pub fn run(self, model: &dyn Predictor) -> Result<ServeSummary> {
        let Server { listener, opts } = self;
        let addr = listener.local_addr().context("listener address")?;
        let (cache, warm_start) = match opts.cache_path.as_deref() {
            Some(p) => ClipCache::load_or_cold_bounded_with(
                p,
                model.fingerprint(),
                opts.time_scale,
                opts.cache_max_entries,
                opts.cache_mmap,
            ),
            None => (ClipCache::bounded(opts.cache_max_entries), false),
        };
        let counters = Counters::default();
        let shutdown = AtomicBool::new(false);
        let queue_depth = opts.queue_depth.max(1);
        let (tx, rx) = sync_channel::<Job>(queue_depth);
        let retry_ms = (opts.linger_us / 1_000).max(1) as u32;
        let linger = Duration::from_micros(opts.linger_us);
        let time_scale = opts.time_scale;
        let g = model.geometry().clone();

        let loop_result = std::thread::scope(|s| {
            let cache = &cache;
            let counters = &counters;
            let shutdown = &shutdown;
            // Acceptor owns the only long-lived sender clone; sessions
            // clone from it. When the acceptor breaks out and the last
            // session ends, the channel disconnects and the predict loop
            // below drains out — that ordering *is* the graceful drain.
            s.spawn(move || {
                for stream in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(st) => st,
                        Err(_) => continue,
                    };
                    let tx = tx.clone();
                    let g = g.clone();
                    s.spawn(move || {
                        session(
                            stream, tx, g, cache, counters, shutdown, retry_ms, addr,
                            queue_depth,
                        )
                    });
                }
            });
            let r = predict_loop(model, rx, cache, counters, linger, time_scale);
            if r.is_err() {
                // fatal model error: stop accepting; sessions see the
                // disconnected queue and answer with Error
                shutdown.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(addr);
            }
            r
        });
        loop_result?;

        let stats = snapshot(&counters, &cache);
        let cache_saved = match opts.cache_path.as_deref() {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)
                            .with_context(|| format!("creating {}", parent.display()))?;
                    }
                }
                let n = cache
                    .save(p, model.fingerprint(), opts.time_scale)
                    .with_context(|| format!("saving clip cache to {}", p.display()))?;
                Some(n)
            }
            None => None,
        };
        Ok(ServeSummary { stats, cache_saved, warm_start })
    }
}

/// Validate wire clips against the model geometry and build the
/// `ClipSample`s the batcher expects. All-or-nothing: one bad clip
/// refuses the whole request before it can occupy a queue slot.
fn convert(clips: &[WireClip], g: &ModelGeometry) -> Result<Vec<(u64, ClipSample)>> {
    clips
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let len = c.len as usize;
            ensure!(
                len >= 1 && len <= g.l_clip,
                "clip {i}: length {len} outside 1..={}",
                g.l_clip
            );
            ensure!(
                c.tokens.len() == len * g.l_token,
                "clip {i}: expected {} tokens for length {len}, got {}",
                len * g.l_token,
                c.tokens.len()
            );
            ensure!(
                c.ctx.len() == g.m_rows,
                "clip {i}: expected {} context rows, got {}",
                g.m_rows,
                c.ctx.len()
            );
            for &t in c.tokens.iter().chain(c.ctx.iter()) {
                ensure!((t as usize) < g.vocab_size, "clip {i}: token {t} outside the vocabulary");
            }
            Ok((
                c.key,
                ClipSample {
                    tokens: c.tokens.clone(),
                    len: c.len,
                    ctx: c.ctx.clone(),
                    // target time is training-only; the forward pass
                    // never reads it
                    time: 1.0,
                    key: c.key,
                    bench: 0,
                },
            ))
        })
        .collect()
}

/// One client connection: decode frames, admit predict work, answer.
#[allow(clippy::too_many_arguments)]
fn session(
    mut stream: TcpStream,
    tx: SyncSender<Job>,
    g: ModelGeometry,
    cache: &ClipCache,
    counters: &Counters,
    shutdown: &AtomicBool,
    retry_ms: u32,
    addr: SocketAddr,
    queue_depth: usize,
) {
    loop {
        // client hangup (or a poisoned length prefix) ends the session
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return,
        };
        let req = match Request::decode(&frame) {
            Ok(r) => r,
            Err(e) => {
                let msg = Response::Error(format!("bad request: {e}"));
                let _ = write_frame(&mut stream, &msg.encode());
                return;
            }
        };
        let resp = match req {
            Request::Stats => Response::Stats(snapshot(counters, cache)),
            Request::Shutdown => {
                let _ = write_frame(&mut stream, &Response::ShutdownAck.encode());
                shutdown.store(true, Ordering::SeqCst);
                // wake the blocking accept so the acceptor re-checks
                let _ = TcpStream::connect(addr);
                return;
            }
            Request::Predict { flags, clips } => match convert(&clips, &g) {
                Err(e) => Response::Error(format!("invalid clips: {e}")),
                Ok(converted) => {
                    counters.requests.fetch_add(1, Ordering::Relaxed);
                    if converted.is_empty() {
                        Response::Predictions(Vec::new())
                    } else {
                        let use_cache = flags & FLAG_USE_CACHE != 0;
                        let (rtx, rrx) = sync_channel::<Vec<f64>>(1);
                        match tx.try_send(Job { clips: converted, use_cache, reply: rtx }) {
                            Ok(()) => match rrx.recv() {
                                Ok(preds) => Response::Predictions(preds),
                                Err(_) => {
                                    Response::Error("predictor dropped the request".into())
                                }
                            },
                            Err(TrySendError::Full(_)) => {
                                counters.rejected.fetch_add(1, Ordering::Relaxed);
                                Response::Busy { retry_ms, queue_depth: queue_depth as u32 }
                            }
                            Err(TrySendError::Disconnected(_)) => {
                                Response::Error("server is shutting down".into())
                            }
                        }
                    }
                }
            },
        };
        if write_frame(&mut stream, &resp.encode()).is_err() {
            return;
        }
    }
}

/// Route one settled batch's rows back to their requests; a request
/// replies the moment its last row lands.
fn settle(
    tags: &[Tag],
    preds: &[f32],
    cache: &ClipCache,
    counters: &Counters,
    inflight: &mut HashMap<u64, Inflight>,
) {
    debug_assert_eq!(tags.len(), preds.len());
    counters.batches.fetch_add(1, Ordering::Relaxed);
    counters.predicted_clips.fetch_add(tags.len() as u64, Ordering::Relaxed);
    if tags.windows(2).any(|w| w[0].0 != w[1].0) {
        counters.cross_batches.fetch_add(1, Ordering::Relaxed);
    }
    for (&(id, slot, key), &p) in tags.iter().zip(preds) {
        let v = p as f64;
        let Some(fl) = inflight.get_mut(&id) else { continue };
        if fl.use_cache {
            cache.insert(key, v);
        }
        finish_slot(inflight, id, slot, v);
    }
}

/// Record one resolved row; send the reply when the request completes.
/// A send to a dead session is fine — the client just stopped waiting.
fn finish_slot(inflight: &mut HashMap<u64, Inflight>, id: u64, slot: usize, v: f64) {
    let Some(fl) = inflight.get_mut(&id) else { return };
    fl.out[slot] = v;
    fl.remaining -= 1;
    if fl.remaining == 0 {
        let fl = inflight.remove(&id).expect("entry just updated");
        let _ = fl.reply.send(fl.out);
    }
}

/// The single predict loop: pulls admitted jobs, resolves cache hits
/// inline, fills the shared accumulator with the misses, and flushes on
/// batch-full or linger expiry.
fn predict_loop(
    model: &dyn Predictor,
    rx: Receiver<Job>,
    cache: &ClipCache,
    counters: &Counters,
    linger: Duration,
    time_scale: f32,
) -> Result<()> {
    let mut acc: BatchAccumulator<Tag> =
        BatchAccumulator::new(model.max_fwd_batch(), model.geometry().clone());
    let mut runner = BatchRunner::new();
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut deadline: Option<Instant> = None;

    loop {
        let job = match deadline {
            Some(d) => match rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                Ok(j) => Some(j),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(j) => Some(j),
                Err(_) => break,
            },
        };
        match job {
            Some(job) => {
                let id = next_id;
                next_id += 1;
                let use_cache = job.use_cache;
                inflight.insert(
                    id,
                    Inflight {
                        reply: job.reply,
                        out: vec![0.0; job.clips.len()],
                        remaining: job.clips.len(),
                        use_cache,
                    },
                );
                for (slot, (key, sample)) in job.clips.into_iter().enumerate() {
                    if use_cache {
                        if let Some(v) = cache.get(key) {
                            finish_slot(&mut inflight, id, slot, v);
                            continue;
                        }
                    }
                    if let Some((tags, batch)) = acc.push((id, slot, key), sample) {
                        deadline = None;
                        let preds = runner.forward(model, &batch, time_scale)?;
                        settle(&tags, preds, cache, counters, &mut inflight);
                    }
                }
                if acc.pending() == 0 {
                    deadline = None;
                } else if deadline.is_none() {
                    deadline = Some(Instant::now() + linger);
                }
            }
            None => {
                // linger expired with no new work: flush the partial batch
                flush_tail(
                    model,
                    &mut acc,
                    &mut runner,
                    cache,
                    counters,
                    &mut inflight,
                    time_scale,
                )?;
                deadline = None;
            }
        }
    }
    // drain: the channel disconnected with clips still accumulated
    flush_tail(model, &mut acc, &mut runner, cache, counters, &mut inflight, time_scale)?;
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn flush_tail(
    model: &dyn Predictor,
    acc: &mut BatchAccumulator<Tag>,
    runner: &mut BatchRunner,
    cache: &ClipCache,
    counters: &Counters,
    inflight: &mut HashMap<u64, Inflight>,
    time_scale: f32,
) -> Result<()> {
    let tail = acc.drain();
    if tail.is_empty() {
        return Ok(());
    }
    let tags: Vec<Tag> = tail.iter().map(|&(t, _)| t).collect();
    let preds = runner.forward_tail(model, &tail, time_scale)?;
    settle(&tags, preds, cache, counters, inflight);
    Ok(())
}
