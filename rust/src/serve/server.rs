//! The `capsim serve` daemon: weights loaded once, clips predicted for
//! many clients over the [`wire`](super::wire) protocol.
//!
//! ```text
//!  session tier (one of two layers)           N predict loops (replicas)
//!  ┌─────────────────────────────┐  per-loop  ┌──────────────────────┐
//!  │ epoll event loop (1 thread, │  bounded   │ loop 0: cache lookups│
//!  │   all sockets) — or one     │──channels──▶ BatchAccumulator     │
//!  │   thread per connection     │            │ flush: full batch or │
//!  │ validate → round-robin over │            │   linger deadline    │
//!  │   the loops; all full →Busy ◀────────────├──────────────────────┤
//!  └─────────────────────────────┘  replies   │ loop 1: …            │
//!                                             └──────────────────────┘
//! ```
//!
//! **Two session layers, one contract.** [`SessionLayer`] picks who owns
//! the client sockets: the readiness-driven event loop in
//! [`event`](super::event) (default on Linux — connection count stops
//! being a thread count) or the portable thread-per-connection fallback
//! (default elsewhere). Both run the same validate → dispatch → reply
//! sequence per connection, so which layer served a request is
//! observable only as latency, never as different bytes —
//! `tests/serve_e2e.rs` pins bit-equality across layers × replica
//! counts. Idle connections are reaped after
//! [`ServeOptions::idle_timeout_ms`] in either layer, so a half-open
//! client cannot pin daemon state forever.
//!
//! **One read-only model, N predict loops.** Every loop shares the same
//! weight set (the forward pass is `&self`; all mutable forward state
//! lives in the loop's own [`BatchRunner`]) and the same concurrent
//! [`ClipCache`], but owns a private `BatchAccumulator` and in-flight
//! routing map. Requests are spread across loops round-robin, failing
//! over to any loop with queue room. Because every registered backend is
//! row-local (the batch-invariance contract pinned by the runtime
//! tests), a clip's prediction is bit-identical whatever replica and
//! whatever batch mix served it — replication changes throughput, never
//! answers (`tests/serve_e2e.rs` proves it across `predict_loops`
//! ∈ {1, 2, 4}).
//!
//! Backpressure is the bounded admission tier: each loop's channel holds
//! `queue_depth / N` waiting requests, and only when **every** loop is
//! full does a request bounce with [`Response::Busy`] + a retry hint, so
//! daemon memory stays bounded no matter how many clients pile on.
//! Shutdown drains: accepted work is finished, every replica flushes its
//! own tail batch, and the clip cache is saved before [`Server::run`]
//! returns.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::coordinator::ClipCache;
use crate::dataset::ClipSample;
use crate::predictor::{BatchAccumulator, BatchRunner};
use crate::runtime::{ModelGeometry, Predictor};
use crate::util::epoll::{self, Poller};

use super::event::{self, Completions};
use super::wire::{
    read_frame, write_frame, LoopStats, Request, Response, StatsReply, WireClip, FLAG_USE_CACHE,
};

/// Upper bound on [`ServeOptions::linger_us`] (60 s). Option parsing
/// (CLI and TOML) clamps to this, and [`retry_hint_ms`] saturates
/// anyway, so an absurd linger can never wrap the `u32` retry hint into
/// a tiny value that makes clients hammer an overloaded daemon.
pub const MAX_LINGER_US: u64 = 60_000_000;

/// The `Busy` retry hint for a given linger: about one linger period,
/// at least 1 ms, **saturating** on the `u64 → u32` conversion (a plain
/// `as u32` silently truncated oversized lingers to a wrapped hint).
pub fn retry_hint_ms(linger_us: u64) -> u32 {
    u32::try_from((linger_us / 1_000).max(1)).unwrap_or(u32::MAX)
}

/// Which tier owns the client sockets (`--session-layer` /
/// `serve.session_layer`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionLayer {
    /// Pick for the host: epoll on Linux, threads elsewhere (default).
    Auto,
    /// One readiness-driven event loop thread owns every connection
    /// (Linux only). Connection count stops being a thread count.
    Epoll,
    /// One OS thread per connection — the portable fallback.
    Threads,
}

impl SessionLayer {
    /// Parse a CLI/TOML value. `None` for unknown strings — the CLI
    /// treats that as an error, TOML falls back to the default.
    pub fn parse(s: &str) -> Option<SessionLayer> {
        match s {
            "auto" => Some(SessionLayer::Auto),
            "epoll" => Some(SessionLayer::Epoll),
            "threads" => Some(SessionLayer::Threads),
            _ => None,
        }
    }

    /// Resolve `Auto` against the host. Forcing `epoll` on a host
    /// without it is an error, not a silent fallback — the same rule as
    /// forcing an unavailable kernel tier.
    pub fn resolve(self) -> Result<SessionLayer> {
        match self {
            SessionLayer::Auto => Ok(if epoll::available() {
                SessionLayer::Epoll
            } else {
                SessionLayer::Threads
            }),
            SessionLayer::Epoll => {
                ensure!(
                    epoll::available(),
                    "session layer 'epoll' forced but this host has no epoll \
                     (Linux only); use --session-layer threads"
                );
                Ok(SessionLayer::Epoll)
            }
            SessionLayer::Threads => Ok(SessionLayer::Threads),
        }
    }
}

impl std::fmt::Display for SessionLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SessionLayer::Auto => "auto",
            SessionLayer::Epoll => "epoll",
            SessionLayer::Threads => "threads",
        })
    }
}

/// Daemon configuration (CLI flags + `[serve]` TOML keys).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Listen address (`--listen`); port 0 picks a free port.
    pub listen: String,
    /// How long a partial batch may wait for more requests (`--linger-us`,
    /// clamped to [`MAX_LINGER_US`] at parse time).
    pub linger_us: u64,
    /// Admission bound (`--queue-depth`): total requests waiting for the
    /// predict loops beyond this bounce with `Busy`. Split evenly across
    /// the loops (each gets at least 1 slot).
    pub queue_depth: usize,
    /// Replicated predict loops (`--predict-loops` /
    /// `serve.predict_loops`): each owns a private accumulator/runner
    /// over the shared read-only weights. Clamped to >= 1.
    pub predict_loops: usize,
    /// Prediction time scale — part of the cache key.
    pub time_scale: f32,
    /// Warm-start / save path for the persistent clip cache.
    pub cache_path: Option<PathBuf>,
    /// Entry bound for the persistent cache (`0` = unbounded).
    pub cache_max_entries: usize,
    /// Serve warm-start entries straight from the mmap-frozen image
    /// (`true`, the default) or copy them onto the heap
    /// (`cache_mmap = false` / `--cache-heap`).
    pub cache_mmap: bool,
    /// Session tier (`--session-layer` / `serve.session_layer`):
    /// `auto` (default) resolves to epoll on Linux, threads elsewhere.
    pub session_layer: SessionLayer,
    /// Reap a connection after this many ms without traffic (`0` =
    /// never). The event loop reaps between requests; the threaded
    /// fallback applies it as a socket read timeout. A connection
    /// waiting on an in-flight predict is working, not idle.
    pub idle_timeout_ms: u64,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            listen: "127.0.0.1:4650".into(),
            linger_us: 2_000,
            queue_depth: 16,
            predict_loops: 1,
            time_scale: 40.0,
            cache_path: None,
            cache_max_entries: 1_000_000,
            cache_mmap: true,
            session_layer: SessionLayer::Auto,
            idle_timeout_ms: 60_000,
        }
    }
}

/// What the daemon did, reported after a graceful drain.
#[derive(Debug)]
pub struct ServeSummary {
    pub stats: StatsReply,
    /// Entries persisted on shutdown (None without a cache path).
    pub cache_saved: Option<usize>,
    /// Whether the cache warm-started from disk.
    pub warm_start: bool,
}

/// Forward-side counters owned by one predict loop. Per-loop rather
/// than global so `StatsReply::per_loop` can show whether the replicas
/// actually share the load (and the fill each one achieves).
#[derive(Default)]
struct LoopCounters {
    predicted_clips: AtomicU64,
    batches: AtomicU64,
    cross_batches: AtomicU64,
}

pub(super) struct Counters {
    pub(super) requests: AtomicU64,
    pub(super) rejected: AtomicU64,
    loops: Vec<LoopCounters>,
}

impl Counters {
    fn new(n_loops: usize) -> Counters {
        Counters {
            requests: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            loops: (0..n_loops).map(|_| LoopCounters::default()).collect(),
        }
    }
}

pub(super) fn snapshot(counters: &Counters, cache: &ClipCache) -> StatsReply {
    let cs = cache.stats();
    let per_loop: Vec<LoopStats> = counters
        .loops
        .iter()
        .map(|l| LoopStats {
            batches: l.batches.load(Ordering::Relaxed),
            predicted_clips: l.predicted_clips.load(Ordering::Relaxed),
            cross_batches: l.cross_batches.load(Ordering::Relaxed),
        })
        .collect();
    StatsReply {
        requests: counters.requests.load(Ordering::Relaxed),
        rejected: counters.rejected.load(Ordering::Relaxed),
        predicted_clips: per_loop.iter().map(|l| l.predicted_clips).sum(),
        batches: per_loop.iter().map(|l| l.batches).sum(),
        cross_batches: per_loop.iter().map(|l| l.cross_batches).sum(),
        cache_hits: cs.hits,
        cache_misses: cs.misses,
        cache_len: cache.len() as u64,
        cache_evictions: cs.evictions,
        cache_frozen_len: cache.frozen_len() as u64,
        cache_source: cache.source().code(),
        per_loop,
    }
}

/// Where a finished request's predictions go: back to a blocked session
/// thread (threaded layer) or into the event loop's completion queue
/// (epoll layer). Dropping an unsent `ReplyTo` delivers the failure —
/// the channel variant by disconnecting the receiver, the event variant
/// by pushing an explicit `None` — so a dying replica can never strand
/// a connection in either layer.
pub(super) struct ReplyTo {
    inner: Option<ReplyInner>,
}

enum ReplyInner {
    Channel(SyncSender<Vec<f64>>),
    Event { conn: u64, completions: Arc<Completions> },
}

impl ReplyTo {
    pub(super) fn channel(tx: SyncSender<Vec<f64>>) -> ReplyTo {
        ReplyTo { inner: Some(ReplyInner::Channel(tx)) }
    }

    pub(super) fn event(conn: u64, completions: Arc<Completions>) -> ReplyTo {
        ReplyTo { inner: Some(ReplyInner::Event { conn, completions }) }
    }

    /// Disarm a reply that will never fire because its job bounced at
    /// admission (`Busy` / shutting down) and the caller answers inline.
    /// The drop-side failure push exists for replicas dying with a
    /// *dispatched* job; letting it fire for a bounced one would queue a
    /// stale `(conn, None)` completion that the event loop could consume
    /// as the reply to that connection's next pipelined request.
    pub(super) fn defuse(mut self) {
        self.inner = None;
    }

    /// Deliver the predictions. A dead recipient (client hung up) is
    /// fine — the answer is simply dropped.
    fn send(mut self, preds: Vec<f64>) {
        match self.inner.take() {
            Some(ReplyInner::Channel(tx)) => {
                let _ = tx.send(preds);
            }
            Some(ReplyInner::Event { conn, completions }) => completions.push(conn, Some(preds)),
            None => {}
        }
    }
}

impl Drop for ReplyTo {
    fn drop(&mut self) {
        if let Some(ReplyInner::Event { conn, completions }) = self.inner.take() {
            completions.push(conn, None);
        }
    }
}

/// One admitted predict request, queued for a predict loop.
pub(super) struct Job {
    pub(super) clips: Vec<(u64, ClipSample)>,
    pub(super) use_cache: bool,
    pub(super) reply: ReplyTo,
}

/// Routing tag threaded through a loop's accumulator:
/// `(request id, slot in that request, clip content key)`.
type Tag = (u64, usize, u64);

/// A request whose rows are still spread across pending batches.
struct Inflight {
    reply: ReplyTo,
    out: Vec<f64>,
    remaining: usize,
    use_cache: bool,
}

/// A bound listener, ready to [`run`](Server::run). Binding is split
/// from running so callers (tests, the bench) can learn the actual
/// port of a `:0` bind before the daemon blocks.
pub struct Server {
    listener: TcpListener,
    opts: ServeOptions,
}

impl Server {
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(&opts.listen)
            .with_context(|| format!("binding {}", opts.listen))?;
        Ok(Server { listener, opts })
    }

    /// The bound address (resolves a `:0` port).
    pub fn addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local address")
    }

    /// Serve until a `Shutdown` request (or a fatal model error), then
    /// drain every replica's tail, save the cache, and report. Blocks
    /// the calling thread until the drain completes. The model is
    /// shared read-only by all `predict_loops` replicas (`Send + Sync`;
    /// each loop keeps its own mutable forward state), so one weight
    /// set in memory serves every loop — no per-replica
    /// re-deserialization.
    pub fn run(self, model: &(dyn Predictor + Send + Sync)) -> Result<ServeSummary> {
        let Server { listener, opts } = self;
        let addr = listener.local_addr().context("listener address")?;
        let layer = opts.session_layer.resolve()?;
        let idle = match opts.idle_timeout_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        // Build the poller before any thread spawns so a host that
        // cannot epoll (or is out of fds) fails the whole run cleanly.
        let event_state = match layer {
            SessionLayer::Epoll => {
                let poller = Poller::new().context("creating the epoll poller")?;
                let completions = Arc::new(Completions::new(poller.waker()));
                Some((poller, completions))
            }
            _ => None,
        };
        let (cache, warm_start) = match opts.cache_path.as_deref() {
            Some(p) => ClipCache::load_or_cold_bounded_with(
                p,
                model.fingerprint(),
                opts.time_scale,
                opts.cache_max_entries,
                opts.cache_mmap,
            ),
            None => (ClipCache::bounded(opts.cache_max_entries), false),
        };
        let n_loops = opts.predict_loops.max(1);
        let counters = Counters::new(n_loops);
        let shutdown = AtomicBool::new(false);
        // split the admission bound across the loops; every loop keeps at
        // least one slot so a large replica count never starves admission
        let per_loop_depth = opts.queue_depth.max(1).div_ceil(n_loops);
        let admission_cap = per_loop_depth * n_loops;
        let mut txs = Vec::with_capacity(n_loops);
        let mut rxs = Vec::with_capacity(n_loops);
        for _ in 0..n_loops {
            let (tx, rx) = sync_channel::<Job>(per_loop_depth);
            txs.push(tx);
            rxs.push(rx);
        }
        let rr = AtomicUsize::new(0);
        let linger_us = opts.linger_us.min(MAX_LINGER_US);
        let retry_ms = retry_hint_ms(linger_us);
        let linger = Duration::from_micros(linger_us);
        let time_scale = opts.time_scale;
        let g = model.geometry().clone();

        let loop_result = std::thread::scope(|s| {
            let cache = &cache;
            let counters = &counters;
            let shutdown = &shutdown;
            let rr = &rr;
            // The session tier owns the only long-lived sender clones.
            // When it exits (event loop returns, or the acceptor breaks
            // out and the last session thread ends), every loop's channel
            // disconnects and the predict loops below drain out — that
            // ordering *is* the graceful drain of all N tails.
            let tier = match event_state {
                Some((poller, completions)) => {
                    let ctx = event::Ctx {
                        txs,
                        rr,
                        g,
                        cache,
                        counters,
                        shutdown,
                        retry_ms,
                        queue_depth: admission_cap,
                        idle,
                        completions,
                    };
                    s.spawn(move || event::run(listener, poller, ctx))
                }
                None => s.spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match stream {
                            Ok(st) => st,
                            Err(_) => continue,
                        };
                        let txs = txs.clone();
                        let g = g.clone();
                        s.spawn(move || {
                            session(
                                stream, txs, rr, g, cache, counters, shutdown, retry_ms, addr,
                                admission_cap, idle,
                            )
                        });
                    }
                    Ok(())
                }),
            };
            let handles: Vec<_> = rxs
                .into_iter()
                .enumerate()
                .map(|(i, rx)| {
                    let lc = &counters.loops[i];
                    s.spawn(move || {
                        let r = predict_loop(model, rx, cache, lc, linger, time_scale);
                        if r.is_err() {
                            // fatal model error in this replica: stop
                            // accepting; sessions fail over to surviving
                            // loops and, once none are left, answer Error
                            shutdown.store(true, Ordering::SeqCst);
                            let _ = TcpStream::connect(addr);
                        }
                        r
                    })
                })
                .collect();
            let mut first = Ok(());
            for h in handles {
                let r = h.join().expect("predict loop panicked");
                if first.is_ok() {
                    first = r;
                }
            }
            let tier_r = tier.join().expect("session tier panicked");
            if first.is_ok() {
                first = tier_r;
            }
            first
        });
        loop_result?;

        let stats = snapshot(&counters, &cache);
        let cache_saved = match opts.cache_path.as_deref() {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)
                            .with_context(|| format!("creating {}", parent.display()))?;
                    }
                }
                let n = cache
                    .save(p, model.fingerprint(), opts.time_scale)
                    .with_context(|| format!("saving clip cache to {}", p.display()))?;
                Some(n)
            }
            None => None,
        };
        Ok(ServeSummary { stats, cache_saved, warm_start })
    }
}

/// Validate wire clips against the model geometry and build the
/// `ClipSample`s the batcher expects. All-or-nothing: one bad clip
/// refuses the whole request before it can occupy a queue slot.
pub(super) fn convert(clips: &[WireClip], g: &ModelGeometry) -> Result<Vec<(u64, ClipSample)>> {
    clips
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let len = c.len as usize;
            ensure!(
                len >= 1 && len <= g.l_clip,
                "clip {i}: length {len} outside 1..={}",
                g.l_clip
            );
            ensure!(
                c.tokens.len() == len * g.l_token,
                "clip {i}: expected {} tokens for length {len}, got {}",
                len * g.l_token,
                c.tokens.len()
            );
            ensure!(
                c.ctx.len() == g.m_rows,
                "clip {i}: expected {} context rows, got {}",
                g.m_rows,
                c.ctx.len()
            );
            for &t in c.tokens.iter().chain(c.ctx.iter()) {
                ensure!((t as usize) < g.vocab_size, "clip {i}: token {t} outside the vocabulary");
            }
            Ok((
                c.key,
                ClipSample {
                    tokens: c.tokens.clone(),
                    len: c.len,
                    ctx: c.ctx.clone(),
                    // target time is training-only; the forward pass
                    // never reads it
                    time: 1.0,
                    key: c.key,
                    bench: 0,
                },
            ))
        })
        .collect()
}

/// Outcome of offering a job to the predict loops. The bounce variants
/// hand the job back so the caller can [`ReplyTo::defuse`] its reply —
/// dropping it inside `dispatch` would let the event variant's drop
/// hook push a failure completion for a request that was never admitted.
pub(super) enum Dispatch {
    /// A loop took the job; await the reply.
    Sent,
    /// Every live loop's queue was full — backpressure, answer `Busy`.
    Full(Job),
    /// No loop is receiving any more — shutdown (or every replica died).
    Disconnected(Job),
}

/// Offer `job` to the loops starting at the round-robin cursor; the
/// first one with queue room takes it. Round-robin spreads steady load
/// evenly; the failover scan keeps one slow replica from bouncing
/// requests while its siblings sit idle. Row-locality means the choice
/// of loop can never change an answer, only its latency.
pub(super) fn dispatch(txs: &[SyncSender<Job>], rr: &AtomicUsize, mut job: Job) -> Dispatch {
    let n = txs.len();
    let start = rr.fetch_add(1, Ordering::Relaxed) % n;
    let mut saw_full = false;
    for k in 0..n {
        match txs[(start + k) % n].try_send(job) {
            Ok(()) => return Dispatch::Sent,
            Err(TrySendError::Full(j)) => {
                saw_full = true;
                job = j;
            }
            Err(TrySendError::Disconnected(j)) => job = j,
        }
    }
    if saw_full {
        Dispatch::Full(job)
    } else {
        Dispatch::Disconnected(job)
    }
}

/// One client connection: decode frames, admit predict work, answer.
#[allow(clippy::too_many_arguments)]
fn session(
    mut stream: TcpStream,
    txs: Vec<SyncSender<Job>>,
    rr: &AtomicUsize,
    g: ModelGeometry,
    cache: &ClipCache,
    counters: &Counters,
    shutdown: &AtomicBool,
    retry_ms: u32,
    addr: SocketAddr,
    queue_depth: usize,
    idle: Option<Duration>,
) {
    // Reap half-open clients: a connection that goes `idle` without
    // completing a frame times out the blocking read and ends the
    // session. The reply wait below blocks on a channel, not the
    // socket, so an in-flight predict is never cut short by this.
    let _ = stream.set_read_timeout(idle);
    loop {
        // client hangup (or a poisoned length prefix) ends the session
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return,
        };
        let req = match Request::decode(&frame) {
            Ok(r) => r,
            Err(e) => {
                let msg = Response::Error(format!("bad request: {e}"));
                let _ = write_frame(&mut stream, &msg.encode());
                return;
            }
        };
        let resp = match req {
            Request::Stats => Response::Stats(snapshot(counters, cache)),
            Request::Shutdown => {
                let _ = write_frame(&mut stream, &Response::ShutdownAck.encode());
                shutdown.store(true, Ordering::SeqCst);
                // wake the blocking accept so the acceptor re-checks
                let _ = TcpStream::connect(addr);
                return;
            }
            Request::Predict { flags, clips } => match convert(&clips, &g) {
                Err(e) => Response::Error(format!("invalid clips: {e}")),
                Ok(converted) => {
                    counters.requests.fetch_add(1, Ordering::Relaxed);
                    if converted.is_empty() {
                        Response::Predictions(Vec::new())
                    } else {
                        let use_cache = flags & FLAG_USE_CACHE != 0;
                        let (rtx, rrx) = sync_channel::<Vec<f64>>(1);
                        let reply = ReplyTo::channel(rtx);
                        match dispatch(&txs, rr, Job { clips: converted, use_cache, reply }) {
                            Dispatch::Sent => match rrx.recv() {
                                Ok(preds) => Response::Predictions(preds),
                                Err(_) => {
                                    Response::Error("predictor dropped the request".into())
                                }
                            },
                            Dispatch::Full(bounced) => {
                                bounced.reply.defuse();
                                counters.rejected.fetch_add(1, Ordering::Relaxed);
                                Response::Busy { retry_ms, queue_depth: queue_depth as u32 }
                            }
                            Dispatch::Disconnected(bounced) => {
                                bounced.reply.defuse();
                                Response::Error("server is shutting down".into())
                            }
                        }
                    }
                }
            },
        };
        if write_frame(&mut stream, &resp.encode()).is_err() {
            return;
        }
    }
}

/// Route one settled batch's rows back to their requests; a request
/// replies the moment its last row lands.
fn settle(
    tags: &[Tag],
    preds: &[f32],
    cache: &ClipCache,
    lc: &LoopCounters,
    inflight: &mut HashMap<u64, Inflight>,
) {
    debug_assert_eq!(tags.len(), preds.len());
    lc.batches.fetch_add(1, Ordering::Relaxed);
    lc.predicted_clips.fetch_add(tags.len() as u64, Ordering::Relaxed);
    if tags.windows(2).any(|w| w[0].0 != w[1].0) {
        lc.cross_batches.fetch_add(1, Ordering::Relaxed);
    }
    for (&(id, slot, key), &p) in tags.iter().zip(preds) {
        let v = p as f64;
        let Some(fl) = inflight.get_mut(&id) else { continue };
        if fl.use_cache {
            cache.insert(key, v);
        }
        finish_slot(inflight, id, slot, v);
    }
}

/// Record one resolved row; send the reply when the request completes.
/// A send to a dead session is fine — the client just stopped waiting.
fn finish_slot(inflight: &mut HashMap<u64, Inflight>, id: u64, slot: usize, v: f64) {
    let Some(fl) = inflight.get_mut(&id) else { return };
    fl.out[slot] = v;
    fl.remaining -= 1;
    if fl.remaining == 0 {
        let fl = inflight.remove(&id).expect("entry just updated");
        fl.reply.send(fl.out);
    }
}

/// One predict-loop replica: pulls jobs admitted to its own bounded
/// channel, resolves cache hits inline (the cache is shared by all
/// replicas), fills its private accumulator with the misses, and
/// flushes on batch-full or linger expiry. Request ids are local to the
/// loop — a request's rows never leave the replica that admitted it.
fn predict_loop(
    model: &(dyn Predictor + Send + Sync),
    rx: Receiver<Job>,
    cache: &ClipCache,
    lc: &LoopCounters,
    linger: Duration,
    time_scale: f32,
) -> Result<()> {
    let mut acc: BatchAccumulator<Tag> =
        BatchAccumulator::new(model.max_fwd_batch(), model.geometry().clone());
    let mut runner = BatchRunner::new();
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut deadline: Option<Instant> = None;

    loop {
        let job = match deadline {
            Some(d) => match rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                Ok(j) => Some(j),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(j) => Some(j),
                Err(_) => break,
            },
        };
        match job {
            Some(job) => {
                let id = next_id;
                next_id += 1;
                let use_cache = job.use_cache;
                inflight.insert(
                    id,
                    Inflight {
                        reply: job.reply,
                        out: vec![0.0; job.clips.len()],
                        remaining: job.clips.len(),
                        use_cache,
                    },
                );
                for (slot, (key, sample)) in job.clips.into_iter().enumerate() {
                    if use_cache {
                        if let Some(v) = cache.get(key) {
                            finish_slot(&mut inflight, id, slot, v);
                            continue;
                        }
                    }
                    if let Some((tags, batch)) = acc.push((id, slot, key), sample) {
                        deadline = None;
                        let preds = runner.forward(model, &batch, time_scale)?;
                        settle(&tags, preds, cache, lc, &mut inflight);
                    }
                }
                if acc.pending() == 0 {
                    deadline = None;
                } else if deadline.is_none() {
                    deadline = Some(Instant::now() + linger);
                }
            }
            None => {
                // linger expired with no new work: flush the partial batch
                flush_tail(model, &mut acc, &mut runner, cache, lc, &mut inflight, time_scale)?;
                deadline = None;
            }
        }
    }
    // drain: this replica's channel disconnected with clips still
    // accumulated — flush its tail before reporting back
    flush_tail(model, &mut acc, &mut runner, cache, lc, &mut inflight, time_scale)?;
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn flush_tail(
    model: &(dyn Predictor + Send + Sync),
    acc: &mut BatchAccumulator<Tag>,
    runner: &mut BatchRunner,
    cache: &ClipCache,
    lc: &LoopCounters,
    inflight: &mut HashMap<u64, Inflight>,
    time_scale: f32,
) -> Result<()> {
    let tail = acc.drain();
    if tail.is_empty() {
        return Ok(());
    }
    let tags: Vec<Tag> = tail.iter().map(|&(t, _)| t).collect();
    let preds = runner.forward_tail(model, &tail, time_scale)?;
    settle(&tags, preds, cache, lc, inflight);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_hint_saturates_instead_of_wrapping() {
        assert_eq!(retry_hint_ms(0), 1, "hint must stay usable");
        assert_eq!(retry_hint_ms(500), 1);
        assert_eq!(retry_hint_ms(2_000), 2);
        assert_eq!(retry_hint_ms(MAX_LINGER_US), 60_000);
        // regression: (linger_us / 1000) as u32 wrapped this to a tiny
        // hint; the saturating conversion pins the ceiling instead
        assert_eq!(retry_hint_ms(u64::MAX), u32::MAX);
        assert_eq!(retry_hint_ms((u32::MAX as u64 + 7) * 1_000), u32::MAX);
    }

    fn dummy_job() -> (Job, Receiver<Vec<f64>>) {
        let (rtx, rrx) = sync_channel(1);
        (Job { clips: Vec::new(), use_cache: false, reply: ReplyTo::channel(rtx) }, rrx)
    }

    #[test]
    fn session_layer_parses_displays_and_resolves() {
        for (s, l) in [
            ("auto", SessionLayer::Auto),
            ("epoll", SessionLayer::Epoll),
            ("threads", SessionLayer::Threads),
        ] {
            assert_eq!(SessionLayer::parse(s), Some(l));
            assert_eq!(l.to_string(), s);
        }
        assert_eq!(SessionLayer::parse("kqueue"), None);
        assert_eq!(SessionLayer::parse("Epoll"), None, "values are lowercase");
        // threads always resolves; auto never stays auto
        assert_eq!(SessionLayer::Threads.resolve().unwrap(), SessionLayer::Threads);
        let auto = SessionLayer::Auto.resolve().unwrap();
        assert_ne!(auto, SessionLayer::Auto);
        if crate::util::epoll::available() {
            assert_eq!(auto, SessionLayer::Epoll);
            assert_eq!(SessionLayer::Epoll.resolve().unwrap(), SessionLayer::Epoll);
        } else {
            assert_eq!(auto, SessionLayer::Threads);
            assert!(SessionLayer::Epoll.resolve().is_err(), "forced epoll must not fall back");
        }
    }

    #[test]
    fn dropping_a_channel_reply_disconnects_the_receiver() {
        let (job, rrx) = dummy_job();
        drop(job);
        assert!(rrx.recv().is_err(), "an unsent reply must not hang the session");
    }

    #[test]
    fn dispatch_round_robins_and_fails_over() {
        let (tx0, rx0) = sync_channel::<Job>(1);
        let (tx1, rx1) = sync_channel::<Job>(1);
        let txs = vec![tx0, tx1];
        let rr = AtomicUsize::new(0);
        // first two jobs land on alternating loops
        assert!(matches!(dispatch(&txs, &rr, dummy_job().0), Dispatch::Sent));
        assert!(matches!(dispatch(&txs, &rr, dummy_job().0), Dispatch::Sent));
        assert!(rx0.try_recv().is_ok(), "loop 0 got the first job");
        assert!(rx1.try_recv().is_ok(), "loop 1 got the second job");
        // fill loop 0's slot: the next job targeting it fails over to 1
        assert!(matches!(dispatch(&txs, &rr, dummy_job().0), Dispatch::Sent));
        assert!(matches!(dispatch(&txs, &rr, dummy_job().0), Dispatch::Sent));
        // both slots now full: backpressure, not an error — and the job
        // comes back so the caller can defuse its reply
        assert!(matches!(dispatch(&txs, &rr, dummy_job().0), Dispatch::Full(_)));
        drop(rx0);
        drop(rx1);
        // all receivers gone: shutdown, not backpressure
        assert!(matches!(dispatch(&txs, &rr, dummy_job().0), Dispatch::Disconnected(_)));
    }

    #[test]
    fn bounced_job_comes_back_with_a_live_reply() {
        let (tx, _rx) = sync_channel::<Job>(1);
        let txs = vec![tx];
        let rr = AtomicUsize::new(0);
        assert!(matches!(dispatch(&txs, &rr, dummy_job().0), Dispatch::Sent));
        let (job, rrx) = dummy_job();
        match dispatch(&txs, &rr, job) {
            // the returned reply is the same one the caller built: only
            // dropping (or defusing) it disconnects the receiver
            Dispatch::Full(bounced) => {
                assert!(matches!(rrx.try_recv(), Err(std::sync::mpsc::TryRecvError::Empty)));
                bounced.reply.defuse();
                assert!(rrx.recv().is_err(), "defused channel reply disconnects");
            }
            _ => panic!("one-slot queue with a parked job must bounce Full"),
        }
    }

    #[test]
    fn dispatch_skips_a_dead_loop_while_one_survives() {
        let (tx0, rx0) = sync_channel::<Job>(1);
        let (tx1, _rx1_keepalive) = sync_channel::<Job>(4);
        drop(rx0); // replica 0 died (fatal model error)
        let txs = vec![tx0, tx1];
        let rr = AtomicUsize::new(0); // cursor points at the dead loop
        for _ in 0..3 {
            assert!(
                matches!(dispatch(&txs, &rr, dummy_job().0), Dispatch::Sent),
                "the surviving replica keeps serving"
            );
        }
    }
}
