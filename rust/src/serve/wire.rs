//! Wire format of the `capsim serve` socket protocol.
//!
//! Every message is one **frame**: a `u32` little-endian payload length
//! followed by that many payload bytes. Payloads are tag-prefixed binary
//! (requests `0x01..`, responses `0x81..`), all integers little-endian,
//! `f64` values as IEEE-754 bit patterns — the same fixed-width LE
//! conventions as the clip-cache file format, so the protocol stays
//! dependency-free and bit-exact across client and server.
//!
//! Decoding is defensive: a frame longer than [`MAX_FRAME`] is refused
//! before allocation, element counts are checked against the bytes
//! actually present before any `Vec` is sized from them, and trailing
//! bytes after a complete message are an error (they would mean the
//! peers disagree about the format).

use std::io::{Read, Write};

use anyhow::{bail, ensure, Result};

/// Upper bound on a frame payload (16 MiB) — far above any real request
/// (a max-geometry predict batch is a few hundred KiB) but small enough
/// that a corrupt length prefix cannot drive a huge allocation.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// `Request::Predict` flag: route the request through the server's
/// persistent clip cache (lookups before inference, inserts after).
pub const FLAG_USE_CACHE: u8 = 1;

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> std::io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME as usize);
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one length-prefixed frame; refuses oversized lengths before
/// allocating.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Vec<u8>> {
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let n = u32::from_le_bytes(b4);
    if n > MAX_FRAME {
        return Err(oversized(n));
    }
    let mut buf = vec![0u8; n as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn oversized(n: u32) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::InvalidData,
        format!("frame of {n} bytes exceeds the {MAX_FRAME} byte cap"),
    )
}

/// Incremental frame decoder for non-blocking sockets: feed whatever
/// bytes arrived, pop complete frames. Resumable at **any** byte
/// boundary — a frame split mid-header or mid-payload just waits for
/// more bytes — and bit-identical to repeated [`read_frame`] calls over
/// the same stream (the property suite in `tests/prop_wire_codec.rs`
/// pins this). An oversized length prefix is refused the moment the
/// 4-byte header is visible, before any payload allocation, with the
/// same [`MAX_FRAME`] cap as the blocking path.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Bytes fed but not yet popped as complete frames.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Payload length of the frame at the front of the buffer: `None`
    /// while the header is still partial, an error past [`MAX_FRAME`].
    fn front_len(&self) -> std::io::Result<Option<usize>> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let n = u32::from_le_bytes(self.buf[..4].try_into().unwrap());
        if n > MAX_FRAME {
            return Err(oversized(n));
        }
        Ok(Some(n as usize))
    }

    /// Append bytes read off the socket. Errors as soon as the front
    /// frame's header announces an oversized payload — the connection is
    /// already unframed at that point and must be dropped.
    pub fn feed(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.buf.extend_from_slice(bytes);
        self.front_len().map(|_| ())
    }

    /// Pop the next complete frame payload, `None` while incomplete. A
    /// later frame's corrupt header only becomes visible (and refused)
    /// once it reaches the front, exactly like sequential [`read_frame`]
    /// calls would encounter it.
    pub fn pop(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        let n = match self.front_len()? {
            Some(n) => n,
            None => return Ok(None),
        };
        if self.buf.len() < 4 + n {
            return Ok(None);
        }
        let frame = self.buf[4..4 + n].to_vec();
        self.buf.drain(..4 + n);
        Ok(Some(frame))
    }
}

/// One clip as it crosses the wire: the caller-chosen content key plus
/// the tokenized clip body (`len` instructions × `l_token` tokens) and
/// its register-context row. The server validates every field against
/// the loaded model's geometry before admission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireClip {
    pub key: u64,
    pub len: u16,
    pub tokens: Vec<u16>,
    pub ctx: Vec<u16>,
}

/// Client → server messages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Predict the time of each clip, in order.
    Predict { flags: u8, clips: Vec<WireClip> },
    /// Snapshot the server counters.
    Stats,
    /// Drain in-flight work, save the cache, and exit.
    Shutdown,
}

/// Per-predict-loop forward counters: one entry per replica in
/// [`StatsReply::per_loop`], in loop-spawn order. The global
/// `predicted_clips`/`batches`/`cross_batches` are the sums of these,
/// so the per-loop view shows whether the replicas actually share load
/// (and what fill each one achieves) without changing any aggregate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoopStats {
    /// Forward batches this loop executed.
    pub batches: u64,
    /// Clip rows this loop sent through the model.
    pub predicted_clips: u64,
    /// Batches mixing clips from more than one request.
    pub cross_batches: u64,
}

impl LoopStats {
    /// Mean live rows per forward batch on this loop (0 when none ran).
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.predicted_clips as f64 / self.batches as f64
        }
    }
}

/// Server counters as reported over the wire (`serve --stats`) and in
/// the post-run [`ServeSummary`](super::ServeSummary).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StatsReply {
    /// Predict requests admitted for counting (including cache-only ones).
    pub requests: u64,
    /// Predict requests bounced with [`Response::Busy`].
    pub rejected: u64,
    /// Clip rows sent through the model (cache hits excluded).
    pub predicted_clips: u64,
    /// Forward batches executed.
    pub batches: u64,
    /// Batches that mixed clips from more than one request.
    pub cross_batches: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_len: u64,
    pub cache_evictions: u64,
    /// Entries served by the read-only mmap-frozen tier (0 when the
    /// cache is heap-resident or cold).
    pub cache_frozen_len: u64,
    /// Where the cache contents came from, as
    /// [`CacheSource::code`](crate::coordinator::CacheSource::code):
    /// 0 cold, 1 heap-loaded, 2 mmap-frozen.
    pub cache_source: u64,
    /// Per-replica forward counters, one entry per predict loop. The
    /// global forward counters above are the sums of these.
    pub per_loop: Vec<LoopStats>,
}

impl StatsReply {
    /// Mean live rows per forward batch (0 when none ran). Values above
    /// 1 under concurrent single-clip load are the cross-request
    /// batching working.
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.predicted_clips as f64 / self.batches as f64
        }
    }

    /// Fraction of cache lookups served from the cache (0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.cache_hits + self.cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / lookups as f64
        }
    }
}

/// Server → client messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Predicted clip times, in request order.
    Predictions(Vec<f64>),
    Stats(StatsReply),
    /// The admission queue is full; retry after `retry_ms`.
    Busy { retry_ms: u32, queue_depth: u32 },
    ShutdownAck,
    /// The request was refused (validation failure, shutdown race, …).
    Error(String),
}

const TAG_PREDICT: u8 = 0x01;
const TAG_STATS: u8 = 0x02;
const TAG_SHUTDOWN: u8 = 0x03;
const TAG_PREDICTIONS: u8 = 0x81;
const TAG_STATS_REPLY: u8 = 0x82;
const TAG_BUSY: u8 = 0x83;
const TAG_SHUTDOWN_ACK: u8 = 0x84;
const TAG_ERROR: u8 = 0x85;

/// Bounds-checked little-endian read cursor over a frame payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.remaining() >= n,
            "truncated message: wanted {n} bytes, have {}",
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a `u32` element count and check the remaining bytes can hold
    /// `count * elem_size` — the guard that keeps a forged count from
    /// sizing an allocation the frame cannot back.
    fn count(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            self.remaining() >= n.saturating_mul(elem_size),
            "truncated message: {n} elements of {elem_size} bytes exceed the frame"
        );
        Ok(n)
    }

    fn u16_vec(&mut self) -> Result<Vec<u16>> {
        let n = self.count(2)?;
        (0..n).map(|_| self.u16()).collect()
    }

    fn finish(self) -> Result<()> {
        ensure!(self.remaining() == 0, "{} trailing bytes after message", self.remaining());
        Ok(())
    }
}

fn put_u16s(out: &mut Vec<u8>, xs: &[u16]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Request::Predict { flags, clips } => {
                let mut out = vec![TAG_PREDICT, *flags];
                out.extend_from_slice(&(clips.len() as u32).to_le_bytes());
                for c in clips {
                    out.extend_from_slice(&c.key.to_le_bytes());
                    out.extend_from_slice(&c.len.to_le_bytes());
                    put_u16s(&mut out, &c.tokens);
                    put_u16s(&mut out, &c.ctx);
                }
                out
            }
            Request::Stats => vec![TAG_STATS],
            Request::Shutdown => vec![TAG_SHUTDOWN],
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut c = Cursor::new(buf);
        let req = match c.u8()? {
            TAG_PREDICT => {
                let flags = c.u8()?;
                // a clip is at least key + len + two empty counts
                let n = c.count(8 + 2 + 4 + 4)?;
                let mut clips = Vec::with_capacity(n);
                for _ in 0..n {
                    clips.push(WireClip {
                        key: c.u64()?,
                        len: c.u16()?,
                        tokens: c.u16_vec()?,
                        ctx: c.u16_vec()?,
                    });
                }
                Request::Predict { flags, clips }
            }
            TAG_STATS => Request::Stats,
            TAG_SHUTDOWN => Request::Shutdown,
            t => bail!("unknown request tag 0x{t:02X}"),
        };
        c.finish()?;
        Ok(req)
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Predictions(preds) => {
                let mut out = vec![TAG_PREDICTIONS];
                out.extend_from_slice(&(preds.len() as u32).to_le_bytes());
                for &p in preds {
                    out.extend_from_slice(&p.to_bits().to_le_bytes());
                }
                out
            }
            Response::Stats(s) => {
                let mut out = vec![TAG_STATS_REPLY];
                for v in [
                    s.requests,
                    s.rejected,
                    s.predicted_clips,
                    s.batches,
                    s.cross_batches,
                    s.cache_hits,
                    s.cache_misses,
                    s.cache_len,
                    s.cache_evictions,
                    s.cache_frozen_len,
                    s.cache_source,
                ] {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out.extend_from_slice(&(s.per_loop.len() as u32).to_le_bytes());
                for l in &s.per_loop {
                    out.extend_from_slice(&l.batches.to_le_bytes());
                    out.extend_from_slice(&l.predicted_clips.to_le_bytes());
                    out.extend_from_slice(&l.cross_batches.to_le_bytes());
                }
                out
            }
            Response::Busy { retry_ms, queue_depth } => {
                let mut out = vec![TAG_BUSY];
                out.extend_from_slice(&retry_ms.to_le_bytes());
                out.extend_from_slice(&queue_depth.to_le_bytes());
                out
            }
            Response::ShutdownAck => vec![TAG_SHUTDOWN_ACK],
            Response::Error(msg) => {
                let mut out = vec![TAG_ERROR];
                out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
                out.extend_from_slice(msg.as_bytes());
                out
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Response> {
        let mut c = Cursor::new(buf);
        let resp = match c.u8()? {
            TAG_PREDICTIONS => {
                let n = c.count(8)?;
                let preds = (0..n)
                    .map(|_| Ok(f64::from_bits(c.u64()?)))
                    .collect::<Result<Vec<f64>>>()?;
                Response::Predictions(preds)
            }
            TAG_STATS_REPLY => {
                let mut s = StatsReply {
                    requests: c.u64()?,
                    rejected: c.u64()?,
                    predicted_clips: c.u64()?,
                    batches: c.u64()?,
                    cross_batches: c.u64()?,
                    cache_hits: c.u64()?,
                    cache_misses: c.u64()?,
                    cache_len: c.u64()?,
                    cache_evictions: c.u64()?,
                    cache_frozen_len: c.u64()?,
                    cache_source: c.u64()?,
                    per_loop: Vec::new(),
                };
                let n = c.count(24)?;
                s.per_loop = (0..n)
                    .map(|_| {
                        Ok(LoopStats {
                            batches: c.u64()?,
                            predicted_clips: c.u64()?,
                            cross_batches: c.u64()?,
                        })
                    })
                    .collect::<Result<Vec<LoopStats>>>()?;
                Response::Stats(s)
            }
            TAG_BUSY => Response::Busy { retry_ms: c.u32()?, queue_depth: c.u32()? },
            TAG_SHUTDOWN_ACK => Response::ShutdownAck,
            TAG_ERROR => {
                let n = c.count(1)?;
                Response::Error(String::from_utf8_lossy(c.take(n)?).into_owned())
            }
            t => bail!("unknown response tag 0x{t:02X}"),
        };
        c.finish()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clip(key: u64) -> WireClip {
        WireClip {
            key,
            len: 3,
            tokens: (0..12).map(|t| t as u16 + 1).collect(),
            ctx: vec![7; 5],
        }
    }

    #[test]
    fn request_roundtrip() {
        let reqs = [
            Request::Predict { flags: FLAG_USE_CACHE, clips: vec![clip(1), clip(2)] },
            Request::Predict { flags: 0, clips: vec![] },
            Request::Stats,
            Request::Shutdown,
        ];
        for r in reqs {
            assert_eq!(Request::decode(&r.encode()).unwrap(), r);
        }
    }

    #[test]
    fn response_roundtrip() {
        let stats = StatsReply {
            requests: 10,
            rejected: 2,
            predicted_clips: 40,
            batches: 8,
            cross_batches: 3,
            cache_hits: 5,
            cache_misses: 35,
            cache_len: 35,
            cache_evictions: 1,
            cache_frozen_len: 20,
            cache_source: 2,
            per_loop: vec![
                LoopStats { batches: 5, predicted_clips: 25, cross_batches: 2 },
                LoopStats { batches: 3, predicted_clips: 15, cross_batches: 1 },
            ],
        };
        let resps = [
            Response::Predictions(vec![1.5, -0.25, 1e300]),
            Response::Predictions(vec![]),
            Response::Stats(stats.clone()),
            Response::Stats(StatsReply::default()),
            Response::Busy { retry_ms: 2, queue_depth: 16 },
            Response::ShutdownAck,
            Response::Error("nope".into()),
        ];
        for r in resps {
            assert_eq!(Response::decode(&r.encode()).unwrap(), r);
        }
        assert!((stats.mean_fill() - 5.0).abs() < 1e-12);
        assert!((stats.hit_rate() - 0.125).abs() < 1e-12);
        assert!((stats.per_loop[0].mean_fill() - 5.0).abs() < 1e-12);
        assert_eq!(stats.per_loop.iter().map(|l| l.batches).sum::<u64>(), stats.batches);
    }

    #[test]
    fn truncated_and_trailing_bytes_are_refused() {
        let enc = Request::Predict { flags: 0, clips: vec![clip(9)] }.encode();
        for cut in 1..enc.len() {
            assert!(Request::decode(&enc[..cut]).is_err(), "cut at {cut}");
        }
        let mut long = enc.clone();
        long.push(0);
        assert!(Request::decode(&long).is_err(), "trailing byte");
        // a forged element count cannot size an allocation the frame
        // cannot back
        let mut forged = vec![TAG_PREDICTIONS];
        forged.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Response::decode(&forged).is_err());
        // same guard on the per-loop counter list in a stats reply
        let mut stats = Response::Stats(StatsReply::default()).encode();
        let count_at = stats.len() - 4;
        stats[count_at..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Response::decode(&stats).is_err());
    }

    #[test]
    fn frames_roundtrip_and_cap_oversized_lengths() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        let bad = (MAX_FRAME + 1).to_le_bytes();
        assert!(read_frame(&mut &bad[..]).is_err());
    }

    #[test]
    fn decoder_matches_blocking_reads_at_any_split() {
        let mut stream = Vec::new();
        write_frame(&mut stream, b"hello").unwrap();
        write_frame(&mut stream, b"").unwrap();
        write_frame(&mut stream, &[0xAB; 300]).unwrap();
        // byte-at-a-time feed: the worst-case split
        let mut dec = FrameDecoder::new();
        let mut frames = Vec::new();
        for &b in &stream {
            dec.feed(&[b]).unwrap();
            while let Some(f) = dec.pop().unwrap() {
                frames.push(f);
            }
        }
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], b"hello");
        assert_eq!(frames[1], b"");
        assert_eq!(frames[2], vec![0xAB; 300]);
        assert_eq!(dec.buffered(), 0);
        // whole stream in one feed pops the same frames
        let mut dec = FrameDecoder::new();
        dec.feed(&stream).unwrap();
        for want in &frames {
            assert_eq!(&dec.pop().unwrap().unwrap(), want);
        }
        assert!(dec.pop().unwrap().is_none());
    }

    #[test]
    fn decoder_refuses_oversized_headers_like_read_frame() {
        let mut dec = FrameDecoder::new();
        assert!(dec.feed(&(MAX_FRAME + 1).to_le_bytes()).is_err());
        // behind a valid frame, the bad header is refused once it
        // reaches the front — the valid frame still comes out first
        let mut stream = Vec::new();
        write_frame(&mut stream, b"ok").unwrap();
        stream.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.feed(&stream).unwrap();
        assert_eq!(dec.pop().unwrap().unwrap(), b"ok");
        assert!(dec.pop().is_err());
        // a partial header is just "not yet", never an error
        let mut dec = FrameDecoder::new();
        dec.feed(&[0xFF, 0xFF]).unwrap();
        assert!(dec.pop().unwrap().is_none());
        assert_eq!(dec.buffered(), 2);
    }

    #[test]
    fn unknown_tags_are_refused() {
        assert!(Request::decode(&[0x7F]).is_err());
        assert!(Response::decode(&[0x01]).is_err(), "request tag is not a response");
        assert!(Request::decode(&[]).is_err(), "empty payload");
    }
}
