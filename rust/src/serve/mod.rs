//! `capsim serve` — the long-lived prediction daemon.
//!
//! A CAPSim deployment that re-runs the CLI per query pays the weight
//! load, cache warm-up, and workspace allocation on every call. The
//! daemon pays them **once**: weights load through the same
//! [`runtime::Backend`](crate::runtime::Backend) registry the CLI uses,
//! a persistent [`ClipCache`](crate::coordinator::ClipCache) and one
//! [`BatchRunner`](crate::predictor::BatchRunner) live for the process,
//! and clients submit clips over a small length-prefixed socket protocol
//! ([`wire`]).
//!
//! The piece that makes a *shared* daemon worthwhile is cross-request
//! batching ([`server`]): every client's cache-missing clips feed a
//! [`BatchAccumulator`](crate::predictor::BatchAccumulator) — the same
//! type the suite engine fills across benchmark boundaries — so
//! concurrent small requests ride full forward batches instead of each
//! paying a padded one. Row-local backends make this invisible in the
//! answers: predictions are bit-identical to single-shot runs, whatever
//! the batch mix.
//!
//! The daemon is three tiers. The **session layer** owns client
//! connections: a readiness-driven epoll event loop ([`event`], the
//! Linux default — one thread for every socket) or the portable
//! thread-per-connection fallback, selected by [`SessionLayer`]
//! (`--session-layer` / `serve.session_layer`). The **replica
//! dispatch** tier scales the predict side (`--predict-loops N`): N
//! replicated predict loops pull from the bounded admission tier, each
//! with a private accumulator and [`BatchRunner`] state. Underneath sit
//! the **shared weights and cache**: one read-only weight set and one
//! concurrent clip cache serve every replica. Row-locality does the
//! correctness work at every tier — which session layer, which replica,
//! and which batch mix serve a clip can never change its bits, proved
//! by the `serve_e2e` invariance matrix over session layers × replica
//! counts. [`StatsReply::per_loop`] reports each replica's batch/fill
//! counters so load sharing is observable.
//!
//! [`client`] is the matching client plus the deterministic burst-load
//! harness (bounded worker pool — hundreds of logical clients without
//! hundreds of threads) used by the e2e tests, the CI smoke job, and
//! the Fig.-7 latency table.

pub mod client;
mod event;
pub mod server;
pub mod wire;

pub use client::{burst, synthetic_clips, BurstReport, BurstSpec, Client, PredictOutcome};
pub use server::{retry_hint_ms, Server, ServeOptions, ServeSummary, SessionLayer, MAX_LINGER_US};
pub use wire::{
    FrameDecoder, LoopStats, Request, Response, StatsReply, WireClip, FLAG_USE_CACHE, MAX_FRAME,
};
