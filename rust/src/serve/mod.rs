//! `capsim serve` — the long-lived prediction daemon.
//!
//! A CAPSim deployment that re-runs the CLI per query pays the weight
//! load, cache warm-up, and workspace allocation on every call. The
//! daemon pays them **once**: weights load through the same
//! [`runtime::Backend`](crate::runtime::Backend) registry the CLI uses,
//! a persistent [`ClipCache`](crate::coordinator::ClipCache) and one
//! [`BatchRunner`](crate::predictor::BatchRunner) live for the process,
//! and clients submit clips over a small length-prefixed socket protocol
//! ([`wire`]).
//!
//! The piece that makes a *shared* daemon worthwhile is cross-request
//! batching ([`server`]): every client's cache-missing clips feed one
//! [`BatchAccumulator`](crate::predictor::BatchAccumulator) — the same
//! type the suite engine fills across benchmark boundaries — so
//! concurrent small requests ride full forward batches instead of each
//! paying a padded one. Row-local backends make this invisible in the
//! answers: predictions are bit-identical to single-shot runs, whatever
//! the batch mix.
//!
//! [`client`] is the matching client plus the deterministic burst-load
//! harness used by the e2e tests, the CI smoke job, and the Fig.-7
//! latency table.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{burst, synthetic_clips, BurstReport, BurstSpec, Client, PredictOutcome};
pub use server::{Server, ServeOptions, ServeSummary};
pub use wire::{Request, Response, StatsReply, WireClip, FLAG_USE_CACHE, MAX_FRAME};
