//! Client side of the serve protocol, plus the burst-load harness the
//! CI smoke job and the Fig.-7 latency bench drive.

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::dataset::ClipSample;
use crate::runtime::{fingerprint_mix, ModelGeometry};
use crate::util::{stats, Rng};

use super::wire::{read_frame, write_frame, Request, Response, StatsReply, WireClip, FLAG_USE_CACHE};

/// The two normal outcomes of one predict round-trip: `Busy` is
/// backpressure, not failure — retry after the server's hint.
#[derive(Debug)]
pub enum PredictOutcome {
    Predictions(Vec<f64>),
    Busy { retry_ms: u32 },
}

/// One connection to a running `capsim serve` daemon.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<Client> {
        let stream =
            TcpStream::connect(&addr).with_context(|| format!("connecting to {addr:?}"))?;
        Ok(Client { stream })
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &req.encode()).context("sending request")?;
        let frame = read_frame(&mut self.stream).context("reading reply")?;
        Response::decode(&frame)
    }

    /// One predict round-trip; see [`PredictOutcome`].
    pub fn predict(
        &mut self,
        clips: &[(u64, ClipSample)],
        use_cache: bool,
    ) -> Result<PredictOutcome> {
        let wire: Vec<WireClip> = clips
            .iter()
            .map(|(k, s)| WireClip {
                key: *k,
                len: s.len,
                tokens: s.tokens.clone(),
                ctx: s.ctx.clone(),
            })
            .collect();
        let flags = if use_cache { FLAG_USE_CACHE } else { 0 };
        match self.roundtrip(&Request::Predict { flags, clips: wire })? {
            Response::Predictions(p) => {
                ensure!(
                    p.len() == clips.len(),
                    "expected {} predictions, got {}",
                    clips.len(),
                    p.len()
                );
                Ok(PredictOutcome::Predictions(p))
            }
            Response::Busy { retry_ms, .. } => Ok(PredictOutcome::Busy { retry_ms }),
            Response::Error(e) => bail!("server refused the request: {e}"),
            other => bail!("unexpected reply to predict: {other:?}"),
        }
    }

    /// Predict, honoring `Busy` retry hints up to `max_retries` times.
    /// Returns the predictions and how many retries were needed.
    pub fn predict_retry(
        &mut self,
        clips: &[(u64, ClipSample)],
        use_cache: bool,
        max_retries: usize,
    ) -> Result<(Vec<f64>, usize)> {
        for attempt in 0..=max_retries {
            match self.predict(clips, use_cache)? {
                PredictOutcome::Predictions(p) => return Ok((p, attempt)),
                PredictOutcome::Busy { retry_ms } => {
                    std::thread::sleep(Duration::from_millis(retry_ms.max(1) as u64));
                }
            }
        }
        bail!("server still busy after {max_retries} retries")
    }

    pub fn stats(&mut self) -> Result<StatsReply> {
        match self.roundtrip(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected reply to stats: {other:?}"),
        }
    }

    /// Ask the daemon to drain and exit.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.roundtrip(&Request::Shutdown)? {
            Response::ShutdownAck => Ok(()),
            other => bail!("unexpected reply to shutdown: {other:?}"),
        }
    }
}

/// Shape of a burst-load run: `clients` concurrent connections each
/// sending `requests` requests of `clips` clips, driven by a bounded
/// pool of `workers` OS threads (`0` = auto).
#[derive(Clone, Copy, Debug)]
pub struct BurstSpec {
    /// Logical clients — concurrent *connections*, not threads.
    pub clients: usize,
    pub requests: usize,
    pub clips: usize,
    pub use_cache: bool,
    pub seed: u64,
    /// Worker threads multiplexing the logical clients (`--workers`,
    /// `0` = auto: up to 16, never more than `clients`).
    pub workers: usize,
}

impl Default for BurstSpec {
    fn default() -> BurstSpec {
        BurstSpec { clients: 4, requests: 25, clips: 6, use_cache: true, seed: 0x5EED, workers: 0 }
    }
}

/// Per-request latencies plus the server's counter snapshot after the
/// burst — the raw material of the Fig.-7 p50/p99-per-concurrency table.
#[derive(Debug)]
pub struct BurstReport {
    pub latencies_s: Vec<f64>,
    /// Total `Busy` bounces the clients absorbed (each then retried).
    pub busy_retries: usize,
    pub stats: StatsReply,
}

impl BurstReport {
    pub fn p50_ms(&self) -> f64 {
        stats::percentile(&self.latencies_s, 50.0) * 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        stats::percentile(&self.latencies_s, 99.0) * 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        stats::mean(&self.latencies_s) * 1e3
    }
}

/// Deterministic geometry-valid clips for load generation: every
/// `(seed, client, request, i)` combination yields the same clip on
/// every machine, and distinct combinations yield distinct keys.
pub fn synthetic_clips(
    seed: u64,
    client: u64,
    request: u64,
    n: usize,
    g: &ModelGeometry,
) -> Vec<(u64, ClipSample)> {
    (0..n as u64)
        .map(|i| {
            let mut h = fingerprint_mix(0xCBF2_9CE4_8422_2325, seed);
            for v in [client, request, i] {
                h = fingerprint_mix(h, v);
            }
            let mut rng = Rng::new(h);
            let len = 1 + rng.below(g.l_clip as u64) as u16;
            let tokens: Vec<u16> = (0..len as usize * g.l_token)
                .map(|_| 1 + rng.below(g.vocab_size as u64 - 1) as u16)
                .collect();
            let ctx: Vec<u16> =
                (0..g.m_rows).map(|_| rng.below(g.vocab_size as u64) as u16).collect();
            let key = fingerprint_mix(h, rng.next_u64());
            (key, ClipSample { tokens, len, ctx, time: 1.0, key, bench: 0 })
        })
        .collect()
}

/// Fire one burst at a running daemon and collect per-request latency.
/// The logical clients are multiplexed over a bounded worker pool: each
/// worker owns the clients `c ≡ w (mod workers)`, opens **all** their
/// connections up front and holds them for the whole burst (so the
/// daemon really sees `clients` concurrent connections — `--clients
/// 256` exercises a 256-socket session table), then round-robins their
/// requests. One thread per logical client used to make the harness hit
/// the thread ceiling before the daemon did. Requests retry through
/// `Busy` bounces and latency includes those retries (it is what a
/// caller actually waits); the deterministic clip streams depend only
/// on `(seed, client, request)`, so the worker count never changes what
/// is sent. Round-robining means each held connection idles for its
/// siblings' request times between its own — against a daemon with a
/// short `--idle-timeout-ms` the reaper can close it mid-burst, so a
/// failed request reconnects once (latency then includes the
/// reconnect) before giving up.
pub fn burst(addr: SocketAddr, g: &ModelGeometry, spec: &BurstSpec) -> Result<BurstReport> {
    let workers = match spec.workers {
        0 => spec.clients.clamp(1, 16),
        w => w.min(spec.clients.max(1)),
    };
    let mut latencies: Vec<f64> = Vec::with_capacity(spec.clients * spec.requests);
    let mut busy_retries = 0usize;
    std::thread::scope(|s| -> Result<()> {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                s.spawn(move || -> Result<(Vec<f64>, usize)> {
                    let mine: Vec<usize> = (w..spec.clients).step_by(workers).collect();
                    let mut conns = Vec::with_capacity(mine.len());
                    for &c in &mine {
                        conns.push((c as u64, Client::connect(addr)?));
                    }
                    let mut lats = Vec::with_capacity(mine.len() * spec.requests);
                    let mut retries = 0usize;
                    for r in 0..spec.requests {
                        for (c, client) in conns.iter_mut() {
                            let clips = synthetic_clips(spec.seed, *c, r as u64, spec.clips, g);
                            let t0 = Instant::now();
                            let (_preds, n_retry) =
                                match client.predict_retry(&clips, spec.use_cache, 10_000) {
                                    Ok(done) => done,
                                    Err(_) => {
                                        // the daemon's idle reaper can close a
                                        // held connection while the worker is
                                        // busy with its siblings; one fresh
                                        // connection, then fail for real
                                        *client = Client::connect(addr)?;
                                        client.predict_retry(&clips, spec.use_cache, 10_000)?
                                    }
                                };
                            lats.push(t0.elapsed().as_secs_f64());
                            retries += n_retry;
                        }
                    }
                    Ok((lats, retries))
                })
            })
            .collect();
        for h in handles {
            let (lats, retries) = h.join().expect("burst worker thread panicked")?;
            latencies.extend(lats);
            busy_retries += retries;
        }
        Ok(())
    })?;
    let stats = Client::connect(addr)?.stats()?;
    Ok(BurstReport { latencies_s: latencies, busy_retries, stats })
}
