//! The epoll session layer: **one thread owns every client socket**.
//!
//! Where the threaded layer spends an OS thread per connection blocked
//! in `read_frame`, this loop keeps all sockets non-blocking, sleeps in
//! [`Poller::wait`], and advances whichever connection the kernel says
//! is ready: bytes read feed the connection's incremental
//! [`FrameDecoder`], complete requests dispatch into the same
//! round-robin replica queues the threaded layer uses, and replies
//! accumulate in a per-connection outbox that flushes on writability.
//! Predict replies cross back from the replica threads through
//! [`Completions`] — a mutex'd queue plus the poller's eventfd waker.
//!
//! **Semantics are the threaded layer's, exactly.** Requests on one
//! connection are served strictly in order (frame processing is gated
//! while a predict is in flight, mirroring the threaded session's
//! blocking reply wait), `Busy`/`Error` answers and counter updates are
//! the same code paths ([`dispatch`], [`convert`], [`snapshot`]), and
//! shutdown mirrors the drain: stop accepting, keep serving live
//! connections, exit when the last one closes — dropping the job
//! senders then drains every replica's tail. Row-locality already
//! guarantees the predict tier is mix-invariant, so the only thing this
//! layer could get wrong is framing or ordering; `tests/serve_e2e.rs`
//! pins bit-equality against the threaded layer and
//! `tests/prop_wire_codec.rs` pins the codec against the blocking
//! reader.
//!
//! Interest management is level-triggered and explicit: read interest
//! is dropped while a request is in flight (no busy-wake on bytes we
//! will not decode yet), write interest exists only while the outbox
//! has unsent bytes. Hangup is a drain, not an instant close — the
//! kernel may still hold request bytes past a FIN/RST, and the
//! threaded layer reads until the socket actually fails — so the read
//! side is drained, buffered requests are served, and the fd is
//! deregistered (HUP ignores the interest mask) until the reply lands
//! or the flush fails. Idle connections (no traffic for
//! `idle_timeout_ms`, nothing in flight) are reaped on a timeout
//! derived from the nearest deadline, so a half-open client costs one
//! table entry for a bounded time instead of a thread forever.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::util::epoll::Waker;

/// Completed predictions crossing from the predict loops back into the
/// event loop. `None` means the replica died before answering — the
/// event-layer mirror of the threaded session's disconnected reply
/// channel, surfaced to the client as the same `Error` response.
pub(super) struct Completions {
    queue: Mutex<VecDeque<(u64, Option<Vec<f64>>)>>,
    waker: Waker,
}

impl Completions {
    pub(super) fn new(waker: Waker) -> Completions {
        Completions { queue: Mutex::new(VecDeque::new()), waker }
    }

    /// Called from predict-loop threads (via `ReplyTo`): enqueue and
    /// poke the event loop awake.
    pub(super) fn push(&self, conn: u64, preds: Option<Vec<f64>>) {
        self.queue.lock().expect("completion queue poisoned").push_back((conn, preds));
        self.waker.wake();
    }

    fn drain(&self) -> VecDeque<(u64, Option<Vec<f64>>)> {
        std::mem::take(&mut *self.queue.lock().expect("completion queue poisoned"))
    }
}

/// Everything the event loop shares with the rest of the daemon — the
/// same set the threaded `session` receives, plus the completion queue.
pub(super) struct Ctx<'a> {
    pub txs: Vec<std::sync::mpsc::SyncSender<super::server::Job>>,
    pub rr: &'a std::sync::atomic::AtomicUsize,
    pub g: crate::runtime::ModelGeometry,
    pub cache: &'a crate::coordinator::ClipCache,
    pub counters: &'a super::server::Counters,
    pub shutdown: &'a std::sync::atomic::AtomicBool,
    pub retry_ms: u32,
    pub queue_depth: usize,
    pub idle: Option<std::time::Duration>,
    pub completions: std::sync::Arc<Completions>,
}

#[cfg(unix)]
pub(super) use imp::run;

#[cfg(not(unix))]
pub(super) fn run(
    _listener: std::net::TcpListener,
    _poller: crate::util::epoll::Poller,
    _ctx: Ctx<'_>,
) -> anyhow::Result<()> {
    // Unreachable in practice: `Poller::new` already failed on any host
    // that would land here, and `SessionLayer::resolve` refuses first.
    anyhow::bail!("the epoll session layer is unsupported on this platform")
}

#[cfg(unix)]
mod imp {
    use std::collections::HashMap;
    use std::io::{ErrorKind, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use anyhow::{Context as _, Result};

    use crate::util::epoll::{Event, Poller};

    use super::super::server::{convert, dispatch, snapshot, Dispatch, Job, ReplyTo};
    use super::super::wire::{FrameDecoder, Request, Response, FLAG_USE_CACHE};
    use super::Ctx;

    /// Token the listener is registered under. Connection tokens count
    /// up from 0 and are never reused, so a stale readiness event after
    /// a close can only miss the table, never hit a new connection.
    const LISTENER_TOKEN: u64 = u64::MAX - 1;

    struct Conn {
        stream: TcpStream,
        decoder: FrameDecoder,
        outbox: Vec<u8>,
        out_pos: usize,
        last_activity: Instant,
        /// A predict is queued or batching; frame processing is gated
        /// until its reply lands (per-connection request order).
        inflight: bool,
        /// Close once the outbox drains (shutdown ack, fatal response).
        closing: bool,
        /// Peer sent EOF; serve what is buffered, then close.
        peer_eof: bool,
        /// Still in the poller's interest table. Cleared on hangup —
        /// the fd is deregistered early because `EPOLLHUP`/`EPOLLERR`
        /// are reported regardless of interest and would busy-wake the
        /// loop while an in-flight reply is still being computed.
        registered: bool,
        want_read: bool,
        want_write: bool,
    }

    impl Conn {
        fn new(stream: TcpStream) -> Conn {
            Conn {
                stream,
                decoder: FrameDecoder::new(),
                outbox: Vec::new(),
                out_pos: 0,
                last_activity: Instant::now(),
                inflight: false,
                closing: false,
                peer_eof: false,
                registered: true,
                want_read: true,
                want_write: false,
            }
        }

        fn outbox_drained(&self) -> bool {
            self.out_pos == self.outbox.len()
        }
    }

    /// Append one response as a wire frame to the connection's outbox.
    fn push_frame(outbox: &mut Vec<u8>, payload: &[u8]) {
        outbox.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        outbox.extend_from_slice(payload);
    }

    /// Serve until shutdown is requested **and** the last live
    /// connection closes (the threaded layer's drain ordering), then
    /// return — dropping `ctx.txs` is what lets the replicas drain.
    pub(in super::super) fn run(
        listener: TcpListener,
        mut poller: Poller,
        ctx: Ctx<'_>,
    ) -> Result<()> {
        listener.set_nonblocking(true).context("non-blocking listener")?;
        poller
            .add(listener.as_raw_fd(), LISTENER_TOKEN, true, false)
            .context("registering the listener")?;
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut next_token: u64 = 0;
        let mut events: Vec<Event> = Vec::new();
        loop {
            let timeout = reap_idle(&mut conns, &poller, ctx.idle);
            // Checked after reaping: if the reaper just closed the last
            // connection during shutdown, nothing would ever wake the
            // poll again.
            if ctx.shutdown.load(Ordering::SeqCst) && conns.is_empty() {
                break;
            }
            events.clear();
            poller.wait(&mut events, timeout).context("epoll wait")?;
            // Completions first: a reply both fills an outbox and
            // un-gates the connection's next buffered request.
            for (token, preds) in ctx.completions.drain() {
                let found = match conns.get_mut(&token) {
                    // Bounced jobs are defused at dispatch, so a
                    // completion for a live token always answers its one
                    // in-flight request; the `inflight` guard is pure
                    // defense (tokens are never reused, so a completion
                    // racing a close can only miss the table).
                    Some(conn) if conn.inflight => {
                        conn.inflight = false;
                        conn.last_activity = Instant::now();
                        let resp = match preds {
                            Some(p) => Response::Predictions(p),
                            None => Response::Error("predictor dropped the request".into()),
                        };
                        push_frame(&mut conn.outbox, &resp.encode());
                        true
                    }
                    _ => false,
                };
                if found {
                    step_conn(token, &mut conns, &poller, &ctx);
                }
            }
            for ev in events.iter().copied() {
                if ev.token == LISTENER_TOKEN {
                    accept_ready(&listener, &poller, &mut conns, &mut next_token, &ctx);
                } else {
                    socket_ready(ev, &mut conns, &poller, &ctx);
                }
            }
        }
        Ok(())
    }

    /// Drain the accept queue. During shutdown new connections are
    /// accepted and immediately dropped — the exact behavior of the
    /// threaded acceptor's post-shutdown poke, and what turns a fatal
    /// replica's `connect(addr)` poke into a loop wakeup.
    fn accept_ready(
        listener: &TcpListener,
        poller: &Poller,
        conns: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
        ctx: &Ctx<'_>,
    ) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if ctx.shutdown.load(Ordering::SeqCst) {
                        continue; // accepted and dropped
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let token = *next_token;
                    *next_token += 1;
                    if poller.add(stream.as_raw_fd(), token, true, false).is_ok() {
                        conns.insert(token, Conn::new(stream));
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Kernel readiness on one connection: pull bytes on readable, then
    /// let `step_conn` decode/dispatch/flush. Hangup (`EPOLLHUP` /
    /// `EPOLLERR`) is not an immediate close: the kernel may still hold
    /// request bytes past a FIN/RST, and the threaded layer reads until
    /// the socket actually fails — so drain the read side first, serve
    /// what was buffered, and let the (best-effort) outbox flush or the
    /// drained/peer-EOF check in `step_conn` retire the connection.
    fn socket_ready(ev: Event, conns: &mut HashMap<u64, Conn>, poller: &Poller, ctx: &Ctx<'_>) {
        let token = ev.token;
        let mut dead = false;
        if let Some(conn) = conns.get_mut(&token) {
            if ev.readable || ev.hangup {
                let mut buf = [0u8; 16 * 1024];
                loop {
                    match conn.stream.read(&mut buf) {
                        Ok(0) => {
                            conn.peer_eof = true;
                            break;
                        }
                        Ok(n) => {
                            conn.last_activity = Instant::now();
                            if conn.decoder.feed(&buf[..n]).is_err() {
                                // poisoned length prefix: the threaded
                                // layer also just drops the connection
                                dead = true;
                                break;
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => {
                            dead = true;
                            break;
                        }
                    }
                }
            }
            if ev.hangup && !dead {
                // Nothing more will arrive; deregister now (HUP/ERR
                // ignore the interest mask, so a registered fd would
                // wake every poll until an in-flight reply lands) and
                // let `step_conn` serve the buffered tail.
                conn.peer_eof = true;
                if conn.registered {
                    conn.registered = false;
                    let _ = poller.delete(conn.stream.as_raw_fd());
                }
            }
        } else {
            return; // stale event for an already-closed connection
        }
        if dead {
            close_conn(token, conns, poller);
        } else {
            step_conn(token, conns, poller, ctx);
        }
    }

    /// Advance one connection after any state change: decode buffered
    /// frames while the ordering gate allows, flush the outbox,
    /// recompute poll interest, close when finished or broken.
    fn step_conn(token: u64, conns: &mut HashMap<u64, Conn>, poller: &Poller, ctx: &Ctx<'_>) {
        let mut dead = false;
        if let Some(conn) = conns.get_mut(&token) {
            while !dead && !conn.inflight && !conn.closing {
                match conn.decoder.pop() {
                    Ok(Some(frame)) => handle_frame(token, conn, &frame, ctx),
                    Ok(None) => break,
                    Err(_) => dead = true,
                }
            }
            if !dead {
                dead = flush_outbox(conn).is_err();
            }
            if !dead
                && conn.outbox_drained()
                && (conn.closing
                    || (conn.peer_eof && !conn.inflight && conn.decoder.buffered() == 0))
            {
                dead = true;
            }
            if !dead && conn.registered {
                let want_read = !conn.inflight && !conn.closing && !conn.peer_eof;
                let want_write = !conn.outbox_drained();
                if (want_read, want_write) != (conn.want_read, conn.want_write) {
                    conn.want_read = want_read;
                    conn.want_write = want_write;
                    dead = poller
                        .modify(conn.stream.as_raw_fd(), token, want_read, want_write)
                        .is_err();
                }
            }
        }
        if dead {
            close_conn(token, conns, poller);
        }
    }

    /// One complete request frame — the same decode → dispatch → respond
    /// sequence as the threaded `session`, with the outbox standing in
    /// for the blocking `write_frame`.
    fn handle_frame(token: u64, conn: &mut Conn, frame: &[u8], ctx: &Ctx<'_>) {
        conn.last_activity = Instant::now();
        let req = match Request::decode(frame) {
            Ok(r) => r,
            Err(e) => {
                let resp = Response::Error(format!("bad request: {e}"));
                push_frame(&mut conn.outbox, &resp.encode());
                conn.closing = true; // threaded layer ends the session here too
                return;
            }
        };
        let resp = match req {
            Request::Stats => Response::Stats(snapshot(ctx.counters, ctx.cache)),
            Request::Shutdown => {
                ctx.shutdown.store(true, Ordering::SeqCst);
                conn.closing = true;
                Response::ShutdownAck
            }
            Request::Predict { flags, clips } => match convert(&clips, &ctx.g) {
                Err(e) => Response::Error(format!("invalid clips: {e}")),
                Ok(converted) => {
                    ctx.counters.requests.fetch_add(1, Ordering::Relaxed);
                    if converted.is_empty() {
                        Response::Predictions(Vec::new())
                    } else {
                        let use_cache = flags & FLAG_USE_CACHE != 0;
                        let reply = ReplyTo::event(token, Arc::clone(&ctx.completions));
                        match dispatch(&ctx.txs, ctx.rr, Job { clips: converted, use_cache, reply })
                        {
                            Dispatch::Sent => {
                                conn.inflight = true;
                                return; // reply arrives through Completions
                            }
                            Dispatch::Full(bounced) => {
                                // Defused, not dropped: a drop-side `None`
                                // completion here could be consumed as the
                                // reply to this connection's *next*
                                // pipelined request if one dispatches
                                // before the completion queue drains —
                                // leaving every later reply off by one.
                                bounced.reply.defuse();
                                ctx.counters.rejected.fetch_add(1, Ordering::Relaxed);
                                Response::Busy {
                                    retry_ms: ctx.retry_ms,
                                    queue_depth: ctx.queue_depth as u32,
                                }
                            }
                            Dispatch::Disconnected(bounced) => {
                                bounced.reply.defuse();
                                Response::Error("server is shutting down".into())
                            }
                        }
                    }
                }
            },
        };
        push_frame(&mut conn.outbox, &resp.encode());
    }

    /// Write as much of the outbox as the socket accepts right now.
    /// `Err` means the connection is broken.
    fn flush_outbox(conn: &mut Conn) -> std::io::Result<()> {
        while conn.out_pos < conn.outbox.len() {
            match conn.stream.write(&conn.outbox[conn.out_pos..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => {
                    conn.out_pos += n;
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if conn.outbox_drained() {
            conn.outbox.clear();
            conn.out_pos = 0;
        }
        Ok(())
    }

    fn close_conn(token: u64, conns: &mut HashMap<u64, Conn>, poller: &Poller) {
        if let Some(conn) = conns.remove(&token) {
            // Closing the fd deregisters it anyway; explicit delete keeps
            // the table and the interest set in lockstep.
            if conn.registered {
                let _ = poller.delete(conn.stream.as_raw_fd());
            }
        }
    }

    /// Reap connections idle past the deadline and return the time to
    /// the nearest remaining deadline as the poll timeout. In-flight
    /// connections are waiting on the predict tier, not idle — they are
    /// exempt until their reply lands (which refreshes the clock).
    fn reap_idle(
        conns: &mut HashMap<u64, Conn>,
        poller: &Poller,
        idle: Option<Duration>,
    ) -> Option<Duration> {
        let idle = idle?;
        let now = Instant::now();
        let mut next: Option<Duration> = None;
        let mut expired: Vec<u64> = Vec::new();
        for (&token, conn) in conns.iter() {
            if conn.inflight {
                continue;
            }
            let age = now.duration_since(conn.last_activity);
            if age >= idle {
                expired.push(token);
            } else {
                let left = idle - age;
                next = Some(next.map_or(left, |n| n.min(left)));
            }
        }
        for token in expired {
            close_conn(token, conns, poller);
        }
        next
    }
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use crate::serve::server::ReplyTo;
    use crate::util::epoll::Poller;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn completions_wake_the_poller_and_drain_in_order() {
        let mut poller = Poller::new().unwrap();
        let completions = Arc::new(Completions::new(poller.waker()));
        let c2 = Arc::clone(&completions);
        let t = std::thread::spawn(move || {
            c2.push(1, Some(vec![1.0]));
            c2.push(2, None);
        });
        t.join().unwrap();
        let mut evs = Vec::new();
        poller.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
        let drained: Vec<_> = completions.drain().into();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0], (1, Some(vec![1.0])));
        assert_eq!(drained[1], (2, None));
        assert!(completions.drain().is_empty());
    }

    #[test]
    fn dropping_an_event_reply_delivers_an_explicit_failure() {
        let poller = Poller::new().unwrap();
        let completions = Arc::new(Completions::new(poller.waker()));
        let reply = ReplyTo::event(42, Arc::clone(&completions));
        drop(reply); // replica died before answering
        let drained = completions.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0], (42, None), "the connection must learn, not hang");
    }

    /// Regression: a job bounced at admission (`Busy`) must leave the
    /// completion queue untouched once defused. Before the defuse, the
    /// drop-side `(conn, None)` could be consumed as the reply to the
    /// connection's *next* pipelined request dispatched ahead of the
    /// drain, putting every later reply on that connection off by one.
    #[test]
    fn bounced_event_reply_defuses_to_no_stale_completion() {
        use crate::serve::server::{dispatch, Dispatch, Job};
        use std::sync::atomic::AtomicUsize;
        use std::sync::mpsc::sync_channel;

        let poller = Poller::new().unwrap();
        let completions = Arc::new(Completions::new(poller.waker()));
        let (tx, _rx) = sync_channel::<Job>(1);
        let txs = vec![tx];
        let rr = AtomicUsize::new(0);
        let park = Job {
            clips: Vec::new(),
            use_cache: false,
            reply: ReplyTo::event(7, Arc::clone(&completions)),
        };
        assert!(matches!(dispatch(&txs, &rr, park), Dispatch::Sent));
        let bounce = Job {
            clips: Vec::new(),
            use_cache: false,
            reply: ReplyTo::event(7, Arc::clone(&completions)),
        };
        match dispatch(&txs, &rr, bounce) {
            Dispatch::Full(job) => job.reply.defuse(),
            _ => panic!("one-slot queue with a parked job must bounce Full"),
        }
        assert!(
            completions.drain().is_empty(),
            "a defused bounce must not fabricate a completion"
        );
    }
}
