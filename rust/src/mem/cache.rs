//! Set-associative cache with LRU replacement and write-back/write-allocate
//! policy — the building block of the L1I/L1D/L2 hierarchy.
//!
//! The model is a *timing* cache: it tracks tags and dirty bits (to charge
//! write-back traffic) but holds no data — the functional simulator owns the
//! actual bytes. This matches the gem5-classic split the paper relies on.

/// Geometry + latency of one cache level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub line_bytes: usize,
    /// Hit latency in cycles (charged on every access that hits this level).
    pub hit_latency: u64,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.ways * self.line_bytes)
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp: larger = more recently used.
    lru: u64,
}

/// Result of a cache lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LookupResult {
    pub hit: bool,
    /// A dirty line was evicted (charge a write-back to the next level).
    pub writeback: bool,
    /// Address of the evicted victim line, if any.
    pub victim: Option<u64>,
}

/// Access statistics for one level.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub accesses: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl CacheStats {
    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// One set-associative cache level.
#[derive(Clone, Debug)]
pub struct Cache {
    cfg: CacheConfig,
    lines: Vec<Line>, // sets * ways
    set_mask: u64,
    line_shift: u32,
    clock: u64,
    pub stats: CacheStats,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!(cfg.line_bytes.is_power_of_two());
        Cache {
            cfg,
            lines: vec![Line::default(); sets * cfg.ways],
            set_mask: sets as u64 - 1,
            line_shift: cfg.line_bytes.trailing_zeros(),
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    #[inline]
    fn index(&self, addr: u64) -> (usize, u64) {
        let block = addr >> self.line_shift;
        ((block & self.set_mask) as usize, block >> self.cfg.sets().trailing_zeros())
    }

    /// Access `addr`; on miss, allocate (write-allocate) and report the
    /// victim. `is_write` marks the line dirty.
    pub fn access(&mut self, addr: u64, is_write: bool) -> LookupResult {
        self.clock += 1;
        self.stats.accesses += 1;
        let (set, tag) = self.index(addr);
        let base = set * self.cfg.ways;
        let ways = &mut self.lines[base..base + self.cfg.ways];

        // hit?
        for line in ways.iter_mut() {
            if line.valid && line.tag == tag {
                line.lru = self.clock;
                if is_write {
                    line.dirty = true;
                }
                return LookupResult { hit: true, writeback: false, victim: None };
            }
        }

        // miss: pick LRU victim
        self.stats.misses += 1;
        let victim_way = ways
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| if l.valid { l.lru } else { 0 })
            .map(|(i, _)| i)
            .unwrap();
        let victim = &mut ways[victim_way];
        let mut writeback = false;
        let mut victim_addr = None;
        if victim.valid {
            let sets_bits = self.set_mask.count_ones();
            let block = (victim.tag << sets_bits) | set as u64;
            victim_addr = Some(block << self.line_shift);
            if victim.dirty {
                writeback = true;
                self.stats.writebacks += 1;
            }
        }
        *victim = Line { tag, valid: true, dirty: is_write, lru: self.clock };
        LookupResult { hit: false, writeback, victim: victim_addr }
    }

    /// Non-allocating probe (used by tests and warmup statistics).
    pub fn probe(&self, addr: u64) -> bool {
        let (set, tag) = self.index(addr);
        let base = set * self.cfg.ways;
        self.lines[base..base + self.cfg.ways]
            .iter()
            .any(|l| l.valid && l.tag == tag)
    }

    /// Invalidate everything (checkpoint-restore starts cold, like gem5).
    pub fn flush(&mut self) {
        for l in &mut self.lines {
            *l = Line::default();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        // 4 sets x 2 ways x 64B = 512B
        Cache::new(CacheConfig { size_bytes: 512, ways: 2, line_bytes: 64, hit_latency: 2 })
    }

    #[test]
    fn geometry() {
        let c = small();
        assert_eq!(c.config().sets(), 4);
    }

    #[test]
    fn first_access_misses_then_hits() {
        let mut c = small();
        assert!(!c.access(0x100, false).hit);
        assert!(c.access(0x100, false).hit);
        assert!(c.access(0x13F, false).hit, "same 64B line");
        assert!(!c.access(0x140, false).hit, "next line");
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = small();
        // set 0 lines: addresses with block % 4 == 0
        let a = 0x0000; // set 0
        let b = 0x0100; // set 0 (block 4)
        let d = 0x0200; // set 0 (block 8)
        c.access(a, false);
        c.access(b, false);
        c.access(a, false); // a more recent than b
        let r = c.access(d, false); // evicts b
        assert!(!r.hit);
        assert!(c.probe(a));
        assert!(!c.probe(b));
        assert!(c.probe(d));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = small();
        c.access(0x0000, true); // dirty
        c.access(0x0100, false);
        let r = c.access(0x0200, false); // evicts 0x0000
        assert!(r.writeback);
        assert_eq!(r.victim, Some(0x0000));
        assert_eq!(c.stats.writebacks, 1);
    }

    #[test]
    fn clean_eviction_no_writeback() {
        let mut c = small();
        c.access(0x0000, false);
        c.access(0x0100, false);
        let r = c.access(0x0200, false);
        assert!(!r.writeback);
        assert_eq!(r.victim, Some(0x0000));
    }

    #[test]
    fn flush_invalidates() {
        let mut c = small();
        c.access(0x40, false);
        assert!(c.probe(0x40));
        c.flush();
        assert!(!c.probe(0x40));
    }

    #[test]
    fn stats_track_miss_rate() {
        let mut c = small();
        c.access(0, false);
        c.access(0, false);
        c.access(0, false);
        c.access(64, false);
        assert_eq!(c.stats.accesses, 4);
        assert_eq!(c.stats.misses, 2);
        assert!((c.stats.miss_rate() - 0.5).abs() < 1e-12);
    }
}
