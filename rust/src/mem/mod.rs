//! Memory subsystem: sparse paged physical memory ([`Memory`]) plus the
//! cache hierarchy ([`hierarchy::CacheHierarchy`]) the O3 model queries for
//! access latencies (L1I / L1D / unified L2 / DRAM).

pub mod cache;
pub mod hierarchy;
pub mod paged;

pub use cache::{Cache, CacheConfig};
pub use hierarchy::{Access, CacheHierarchy, HierarchyConfig, LevelStats};
pub use paged::Memory;
