//! The cache hierarchy the O3 model charges memory latencies against:
//! split L1I / L1D backed by a unified L2 backed by fixed-latency DRAM —
//! the classic configuration the paper's gem5 Power8 model uses.

use super::cache::{Cache, CacheConfig, CacheStats};

/// What kind of access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    InstFetch,
    Load,
    Store,
}

/// Full hierarchy configuration.
#[derive(Clone, Copy, Debug)]
pub struct HierarchyConfig {
    pub l1i: CacheConfig,
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    /// DRAM access latency in cycles (charged on L2 miss).
    pub dram_latency: u64,
}

impl Default for HierarchyConfig {
    /// Power8-flavoured defaults (scaled; see DESIGN.md):
    /// 32 KiB 8-way L1I/L1D (2-cycle), 256 KiB 8-way L2 (12-cycle),
    /// 80-cycle DRAM.
    fn default() -> Self {
        HierarchyConfig {
            l1i: CacheConfig { size_bytes: 32 * 1024, ways: 8, line_bytes: 64, hit_latency: 2 },
            l1d: CacheConfig { size_bytes: 32 * 1024, ways: 8, line_bytes: 64, hit_latency: 2 },
            l2: CacheConfig { size_bytes: 256 * 1024, ways: 8, line_bytes: 64, hit_latency: 12 },
            dram_latency: 80,
        }
    }
}

/// Per-level statistics snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct LevelStats {
    pub l1i: CacheStats,
    pub l1d: CacheStats,
    pub l2: CacheStats,
    pub dram_accesses: u64,
}

/// The hierarchy. `access()` returns the total latency of the access and
/// updates all touched levels.
#[derive(Clone, Debug)]
pub struct CacheHierarchy {
    cfg: HierarchyConfig,
    l1i: Cache,
    l1d: Cache,
    l2: Cache,
    dram_accesses: u64,
}

impl CacheHierarchy {
    pub fn new(cfg: HierarchyConfig) -> Self {
        CacheHierarchy {
            cfg,
            l1i: Cache::new(cfg.l1i),
            l1d: Cache::new(cfg.l1d),
            l2: Cache::new(cfg.l2),
            dram_accesses: 0,
        }
    }

    pub fn config(&self) -> &HierarchyConfig {
        &self.cfg
    }

    /// Perform a timed access; returns latency in cycles.
    pub fn access(&mut self, kind: Access, addr: u64) -> u64 {
        let is_write = kind == Access::Store;
        let (l1, l1_latency) = match kind {
            Access::InstFetch => (&mut self.l1i, self.cfg.l1i.hit_latency),
            _ => (&mut self.l1d, self.cfg.l1d.hit_latency),
        };
        let r1 = l1.access(addr, is_write);
        if r1.hit {
            return l1_latency;
        }
        // L1 miss -> L2 (write-back of the L1 victim also goes to L2 but is
        // off the critical path; we account its occupancy, not its latency)
        if let Some(victim) = r1.victim {
            if r1.writeback {
                self.l2.access(victim, true);
            }
        }
        let r2 = self.l2.access(addr, is_write && false); // fill is clean; dirtiness tracked in L1
        let mut latency = l1_latency + self.cfg.l2.hit_latency;
        if !r2.hit {
            if r2.writeback {
                self.dram_accesses += 1; // L2 victim write-back to DRAM
            }
            self.dram_accesses += 1;
            latency += self.cfg.dram_latency;
        }
        latency
    }

    /// Cold-start (checkpoint restore begins with empty caches, as in the
    /// paper's gem5 restore flow; the warm-up interval re-warms them).
    pub fn flush(&mut self) {
        self.l1i.flush();
        self.l1d.flush();
        self.l2.flush();
    }

    pub fn stats(&self) -> LevelStats {
        LevelStats {
            l1i: self.l1i.stats,
            l1d: self.l1d.stats,
            l2: self.l2.stats,
            dram_accesses: self.dram_accesses,
        }
    }
}

impl Default for CacheHierarchy {
    fn default() -> Self {
        Self::new(HierarchyConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CacheHierarchy {
        CacheHierarchy::new(HierarchyConfig {
            l1i: CacheConfig { size_bytes: 256, ways: 2, line_bytes: 64, hit_latency: 1 },
            l1d: CacheConfig { size_bytes: 256, ways: 2, line_bytes: 64, hit_latency: 2 },
            l2: CacheConfig { size_bytes: 1024, ways: 4, line_bytes: 64, hit_latency: 10 },
            dram_latency: 100,
        })
    }

    #[test]
    fn cold_miss_pays_full_path() {
        let mut h = tiny();
        assert_eq!(h.access(Access::Load, 0x1000), 2 + 10 + 100);
        // now L1D-hot
        assert_eq!(h.access(Access::Load, 0x1000), 2);
    }

    #[test]
    fn l2_hit_after_l1_eviction() {
        let mut h = tiny();
        h.access(Access::Load, 0x0000);
        // fill enough L1D set-0 lines to evict 0x0000 (sets=2, ways=2)
        h.access(Access::Load, 0x0080);
        h.access(Access::Load, 0x0100);
        // 0x0000 should now be L1-miss but L2-hit
        let lat = h.access(Access::Load, 0x0000);
        assert_eq!(lat, 2 + 10);
    }

    #[test]
    fn icache_and_dcache_are_split() {
        let mut h = tiny();
        h.access(Access::InstFetch, 0x2000);
        // same line via data port must still miss L1D (but hit L2)
        let lat = h.access(Access::Load, 0x2000);
        assert_eq!(lat, 2 + 10);
        let s = h.stats();
        assert_eq!(s.l1i.accesses, 1);
        assert_eq!(s.l1d.accesses, 1);
    }

    #[test]
    fn flush_forces_cold_misses() {
        let mut h = tiny();
        h.access(Access::Load, 0x3000);
        h.flush();
        assert_eq!(h.access(Access::Load, 0x3000), 2 + 10 + 100);
    }

    #[test]
    fn dram_counter_counts_l2_misses() {
        let mut h = tiny();
        h.access(Access::Load, 0x0);
        h.access(Access::Load, 0x10000);
        assert_eq!(h.stats().dram_accesses, 2);
        h.access(Access::Load, 0x0);
        assert_eq!(h.stats().dram_accesses, 2);
    }
}
