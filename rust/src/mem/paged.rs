//! Sparse paged memory. Benchmarks touch a few MB scattered across a 64-bit
//! address space; 4 KiB pages in a hash map keep checkpoints cheap to clone
//! (the simpoint module snapshots memory by cloning this structure).

use std::collections::HashMap;

pub const PAGE_SHIFT: u32 = 12;
pub const PAGE_SIZE: usize = 1 << PAGE_SHIFT;

/// Sparse byte-addressable memory; unmapped bytes read as zero.
#[derive(Clone, Debug, Default)]
pub struct Memory {
    pages: HashMap<u64, Box<[u8; PAGE_SIZE]>>,
}

impl Memory {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn page_of(addr: u64) -> (u64, usize) {
        (addr >> PAGE_SHIFT, (addr & (PAGE_SIZE as u64 - 1)) as usize)
    }

    #[inline]
    pub fn read_u8(&self, addr: u64) -> u8 {
        let (p, off) = Self::page_of(addr);
        self.pages.get(&p).map_or(0, |pg| pg[off])
    }

    #[inline]
    pub fn write_u8(&mut self, addr: u64, val: u8) {
        let (p, off) = Self::page_of(addr);
        self.pages
            .entry(p)
            .or_insert_with(|| Box::new([0u8; PAGE_SIZE]))[off] = val;
    }

    /// Read `n <= 8` bytes little-endian. The fast path stays within one
    /// page (the common case — PISA accesses are naturally aligned in the
    /// workloads, but misaligned crossings are still correct).
    #[inline]
    pub fn read_le(&self, addr: u64, n: usize) -> u64 {
        debug_assert!(n <= 8);
        let (p, off) = Self::page_of(addr);
        if off + n <= PAGE_SIZE {
            if let Some(pg) = self.pages.get(&p) {
                let mut buf = [0u8; 8];
                buf[..n].copy_from_slice(&pg[off..off + n]);
                return u64::from_le_bytes(buf);
            }
            return 0;
        }
        let mut v: u64 = 0;
        for i in 0..n {
            v |= (self.read_u8(addr + i as u64) as u64) << (8 * i);
        }
        v
    }

    /// Write `n <= 8` bytes little-endian.
    #[inline]
    pub fn write_le(&mut self, addr: u64, n: usize, val: u64) {
        debug_assert!(n <= 8);
        let (p, off) = Self::page_of(addr);
        if off + n <= PAGE_SIZE {
            let pg = self
                .pages
                .entry(p)
                .or_insert_with(|| Box::new([0u8; PAGE_SIZE]));
            pg[off..off + n].copy_from_slice(&val.to_le_bytes()[..n]);
            return;
        }
        for i in 0..n {
            self.write_u8(addr + i as u64, (val >> (8 * i)) as u8);
        }
    }

    pub fn read_u32(&self, addr: u64) -> u32 {
        self.read_le(addr, 4) as u32
    }
    pub fn write_u32(&mut self, addr: u64, val: u32) {
        self.write_le(addr, 4, val as u64);
    }
    pub fn read_u64(&self, addr: u64) -> u64 {
        self.read_le(addr, 8)
    }
    pub fn write_u64(&mut self, addr: u64, val: u64) {
        self.write_le(addr, 8, val);
    }
    pub fn read_f64(&self, addr: u64) -> f64 {
        f64::from_bits(self.read_u64(addr))
    }
    pub fn write_f64(&mut self, addr: u64, val: f64) {
        self.write_u64(addr, val.to_bits());
    }

    /// Bulk write (program loading).
    pub fn write_bytes(&mut self, addr: u64, bytes: &[u8]) {
        for (i, b) in bytes.iter().enumerate() {
            self.write_u8(addr + i as u64, *b);
        }
    }

    /// Number of mapped pages (footprint metric).
    pub fn mapped_pages(&self) -> usize {
        self.pages.len()
    }

    /// Deterministic digest of the *logical* memory contents (FNV-1a over
    /// mapped pages in ascending address order). All-zero pages are
    /// skipped, so two memories that read identically digest identically
    /// even if one mapped a page it only ever wrote zeroes to. Used by the
    /// differential tests to compare architectural state across execution
    /// paths without materializing byte-level diffs.
    pub fn digest(&self) -> u64 {
        let mut ids: Vec<u64> = self.pages.keys().copied().collect();
        ids.sort_unstable();
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        for id in ids {
            let pg = &self.pages[&id];
            if pg.iter().all(|&b| b == 0) {
                continue;
            }
            mix(id);
            for chunk in pg.chunks(8) {
                let mut word = [0u8; 8];
                word[..chunk.len()].copy_from_slice(chunk);
                mix(u64::from_le_bytes(word));
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn zero_initialized() {
        let m = Memory::new();
        assert_eq!(m.read_u64(0xDEAD_BEEF), 0);
        assert_eq!(m.read_u8(u64::MAX), 0);
    }

    #[test]
    fn rw_roundtrip_widths() {
        let mut m = Memory::new();
        m.write_u8(10, 0xAB);
        assert_eq!(m.read_u8(10), 0xAB);
        m.write_u32(100, 0xDEADBEEF);
        assert_eq!(m.read_u32(100), 0xDEADBEEF);
        m.write_u64(200, 0x0123_4567_89AB_CDEF);
        assert_eq!(m.read_u64(200), 0x0123_4567_89AB_CDEF);
        m.write_f64(300, -2.75);
        assert_eq!(m.read_f64(300), -2.75);
    }

    #[test]
    fn little_endian_layout() {
        let mut m = Memory::new();
        m.write_u32(0, 0x0403_0201);
        assert_eq!(m.read_u8(0), 1);
        assert_eq!(m.read_u8(3), 4);
    }

    #[test]
    fn cross_page_access() {
        let mut m = Memory::new();
        let addr = PAGE_SIZE as u64 - 3;
        m.write_u64(addr, 0x1122_3344_5566_7788);
        assert_eq!(m.read_u64(addr), 0x1122_3344_5566_7788);
        assert_eq!(m.mapped_pages(), 2);
    }

    #[test]
    fn prop_rw_roundtrip_random() {
        prop::check(
            "memory rw roundtrip",
            128,
            |r| (r.next_u64() >> 20, r.next_u64(), 1 + r.range(0, 8)),
            |(addr, val, n)| {
                let mut m = Memory::new();
                m.write_le(*addr, *n, *val);
                let mask = if *n == 8 { u64::MAX } else { (1u64 << (8 * n)) - 1 };
                m.read_le(*addr, *n) == val & mask
            },
        );
    }

    #[test]
    fn digest_tracks_logical_contents() {
        let mut a = Memory::new();
        let mut b = Memory::new();
        assert_eq!(a.digest(), b.digest(), "two empty memories");
        a.write_u64(0x1000, 7);
        assert_ne!(a.digest(), b.digest());
        b.write_u64(0x1000, 7);
        assert_eq!(a.digest(), b.digest(), "identical contents");
        // an all-zero mapped page is logically empty
        a.write_u64(0x9000, 0);
        assert_eq!(a.digest(), b.digest(), "zero page ignored");
        a.write_u8(0x1000, 8);
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn clone_is_snapshot() {
        let mut m = Memory::new();
        m.write_u64(64, 7);
        let snap = m.clone();
        m.write_u64(64, 9);
        assert_eq!(snap.read_u64(64), 7);
        assert_eq!(m.read_u64(64), 9);
    }
}
