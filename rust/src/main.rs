//! `capsim` — the command-line launcher.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline crate set):
//!
//! ```text
//! capsim table1                      print Table I (context registers)
//! capsim table2 [--config F]        print Table II (suite, checkpoints)
//! capsim trace  --bench N [--max M] trace a benchmark functionally
//! capsim o3     --bench N           cycle-level stats for a benchmark
//! capsim dataset --out F [--config F] build + save the golden dataset
//! capsim train  [--steps N] [--variant V] train a predictor end-to-end
//! capsim compare [--config F]       Fig.-7 style gem5 vs CAPSim timing
//! capsim serve  [--listen A] [--linger-us N] [--predict-loops N]
//!               [--session-layer L] run the prediction daemon
//!               (--stats / --shutdown query a running daemon instead)
//! capsim burst  [--listen A] [--clients N] [--workers N]
//!               fire a client burst at a daemon
//! capsim backends                   CPU features, kernel tiers, backends
//! capsim info                       artifact manifest summary
//! ```

use std::collections::HashMap;
use std::net::{SocketAddr, ToSocketAddrs};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use capsim::config::PipelineConfig;
use capsim::coordinator::{build_dataset, capsim_mode, gem5_mode, ClipCache};
use capsim::functional::AtomicCpu;
use capsim::o3::O3Core;
use capsim::predictor::{train, TrainParams};
use capsim::report::Table;
use capsim::runtime::{cpu_features, Backend, KernelTier, Predictor, Runtime};
use capsim::serve::{BurstSpec, Client, Server, ServeOptions, SessionLayer, MAX_LINGER_US};
use capsim::util::stats;
use capsim::workloads::{suite, Scale};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(key.to_string(), val);
        }
        i += 1;
    }
    m
}

fn load_config(flags: &HashMap<String, String>) -> Result<PipelineConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => PipelineConfig::load(Path::new(path))
            .map_err(|e| anyhow!("config {path}: {e}"))?,
        None => PipelineConfig::default(),
    };
    if flags.contains_key("full") {
        cfg.scale = Scale::Full;
    }
    if let Some(v) = flags.get("threads") {
        let t: i64 = v
            .parse()
            .map_err(|_| anyhow!("--threads expects an integer, got {v}"))?;
        // negative means auto, matching the pipeline.threads TOML handling
        cfg.threads = t.max(0) as usize;
    }
    if let Some(v) = flags.get("queue-depth") {
        let d: i64 = v
            .parse()
            .map_err(|_| anyhow!("--queue-depth expects an integer, got {v}"))?;
        cfg.queue_depth = d.max(0) as usize;
    }
    if let Some(v) = flags.get("batch-depth") {
        let d: i64 = v
            .parse()
            .map_err(|_| anyhow!("--batch-depth expects an integer, got {v}"))?;
        cfg.batch_depth = d.max(0) as usize;
    }
    if let Some(dir) = flags.get("cache-dir") {
        cfg.cache_dir = dir.clone();
    }
    if let Some(v) = flags.get("cache-max-entries") {
        let n: i64 = v
            .parse()
            .map_err(|_| anyhow!("--cache-max-entries expects an integer, got {v}"))?;
        // 0 (or negative) disables the bound
        cfg.cache_max_entries = n.max(0) as usize;
    }
    if flags.contains_key("cache-heap") {
        cfg.cache_mmap = false;
    }
    // backend selection: --backend is the registry flag; --native survives
    // as a deprecating alias (and loses to an explicit --backend)
    if let Some(name) = flags.get("backend") {
        cfg.backend = name.parse()?;
    } else if flags.contains_key("native") {
        eprintln!(
            "warning: the --native flag is deprecated and will be removed; \
             use `--backend native` instead"
        );
        cfg.backend = Backend::Native;
    }
    // kernel tier: the CLI flag is strict (a typo should not silently
    // fall back to auto-detection the way an unknown TOML value does)
    if let Some(v) = flags.get("kernel-tier") {
        cfg.kernel_tier = v.parse()?;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);

    match cmd {
        "table1" => table1(),
        "table2" => table2(&flags)?,
        "trace" => trace_cmd(&flags)?,
        "o3" => o3_cmd(&flags)?,
        "dataset" => dataset_cmd(&flags)?,
        "train" => train_cmd(&flags)?,
        "compare" => compare_cmd(&flags)?,
        "serve" => serve_cmd(&flags)?,
        "burst" => burst_cmd(&flags)?,
        "backends" => backends_cmd(&flags)?,
        "info" => info_cmd(&flags)?,
        _ => help(),
    }
    Ok(())
}

fn help() {
    println!(
        "capsim — attention-based CPU performance simulator\n\
         usage: capsim <table1|table2|trace|o3|dataset|train|compare|serve|burst|backends|info>\n\
         flags: --config FILE  --bench N  --max M  --steps N  --variant V  --out F\n\
                --full  --threads N (0 = auto; precedence: --threads >\n\
                pipeline.threads > CAPSIM_THREADS env > core count)\n\
                --queue-depth N / --batch-depth N (streaming engine channel\n\
                capacities, 0 = auto)\n\
                --cache-dir DIR (persist the clip cache across runs, keyed by\n\
                model fingerprint + time_scale; mismatches cold-start)\n\
                --cache-max-entries N (bound the clip cache; oldest-inserted\n\
                entries are evicted; 0 = unbounded)\n\
                --cache-heap (copy a warm-start image onto the heap instead\n\
                of serving from the mmap-frozen view; pipeline.cache_mmap)\n\
                --backend B (pjrt | native | attention; pjrt needs\n\
                `make artifacts`, native/attention are dependency-free —\n\
                attention runs the pure-Rust model)\n\
                --native (deprecated alias for --backend native)\n\
                --kernel-tier T (auto | scalar | avx2 | neon; precedence:\n\
                --kernel-tier > pipeline.kernel_tier > CAPSIM_KERNEL_TIER\n\
                env > auto-detect; all tiers are bit-identical — see\n\
                `capsim backends` for what this host supports)\n\
         serve:  --listen ADDR (default 127.0.0.1:4650 / serve.listen TOML;\n\
                port 0 picks a free port)\n\
                --linger-us N (how long a partial batch waits for more\n\
                requests before flushing; default 2000 / serve.linger_us;\n\
                capped at 60s)\n\
                --predict-loops N (replicated predict loops over one shared\n\
                read-only weight set; 0 = auto / serve.predict_loops;\n\
                row-locality keeps answers bit-identical for every N)\n\
                --session-layer L (auto | epoll | threads; auto picks the\n\
                epoll event loop on Linux, one thread per connection\n\
                elsewhere / serve.session_layer; bit-identical either way)\n\
                --idle-timeout-ms N (reap a connection after N ms without\n\
                traffic; 0 = never / serve.idle_timeout_ms; default 60000)\n\
                --queue-depth N (admission bound, split across the loops;\n\
                overload answers Busy + retry hint), --cache-dir DIR\n\
                (persistent clip cache, saved on graceful shutdown),\n\
                --time-scale X (cache key part)\n\
                --stats / --shutdown (query or stop a *running* daemon)\n\
         burst:  --listen ADDR  --clients N  --requests N  --clips N\n\
                --workers N (worker threads multiplexing the logical\n\
                clients; 0 = auto)  --seed N  --no-cache\n\
                --expect-cross-batch (fail unless batches mixed requests)\n\
                --shutdown (stop the daemon after)"
    );
}

fn table1() {
    let mut t = Table::new(
        "Table I — registers used in the context matrix",
        &["Register", "ValueTokens", "Description"],
    );
    for r in capsim::context::REGISTER_SPEC {
        let name = capsim::tokenizer::Vocab::name(capsim::tokenizer::Vocab::reg(r.name()));
        let desc = match r {
            capsim::context::CtxReg::Gpr(_) => "general purpose register",
            capsim::context::CtxReg::Fpr(_) => "floating point register (VSR role)",
            capsim::context::CtxReg::Cr => "condition register",
            capsim::context::CtxReg::Lr => "link register",
            capsim::context::CtxReg::Ctr => "count register",
            capsim::context::CtxReg::Xer => "fixed point exception register",
            capsim::context::CtxReg::Cia => "current instruction address",
            capsim::context::CtxReg::Nia => "next instruction address",
        };
        t.row(vec![name, "8".into(), desc.into()]);
    }
    t.emit("table1");
}

fn table2(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let benches = suite(cfg.scale);
    let (_, profiles) =
        build_dataset(&benches, &cfg, cfg.effective_threads());
    let mut t = Table::new(
        "Table II — benchmarks, tags, sets, checkpoints",
        &["Name", "CKP Num", "Tag", "Set No.", "Intervals", "Insts"],
    );
    for (b, p) in benches.iter().zip(&profiles) {
        t.row(vec![
            b.name.into(),
            p.selected.len().to_string(),
            p.tag_string.clone(),
            b.set_no.to_string(),
            p.n_intervals.to_string(),
            p.total_insts.to_string(),
        ]);
    }
    t.emit("table2");
    Ok(())
}

fn bench_arg(
    flags: &HashMap<String, String>,
    benches: &[capsim::workloads::Benchmark],
) -> Result<usize> {
    let sel = flags.get("bench").context("--bench <index|name> required")?;
    if let Ok(i) = sel.parse::<usize>() {
        if i < benches.len() {
            return Ok(i);
        }
        bail!("bench index {i} out of range (0..{})", benches.len());
    }
    benches
        .iter()
        .position(|b| b.name == sel.as_str() || b.name.ends_with(sel.as_str()))
        .ok_or_else(|| anyhow!("unknown benchmark {sel}"))
}

fn trace_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let benches = suite(cfg.scale);
    let i = bench_arg(flags, &benches)?;
    let max: u64 = flags.get("max").and_then(|v| v.parse().ok()).unwrap_or(20);
    let mut cpu = AtomicCpu::load(&benches[i].program);
    let trace = cpu.run_trace(max);
    println!("# {} — first {} instructions", benches[i].name, trace.len());
    for r in &trace {
        println!(
            "{:#08x}: {:<24}{}",
            r.pc,
            capsim::isa::disasm::disasm(&r.inst),
            r.mem_addr
                .map(|a| format!(" [mem {a:#x}]"))
                .unwrap_or_default()
        );
    }
    Ok(())
}

fn o3_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let benches = suite(cfg.scale);
    let i = bench_arg(flags, &benches)?;
    let max: u64 = flags
        .get("max")
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000);
    let mut cpu = AtomicCpu::load(&benches[i].program);
    let trace = cpu.run_trace(max);
    let mut core = O3Core::new(cfg.o3.clone());
    let r = core.simulate(&trace);
    println!("# {} — O3 timing over {} insts", benches[i].name, trace.len());
    println!("cycles          {}", r.stats.cycles);
    println!("IPC             {:.3}", r.stats.ipc());
    println!("branches        {}", r.stats.branches);
    println!(
        "mispredict rate {:.2}%",
        100.0 * r.stats.mispredicts as f64 / r.stats.branches.max(1) as f64
    );
    println!("icache stalls   {}", r.stats.icache_stall_cycles);
    println!("stl forwards    {}", r.stats.stl_forwards);
    Ok(())
}

fn dataset_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let out = flags.get("out").map(String::as_str).unwrap_or("dataset.bin");
    let benches = suite(cfg.scale);
    let (ds, profiles) =
        build_dataset(&benches, &cfg, cfg.effective_threads());
    println!(
        "dataset: {} clips from {} benchmarks ({} dropped long), mean time {:.1} cycles",
        ds.len(),
        profiles.len(),
        ds.dropped_long,
        ds.mean_time()
    );
    ds.save(Path::new(out))?;
    println!("saved {out}");
    Ok(())
}

fn train_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    if cfg.backend != Backend::Pjrt {
        bail!(
            "`capsim train` drives SGD through the AOT train entry points, which only \
             the pjrt backend has; the {} backend is training-free (drop --backend)",
            cfg.backend
        );
    }
    let variant = flags.get("variant").map(String::as_str).unwrap_or("capsim");
    let steps: usize = flags
        .get("steps")
        .and_then(|v| v.parse().ok())
        .unwrap_or(cfg.train_steps);

    let benches = suite(cfg.scale);
    let (ds, _) = build_dataset(&benches, &cfg, cfg.effective_threads());
    println!("dataset: {} clips", ds.len());

    let rt = Runtime::load(Path::new(&cfg.artifacts))?;
    let mut model = rt.load_variant(variant)?;
    model.init_params(cfg.seed as u32)?;

    let (tr, va, te) = ds.split(cfg.seed);
    let log = train(
        &mut model,
        &ds,
        &tr,
        &va,
        &TrainParams { steps, lr: cfg.lr, ..Default::default() },
    )?;
    for (step, loss) in log.smoothed_train(25) {
        println!("step {step:>5}  train-MAPE {loss:.4}");
    }
    let ev = capsim::predictor::evaluate(&model, &ds, &te, log.time_scale)?;
    println!(
        "test: MAPE {:.4}  accuracy {:.1}%  over {} clips",
        ev.mape, ev.accuracy_pct, ev.n
    );
    Ok(())
}

fn compare_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let variant = flags.get("variant").map(String::as_str).unwrap_or("capsim");
    let benches = suite(cfg.scale);
    let (ds, profiles) = build_dataset(&benches, &cfg, cfg.effective_threads());

    // backend via the runtime registry: `pjrt` trains the AOT model
    // first; `native`/`attention` are training-free and dependency-free
    let steps = flags
        .get("steps")
        .and_then(|v| v.parse().ok())
        .unwrap_or(cfg.train_steps);
    let (model, time_scale) = cfg.backend.build_trained(&cfg, &ds, steps, variant)?;
    match model.kernel_tier() {
        Some(t) => println!("backend: {} (kernel tier: {t})", cfg.backend),
        None => println!("backend: {}", cfg.backend),
    }

    // per-benchmark rows use the paper methodology (each benchmark stands
    // alone, no cache) so wall times are order-independent; the engine's
    // cross-benchmark dedup is reported separately below
    let mut t = Table::new(
        "Fig. 7 — restore time: gem5 mode vs CAPSim",
        &["Benchmark", "CKPs", "gem5 s", "CAPSim s", "Speedup", "Err %", "uniq/total"],
    );
    let mut speedups = Vec::new();
    let (mut uniq_total, mut clips_total) = (0usize, 0usize);
    for (b, p) in benches.iter().zip(&profiles) {
        let g = gem5_mode(&p.selected, p.n_intervals, &cfg);
        let c = capsim_mode(
            &p.selected,
            p.n_intervals,
            &cfg,
            model.as_ref(),
            time_scale,
            None,
        )?;
        let speedup = g.wall_s / c.wall_s.max(1e-9);
        let err = 100.0 * (c.total_cycles - g.total_cycles).abs() / g.total_cycles;
        speedups.push(speedup);
        uniq_total += c.clips_unique;
        clips_total += c.clips_total;
        t.row(vec![
            b.name.into(),
            p.selected.len().to_string(),
            format!("{:.3}", g.wall_s),
            format!("{:.3}", c.wall_s),
            format!("{:.2}x", speedup),
            format!("{:.1}", err),
            format!("{}/{}", c.clips_unique, c.clips_total),
        ]);
    }
    t.emit("fig7");
    println!(
        "speedup: mean {:.2}x  max {:.2}x  (threads = {})",
        stats::mean(&speedups),
        speedups.iter().cloned().fold(0.0, f64::max),
        cfg.effective_threads()
    );

    // cross-benchmark engine run through the streaming stage-pipelined
    // engine: one shared cache, scan/predict overlapped, optionally
    // warm-started from (and persisted back to) --cache-dir
    let cache_file = if cfg.cache_dir.is_empty() {
        None
    } else {
        Some(Path::new(&cfg.cache_dir).join("clip_cache.bin"))
    };
    let cache = match &cache_file {
        Some(path) => {
            let (c, warm) = ClipCache::load_or_cold_bounded_with(
                path,
                model.fingerprint(),
                time_scale,
                cfg.cache_max_entries,
                cfg.cache_mmap,
            );
            if warm {
                println!(
                    "warm-started clip cache from {path:?} ({} clips, {})",
                    c.len(),
                    c.source().label()
                );
            } else {
                println!("no usable clip cache at {path:?} (cold start)");
            }
            c
        }
        None => ClipCache::bounded(cfg.cache_max_entries),
    };
    let shared = capsim::coordinator::capsim_suite(
        &profiles,
        &cfg,
        model.as_ref(),
        time_scale,
        &cache,
        capsim::coordinator::SuiteBatching::Streamed,
    )?;
    println!(
        "clip dedup: {clips_total} clip occurrences; per-benchmark dedup predicts \
         {uniq_total}, cross-benchmark cache predicts {} ({} resolved across \
         benchmarks) in {:.3}s",
        shared.clips_unique, shared.cache_hits, shared.wall_s
    );
    if let Some(st) = shared.stages {
        println!(
            "stage overlap: scan {:.3}s + predict {:.3}s in {:.3}s wall ({:.2}x)",
            st.scan_busy_s,
            st.predict_busy_s,
            st.wall_s,
            st.overlap()
        );
    }
    let warm_stats = cache.stats();
    if warm_stats.hits > 0 {
        println!("warm-start hit rate: {}", warm_stats.hit_line());
    }
    if warm_stats.evictions > 0 {
        println!(
            "cache bound: {} entries, {} oldest-inserted clips evicted",
            cfg.cache_max_entries, warm_stats.evictions
        );
    }
    if let Some(path) = &cache_file {
        std::fs::create_dir_all(&cfg.cache_dir)?;
        let saved = cache.save(path, model.fingerprint(), time_scale)?;
        println!("saved clip cache ({saved} clips) to {path:?}");
    }
    Ok(())
}

/// Resolve `--listen` (falling back to the `serve.listen` config key)
/// into a connectable socket address.
fn resolve_addr(flags: &HashMap<String, String>, cfg: &PipelineConfig) -> Result<SocketAddr> {
    let listen = flags
        .get("listen")
        .cloned()
        .unwrap_or_else(|| cfg.serve_listen.clone());
    listen
        .to_socket_addrs()
        .with_context(|| format!("resolving {listen}"))?
        .next()
        .ok_or_else(|| anyhow!("{listen} resolved to no address"))
}

fn serve_opts(flags: &HashMap<String, String>, cfg: &PipelineConfig) -> Result<ServeOptions> {
    let mut cfg = cfg.clone();
    if let Some(v) = flags.get("predict-loops") {
        let n: i64 = v
            .parse()
            .map_err(|_| anyhow!("--predict-loops expects an integer, got {v}"))?;
        // 0 (or negative) means auto, like the serve.predict_loops key
        cfg.serve_predict_loops = n.max(0) as usize;
    }
    let mut opts = ServeOptions {
        listen: flags
            .get("listen")
            .cloned()
            .unwrap_or_else(|| cfg.serve_listen.clone()),
        linger_us: cfg.serve_linger_us,
        queue_depth: cfg.effective_queue_depth(),
        predict_loops: cfg.effective_predict_loops(),
        time_scale: 40.0,
        cache_path: if cfg.cache_dir.is_empty() {
            None
        } else {
            Some(Path::new(&cfg.cache_dir).join("clip_cache.bin"))
        },
        cache_max_entries: cfg.cache_max_entries,
        cache_mmap: cfg.cache_mmap,
        session_layer: cfg.serve_session_layer,
        idle_timeout_ms: cfg.serve_idle_timeout_ms,
    };
    // the CLI flag is strict where the TOML key falls back to auto
    if let Some(v) = flags.get("session-layer") {
        opts.session_layer = SessionLayer::parse(v)
            .ok_or_else(|| anyhow!("--session-layer expects auto|epoll|threads, got {v}"))?;
    }
    if let Some(v) = flags.get("idle-timeout-ms") {
        let n: i64 = v
            .parse()
            .map_err(|_| anyhow!("--idle-timeout-ms expects an integer, got {v}"))?;
        // 0 (or negative) disables idle reaping, like the TOML key
        opts.idle_timeout_ms = n.max(0) as u64;
    }
    if let Some(v) = flags.get("linger-us") {
        opts.linger_us = v
            .parse()
            .map_err(|_| anyhow!("--linger-us expects an integer, got {v}"))?;
    }
    // validate here, at the option edge, so the Busy retry hint derived
    // from the linger can never truncate (the TOML path clamps likewise)
    if opts.linger_us > MAX_LINGER_US {
        eprintln!(
            "warning: --linger-us {} exceeds the {MAX_LINGER_US} us ceiling; clamping",
            opts.linger_us
        );
        opts.linger_us = MAX_LINGER_US;
    }
    if let Some(v) = flags.get("time-scale") {
        opts.time_scale = v
            .parse()
            .map_err(|_| anyhow!("--time-scale expects a number, got {v}"))?;
    }
    Ok(opts)
}

fn print_stats(stats: &capsim::serve::StatsReply) {
    println!(
        "requests {}  rejected {}  batches {}  cross-request batches {}  mean fill {:.2}",
        stats.requests, stats.rejected, stats.batches, stats.cross_batches, stats.mean_fill()
    );
    println!("predicted {} clips through the model", stats.predicted_clips);
    if stats.per_loop.len() > 1 {
        for (i, l) in stats.per_loop.iter().enumerate() {
            println!(
                "predict loop {i}: {} batches, {} clips, mean fill {:.2}, {} cross-request",
                l.batches,
                l.predicted_clips,
                l.mean_fill(),
                l.cross_batches
            );
        }
    }
    println!(
        "cache: {} clips resident ({}, {} mmap-frozen), hit rate {:.1}% \
         ({} hits / {} lookups), {} evictions",
        stats.cache_len,
        capsim::coordinator::CacheSource::from_code(stats.cache_source).label(),
        stats.cache_frozen_len,
        100.0 * stats.hit_rate(),
        stats.cache_hits,
        stats.cache_hits + stats.cache_misses,
        stats.cache_evictions
    );
}

fn serve_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;

    // client modes against a running daemon
    if flags.contains_key("stats") {
        let addr = resolve_addr(flags, &cfg)?;
        let stats = Client::connect(addr)?.stats()?;
        print_stats(&stats);
        return Ok(());
    }
    if flags.contains_key("shutdown") {
        let addr = resolve_addr(flags, &cfg)?;
        Client::connect(addr)?.shutdown()?;
        println!("shutdown acknowledged by {addr}");
        return Ok(());
    }

    if cfg.backend == Backend::Pjrt {
        bail!(
            "`capsim serve` keeps one model resident in-process, which needs a \
             dependency-free backend; pick --backend native or --backend attention"
        );
    }
    // one weight set, shared read-only by every predict-loop replica
    let model = cfg.backend.build_shared(&cfg)?;
    let opts = serve_opts(flags, &cfg)?;
    let (linger_us, queue_depth, predict_loops) =
        (opts.linger_us, opts.queue_depth, opts.predict_loops);
    // resolve for the banner; Server::run re-resolves (and errors
    // cleanly on a forced-but-unavailable layer)
    let session_layer = opts.session_layer.resolve().unwrap_or(opts.session_layer);
    let server = Server::bind(opts)?;
    let tier = model
        .kernel_tier()
        .map(|t| format!(", kernel tier {t}"))
        .unwrap_or_default();
    println!(
        "serving {} predictions on {} (session layer {}, linger {} us, queue depth {}, \
         predict loops {}{tier})",
        cfg.backend,
        server.addr(),
        session_layer,
        linger_us,
        queue_depth,
        predict_loops
    );
    let summary = server.run(model.as_ref())?;
    println!("warm start: {}", summary.warm_start);
    print_stats(&summary.stats);
    match summary.cache_saved {
        Some(n) => println!("saved clip cache ({n} clips)"),
        None => println!("no cache dir configured; nothing persisted"),
    }
    Ok(())
}

fn burst_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let addr = resolve_addr(flags, &cfg)?;
    let int_flag = |key: &str, default: usize| -> Result<usize> {
        match flags.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v}")),
            None => Ok(default),
        }
    };
    let spec = BurstSpec {
        clients: int_flag("clients", 4)?.max(1),
        requests: int_flag("requests", 25)?.max(1),
        clips: int_flag("clips", 6)?.max(1),
        use_cache: !flags.contains_key("no-cache"),
        seed: int_flag("seed", 0x5EED)? as u64,
        // 0 = auto: the pool stays bounded however many logical
        // clients the burst opens
        workers: int_flag("workers", 0)?,
    };
    // load generation uses the default geometry — the one every
    // dependency-free backend serves; the daemon validates each clip
    let g = capsim::runtime::default_geometry();
    let report = capsim::serve::burst(addr, &g, &spec)?;
    println!(
        "{} clients x {} requests x {} clips against {addr}",
        spec.clients, spec.requests, spec.clips
    );
    println!(
        "latency: p50 {:.3} ms  p99 {:.3} ms  mean {:.3} ms  ({} Busy retries absorbed)",
        report.p50_ms(),
        report.p99_ms(),
        report.mean_ms(),
        report.busy_retries
    );
    print_stats(&report.stats);
    if flags.contains_key("expect-cross-batch") {
        if report.stats.cross_batches == 0 || report.stats.mean_fill() <= 1.0 {
            bail!(
                "expected cross-request batching but saw {} cross-request batches \
                 at mean fill {:.2}",
                report.stats.cross_batches,
                report.stats.mean_fill()
            );
        }
        println!("cross-request batching confirmed");
    }
    if flags.contains_key("shutdown") {
        Client::connect(addr)?.shutdown()?;
        println!("shutdown acknowledged by {addr}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::parse_flags;

    #[test]
    fn flags_with_values_and_booleans() {
        let args: Vec<String> = ["--bench", "505.mcf", "--full", "--max", "100"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = parse_flags(&args);
        assert_eq!(f.get("bench").map(String::as_str), Some("505.mcf"));
        assert_eq!(f.get("full").map(String::as_str), Some("true"));
        assert_eq!(f.get("max").map(String::as_str), Some("100"));
    }

    #[test]
    fn empty_args() {
        assert!(parse_flags(&[]).is_empty());
    }

    #[test]
    fn serve_opts_clamps_linger_and_resolves_predict_loops() {
        use std::collections::HashMap;
        let cfg = capsim::config::PipelineConfig::default();
        let mut flags: HashMap<String, String> = HashMap::new();
        // regression for the wrapped retry hint: an absurd --linger-us
        // clamps at the option edge instead of truncating downstream
        flags.insert("linger-us".into(), "999999999999".into());
        flags.insert("predict-loops".into(), "3".into());
        let opts = super::serve_opts(&flags, &cfg).unwrap();
        assert_eq!(opts.linger_us, capsim::serve::MAX_LINGER_US);
        assert_eq!(opts.predict_loops, 3);
        // 0 or negative means auto, which resolves to at least one loop
        flags.insert("predict-loops".into(), "-1".into());
        let opts = super::serve_opts(&flags, &cfg).unwrap();
        assert!((1..=4).contains(&opts.predict_loops));
        flags.insert("predict-loops".into(), "not-a-number".into());
        assert!(super::serve_opts(&flags, &cfg).is_err());
    }

    #[test]
    fn serve_opts_session_layer_flag_is_strict_and_idle_clamps() {
        use std::collections::HashMap;
        let cfg = capsim::config::PipelineConfig::default();
        let mut flags: HashMap<String, String> = HashMap::new();
        let opts = super::serve_opts(&flags, &cfg).unwrap();
        assert_eq!(opts.session_layer, capsim::serve::SessionLayer::Auto);
        assert_eq!(opts.idle_timeout_ms, 60_000);
        flags.insert("session-layer".into(), "threads".into());
        flags.insert("idle-timeout-ms".into(), "-9".into());
        let opts = super::serve_opts(&flags, &cfg).unwrap();
        assert_eq!(opts.session_layer, capsim::serve::SessionLayer::Threads);
        assert_eq!(opts.idle_timeout_ms, 0, "negative disables reaping");
        // unknown layers error on the CLI (the TOML key falls back)
        flags.insert("session-layer".into(), "kqueue".into());
        assert!(super::serve_opts(&flags, &cfg).is_err());
    }
}

/// `capsim backends` — what this host can run: detected CPU features,
/// kernel tier availability and the auto/effective selection, and the
/// backend registry with the configured backend marked.
fn backends_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    println!("host: {} / {}", std::env::consts::ARCH, std::env::consts::OS);

    let feats = cpu_features();
    if feats.is_empty() {
        println!("cpu features: (no feature probes on this architecture)");
    } else {
        for (name, detected) in feats {
            println!("cpu feature {name:<8} {}", if detected { "yes" } else { "no" });
        }
    }

    println!("kernel tiers:");
    for t in KernelTier::ALL {
        let status = if t == KernelTier::Auto {
            format!("resolves to {}", KernelTier::detect())
        } else if t.available() {
            "available".to_string()
        } else {
            "unavailable on this host".to_string()
        };
        println!("  {:<8} {status}", t.name());
    }
    println!("auto-selected tier: {}", KernelTier::detect());
    // the effective tier folds in the full precedence chain (CLI flag >
    // TOML > CAPSIM_KERNEL_TIER env > detect); a forced-but-unavailable
    // tier errors here exactly as it would at model build time
    let effective = cfg.effective_kernel_tier()?;
    println!("configured tier: {} (effective: {effective})", cfg.kernel_tier);

    println!("backends:");
    for b in Backend::ALL {
        let mark = if b == cfg.backend { "  [active]" } else { "" };
        let needs = if b.requires_artifacts() {
            "needs `make artifacts`"
        } else {
            "dependency-free"
        };
        println!("  {:<10} {needs}{mark}", b.name());
    }

    println!(
        "serve: session layer {} (serve.session_layer {}; epoll available: {}), \
         predict loops {} (serve.predict_loops {}; 0 = auto), linger {} us, \
         queue depth {}, idle timeout {} ms",
        cfg.serve_session_layer.resolve().unwrap_or(cfg.serve_session_layer),
        cfg.serve_session_layer,
        capsim::util::epoll::available(),
        cfg.effective_predict_loops(),
        cfg.serve_predict_loops,
        cfg.serve_linger_us,
        cfg.effective_queue_depth(),
        cfg.serve_idle_timeout_ms
    );

    use capsim::util::image;
    println!("persistence:");
    println!(
        "  image container: CPIM v{} (clip cache + attention weights; \
         zero-copy mmap warm start)",
        image::IMAGE_VERSION
    );
    println!("  legacy formats: CPLC v1 cache, CAWB v1 weights (read-only migration window)");
    println!(
        "  mmap: {}",
        if cfg!(unix) {
            "available (read-only MAP_SHARED, shared across processes)"
        } else {
            "unavailable on this target (8-byte-aligned heap fallback)"
        }
    );
    if !cfg.cache_dir.is_empty() {
        let path = Path::new(&cfg.cache_dir).join("clip_cache.bin");
        match image::peek_format(&path) {
            Ok((m, v)) if m == image::IMAGE_MAGIC => {
                println!("  cache file {path:?}: CPIM v{v} image (mmap-frozen on load)");
            }
            Ok((m, v)) if m == capsim::coordinator::cache::FILE_MAGIC => {
                println!("  cache file {path:?}: legacy CPLC v{v} (migrates on next save)");
            }
            Ok(_) => println!("  cache file {path:?}: unrecognized format (would cold-start)"),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                println!("  cache file {path:?}: absent (cold start)");
            }
            Err(e) => println!("  cache file {path:?}: unreadable ({e})"),
        }
        println!(
            "  cache residency: {}",
            if cfg.cache_mmap { "mmap-frozen tier (default)" } else { "heap copy (cache_mmap = false)" }
        );
    }
    Ok(())
}

fn info_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let rt = Runtime::load(Path::new(&cfg.artifacts))?;
    let g = &rt.manifest.geometry;
    println!("artifacts: {}", cfg.artifacts);
    println!(
        "geometry: vocab {} embed {} l_token {} l_clip {} M {} train_batch {}",
        g.vocab_size, g.embed_dim, g.l_token, g.l_clip, g.m_rows, g.train_batch
    );
    for (name, v) in &rt.manifest.variants {
        println!(
            "variant {name}: {} params, fwd batches {:?}",
            v.param_size,
            v.fwd_files.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}
