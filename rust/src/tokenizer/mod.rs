//! The standardization transformation (paper §V-A, Fig. 5) and its
//! vocabulary.
//!
//! Each raw instruction becomes a fixed-order token sequence:
//!
//! ```text
//! <REP> <OPCODE> op <DSTS> d… </DSTS> <SRCS> s… </SRCS> [<MEM> base </MEM>] <END>
//! ```
//!
//! * the leading `<REP>` is the learnable representative token whose
//!   attention output row becomes the instruction's ideal-execution-time
//!   vector (paper Eq. 7);
//! * implicit registers appear even when absent from the assembly text —
//!   e.g. `cmpi` destinations include `CR`, `bl` writes `LR` (Fig. 5c);
//! * immediates and displacements collapse to `<CONST>` (Fig. 5a);
//! * memory operands are wrapped in `<MEM>…</MEM>` with their base (and
//!   index) registers (Fig. 5b).
//!
//! The same vocabulary also encodes the context matrix's value-byte tokens
//! (Fig. 6) — see [`vocab::Vocab`].

pub mod standardize;
pub mod vocab;

pub use standardize::{standardize, tokenize_clip};
pub use vocab::{RegName, Vocab};
