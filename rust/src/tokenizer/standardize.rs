//! The Fig.-5 standardization transformation proper.

use crate::functional::TraceRecord;
use crate::isa::Inst;

use super::vocab::{self, Vocab};

/// Standardize one instruction into at most `l_token` tokens (padded with
/// `<PAD>`, truncated if over — the `<END>` token survives truncation).
pub fn standardize(inst: &Inst, has_imm: bool, l_token: usize) -> Vec<u16> {
    let mut t = Vec::with_capacity(l_token);
    t.push(vocab::REP);
    t.push(vocab::OPCODE);
    t.push(Vocab::opcode(inst.op));

    let dsts = inst.dsts();
    if !dsts.is_empty() {
        t.push(vocab::DSTS_OPEN);
        for d in &dsts {
            t.push(Vocab::reg_ref(*d));
        }
        t.push(vocab::DSTS_CLOSE);
    }

    let srcs = inst.srcs();
    if !srcs.is_empty() || has_imm {
        t.push(vocab::SRCS_OPEN);
        for s in &srcs {
            t.push(Vocab::reg_ref(*s));
        }
        if has_imm {
            t.push(vocab::CONST);
        }
        t.push(vocab::SRCS_CLOSE);
    }

    if inst.is_mem() {
        t.push(vocab::MEM_OPEN);
        t.push(Vocab::reg_ref(crate::isa::inst::RegRef::Gpr(inst.ra)));
        if inst.is_indexed_mem() {
            t.push(Vocab::reg_ref(crate::isa::inst::RegRef::Gpr(inst.rb)));
        }
        t.push(vocab::MEM_CLOSE);
    }

    t.push(vocab::END);
    if t.len() > l_token {
        t.truncate(l_token);
        t[l_token - 1] = vocab::END;
    }
    while t.len() < l_token {
        t.push(vocab::PAD);
    }
    t
}

/// Whether the instruction carries an immediate that standardizes to
/// `<CONST>` (Fig. 5a). Branch offsets count: the constant is part of the
/// instruction's identity the same way Fig. 5 treats literal operands.
pub fn has_const(inst: &Inst) -> bool {
    use crate::isa::Opcode::*;
    matches!(
        inst.op,
        Addi | Andi | Ori | Xori | Sldi | Srdi | Sradi | Li | Lis | Cmpi
            | Cmpli | B | Bl | Beq | Bne | Blt | Bge | Bgt | Ble | Bdnz
    ) || (inst.is_mem() && !inst.is_indexed_mem())
}

/// Tokenize a whole clip of trace records into an `(n x l_token)` matrix
/// (row-major), padding/truncating each instruction independently.
pub fn tokenize_clip(records: &[TraceRecord], l_token: usize) -> Vec<u16> {
    let mut out = Vec::with_capacity(records.len() * l_token);
    for r in records {
        out.extend(standardize(&r.inst, has_const(&r.inst), l_token));
    }
    out
}

/// Content key for clip deduplication (paper §IV-B "unique code sequence
/// content"): FNV-1a over the token stream.
pub fn clip_key(tokens: &[u16]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for t in tokens {
        h ^= *t as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Fast content key computed directly from decoded instruction fields —
/// by construction it induces the same equivalence classes as hashing the
/// standardized tokens (the tokens are a pure function of `(op, rd, ra,
/// rb, has_const)`), but skips tokenization entirely. This is the hot-path
/// dedup key in `coordinator::capsim_mode`: only clips whose key is new
/// ever get tokenized.
pub fn fast_clip_key(records: &[TraceRecord]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    for r in records {
        let i = &r.inst;
        mix(i.op as u64);
        mix(i.rd as u64 | ((i.ra as u64) << 8) | ((i.rb as u64) << 16)
            | ((has_const(i) as u64) << 24));
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Inst, Opcode};
    use crate::tokenizer::vocab as v;
    use crate::tokenizer::vocab::Vocab;

    const LT: usize = 16;

    fn toks(i: Inst) -> Vec<u16> {
        standardize(&i, has_const(&i), LT)
    }

    fn names(ts: &[u16]) -> Vec<String> {
        ts.iter()
            .take_while(|&&t| t != v::PAD)
            .map(|&t| Vocab::name(t))
            .collect()
    }

    #[test]
    fn fig5a_constant_becomes_const_token() {
        // addi r3, r4, 8  ->  <REP><OPCODE>addi<DSTS>r3</DSTS><SRCS>r4<CONST></SRCS><END>
        let t = toks(Inst::new(Opcode::Addi, 3, 4, 0, 8));
        assert_eq!(
            names(&t),
            ["<REP>", "<OPCODE>", "addi", "<DSTS>", "r3", "</DSTS>",
             "<SRCS>", "r4", "<CONST>", "</SRCS>", "<END>"]
        );
        // the immediate VALUE must not influence tokens (8 vs 100)
        let t2 = toks(Inst::new(Opcode::Addi, 3, 4, 0, 100));
        assert_eq!(t, t2);
    }

    #[test]
    fn fig5b_load_gets_mem_segment() {
        // lwz r5, 8(r9)
        let t = toks(Inst::new(Opcode::Lwz, 5, 9, 0, 8));
        let n = names(&t);
        assert!(n.contains(&"<MEM>".to_string()));
        let mpos = n.iter().position(|x| x == "<MEM>").unwrap();
        assert_eq!(n[mpos + 1], "r9");
        assert_eq!(n[mpos + 2], "</MEM>");
    }

    #[test]
    fn fig5c_cmpi_has_implicit_cr_destination() {
        let t = toks(Inst::new(Opcode::Cmpi, 0, 7, 0, 3));
        let n = names(&t);
        let d = n.iter().position(|x| x == "<DSTS>").unwrap();
        assert_eq!(n[d + 1], "CR");
    }

    #[test]
    fn rep_first_end_last() {
        for op in crate::isa::inst::ALL_OPCODES {
            let i = Inst::new(op, 1, 2, 3, 4);
            let t = toks(i);
            assert_eq!(t[0], v::REP, "{op:?}");
            assert_eq!(t.len(), LT);
            let last = t.iter().rposition(|&x| x != v::PAD).unwrap();
            assert_eq!(t[last], v::END, "{op:?}");
        }
    }

    #[test]
    fn indexed_mem_includes_both_regs() {
        let t = toks(Inst::new(Opcode::Ldx, 3, 1, 2, 0));
        let n = names(&t);
        let m = n.iter().position(|x| x == "<MEM>").unwrap();
        assert_eq!(&n[m + 1..m + 3], ["r1", "r2"]);
    }

    #[test]
    fn blr_reads_lr_implicitly() {
        let t = toks(Inst::new(Opcode::Blr, 0, 0, 0, 0));
        let n = names(&t);
        let s = n.iter().position(|x| x == "<SRCS>").unwrap();
        assert_eq!(n[s + 1], "LR");
    }

    #[test]
    fn clip_tokenization_shape_and_key() {
        use crate::functional::AtomicCpu;
        use crate::isa::Assembler;
        let mut a = Assembler::new(0x1000);
        a.li(1, 5);
        a.addi(1, 1, 1);
        a.cmpi(1, 6);
        a.halt();
        let mut cpu = AtomicCpu::load(&a.finish());
        let tr = cpu.run_trace(10);
        let toks = tokenize_clip(&tr, LT);
        assert_eq!(toks.len(), tr.len() * LT);
        let k1 = clip_key(&toks);
        let k2 = clip_key(&toks);
        assert_eq!(k1, k2);
        let toks2 = &toks[LT..];
        assert_ne!(clip_key(toks2), k1);
    }

    #[test]
    fn fast_key_equivalent_to_token_key() {
        use crate::functional::AtomicCpu;
        use crate::isa::Assembler;
        use crate::util::Rng;
        // random programs: fast keys must agree with token keys on
        // equality/inequality across sliding windows
        let mut rng = Rng::new(3);
        let mut a = Assembler::new(0x1000);
        a.li(31, 40);
        a.mtctr(31);
        let top = a.here();
        a.addi(1, 1, 3);
        a.lwz(2, 8, 1);
        a.cmpi(2, 0);
        let sk = a.label();
        a.beq(sk);
        a.mullw(3, 2, 2);
        a.bind(sk);
        a.bdnz(top);
        a.halt();
        let mut cpu = AtomicCpu::load(&a.finish());
        let tr = cpu.run_trace(10_000);
        let mut seen: std::collections::HashMap<u64, u64> = Default::default();
        for w in tr.windows(8).step_by(3).take(100) {
            let fk = fast_clip_key(w);
            let tk = clip_key(&tokenize_clip(w, LT));
            if let Some(prev) = seen.insert(fk, tk) {
                assert_eq!(prev, tk, "fast key collided across token classes");
            }
            let _ = rng.next_u64();
        }
        assert!(seen.len() > 1);
    }

    #[test]
    fn all_instructions_fit_l_token() {
        // worst case (stdx: 3 srcs + mem segment) must fit in 16 tokens
        let t = toks(Inst::new(Opcode::Stdx, 7, 8, 9, 0));
        assert_eq!(t.len(), LT);
        assert!(names(&t).last().unwrap() == "<END>");
    }
}
