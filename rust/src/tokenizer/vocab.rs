//! The token vocabulary shared by the tokenizer (Fig. 5), the context
//! matrix (Fig. 6) and the AOT-compiled embedding table.
//!
//! Ids are stable by construction: specials, then opcodes in
//! `ALL_OPCODES` order, then register names, then the 256 byte-value
//! tokens. The total must stay within `model_config.json`'s `vocab_size`
//! (checked by the runtime at artifact-load time and by tests here).

use crate::isa::inst::{RegRef, ALL_OPCODES, NUM_OPCODES};
use crate::isa::Opcode;

/// Special token ids (fixed positions).
pub const PAD: u16 = 0;
pub const REP: u16 = 1;
pub const END: u16 = 2;
pub const OPCODE: u16 = 3;
pub const DSTS_OPEN: u16 = 4;
pub const DSTS_CLOSE: u16 = 5;
pub const SRCS_OPEN: u16 = 6;
pub const SRCS_CLOSE: u16 = 7;
pub const MEM_OPEN: u16 = 8;
pub const MEM_CLOSE: u16 = 9;
pub const CONST: u16 = 10;

const NUM_SPECIALS: u16 = 11;
const OPCODE_BASE: u16 = NUM_SPECIALS;
const REG_BASE: u16 = OPCODE_BASE + NUM_OPCODES as u16;

/// Architectural register names, Table-I order (GPRs, FPRs-as-VSRs, then
/// the special registers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegName {
    Gpr(u8),
    Fpr(u8),
    Cr,
    Lr,
    Ctr,
    Xer,
    Fpscr,
    Cia,
    Nia,
}

const NUM_REGS: u16 = 32 + 32 + 7;
const BYTE_BASE: u16 = REG_BASE + NUM_REGS;

/// Total number of tokens in use.
pub const VOCAB_USED: u16 = BYTE_BASE + 256;

/// Token vocabulary (stateless; all ids are computed).
#[derive(Clone, Copy, Debug, Default)]
pub struct Vocab;

impl Vocab {
    pub fn opcode(op: Opcode) -> u16 {
        OPCODE_BASE + op as u16
    }

    pub fn reg(r: RegName) -> u16 {
        REG_BASE
            + match r {
                RegName::Gpr(i) => i as u16,
                RegName::Fpr(i) => 32 + i as u16,
                RegName::Cr => 64,
                RegName::Lr => 65,
                RegName::Ctr => 66,
                RegName::Xer => 67,
                RegName::Fpscr => 68,
                RegName::Cia => 69,
                RegName::Nia => 70,
            }
    }

    pub fn reg_ref(r: RegRef) -> u16 {
        Self::reg(match r {
            RegRef::Gpr(i) => RegName::Gpr(i),
            RegRef::Fpr(i) => RegName::Fpr(i),
            RegRef::Cr => RegName::Cr,
            RegRef::Lr => RegName::Lr,
            RegRef::Ctr => RegName::Ctr,
            RegRef::Xer => RegName::Xer,
        })
    }

    /// Byte-value token (context matrix values, Fig. 6).
    pub fn byte(b: u8) -> u16 {
        BYTE_BASE + b as u16
    }

    /// Human-readable token name (debugging / docs).
    pub fn name(tok: u16) -> String {
        match tok {
            PAD => "<PAD>".into(),
            REP => "<REP>".into(),
            END => "<END>".into(),
            OPCODE => "<OPCODE>".into(),
            DSTS_OPEN => "<DSTS>".into(),
            DSTS_CLOSE => "</DSTS>".into(),
            SRCS_OPEN => "<SRCS>".into(),
            SRCS_CLOSE => "</SRCS>".into(),
            MEM_OPEN => "<MEM>".into(),
            MEM_CLOSE => "</MEM>".into(),
            CONST => "<CONST>".into(),
            t if t >= BYTE_BASE && t < BYTE_BASE + 256 => {
                format!("B{:02X}", t - BYTE_BASE)
            }
            t if t >= REG_BASE && t < BYTE_BASE => {
                let i = t - REG_BASE;
                match i {
                    0..=31 => format!("r{i}"),
                    32..=63 => format!("f{}", i - 32),
                    64 => "CR".into(),
                    65 => "LR".into(),
                    66 => "CTR".into(),
                    67 => "XER".into(),
                    68 => "FPSCR".into(),
                    69 => "CIA".into(),
                    _ => "NIA".into(),
                }
            }
            t if t >= OPCODE_BASE && t < REG_BASE => {
                ALL_OPCODES[(t - OPCODE_BASE) as usize].mnemonic().into()
            }
            t => format!("<UNK:{t}>"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_fits_model_config() {
        // model_config.json declares 512; everything must fit below it
        assert!(VOCAB_USED <= 512, "vocab {VOCAB_USED} exceeds embedding table");
    }

    #[test]
    fn id_ranges_disjoint() {
        let ids = [
            Vocab::opcode(Opcode::Add),
            Vocab::opcode(Opcode::Halt),
            Vocab::reg(RegName::Gpr(0)),
            Vocab::reg(RegName::Nia),
            Vocab::byte(0),
            Vocab::byte(255),
        ];
        for w in ids.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(ids[0] >= NUM_SPECIALS);
        assert_eq!(ids[5] + 1, VOCAB_USED);
    }

    #[test]
    fn names_roundtrip_distinct() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for t in 0..VOCAB_USED {
            assert!(seen.insert(Vocab::name(t)), "dup name for {t}");
        }
    }

    #[test]
    fn table1_registers_have_tokens() {
        // every Table-I register class must be representable
        for r in [RegName::Gpr(31), RegName::Fpr(63 - 32), RegName::Cr,
                  RegName::Lr, RegName::Ctr, RegName::Xer, RegName::Fpscr,
                  RegName::Cia, RegName::Nia] {
            let t = Vocab::reg(r);
            assert!(t >= REG_BASE && t < BYTE_BASE);
        }
    }
}
