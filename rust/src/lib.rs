//! # CAPSim — a fast CPU performance simulator using an attention-based predictor
//!
//! Rust + JAX + Pallas reproduction of *"CAPSim: A Fast CPU Performance
//! Simulator Using Attention-based Predictor"* (Xu et al., 2025).
//!
//! The crate contains every substrate the paper depends on, bottom-up:
//!
//! * [`isa`] — **PISA**, a Power-inspired RISC ISA (Table I register file);
//! * [`mem`] — flat paged memory + an L1I/L1D/L2 cache hierarchy;
//! * [`functional`] — the AtomicSimple-style functional simulator that
//!   produces instruction traces and register snapshots;
//! * [`o3`] — the cycle-level out-of-order superscalar simulator used as the
//!   golden label generator and the "gem5 mode" speed baseline;
//! * [`simpoint`] — BBV profiling + k-means interval selection + checkpoints;
//! * [`slicer`] — Algorithm 1: code-trace-clip generation;
//! * [`sampler`] — Fig. 3: occurrence-sorted clip sampling;
//! * [`tokenizer`] — Fig. 5: standardization transformation into tokens;
//! * [`context`] — Fig. 6: register-value context matrix;
//! * [`dataset`] — clip datasets, splits and the six Table-II benchmark sets;
//! * [`runtime`] — predictor backends behind one `Predictor` trait and a
//!   `Backend` registry (`pipeline.backend` TOML / `--backend` CLI):
//!
//!   | backend | needs | determinism | use |
//!   |---|---|---|---|
//!   | `pjrt` | `make artifacts` + PJRT | batch-sensitive ≈1e-3 | trained-accuracy experiments |
//!   | `native` | nothing | row-local, bit-exact | equivalence tests, smoke runs |
//!   | `attention` | nothing | row-local, bit-exact | pure-Rust transformer: a real model cost in the hot path, CI |
//!
//!   `attention` executes the paper's architecture (token embedding →
//!   multi-head self-attention over the clip stream with padding masks →
//!   clip pooling + context-row fusion → regression head) with in-crate
//!   f32 kernels (`runtime::tensor`), weights seeded deterministically or
//!   loaded from a versioned `artifacts/attention.bin`;
//! * [`predictor`] — batching (including the cross-interval/benchmark
//!   `BatchAccumulator`), the SGD training driver and evaluation;
//! * [`coordinator`] — the end-to-end CAPSim and gem5-mode pipelines, run
//!   by a **streaming stage-pipelined engine** (`coordinator::stream`):
//!   instead of scanning everything and then predicting behind phase
//!   barriers, checkpoint-restore/functional-scan, slice+tokenize,
//!   `BatchAccumulator` fill, `Predictor::forward` and the result merge
//!   run as concurrent stages connected by bounded channels, and every
//!   (benchmark, interval) job from all 24 workloads feeds one shared
//!   worker pool — benchmark-level fan-out, not per-benchmark phases:
//!
//!   ```text
//!     scan jobs (bench × interval, all benchmarks)
//!       ├─ worker 1..threads: restore → warm-up → slice → tokenize
//!       ▼ sync_channel(queue_depth)            [stage 1 → 2, bounded]
//!     merge: reorder to sequence order → clip dedup (interval /
//!       benchmark / suite / ClipCache) → BatchAccumulator fill
//!       ▼ sync_channel(batch_depth)            [stage 2 → 3, bounded]
//!     predict: Predictor::forward → resolve → per-benchmark results
//!   ```
//!
//!   The `threads` knob of `config::PipelineConfig` sizes the scan pool
//!   (`0` = auto: `CAPSIM_THREADS` env, else one per core; set it from
//!   the CLI with `--threads N` or `pipeline.threads` in TOML;
//!   `queue_depth`/`batch_depth` size the channels). Determinism is a
//!   hard contract: the merge consumes scans in sequence-number order,
//!   so `threads = N`, any queue depth, and any stage interleaving are
//!   bit-identical to the sequential path. A cross-benchmark `ClipCache`
//!   dedups identical clips across the whole suite, can **persist**
//!   (`save`/`load`, keyed by model fingerprint + `time_scale`,
//!   `--cache-dir`) for cross-process warm starts, and can be **bounded**
//!   (`--cache-max-entries`, oldest-inserted eviction); `coordinator::engine`
//!   drives entire suites through one shared cache with full inference
//!   batches, and O3 golden-label generation (`coordinator::golden`)
//!   rides the same stage graph;
//! * [`serve`] — the `capsim serve` daemon: weights loaded once, a
//!   persistent clip cache, and **cross-request batching** — concurrent
//!   clients' clips fill one shared `BatchAccumulator` (flush on
//!   batch-full or a small linger deadline), with a bounded admission
//!   queue that answers `Busy` + retry hint under overload, and a
//!   graceful drain that saves the cache on shutdown;
//! * [`workloads`] — the 24 synthetic SPEC-2017-analog benchmarks;
//! * [`report`] — table/series emitters used by the benches;
//! * [`config`], [`util`] — TOML-subset configs and offline-friendly
//!   utilities (JSON, PRNG, stats, property-testing harness).
//!
//! Python/JAX/Pallas run **only at build time** (`make artifacts`); the
//! simulation path is pure Rust + the PJRT C API.

pub mod config;
pub mod context;
pub mod coordinator;
pub mod dataset;
pub mod functional;
pub mod isa;
pub mod mem;
pub mod o3;
pub mod predictor;
pub mod report;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod simpoint;
pub mod slicer;
pub mod tokenizer;
pub mod util;
pub mod workloads;
