//! The trace-clip sampler (paper §IV-B, Fig. 3).
//!
//! Clips are grouped by unique code content and split at an occurrence
//! `threshold` into two populations:
//!
//! * **frequent** clips (occurrences > threshold): sampled *within* each
//!   category — the occurrence count is scaled down by the sampling
//!   `coefficient`, preserving the category distribution;
//! * **rare** clips (occurrences <= threshold): sampled *across*
//!   categories — a `coefficient` fraction of the categories is kept
//!   (periodically, after sorting), each keeping its full occurrence count.
//!
//! The paper's configuration (threshold 200, coefficient 0.02) turns a
//! 30M-clip corpus into a tractable training set (300 h -> 10 h).

use std::collections::HashMap;

/// Sampler parameters (paper §VI-A).
#[derive(Clone, Copy, Debug)]
pub struct SamplerConfig {
    pub threshold: u64,
    pub coefficient: f64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { threshold: 200, coefficient: 0.02 }
    }
}

/// Occurrence statistics for one unique clip content.
#[derive(Clone, Debug)]
pub struct Category {
    pub key: u64,
    /// Indices of all clips with this content, in appearance order.
    pub members: Vec<usize>,
}

impl Category {
    pub fn count(&self) -> usize {
        self.members.len()
    }
}

/// Group clip indices by content key, in order of first appearance
/// (the x-axis of Fig. 8a).
pub fn categorize(keys: &[u64]) -> Vec<Category> {
    let mut order: Vec<u64> = Vec::new();
    let mut map: HashMap<u64, Vec<usize>> = HashMap::new();
    for (i, &k) in keys.iter().enumerate() {
        let e = map.entry(k).or_default();
        if e.is_empty() {
            order.push(k);
        }
        e.push(i);
    }
    order
        .into_iter()
        .map(|k| Category { key: k, members: map.remove(&k).unwrap() })
        .collect()
}

/// Periodic selection of `ceil(frac * n)` items out of `n`.
fn periodic_pick(n: usize, frac: f64) -> Vec<usize> {
    if n == 0 || frac <= 0.0 {
        return Vec::new();
    }
    let keep = ((n as f64 * frac).ceil() as usize).clamp(1, n);
    let stride = n as f64 / keep as f64;
    (0..keep).map(|i| (i as f64 * stride) as usize).collect()
}

/// Apply Fig. 3: returns the selected clip indices (sorted ascending).
pub fn sample(keys: &[u64], cfg: &SamplerConfig) -> Vec<usize> {
    let cats = categorize(keys);
    let mut selected = Vec::new();

    // split populations
    let (frequent, rare): (Vec<&Category>, Vec<&Category>) = cats
        .iter()
        .partition(|c| c.count() as u64 > cfg.threshold);

    // frequent: sample within each category (scale occurrences down)
    for c in frequent {
        for pick in periodic_pick(c.count(), cfg.coefficient) {
            selected.push(c.members[pick]);
        }
    }

    // rare: sample across categories (keep a fraction of categories whole),
    // sorted by descending count (the Fig. 8b ordering)
    let mut rare_sorted = rare;
    rare_sorted.sort_by(|a, b| b.count().cmp(&a.count()).then(a.key.cmp(&b.key)));
    for pick in periodic_pick(rare_sorted.len(), cfg.coefficient) {
        selected.extend_from_slice(&rare_sorted[pick].members);
    }

    selected.sort_unstable();
    selected
}

/// The Fig.-8 distributions: (a) occurrences in first-appearance order and
/// (b) sorted descending.
pub fn occurrence_distribution(keys: &[u64]) -> (Vec<u64>, Vec<u64>) {
    let cats = categorize(keys);
    let original: Vec<u64> = cats.iter().map(|c| c.count() as u64).collect();
    let mut sorted = original.clone();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    (original, sorted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Synthetic key stream: a few hot clips (loop bodies) + a tail of
    /// rare unique clips — the Fig. 8 shape.
    fn synthetic_keys(rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n)
            .map(|_| {
                if rng.chance(0.8) {
                    rng.below(5) // 5 hot categories
                } else {
                    1000 + rng.below(500) // long tail
                }
            })
            .collect()
    }

    #[test]
    fn categorize_preserves_appearance_order_and_counts() {
        let keys = vec![7, 7, 3, 7, 3, 9];
        let cats = categorize(&keys);
        assert_eq!(cats.len(), 3);
        assert_eq!(cats[0].key, 7);
        assert_eq!(cats[0].members, vec![0, 1, 3]);
        assert_eq!(cats[1].key, 3);
        assert_eq!(cats[2].key, 9);
    }

    #[test]
    fn frequent_categories_survive_with_reduced_count() {
        let mut rng = Rng::new(5);
        let keys = synthetic_keys(&mut rng, 20_000);
        let cfg = SamplerConfig { threshold: 200, coefficient: 0.02 };
        let sel = sample(&keys, &cfg);
        assert!(!sel.is_empty());
        // every hot category must still be represented
        let sel_keys: std::collections::HashSet<u64> =
            sel.iter().map(|&i| keys[i]).collect();
        for hot in 0..5u64 {
            assert!(sel_keys.contains(&hot), "hot clip {hot} lost");
        }
        // and the selection must be much smaller than the input
        assert!(sel.len() < keys.len() / 10, "{} of {}", sel.len(), keys.len());
    }

    #[test]
    fn category_distribution_roughly_preserved() {
        let mut rng = Rng::new(6);
        let keys = synthetic_keys(&mut rng, 50_000);
        let cfg = SamplerConfig::default();
        let sel = sample(&keys, &cfg);
        let cats = categorize(&keys);
        let hot: Vec<&Category> =
            cats.iter().filter(|c| c.count() as u64 > cfg.threshold).collect();
        // within the frequent population, the selected share per category
        // should track the original share within ~3x
        let total_hot: usize = hot.iter().map(|c| c.count()).sum();
        let sel_hot: Vec<usize> = hot
            .iter()
            .map(|c| sel.iter().filter(|&&i| keys[i] == c.key).count())
            .collect();
        let total_sel_hot: usize = sel_hot.iter().sum();
        for (c, &s) in hot.iter().zip(&sel_hot) {
            let orig_share = c.count() as f64 / total_hot as f64;
            let sel_share = s as f64 / total_sel_hot as f64;
            assert!(
                sel_share > orig_share / 3.0 && sel_share < orig_share * 3.0,
                "share drift: {orig_share} -> {sel_share}"
            );
        }
    }

    #[test]
    fn rare_clips_sampled_across_categories() {
        // 100 singleton categories, none above threshold
        let keys: Vec<u64> = (0..100).collect();
        let cfg = SamplerConfig { threshold: 10, coefficient: 0.1 };
        let sel = sample(&keys, &cfg);
        assert_eq!(sel.len(), 10, "10% of 100 categories");
        // occurrences within kept categories are preserved (1 each)
        let mut dedup = sel.clone();
        dedup.dedup();
        assert_eq!(dedup.len(), sel.len());
    }

    #[test]
    fn periodic_pick_bounds() {
        assert!(periodic_pick(0, 0.5).is_empty());
        assert_eq!(periodic_pick(10, 1.0).len(), 10);
        assert_eq!(periodic_pick(10, 0.2), vec![0, 5]);
        assert_eq!(periodic_pick(3, 0.01).len(), 1, "at least one survives");
    }

    #[test]
    fn distribution_shapes() {
        let mut rng = Rng::new(8);
        let keys = synthetic_keys(&mut rng, 5_000);
        let (orig, sorted) = occurrence_distribution(&keys);
        assert_eq!(orig.len(), sorted.len());
        assert_eq!(orig.iter().sum::<u64>(), 5_000);
        for w in sorted.windows(2) {
            assert!(w[0] >= w[1], "sorted descending");
        }
        // the Fig. 8 two-population shape: head >> tail
        assert!(sorted[0] > 500);
        assert_eq!(*sorted.last().unwrap(), 1);
    }

    #[test]
    fn deterministic() {
        let mut rng = Rng::new(9);
        let keys = synthetic_keys(&mut rng, 10_000);
        let a = sample(&keys, &SamplerConfig::default());
        let b = sample(&keys, &SamplerConfig::default());
        assert_eq!(a, b);
    }
}
