//! Architectural checkpoints: the register file + memory image at an
//! interval boundary. Restoring one hands either simulator (O3 "gem5 mode"
//! or the functional trace source) the exact state the interval started in.

use crate::functional::AtomicCpu;
use crate::isa::RegFile;
use crate::mem::Memory;

/// A restorable architectural snapshot.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Dynamic instruction index at which the snapshot was taken.
    pub start_inst: u64,
    pub regs: RegFile,
    pub mem: Memory,
}

impl Checkpoint {
    pub fn capture(cpu: &AtomicCpu) -> Self {
        Checkpoint {
            start_inst: cpu.icount,
            regs: cpu.regs.clone(),
            mem: cpu.mem.clone(),
        }
    }

    /// Restore into a fresh functional CPU.
    pub fn restore(&self) -> AtomicCpu {
        AtomicCpu::from_state(self.regs.clone(), self.mem.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Assembler;

    #[test]
    fn capture_restore_resumes_identically() {
        let mut a = Assembler::new(0x1000);
        a.li(1, 1000);
        a.mtctr(1);
        let top = a.here();
        a.addi(2, 2, 3);
        a.bdnz(top);
        a.halt();
        let p = a.finish();

        // run halfway, checkpoint, run to completion
        let mut cpu = AtomicCpu::load(&p);
        cpu.run_trace(1001); // li, mtctr + ~500 loop iterations
        let ck = Checkpoint::capture(&cpu);
        let rest_a = cpu.run_trace(1_000_000);

        // restore and run the same remainder
        let mut cpu2 = ck.restore();
        let rest_b = cpu2.run_trace(1_000_000);

        assert_eq!(rest_a, rest_b, "restored run must replay identically");
        assert_eq!(cpu.regs.gpr[2], cpu2.regs.gpr[2]);
        assert_eq!(ck.start_inst, 1001);
    }
}
