//! BBV profiling + representative-interval selection (the SimPoint flow).

use std::collections::HashMap;

use crate::functional::AtomicCpu;
use crate::isa::asm::Program;
use crate::util::Rng;

use super::checkpoint::Checkpoint;
use super::kmeans::auto_k;

/// SimPoint configuration (scaled defaults; see DESIGN.md §1).
#[derive(Clone, Copy, Debug)]
pub struct SimpointConfig {
    /// Instructions per interval (paper: 5,000,000; scaled default 200k).
    pub interval_insts: u64,
    /// Warm-up instructions simulated before the measured interval
    /// (paper: 1,000,000; scaled default 20k).
    pub warmup_insts: u64,
    /// Maximum number of representative intervals (checkpoints).
    pub max_k: usize,
    /// Random-projection dimension for BBVs (SimPoint uses 15).
    pub bbv_dim: usize,
    /// Elbow threshold for automatic k (fraction of 1-cluster SSE).
    pub elbow_frac: f64,
    pub seed: u64,
}

impl Default for SimpointConfig {
    fn default() -> Self {
        SimpointConfig {
            interval_insts: 200_000,
            warmup_insts: 20_000,
            max_k: 8,
            bbv_dim: 16,
            elbow_frac: 0.05,
            seed: 42,
        }
    }
}

/// Per-interval profile data.
#[derive(Clone, Debug)]
pub struct IntervalProfile {
    /// Projected, L1-normalized basic-block vector.
    pub bbv: Vec<f64>,
    /// Checkpoint at the interval start.
    pub checkpoint: Checkpoint,
}

/// Whole-program profile.
#[derive(Debug)]
pub struct Profile {
    pub intervals: Vec<IntervalProfile>,
    pub total_insts: u64,
}

/// A chosen representative interval.
#[derive(Clone, Debug)]
pub struct SelectedInterval {
    /// Index into the interval sequence.
    pub index: usize,
    /// Fraction of all intervals this representative stands for.
    pub weight: f64,
    pub checkpoint: Checkpoint,
}

/// Random projection of block-id counts into `dim` dimensions — the same
/// trick SimPoint uses to make k-means tractable over huge BBVs. The
/// projection is a deterministic hash of the block id, so it needs no
/// global dictionary.
fn project_bbv(counts: &HashMap<u64, u64>, dim: usize) -> Vec<f64> {
    let mut v = vec![0.0f64; dim];
    let total: u64 = counts.values().sum();
    if total == 0 {
        return v;
    }
    for (&block, &cnt) in counts {
        let mut h = block.wrapping_mul(0x9E3779B97F4A7C15);
        for slot in v.iter_mut() {
            h ^= h >> 29;
            h = h.wrapping_mul(0xBF58476D1CE4E5B9);
            // signed +-1 projection per dimension
            let sign = if h & 1 == 0 { 1.0 } else { -1.0 };
            *slot += sign * cnt as f64;
        }
    }
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        for slot in v.iter_mut() {
            *slot /= norm;
        }
    }
    v
}

/// Run the functional simulator over the whole program, recording one
/// BBV + checkpoint per interval.
pub fn profile(program: &Program, cfg: &SimpointConfig) -> Profile {
    let mut cpu = AtomicCpu::load(program);
    let mut intervals = Vec::new();
    let mut counts: HashMap<u64, u64> = HashMap::new();
    let mut block_start = program.entry;
    let mut block_len: u64 = 0;

    loop {
        let ck = Checkpoint::capture(&cpu);
        let executed = cpu.run_with(cfg.interval_insts, |rec| {
            block_len += 1;
            if rec.ends_block() {
                // weight blocks by their length, like SimPoint
                *counts.entry(block_start).or_insert(0) += block_len;
                block_start = rec.next_pc;
                block_len = 0;
            }
        });
        if executed == 0 {
            break;
        }
        if block_len > 0 {
            *counts.entry(block_start).or_insert(0) += block_len;
            block_len = 0;
        }
        intervals.push(IntervalProfile {
            bbv: project_bbv(&counts, cfg.bbv_dim),
            checkpoint: ck,
        });
        counts.clear();
        if cpu.halted {
            break;
        }
    }
    Profile { intervals, total_insts: cpu.icount }
}

/// Cluster the profile and pick one representative per cluster
/// (closest to the centroid), weighted by cluster population.
pub fn choose_simpoints(profile: &Profile, cfg: &SimpointConfig) -> Vec<SelectedInterval> {
    if profile.intervals.is_empty() {
        return Vec::new();
    }
    let pts: Vec<Vec<f64>> = profile.intervals.iter().map(|i| i.bbv.clone()).collect();
    let km = auto_k(&pts, cfg.max_k, cfg.elbow_frac, cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ 0xABCD);

    let mut selected = Vec::new();
    for c in 0..km.k {
        let members: Vec<usize> = (0..pts.len()).filter(|&i| km.assign[i] == c).collect();
        if members.is_empty() {
            continue;
        }
        // representative: closest member to the centroid
        let cent = &km.centroids[c];
        let rep = *members
            .iter()
            .min_by(|&&a, &&b| {
                let da: f64 = pts[a].iter().zip(cent).map(|(x, y)| (x - y) * (x - y)).sum();
                let db: f64 = pts[b].iter().zip(cent).map(|(x, y)| (x - y) * (x - y)).sum();
                da.partial_cmp(&db).unwrap_or_else(|| {
                    // NaN-free data; tie-break randomly but deterministically
                    if rng.chance(0.5) { std::cmp::Ordering::Less } else { std::cmp::Ordering::Greater }
                })
            })
            .unwrap();
        selected.push(SelectedInterval {
            index: rep,
            weight: members.len() as f64 / pts.len() as f64,
            checkpoint: profile.intervals[rep].checkpoint.clone(),
        });
    }
    selected.sort_by_key(|s| s.index);
    selected
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Assembler;

    /// Two-phase program: phase A (tight add loop), phase B (memory loop).
    fn two_phase_program() -> Program {
        let mut a = Assembler::new(0x1000);
        // phase A: 60k instructions of ALU loop
        a.li(1, 20_000);
        a.mtctr(1);
        let top_a = a.here();
        a.addi(2, 2, 1);
        a.addi(3, 3, 1);
        a.bdnz(top_a);
        // phase B: 60k instructions of store loop
        a.load_imm64(4, 0x100000);
        a.li(1, 15_000);
        a.mtctr(1);
        let top_b = a.here();
        a.std(2, 0, 4);
        a.addi(4, 4, 8);
        a.ld(5, -8, 4);
        a.bdnz(top_b);
        a.halt();
        a.finish()
    }

    fn small_cfg() -> SimpointConfig {
        SimpointConfig {
            interval_insts: 10_000,
            warmup_insts: 1_000,
            max_k: 4,
            ..Default::default()
        }
    }

    #[test]
    fn profile_covers_whole_program() {
        let p = two_phase_program();
        let cfg = small_cfg();
        let prof = profile(&p, &cfg);
        assert!(prof.total_insts > 100_000);
        let expected = prof.total_insts.div_ceil(cfg.interval_insts);
        assert_eq!(prof.intervals.len() as u64, expected);
        // checkpoints are ordered by start instruction
        for w in prof.intervals.windows(2) {
            assert!(w[1].checkpoint.start_inst > w[0].checkpoint.start_inst);
        }
    }

    #[test]
    fn two_phases_get_at_least_two_clusters() {
        let p = two_phase_program();
        let cfg = small_cfg();
        let prof = profile(&p, &cfg);
        let sel = choose_simpoints(&prof, &cfg);
        assert!(sel.len() >= 2, "expected phases to be separated, got {}", sel.len());
        let wsum: f64 = sel.iter().map(|s| s.weight).sum();
        assert!((wsum - 1.0).abs() < 1e-9, "weights must sum to 1, got {wsum}");
    }

    #[test]
    fn restored_checkpoint_replays_interval() {
        let p = two_phase_program();
        let cfg = small_cfg();
        let prof = profile(&p, &cfg);
        let sel = choose_simpoints(&prof, &cfg);
        let s = &sel[0];
        let mut cpu = s.checkpoint.restore();
        let trace = cpu.run_trace(cfg.interval_insts);
        assert!(!trace.is_empty());
        // the first fetched pc must be the checkpointed CIA
        assert_eq!(trace[0].pc, s.checkpoint.regs.cia);
    }

    #[test]
    fn uniform_program_needs_one_checkpoint() {
        let mut a = Assembler::new(0x1000);
        a.li(1, 30_000);
        a.mtctr(1);
        let top = a.here();
        a.addi(2, 2, 1);
        a.bdnz(top);
        a.halt();
        let prof = profile(&a.finish(), &small_cfg());
        let sel = choose_simpoints(&prof, &small_cfg());
        assert!(sel.len() <= 2, "uniform phase should need few checkpoints, got {}", sel.len());
    }

    #[test]
    fn projection_is_deterministic_and_normalized() {
        let mut counts = HashMap::new();
        counts.insert(0x1000u64, 500u64);
        counts.insert(0x2000u64, 300u64);
        let a = project_bbv(&counts, 8);
        let b = project_bbv(&counts, 8);
        assert_eq!(a, b);
        let norm: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-9, "unit L2 norm, got {norm}");
    }
}
