//! k-means over interval BBVs (Lloyd's algorithm, k-means++ seeding) with
//! an elbow-style automatic k — the SimPoint paper uses BIC; the effect is
//! the same: few checkpoints for phase-stable benchmarks, more for phasey
//! ones (that is where Table II's per-benchmark checkpoint counts come from).

use crate::util::Rng;

/// Clustering result.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub k: usize,
    /// Cluster assignment per point.
    pub assign: Vec<usize>,
    /// Centroids (k x dim).
    pub centroids: Vec<Vec<f64>>,
    /// Total within-cluster sum of squared distances.
    pub sse: f64,
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Lloyd's algorithm with k-means++ seeding. Deterministic per seed.
pub fn kmeans(points: &[Vec<f64>], k: usize, iters: usize, seed: u64) -> KmeansResult {
    assert!(!points.is_empty());
    let k = k.min(points.len()).max(1);
    let mut rng = Rng::new(seed);

    // ---- k-means++ seeding ----
    let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
    centroids.push(points[rng.range(0, points.len())].clone());
    while centroids.len() < k {
        let d2: Vec<f64> = points
            .iter()
            .map(|p| {
                centroids
                    .iter()
                    .map(|c| dist2(p, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            // all points identical to a centroid; duplicate one
            centroids.push(points[rng.range(0, points.len())].clone());
            continue;
        }
        let mut target = rng.f64() * total;
        let mut pick = 0;
        for (i, d) in d2.iter().enumerate() {
            target -= d;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        centroids.push(points[pick].clone());
    }

    // ---- Lloyd iterations ----
    let dim = points[0].len();
    let mut assign = vec![0usize; points.len()];
    for _ in 0..iters {
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    dist2(p, &centroids[a])
                        .partial_cmp(&dist2(p, &centroids[b]))
                        .unwrap()
                })
                .unwrap();
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        let mut sums = vec![vec![0.0; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            counts[assign[i]] += 1;
            for (s, v) in sums[assign[i]].iter_mut().zip(p) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for s in sums[c].iter_mut() {
                    *s /= counts[c] as f64;
                }
                centroids[c] = sums[c].clone();
            }
        }
        if !changed {
            break;
        }
    }

    let sse = points
        .iter()
        .zip(&assign)
        .map(|(p, &a)| dist2(p, &centroids[a]))
        .sum();
    KmeansResult { k, assign, centroids, sse }
}

/// Pick k with an elbow criterion: grow k while each extra cluster still
/// halves the SSE (real phase structure), stopping early once the SSE falls
/// below `frac` of the 1-cluster SSE or the marginal gain fades — Gaussian
/// "no structure" data only ever shaves ~36% per split, so it stays at k=1.
pub fn auto_k(points: &[Vec<f64>], max_k: usize, frac: f64, seed: u64) -> KmeansResult {
    let base = kmeans(points, 1, 20, seed);
    if base.sse <= 1e-12 {
        return base;
    }
    let mut best = base.clone();
    for k in 2..=max_k.min(points.len()) {
        let r = kmeans(points, k, 40, seed);
        if r.sse > 0.5 * best.sse {
            break; // diminishing returns: no real phase boundary left
        }
        best = r;
        if best.sse <= frac * base.sse {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(center: f64, n: usize, spread: f64, rng: &mut Rng) -> Vec<Vec<f64>> {
        (0..n)
            .map(|_| vec![center + spread * rng.normal(), center + spread * rng.normal()])
            .collect()
    }

    #[test]
    fn separates_two_obvious_blobs() {
        let mut rng = Rng::new(1);
        let mut pts = blob(0.0, 30, 0.1, &mut rng);
        pts.extend(blob(10.0, 30, 0.1, &mut rng));
        let r = kmeans(&pts, 2, 50, 7);
        // all of blob A in one cluster, all of blob B in the other
        let a0 = r.assign[0];
        assert!(r.assign[..30].iter().all(|&a| a == a0));
        assert!(r.assign[30..].iter().all(|&a| a != a0));
        assert!(r.sse < 5.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Rng::new(2);
        let pts = blob(0.0, 40, 1.0, &mut rng);
        let r1 = kmeans(&pts, 3, 30, 11);
        let r2 = kmeans(&pts, 3, 30, 11);
        assert_eq!(r1.assign, r2.assign);
    }

    #[test]
    fn k_capped_by_points() {
        let pts = vec![vec![1.0], vec![2.0]];
        let r = kmeans(&pts, 10, 10, 3);
        assert_eq!(r.k, 2);
    }

    #[test]
    fn identical_points_one_effective_cluster() {
        let pts = vec![vec![5.0, 5.0]; 8];
        let r = kmeans(&pts, 3, 10, 5);
        assert!(r.sse < 1e-12);
    }

    #[test]
    fn auto_k_grows_with_structure() {
        let mut rng = Rng::new(3);
        let mut pts = blob(0.0, 20, 0.05, &mut rng);
        pts.extend(blob(5.0, 20, 0.05, &mut rng));
        pts.extend(blob(10.0, 20, 0.05, &mut rng));
        let r = auto_k(&pts, 8, 0.05, 13);
        assert!(r.k >= 3, "needs >=3 clusters, got {}", r.k);
        let flat = blob(1.0, 30, 0.01, &mut rng);
        let r2 = auto_k(&flat, 8, 0.05, 13);
        assert!(r2.k <= 2, "flat data needs few clusters, got {}", r2.k);
    }

    #[test]
    fn sse_nonincreasing_in_k() {
        let mut rng = Rng::new(4);
        let pts = blob(0.0, 50, 2.0, &mut rng);
        let mut prev = f64::INFINITY;
        for k in 1..6 {
            let r = kmeans(&pts, k, 50, 9);
            assert!(r.sse <= prev * 1.05, "k={k}: {} > {prev}", r.sse);
            prev = r.sse.min(prev);
        }
    }
}
