//! SimPoint-style targeted sampling (paper §II, Fig. 1/2): profile the
//! benchmark's basic-block vectors per interval, cluster them with k-means,
//! and keep one representative (checkpointed) interval per cluster with a
//! weight equal to its cluster's share of the program.
//!
//! This is the substrate the paper takes from the SimPoint tool [27]; both
//! the gem5-mode baseline and CAPSim restore the same checkpoints, exactly
//! as in Fig. 1.

pub mod checkpoint;
pub mod kmeans;
pub mod profile;

pub use checkpoint::Checkpoint;
pub use kmeans::{kmeans, KmeansResult};
pub use profile::{choose_simpoints, profile, Profile, SelectedInterval, SimpointConfig};
