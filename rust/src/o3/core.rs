//! The O3 timing core: a trace-driven out-of-order superscalar model.
//!
//! One pass over the dynamic trace computes, per instruction, the cycle at
//! which it fetches, dispatches, issues, completes and **commits**. The
//! out-of-order window emerges from the dependence/structural constraints
//! rather than an explicit per-cycle event loop, which keeps the golden
//! label generator fast while modelling:
//!
//! * fetch groups bounded by FetchWidth, taken branches and I-cache lines
//!   (with I-cache miss stalls);
//! * gshare+BTB+RAS prediction; mispredicted branches stall re-fetch until
//!   resolution + redirect penalty;
//! * ROB / IQ / LSQ occupancy back-pressure (entries free at commit, issue
//!   and completion respectively);
//! * register RAW dependences through the full Table-I register file
//!   (including CR/LR/CTR serialization);
//! * IssueWidth plus per-class FU structural hazards (divider unpipelined);
//! * D-cache access latency from the shared hierarchy, store-to-load
//!   forwarding, loads stalling on older unresolved overlapping stores;
//! * in-order commit bounded by CommitWidth.

use crate::functional::TraceRecord;
use crate::isa::inst::{FuClass, RegRef};
use crate::mem::{Access, CacheHierarchy};

use super::branch_pred::BranchPredictor;
use super::config::O3Config;

/// Aggregate statistics of one simulation.
#[derive(Clone, Debug, Default)]
pub struct O3Stats {
    pub insts: u64,
    pub cycles: u64,
    pub branches: u64,
    pub mispredicts: u64,
    pub icache_stall_cycles: u64,
    pub rob_stall_events: u64,
    pub iq_stall_events: u64,
    pub lsq_stall_events: u64,
    pub stl_forwards: u64,
}

impl O3Stats {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.insts as f64 / self.cycles as f64
        }
    }
}

/// Result: per-instruction commit cycles (aligned with the input trace)
/// plus aggregate stats.
#[derive(Clone, Debug)]
pub struct O3Result {
    pub commit_cycle: Vec<u64>,
    pub stats: O3Stats,
}

/// Sliding per-cycle slot counters (issue and commit bandwidth).
/// The in-flight window never spans more than a few thousand cycles, so a
/// power-of-two ring indexed by cycle works; entries are lazily reset.
struct SlotRing {
    used: Vec<u32>,
    stamp: Vec<u64>,
}

const RING: usize = 1 << 15;

impl SlotRing {
    fn new() -> Self {
        SlotRing { used: vec![0; RING], stamp: vec![u64::MAX; RING] }
    }

    #[inline]
    fn get(&mut self, cycle: u64) -> u32 {
        let i = (cycle as usize) & (RING - 1);
        if self.stamp[i] != cycle {
            self.stamp[i] = cycle;
            self.used[i] = 0;
        }
        self.used[i]
    }

    #[inline]
    fn bump(&mut self, cycle: u64) {
        let i = (cycle as usize) & (RING - 1);
        if self.stamp[i] != cycle {
            self.stamp[i] = cycle;
            self.used[i] = 0;
        }
        self.used[i] += 1;
    }
}

/// Completion-time scoreboard over the architectural register file.
#[derive(Clone, Default)]
struct Scoreboard {
    gpr: [u64; 32],
    fpr: [u64; 32],
    cr: u64,
    lr: u64,
    ctr: u64,
    xer: u64,
}

impl Scoreboard {
    #[inline]
    fn get(&self, r: RegRef) -> u64 {
        match r {
            RegRef::Gpr(i) => self.gpr[i as usize],
            RegRef::Fpr(i) => self.fpr[i as usize],
            RegRef::Cr => self.cr,
            RegRef::Lr => self.lr,
            RegRef::Ctr => self.ctr,
            RegRef::Xer => self.xer,
        }
    }

    #[inline]
    fn set(&mut self, r: RegRef, cycle: u64) {
        match r {
            RegRef::Gpr(i) => self.gpr[i as usize] = cycle,
            RegRef::Fpr(i) => self.fpr[i as usize] = cycle,
            RegRef::Cr => self.cr = cycle,
            RegRef::Lr => self.lr = cycle,
            RegRef::Ctr => self.ctr = cycle,
            RegRef::Xer => self.xer = cycle,
        }
    }
}

/// An in-flight store (for store-to-load forwarding / memory ordering).
#[derive(Clone, Copy)]
struct PendingStore {
    addr: u64,
    bytes: u64,
    /// Cycle the store's data+address are available for forwarding.
    ready: u64,
    /// Trace index (to know program order).
    idx: usize,
}

/// The O3 core. Owns the branch predictor and cache hierarchy so repeated
/// intervals share warm-up state exactly like a restored gem5 checkpoint.
pub struct O3Core {
    pub cfg: O3Config,
    pub bp: BranchPredictor,
    pub caches: CacheHierarchy,
}

impl O3Core {
    pub fn new(cfg: O3Config) -> Self {
        let bp = BranchPredictor::new(cfg.bp);
        let caches = CacheHierarchy::new(cfg.hierarchy);
        O3Core { cfg, bp, caches }
    }

    /// Reset microarchitectural state (checkpoint restore starts cold).
    pub fn reset(&mut self) {
        self.bp = BranchPredictor::new(self.cfg.bp);
        self.caches = CacheHierarchy::new(self.cfg.hierarchy);
    }

    /// Simulate the timing of `trace`; returns per-instruction commit
    /// cycles (monotone nondecreasing) and stats.
    pub fn simulate(&mut self, trace: &[TraceRecord]) -> O3Result {
        let cfg = &self.cfg;
        let n = trace.len();
        let mut commit_cycle = vec![0u64; n];
        let mut stats = O3Stats { insts: n as u64, ..Default::default() };

        let mut sb = Scoreboard::default();
        let mut issue_slots = SlotRing::new();
        let mut commit_slots = SlotRing::new();
        // per-FU-class unit busy-until times
        let mut fu_busy: Vec<Vec<u64>> = FU_CLASSES
            .iter()
            .map(|c| vec![0u64; cfg.units_of(*c)])
            .collect();

        // occupancy rings: cycle at which the (i - CAP)-th entry frees
        let mut rob_free_at: Vec<u64> = vec![0; n]; // commit cycle of i
        let mut iq_free_at: Vec<u64> = vec![0; n]; // issue cycle of i
        let mut lsq_free_at: Vec<u64> = Vec::new(); // per mem-op release
        let mut mem_op_of_idx: Vec<usize> = Vec::new(); // trace idx per mem op

        let mut pending_stores: Vec<PendingStore> = Vec::new();
        // MSHR slots: completion time of each outstanding D-cache miss.
        let mut mshr_busy: Vec<u64> = vec![0; cfg.mshrs.max(1)];
        let l1d_hit = cfg.hierarchy.l1d.hit_latency;

        // ---- front-end cursor ----
        let mut fetch_cycle: u64 = 1;
        let mut fetched_in_group: usize = 0;
        let mut cur_line: u64 = u64::MAX;
        let line_mask = !(cfg.hierarchy.l1i.line_bytes as u64 - 1);
        let l1i_hit = cfg.hierarchy.l1i.hit_latency;
        // cycle before which fetch is blocked (mispredict redirect)
        let mut fetch_blocked_until: u64 = 0;

        let mut last_commit: u64 = 0;
        let mut mem_ops: usize = 0;

        for (i, rec) in trace.iter().enumerate() {
            // ================= FETCH =================
            if fetch_cycle < fetch_blocked_until {
                fetch_cycle = fetch_blocked_until;
                fetched_in_group = 0;
            }
            let line = rec.pc & line_mask;
            let new_group = fetched_in_group >= cfg.fetch_width || line != cur_line;
            if new_group {
                if fetched_in_group > 0 {
                    fetch_cycle += 1;
                }
                fetched_in_group = 0;
                if line != cur_line {
                    cur_line = line;
                    let lat = self.caches.access(Access::InstFetch, rec.pc);
                    if lat > l1i_hit {
                        stats.icache_stall_cycles += lat - l1i_hit;
                        fetch_cycle += lat - l1i_hit;
                    }
                }
            }
            fetched_in_group += 1;
            let my_fetch = fetch_cycle;

            // ================= DISPATCH (rename) =================
            let mut dispatch = my_fetch + cfg.frontend_depth;
            // ROB back-pressure: entry (i - rob_entries) must have committed
            if i >= cfg.rob_entries {
                let free = rob_free_at[i - cfg.rob_entries];
                if free + 1 > dispatch {
                    dispatch = free + 1;
                    stats.rob_stall_events += 1;
                }
            }
            // IQ back-pressure: entry (i - iq_entries) must have issued
            if i >= cfg.iq_entries {
                let free = iq_free_at[i - cfg.iq_entries];
                if free + 1 > dispatch {
                    dispatch = free + 1;
                    stats.iq_stall_events += 1;
                }
            }
            // LSQ back-pressure for memory ops
            if rec.inst.is_mem() && mem_ops >= cfg.lsq_entries {
                let free = lsq_free_at[mem_ops - cfg.lsq_entries];
                if free + 1 > dispatch {
                    dispatch = free + 1;
                    stats.lsq_stall_events += 1;
                }
            }

            // ================= ISSUE =================
            // operands ready?
            let mut ready = dispatch + 1;
            for src in rec.inst.srcs() {
                ready = ready.max(sb.get(src));
            }
            // loads: wait until older overlapping stores can forward or
            // have released; conservatively also wait for older store
            // addresses (they are computed at their `ready`)
            let class = rec.inst.fu_class();
            let width = rec.inst.mem_width().map_or(0, |w| w as u64);
            let mut forwarded = false;
            if rec.inst.is_load() {
                if let Some(addr) = rec.mem_addr {
                    for st in pending_stores.iter().rev() {
                        if st.idx < i
                            && addr < st.addr + st.bytes
                            && st.addr < addr + width
                        {
                            ready = ready.max(st.ready);
                            forwarded = true;
                            stats.stl_forwards += 1;
                            break;
                        }
                    }
                }
            }

            // find an issue cycle with a free slot and a free FU unit
            let units = &mut fu_busy[fu_index(class)];
            let mut c = ready;
            let issue = loop {
                if issue_slots.get(c) < cfg.issue_width as u32 {
                    if let Some(u) = units.iter_mut().find(|b| **b <= c) {
                        // unpipelined divider occupies until completion
                        let occupy = match class {
                            FuClass::IntDiv => cfg.lat.int_div,
                            FuClass::FpDiv => cfg.lat.fp_div,
                            _ => 1,
                        };
                        *u = c + occupy;
                        break c;
                    }
                }
                c += 1;
            };
            issue_slots.bump(issue);
            iq_free_at[i] = issue;

            // ================= EXECUTE / COMPLETE =================
            let complete = match class {
                FuClass::Load if !forwarded => {
                    let lat = self.caches.access(Access::Load, rec.mem_addr.unwrap_or(0));
                    if lat > l1d_hit {
                        // miss: needs an MSHR slot — bounds memory-level
                        // parallelism like a real L1D
                        let slot =
                            mshr_busy.iter_mut().min_by_key(|t| **t).unwrap();
                        let start = issue.max(*slot);
                        *slot = start + lat;
                        start + lat
                    } else {
                        issue + lat
                    }
                }
                FuClass::Load => issue + cfg.lat.stl_forward,
                _ => issue + cfg.lat.of(class),
            };

            // branch resolution
            if rec.inst.is_branch() {
                stats.branches += 1;
                let miss =
                    self.bp
                        .predict_and_update(rec.pc, &rec.inst, rec.taken, rec.next_pc);
                if miss {
                    stats.mispredicts += 1;
                    fetch_blocked_until =
                        fetch_blocked_until.max(complete + cfg.mispredict_penalty);
                } else if rec.taken {
                    // correctly-predicted taken branch still ends the group
                    fetched_in_group = cfg.fetch_width;
                    cur_line = u64::MAX;
                }
            }

            // ================= COMMIT =================
            let mut cc = (complete + 1).max(last_commit);
            while commit_slots.get(cc) >= cfg.commit_width as u32 {
                cc += 1;
            }
            commit_slots.bump(cc);
            commit_cycle[i] = cc;
            last_commit = cc;
            rob_free_at[i] = cc;

            // memory bookkeeping
            if rec.inst.is_mem() {
                mem_op_of_idx.push(i);
                if rec.inst.is_store() {
                    // store releases LSQ at commit; cache written at retire
                    lsq_free_at.push(cc);
                    if let Some(addr) = rec.mem_addr {
                        self.caches.access(Access::Store, addr);
                        pending_stores.push(PendingStore {
                            addr,
                            bytes: width,
                            ready: complete,
                            idx: i,
                        });
                        // keep the window small: drop stores older than ROB
                        if pending_stores.len() > cfg.rob_entries {
                            pending_stores.remove(0);
                        }
                    }
                } else {
                    lsq_free_at.push(complete);
                }
                mem_ops += 1;
            }

            sb_update(&mut sb, rec, complete);
        }

        stats.cycles = last_commit;
        O3Result { commit_cycle, stats }
    }
}

const FU_CLASSES: [FuClass; 11] = [
    FuClass::IntAlu,
    FuClass::IntMul,
    FuClass::IntDiv,
    FuClass::Load,
    FuClass::Store,
    FuClass::FpAdd,
    FuClass::FpMul,
    FuClass::FpDiv,
    FuClass::FpFma,
    FuClass::Branch,
    FuClass::Nop,
];

#[inline]
fn fu_index(c: FuClass) -> usize {
    FU_CLASSES.iter().position(|x| *x == c).unwrap()
}

#[inline]
fn sb_update(sb: &mut Scoreboard, rec: &TraceRecord, complete: u64) {
    for dst in rec.inst.dsts() {
        sb.set(dst, complete);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::AtomicCpu;
    use crate::isa::Assembler;

    fn trace_of(build: impl FnOnce(&mut Assembler)) -> Vec<TraceRecord> {
        let mut a = Assembler::new(0x1000);
        build(&mut a);
        a.halt();
        let mut cpu = AtomicCpu::load(&a.finish());
        cpu.run_trace(1_000_000)
    }

    fn simulate(trace: &[TraceRecord]) -> O3Result {
        O3Core::new(O3Config::default()).simulate(trace)
    }

    #[test]
    fn commit_cycles_monotone() {
        let t = trace_of(|a| {
            a.li(1, 100);
            a.mtctr(1);
            let top = a.here();
            a.addi(2, 2, 1);
            a.mullw(3, 2, 2);
            a.bdnz(top);
        });
        let r = simulate(&t);
        for w in r.commit_cycle.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(r.stats.insts, t.len() as u64);
        assert_eq!(r.stats.cycles, *r.commit_cycle.last().unwrap());
    }

    /// A hot loop repeating `body` `iters` times (keeps the I-cache warm so
    /// the back-end property under test dominates).
    fn loop_trace(iters: i32, body: impl Fn(&mut Assembler)) -> Vec<TraceRecord> {
        trace_of(|a| {
            a.li(31, iters);
            a.mtctr(31);
            let top = a.here();
            body(a);
            a.bdnz(top);
        })
    }

    #[test]
    fn commit_width_bounds_throughput() {
        // independent ALU work: wide core reaches high IPC, 1-wide commits 1/cycle
        let t = loop_trace(300, |a| {
            for k in 0..7u8 {
                a.addi(1 + k, 1 + k, 1);
            }
        });
        let base = simulate(&t);
        let mut narrow_cfg = O3Config::default();
        narrow_cfg.commit_width = 1;
        let narrow = O3Core::new(narrow_cfg).simulate(&t);
        assert!(base.stats.ipc() > 2.0, "wide core should exceed IPC 2, got {}", base.stats.ipc());
        assert!(narrow.stats.ipc() <= 1.01, "1-wide IPC {}", narrow.stats.ipc());
        assert!(narrow.stats.cycles > base.stats.cycles);
    }

    #[test]
    fn dependence_chain_serializes() {
        // chained adds: each depends on the previous -> IPC ~1
        let t = loop_trace(100, |a| {
            for _ in 0..8 {
                a.add(1, 1, 1);
            }
        });
        let r = simulate(&t);
        assert!(r.stats.ipc() < 1.5, "dependent chain IPC {}", r.stats.ipc());

        // independent adds across 8 registers -> much higher IPC
        let t2 = loop_trace(100, |a| {
            for k in 0..8u8 {
                a.addi(1 + k, 1 + k, 1);
            }
        });
        let r2 = simulate(&t2);
        assert!(
            r2.stats.ipc() > 1.8 * r.stats.ipc(),
            "ILP should raise IPC: {} vs {}",
            r2.stats.ipc(),
            r.stats.ipc()
        );
    }

    #[test]
    fn divider_is_unpipelined_structural_hazard() {
        let t = trace_of(|a| {
            a.li(1, 1000);
            a.li(2, 3);
            for k in 0..50u8 {
                a.divd(10 + (k % 8), 1, 2);
            }
        });
        let r = simulate(&t);
        // 50 divides on 1 unpipelined unit at 16 cycles each >= 800 cycles
        assert!(r.stats.cycles >= 700, "cycles {}", r.stats.cycles);
    }

    #[test]
    fn dcache_miss_costs_show_up() {
        // pointer-stride loads over a range far larger than L2
        let t = trace_of(|a| {
            a.load_imm64(1, 0x100000);
            a.li(2, 0);
            a.li(3, 2000);
            a.mtctr(3);
            let top = a.here();
            a.ldx(4, 1, 2);
            a.addi(2, 2, 4096); // new page every time: all misses
            a.bdnz(top);
        });
        let r_cold = simulate(&t);

        // same count of L1-hitting loads
        let t2 = trace_of(|a| {
            a.load_imm64(1, 0x100000);
            a.li(3, 2000);
            a.mtctr(3);
            let top = a.here();
            a.ld(4, 0, 1);
            a.bdnz(top);
        });
        let r_hot = simulate(&t2);
        assert!(
            r_cold.stats.cycles > 5 * r_hot.stats.cycles,
            "misses {} vs hits {}",
            r_cold.stats.cycles,
            r_hot.stats.cycles
        );
    }

    #[test]
    fn mispredicts_cost_cycles() {
        // data-dependent unpredictable branches (xorshift parity)
        let t = trace_of(|a| {
            a.li(1, 12345);
            a.li(5, 0);
            a.li(3, 400);
            a.mtctr(3);
            let top = a.here();
            // xorshift step
            a.sldi(2, 1, 13);
            a.xor(1, 1, 2);
            a.srdi(2, 1, 7);
            a.xor(1, 1, 2);
            a.andi(4, 1, 1);
            a.cmpi(4, 0);
            let skip = a.label();
            a.beq(skip);
            a.addi(5, 5, 1);
            a.bind(skip);
            a.bdnz(top);
        });
        let r = simulate(&t);
        assert!(r.stats.branches > 400);
        let rate = r.stats.mispredicts as f64 / r.stats.branches as f64;
        assert!(rate > 0.1, "unpredictable branch rate {rate}");

        // perfectly-biased loop branch: low mispredict rate
        let t2 = trace_of(|a| {
            a.li(3, 800);
            a.mtctr(3);
            let top = a.here();
            a.addi(1, 1, 1);
            a.bdnz(top);
        });
        let mut core = O3Core::new(O3Config::default());
        let r2 = core.simulate(&t2);
        let rate2 = r2.stats.mispredicts as f64 / r2.stats.branches as f64;
        assert!(rate2 < 0.05, "biased branch rate {rate2}");
        assert!(r.stats.cycles as f64 / t.len() as f64
                > r2.stats.cycles as f64 / t2.len() as f64);
    }

    #[test]
    fn store_load_forwarding_beats_cache() {
        let t = trace_of(|a| {
            a.load_imm64(1, 0x50000);
            a.li(3, 300);
            a.mtctr(3);
            let top = a.here();
            a.std(2, 0, 1);
            a.ld(4, 0, 1); // same address: forward
            a.addi(2, 4, 1);
            a.bdnz(top);
        });
        let r = simulate(&t);
        assert!(r.stats.stl_forwards >= 300);
    }

    #[test]
    fn smaller_rob_never_faster() {
        let t = trace_of(|a| {
            a.load_imm64(1, 0x80000);
            a.li(3, 500);
            a.mtctr(3);
            let top = a.here();
            a.ldx(4, 1, 2);
            a.addi(2, 2, 4096);
            a.fadd(1, 1, 1);
            a.fadd(2, 2, 2);
            a.bdnz(top);
        });
        let base = simulate(&t);
        let mut small = O3Config::default();
        small.rob_entries = 16;
        let r_small = O3Core::new(small).simulate(&t);
        assert!(r_small.stats.cycles >= base.stats.cycles);
        assert!(r_small.stats.rob_stall_events > 0);
    }

    #[test]
    fn table3_configs_all_run_and_differ() {
        let t = trace_of(|a| {
            a.li(3, 200);
            a.mtctr(3);
            let top = a.here();
            for k in 0..6u8 {
                a.addi(10 + k, 10 + k, 1);
            }
            a.mullw(20, 10, 11);
            a.bdnz(top);
        });
        let mut cycles = Vec::new();
        for (_, cfg) in O3Config::table3_rows() {
            cycles.push(O3Core::new(cfg).simulate(&t).stats.cycles);
        }
        // narrower fetch must not be faster than baseline
        assert!(cycles[1] >= cycles[0]);
        assert!(cycles[2] >= cycles[0]);
        assert!(cycles[3] >= cycles[0]);
    }

    #[test]
    fn iq_pressure_stalls_small_queue() {
        // long-latency divides pile up in the IQ; a tiny IQ must stall
        let t = loop_trace(100, |a| {
            a.divd(10, 1, 2);
            for k in 0..6u8 {
                a.addi(11 + k, 11 + k, 1);
            }
        });
        let mut small = O3Config::default();
        small.iq_entries = 4;
        let r_small = O3Core::new(small).simulate(&t);
        let r_base = simulate(&t);
        assert!(r_small.stats.iq_stall_events > 0);
        assert!(r_small.stats.cycles >= r_base.stats.cycles);
    }

    #[test]
    fn lsq_pressure_stalls_memory_streams() {
        let t = loop_trace(200, |a| {
            for k in 0..6 {
                a.ld(4, k * 8, 1);
            }
            a.std(4, 128, 1);
        });
        let mut small = O3Config::default();
        small.lsq_entries = 2;
        let r_small = O3Core::new(small).simulate(&t);
        assert!(r_small.stats.lsq_stall_events > 0);
    }

    #[test]
    fn mshr_limit_serializes_misses() {
        // independent misses to fresh pages: 1 MSHR must be much slower
        // than the default 8
        let t = loop_trace(400, |a| {
            a.ldx(4, 1, 2);
            a.addi(2, 2, 4096);
        });
        let mut one = O3Config::default();
        one.mshrs = 1;
        let r_one = O3Core::new(one).simulate(&t);
        let r_eight = simulate(&t);
        assert!(
            r_one.stats.cycles as f64 > 1.5 * r_eight.stats.cycles as f64,
            "1 MSHR {} vs 8 MSHRs {}",
            r_one.stats.cycles,
            r_eight.stats.cycles
        );
    }

    #[test]
    fn icache_stalls_counted_on_cold_code() {
        let t = trace_of(|a| {
            for _ in 0..200 {
                a.nop();
            }
        });
        let r = simulate(&t);
        assert!(r.stats.icache_stall_cycles > 0, "cold straight-line code");
    }

    #[test]
    fn reset_restores_cold_state() {
        let t = trace_of(|a| {
            a.load_imm64(1, 0x90000);
            for _ in 0..50 {
                a.ld(2, 0, 1);
            }
        });
        let mut core = O3Core::new(O3Config::default());
        let cold = core.simulate(&t).stats.cycles;
        let warm = core.simulate(&t).stats.cycles;
        core.reset();
        let cold2 = core.simulate(&t).stats.cycles;
        assert!(warm <= cold);
        assert_eq!(cold, cold2);
    }
}
