//! The cycle-level out-of-order superscalar timing model — CAPSim's
//! analogue of the paper's gem5-built O3 Power8 simulator (Fig. 1, left).
//!
//! Two roles, exactly as in the paper:
//!
//! 1. **golden label generator** — per-instruction *commit cycles* feed
//!    Algorithm 1 (the slicer) as clip execution times;
//! 2. **speed baseline** — "gem5 mode" restores every SimPoint checkpoint
//!    through this model, which is what CAPSim's Fig.-7 speedup is measured
//!    against.
//!
//! The model is trace-driven: the functional simulator supplies the dynamic
//! instruction stream (so there is no wrong-path fetch); timing honesty
//! comes from modelling the front end (fetch groups, I-cache, gshare+BTB+RAS
//! prediction with mispredict redirect), the out-of-order window (ROB / IQ /
//! LSQ occupancy, register dependences, FU structural hazards, issue width)
//! and the in-order back end (commit width, store release at retire).
//! Table III's four knobs — FetchWidth, IssueWidth, CommitWidth, ROBEntry —
//! are first-class [`O3Config`] fields.

pub mod branch_pred;
pub mod config;
pub mod core;

pub use branch_pred::{BranchPredictor, BpConfig, BpStats};
pub use config::{FuPool, Latencies, O3Config};
pub use core::{O3Core, O3Result, O3Stats};
