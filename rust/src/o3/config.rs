//! O3 core configuration. The four Table-III parameters are the headline
//! knobs; the rest fills in a Power8-flavoured mid-2010s superscalar.

use crate::isa::inst::FuClass;
use crate::mem::HierarchyConfig;

use super::branch_pred::BpConfig;

/// Functional-unit pool sizes.
#[derive(Clone, Copy, Debug)]
pub struct FuPool {
    pub int_alu: usize,
    pub int_mul: usize,
    pub int_div: usize,
    pub fp: usize,
    /// Load/store ports (shared by loads and stores).
    pub mem_ports: usize,
    pub branch: usize,
}

impl Default for FuPool {
    fn default() -> Self {
        FuPool { int_alu: 4, int_mul: 1, int_div: 1, fp: 2, mem_ports: 2, branch: 1 }
    }
}

/// Execution latencies per FU class (cycles). Memory classes are the
/// *post-cache* part; cache latency is added from the hierarchy.
#[derive(Clone, Copy, Debug)]
pub struct Latencies {
    pub int_alu: u64,
    pub int_mul: u64,
    pub int_div: u64,
    pub fp_add: u64,
    pub fp_mul: u64,
    pub fp_div: u64,
    pub fp_fma: u64,
    pub branch: u64,
    /// Store-to-load forward latency.
    pub stl_forward: u64,
}

impl Default for Latencies {
    fn default() -> Self {
        Latencies {
            int_alu: 1,
            int_mul: 4,
            int_div: 16,
            fp_add: 4,
            fp_mul: 4,
            fp_div: 24,
            fp_fma: 5,
            branch: 1,
            stl_forward: 2,
        }
    }
}

impl Latencies {
    pub fn of(&self, class: FuClass) -> u64 {
        match class {
            FuClass::IntAlu => self.int_alu,
            FuClass::IntMul => self.int_mul,
            FuClass::IntDiv => self.int_div,
            FuClass::FpAdd => self.fp_add,
            FuClass::FpMul => self.fp_mul,
            FuClass::FpDiv => self.fp_div,
            FuClass::FpFma => self.fp_fma,
            FuClass::Branch => self.branch,
            // loads/stores: execute-side latency beyond the cache access
            FuClass::Load | FuClass::Store => 1,
            FuClass::Nop => 1,
        }
    }
}

/// The full O3 configuration.
#[derive(Clone, Debug)]
pub struct O3Config {
    // ---- Table III knobs ----
    pub fetch_width: usize,
    pub issue_width: usize,
    pub commit_width: usize,
    pub rob_entries: usize,
    // ---- window ----
    pub iq_entries: usize,
    pub lsq_entries: usize,
    /// Front-end depth: cycles from fetch to dispatch (decode+rename).
    pub frontend_depth: u64,
    /// Miss-status holding registers: max overlapping D-cache misses.
    pub mshrs: usize,
    /// Extra redirect cycles after a mispredicted branch resolves.
    pub mispredict_penalty: u64,
    pub fu: FuPool,
    pub lat: Latencies,
    pub bp: BpConfig,
    pub hierarchy: HierarchyConfig,
}

impl Default for O3Config {
    /// The paper's baseline row of Table III:
    /// FetchWidth 8, IssueWidth 8, CommitWidth 8, ROBEntry 192.
    fn default() -> Self {
        O3Config {
            fetch_width: 8,
            issue_width: 8,
            commit_width: 8,
            rob_entries: 192,
            iq_entries: 64,
            lsq_entries: 48,
            frontend_depth: 5,
            mshrs: 8,
            mispredict_penalty: 8,
            fu: FuPool::default(),
            lat: Latencies::default(),
            bp: BpConfig::default(),
            hierarchy: HierarchyConfig::default(),
        }
    }
}

impl O3Config {
    /// The five Table-III rows, in paper order (baseline first).
    pub fn table3_rows() -> Vec<(String, O3Config)> {
        let base = O3Config::default();
        let mut rows = vec![("8/8/8/192".to_string(), base.clone())];
        let mut v = base.clone();
        v.fetch_width = 4;
        rows.push(("4/8/8/192".to_string(), v));
        let mut v = base.clone();
        v.issue_width = 4;
        rows.push(("8/4/8/192".to_string(), v));
        let mut v = base.clone();
        v.commit_width = 4;
        rows.push(("8/8/4/192".to_string(), v));
        let mut v = base;
        v.rob_entries = 128;
        rows.push(("8/8/8/128".to_string(), v));
        rows
    }

    pub fn units_of(&self, class: FuClass) -> usize {
        match class {
            FuClass::IntAlu => self.fu.int_alu,
            FuClass::IntMul => self.fu.int_mul,
            FuClass::IntDiv => self.fu.int_div,
            FuClass::FpAdd | FuClass::FpMul | FuClass::FpDiv | FuClass::FpFma => self.fu.fp,
            FuClass::Load | FuClass::Store => self.fu.mem_ports,
            FuClass::Branch => self.fu.branch,
            FuClass::Nop => self.fu.int_alu,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_baseline() {
        let c = O3Config::default();
        assert_eq!(c.fetch_width, 8);
        assert_eq!(c.issue_width, 8);
        assert_eq!(c.commit_width, 8);
        assert_eq!(c.rob_entries, 192);
    }

    #[test]
    fn table3_has_five_rows_varying_one_knob() {
        let rows = O3Config::table3_rows();
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[1].1.fetch_width, 4);
        assert_eq!(rows[2].1.issue_width, 4);
        assert_eq!(rows[3].1.commit_width, 4);
        assert_eq!(rows[4].1.rob_entries, 128);
        // everything else stays at baseline
        assert_eq!(rows[4].1.fetch_width, 8);
    }

    #[test]
    fn latencies_cover_all_classes() {
        let l = Latencies::default();
        for class in [FuClass::IntAlu, FuClass::IntMul, FuClass::IntDiv,
                      FuClass::FpAdd, FuClass::FpMul, FuClass::FpDiv,
                      FuClass::FpFma, FuClass::Branch, FuClass::Load,
                      FuClass::Store, FuClass::Nop] {
            assert!(l.of(class) >= 1);
        }
        assert!(l.of(FuClass::IntDiv) > l.of(FuClass::IntMul));
    }
}
