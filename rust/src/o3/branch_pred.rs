//! Branch prediction for the O3 front end: gshare direction predictor,
//! branch target buffer, and a return-address stack (Power's `bl`/`blr`
//! idiom makes the RAS essential).

use crate::isa::{Inst, Opcode};

/// Predictor configuration.
#[derive(Clone, Copy, Debug)]
pub struct BpConfig {
    /// Global-history bits (gshare table is `1 << bits` 2-bit counters).
    pub ghist_bits: u32,
    /// BTB entries (direct-mapped, tagged).
    pub btb_entries: usize,
    /// Return-address stack depth.
    pub ras_entries: usize,
}

impl Default for BpConfig {
    fn default() -> Self {
        BpConfig { ghist_bits: 12, btb_entries: 2048, ras_entries: 16 }
    }
}

/// Aggregate prediction statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct BpStats {
    pub branches: u64,
    pub mispredicts: u64,
    pub direction_mispredicts: u64,
    pub target_mispredicts: u64,
}

impl BpStats {
    pub fn mispredict_rate(&self) -> f64 {
        if self.branches == 0 {
            0.0
        } else {
            self.mispredicts as f64 / self.branches as f64
        }
    }
}

#[derive(Clone, Copy, Default)]
struct BtbEntry {
    tag: u64,
    target: u64,
    valid: bool,
}

/// gshare + BTB + RAS.
#[derive(Clone)]
pub struct BranchPredictor {
    cfg: BpConfig,
    counters: Vec<u8>, // 2-bit saturating
    ghist: u64,
    btb: Vec<BtbEntry>,
    ras: Vec<u64>,
    pub stats: BpStats,
}

impl BranchPredictor {
    pub fn new(cfg: BpConfig) -> Self {
        BranchPredictor {
            cfg,
            counters: vec![1; 1 << cfg.ghist_bits], // weakly not-taken
            ghist: 0,
            btb: vec![BtbEntry::default(); cfg.btb_entries],
            ras: Vec::new(),
            stats: BpStats::default(),
        }
    }

    #[inline]
    fn gidx(&self, pc: u64) -> usize {
        let mask = (1u64 << self.cfg.ghist_bits) - 1;
        (((pc >> 2) ^ self.ghist) & mask) as usize
    }

    #[inline]
    fn bidx(&self, pc: u64) -> usize {
        ((pc >> 2) as usize) % self.btb.len()
    }

    /// Predict and immediately train on the actual outcome; returns
    /// whether the branch was **mispredicted** (direction or target).
    ///
    /// `inst` must be a branch; `taken`/`target` are the true outcome from
    /// the functional trace.
    pub fn predict_and_update(&mut self, pc: u64, inst: &Inst, taken: bool, target: u64) -> bool {
        self.stats.branches += 1;

        // ---- direction ----
        let (pred_taken, gi) = if inst.is_cond_branch() {
            let gi = self.gidx(pc);
            (self.counters[gi] >= 2, Some(gi))
        } else {
            (true, None) // unconditional / indirect always "taken"
        };

        // ---- target ----
        let pred_target = match inst.op {
            Opcode::Blr => self.ras.last().copied(),
            _ => {
                let e = &self.btb[self.bidx(pc)];
                if e.valid && e.tag == pc {
                    Some(e.target)
                } else {
                    None
                }
            }
        };

        let dir_wrong = pred_taken != taken;
        // target only matters if the branch is (and is predicted) taken
        let target_wrong = taken && !dir_wrong && pred_target != Some(target);
        let mispredict = dir_wrong || target_wrong;

        if dir_wrong {
            self.stats.direction_mispredicts += 1;
        } else if target_wrong {
            self.stats.target_mispredicts += 1;
        }
        if mispredict {
            self.stats.mispredicts += 1;
        }

        // ---- train ----
        if let Some(gi) = gi {
            let c = &mut self.counters[gi];
            if taken {
                *c = (*c + 1).min(3);
            } else {
                *c = c.saturating_sub(1);
            }
            self.ghist = (self.ghist << 1) | taken as u64;
        }
        if taken {
            let bi = self.bidx(pc);
            self.btb[bi] = BtbEntry { tag: pc, target, valid: true };
        }
        match inst.op {
            Opcode::Bl => {
                if self.ras.len() == self.cfg.ras_entries {
                    self.ras.remove(0);
                }
                self.ras.push(pc + 4);
            }
            Opcode::Blr => {
                self.ras.pop();
            }
            _ => {}
        }

        mispredict
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Inst, Opcode};

    fn bp() -> BranchPredictor {
        BranchPredictor::new(BpConfig { ghist_bits: 8, btb_entries: 64, ras_entries: 8 })
    }

    #[test]
    fn learns_always_taken_loop() {
        let mut p = bp();
        let i = Inst::new(Opcode::Bdnz, 0, 0, 0, -4);
        let mut wrong = 0;
        for _ in 0..100 {
            if p.predict_and_update(0x1000, &i, true, 0x0FF0) {
                wrong += 1;
            }
        }
        // gshare needs ~ghist_bits iterations to fill its history with the
        // loop pattern before every indexed counter saturates
        assert!(wrong <= 12, "should converge within warmup, got {wrong}");
        let mut late_wrong = 0;
        for _ in 0..100 {
            if p.predict_and_update(0x1000, &i, true, 0x0FF0) {
                late_wrong += 1;
            }
        }
        assert_eq!(late_wrong, 0, "must be perfect once warm");
    }

    #[test]
    fn learns_alternating_pattern_via_history() {
        let mut p = bp();
        let i = Inst::new(Opcode::Beq, 0, 0, 0, 8);
        let mut wrong_late = 0;
        for n in 0..200 {
            let taken = n % 2 == 0;
            let w = p.predict_and_update(0x2000, &i, taken, 0x2020);
            if n >= 100 && w {
                wrong_late += 1;
            }
        }
        assert!(wrong_late <= 5, "gshare should capture T/NT alternation, got {wrong_late}");
    }

    #[test]
    fn unconditional_needs_btb_warmup_only() {
        let mut p = bp();
        let i = Inst::new(Opcode::B, 0, 0, 0, 16);
        assert!(p.predict_and_update(0x3000, &i, true, 0x3040)); // cold BTB
        assert!(!p.predict_and_update(0x3000, &i, true, 0x3040)); // warm
    }

    #[test]
    fn ras_predicts_returns() {
        let mut p = bp();
        let bl = Inst::new(Opcode::Bl, 0, 0, 0, 100);
        let blr = Inst::new(Opcode::Blr, 0, 0, 0, 0);
        // call from two sites; returns must be predicted by RAS, not BTB
        p.predict_and_update(0x1000, &bl, true, 0x2000);
        assert!(!p.predict_and_update(0x2000, &blr, true, 0x1004));
        p.predict_and_update(0x1100, &bl, true, 0x2000);
        assert!(!p.predict_and_update(0x2000, &blr, true, 0x1104));
    }

    #[test]
    fn stats_accumulate() {
        let mut p = bp();
        let i = Inst::new(Opcode::Beq, 0, 0, 0, 4);
        for n in 0..10 {
            p.predict_and_update(0x10, &i, n % 3 == 0, 0x20);
        }
        assert_eq!(p.stats.branches, 10);
        assert!(p.stats.mispredicts > 0);
        assert!(p.stats.mispredict_rate() <= 1.0);
    }
}
