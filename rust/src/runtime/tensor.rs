//! Minimal f32 tensor kernels for the pure-Rust attention backend.
//!
//! No BLAS, no SIMD intrinsics, no dependencies: plain row-major loops in
//! a fixed evaluation order, so every result is a deterministic function
//! of the inputs — bit-identical across runs, thread counts and batch
//! compositions (the backend calls these per clip row, never across
//! rows). Rust never applies fast-math, so `opt-level` does not change
//! the produced bits either.
//!
//! Numerical contracts the property tests pin down
//! (`tests/prop_attention.rs`):
//!
//! * [`masked_softmax`] rows with at least one live column sum to 1 (up
//!   to rounding) and contain no NaN/inf;
//! * fully-masked rows are **well-defined**: all-zero, not NaN (the
//!   attention layer reads them as "attend to nothing");
//! * [`layernorm`] of an all-zero vector is the bias vector (variance 0
//!   is regularized by `EPS`, never divided through directly).

/// Variance regularizer for [`layernorm`].
const EPS: f32 = 1e-5;

/// Row-major matrix product: `out[m, n] = a[m, k] · b[k, n]`.
///
/// `out` is fully overwritten. The k-loop is innermost and accumulates
/// into an f32 register in index order — the canonical scalar schedule.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "lhs shape");
    assert_eq!(b.len(), k * n, "rhs shape");
    assert_eq!(out.len(), m * n, "out shape");
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            out[i * n + j] = acc;
        }
    }
}

/// Vector-matrix product: `out[n] = x[k] · w[k, n]` (a 1-row [`matmul`]).
pub fn vecmat(x: &[f32], w: &[f32], k: usize, n: usize, out: &mut [f32]) {
    matmul(x, w, 1, k, n, out);
}

/// Add a bias vector to every length-`n` row of `x`.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    assert!(n > 0 && x.len() % n == 0, "rows must be bias-sized");
    for row in x.chunks_exact_mut(n) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// In-place masked softmax over each length-`cols` row of `scores`.
///
/// `mask[j] != 0.0` marks column `j` live; the same mask applies to every
/// row (the attention use: one key-padding mask shared by all queries).
/// Masked columns get probability exactly 0.0. A row whose mask is all
/// zero becomes all zeros — a defined, NaN-free "attend to nothing" row —
/// rather than the NaN a naive `exp / sum` would produce.
pub fn masked_softmax(scores: &mut [f32], rows: usize, cols: usize, mask: &[f32]) {
    assert_eq!(scores.len(), rows * cols, "scores shape");
    assert_eq!(mask.len(), cols, "mask shape");
    for r in 0..rows {
        let row = &mut scores[r * cols..(r + 1) * cols];
        // max over live columns for the usual exp-shift stability
        let mut max = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if mask[j] != 0.0 && v > max {
                max = v;
            }
        }
        if max == f32::NEG_INFINITY {
            row.fill(0.0);
            continue;
        }
        let mut sum = 0.0f32;
        for (j, v) in row.iter_mut().enumerate() {
            if mask[j] != 0.0 {
                *v = (*v - max).exp();
                sum += *v;
            } else {
                *v = 0.0;
            }
        }
        // sum >= 1 because the max column contributes exp(0) = 1
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// In-place layer normalization of each length-`gamma.len()` row of `x`:
/// `x = (x - mean) / sqrt(var + EPS) * gamma + beta`.
pub fn layernorm(x: &mut [f32], gamma: &[f32], beta: &[f32]) {
    let d = gamma.len();
    assert_eq!(beta.len(), d, "gamma/beta shape");
    assert!(d > 0 && x.len() % d == 0, "rows must be d-sized");
    for row in x.chunks_exact_mut(d) {
        let mut mean = 0.0f32;
        for &v in row.iter() {
            mean += v;
        }
        mean /= d as f32;
        let mut var = 0.0f32;
        for &v in row.iter() {
            let c = v - mean;
            var += c * c;
        }
        var /= d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma[j] + beta[j];
        }
    }
}

/// GELU activation (tanh approximation, as in the original BERT/GPT
/// formulation): `0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3)))`.
pub fn gelu(x: f32) -> f32 {
    // sqrt(2/pi), to f32 precision
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// Apply [`gelu`] element-wise.
pub fn gelu_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu(*v);
    }
}

/// Numerically stable softplus `ln(1 + e^x)`: strictly positive, smooth,
/// and asymptotically `x` for large `x` (no overflow).
pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = [1.5, -2.0, 0.25, 7.0, 3.0, -1.0];
        let id = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let mut out = [0.0f32; 6];
        matmul(&a, &id, 2, 3, 3, &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn vecmat_is_one_row_matmul() {
        let x = [1.0, 2.0, 3.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // [3, 2]
        let mut out = [0.0f32; 2];
        vecmat(&x, &w, 3, 2, &mut out);
        assert_eq!(out, [4.0, 5.0]);
    }

    #[test]
    fn add_bias_every_row() {
        let mut x = [1.0, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0]);
        assert_eq!(x, [11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn softmax_unmasked_row_sums_to_one() {
        let mut s = [1.0f32, 2.0, 3.0, 4.0];
        masked_softmax(&mut s, 1, 4, &[1.0; 4]);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // monotone inputs stay monotone
        assert!(s[0] < s[1] && s[1] < s[2] && s[2] < s[3]);
    }

    #[test]
    fn softmax_masked_columns_are_exactly_zero() {
        let mut s = [10.0f32, 999.0, -3.0];
        masked_softmax(&mut s, 1, 3, &[1.0, 0.0, 1.0]);
        assert_eq!(s[1], 0.0, "masked column contributes nothing");
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_fully_masked_row_is_zero_not_nan() {
        let mut s = [5.0f32, -2.0, 0.5];
        masked_softmax(&mut s, 1, 3, &[0.0; 3]);
        assert_eq!(s, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn softmax_is_shift_invariant_and_finite_at_extremes() {
        let mut a = [1e4f32, 1e4 + 1.0];
        masked_softmax(&mut a, 1, 2, &[1.0, 1.0]);
        assert!(a.iter().all(|v| v.is_finite()));
        let mut b = [0.0f32, 1.0];
        masked_softmax(&mut b, 1, 2, &[1.0, 1.0]);
        assert!((a[0] - b[0]).abs() < 1e-6 && (a[1] - b[1]).abs() < 1e-6);
    }

    #[test]
    fn layernorm_normalizes_then_scales() {
        let mut x = [1.0f32, 2.0, 3.0, 4.0];
        layernorm(&mut x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = x.iter().sum::<f32>() / 4.0;
        let var: f32 = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3, "var {var}");
    }

    #[test]
    fn layernorm_zero_vector_yields_beta() {
        let mut x = [0.0f32; 3];
        layernorm(&mut x, &[2.0; 3], &[0.5, -0.5, 1.5]);
        assert_eq!(x, [0.5, -0.5, 1.5]);
    }

    #[test]
    fn gelu_fixed_points_and_sign() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3, "large x passes through");
        assert!(gelu(-10.0).abs() < 1e-3, "large negative x gates to 0");
        assert!(gelu(1.0) > 0.8 && gelu(1.0) < 0.9);
    }

    #[test]
    fn softplus_positive_and_asymptotic() {
        assert!(softplus(-50.0) > 0.0);
        assert!((softplus(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        assert_eq!(softplus(50.0), 50.0);
        assert!(softplus(5.0) > 5.0 && softplus(5.0) < 5.01);
    }
}
