//! Minimal f32 tensor kernels for the pure-Rust attention backend.
//!
//! No BLAS, no SIMD intrinsics, no dependencies: plain row-major loops in
//! a fixed evaluation order, so every result is a deterministic function
//! of the inputs — bit-identical across runs, thread counts and batch
//! compositions. Rust never applies fast-math, so `opt-level` does not
//! change the produced bits either.
//!
//! Two kernel tiers share one arithmetic contract:
//!
//! * the **naive scalar tier** ([`matmul`], [`vecmat`], [`add_bias`]) —
//!   the reference schedule: for each output element, accumulate over
//!   `k` in index order into a single f32 register;
//! * the **packed tier** ([`PackedLinear`]) — the hot-loop layout: the
//!   weight matrix is pre-transposed once at model build, so every dot
//!   product walks two contiguous slices, the bias add is folded into
//!   the store, several matrices sharing an input fuse into one
//!   projection (Q‖K‖V), and the output space is cache-blocked and
//!   register-tiled.
//!
//! The packed tier is **bit-identical** to the naive tier by
//! construction: blocking and tiling only reorder *which output
//! elements* are computed when; every output element still accumulates
//! over the full `k` range, in index order, in its own register, and the
//! bias is still one addition after the full accumulation — exactly the
//! naive `matmul` + `add_bias` sequence. (This is also why there is no
//! k-blocking and no multi-accumulator unroll over `k`: either would
//! split the accumulation and change the rounding.) The unit tests below
//! and `tests/prop_attention.rs` pin the equivalence bit-for-bit.
//!
//! Numerical contracts the property tests pin down
//! (`tests/prop_attention.rs`):
//!
//! * [`masked_softmax`] rows with at least one live column sum to 1 (up
//!   to rounding) and contain no NaN/inf;
//! * fully-masked rows are **well-defined**: all-zero, not NaN (the
//!   attention layer reads them as "attend to nothing");
//! * [`layernorm`] of an all-zero vector is the bias vector (variance 0
//!   is regularized by `EPS`, never divided through directly).

/// Variance regularizer for [`layernorm`].
const EPS: f32 = 1e-5;

/// Output-row tile edge of [`PackedLinear::apply`]: `BLOCK_M` input rows
/// (`BLOCK_M × k` floats, ≤ 8 KiB at the model's k ∈ {64, 128}) are
/// reused against each weight tile while it is cache-resident.
const BLOCK_M: usize = 16;

/// Output-column tile edge of [`PackedLinear::apply`]: one tile of packed
/// weight rows (`BLOCK_N × k` floats, 16–32 KiB at the model's shapes)
/// stays L1/L2-resident while every input row of the M-tile streams
/// against it.
const BLOCK_N: usize = 64;

/// A linear layer packed for the inference hot loop: weights stored
/// **pre-transposed** (`wt[j * k + p] = w[p * n + j]`, i.e. row `j` of
/// `wt` is column `j` of the row-major `[k, n]` matrix `w`), with an
/// optional bias folded into the store. `apply` then computes every
/// output as a dot product of two contiguous slices — no strided walk
/// over the weight matrix — under the cache-blocking described in the
/// module docs, and is bit-identical to naive [`matmul`] (+
/// [`add_bias`]).
pub struct PackedLinear {
    /// Transposed weights, row-major `[n, k]`.
    wt: Vec<f32>,
    /// Per-output bias; empty = no bias.
    bias: Vec<f32>,
    k: usize,
    n: usize,
}

impl PackedLinear {
    /// Pack a row-major `[k, n]` matrix (no bias).
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedLinear {
        PackedLinear::pack_with_bias(w, &[], k, n)
    }

    /// Pack a row-major `[k, n]` matrix with a length-`n` bias that
    /// `apply` adds after the full accumulation (one addition per
    /// output, exactly like a separate [`add_bias`] pass).
    pub fn pack_with_bias(w: &[f32], bias: &[f32], k: usize, n: usize) -> PackedLinear {
        assert!(k > 0 && n > 0, "degenerate shape");
        assert_eq!(w.len(), k * n, "weight shape");
        assert!(bias.is_empty() || bias.len() == n, "bias shape");
        let mut wt = vec![0.0f32; k * n];
        for p in 0..k {
            for j in 0..n {
                wt[j * k + p] = w[p * n + j];
            }
        }
        PackedLinear { wt, bias: bias.to_vec(), k, n }
    }

    /// Fuse several row-major `[k, n_i]` matrices that share one input
    /// into a single packed `[k, Σ n_i]` projection (the Q‖K‖V fusion):
    /// one `apply` then produces the concatenated outputs, each
    /// bit-identical to its standalone [`matmul`].
    pub fn pack_fused(parts: &[(&[f32], usize)], k: usize) -> PackedLinear {
        assert!(k > 0 && !parts.is_empty(), "degenerate fusion");
        let n: usize = parts.iter().map(|&(_, ni)| ni).sum();
        assert!(n > 0, "degenerate shape");
        let mut wt = Vec::with_capacity(k * n);
        for &(w, ni) in parts {
            assert_eq!(w.len(), k * ni, "fused part shape");
            for j in 0..ni {
                for p in 0..k {
                    wt.push(w[p * ni + j]);
                }
            }
        }
        PackedLinear { wt, bias: Vec::new(), k, n }
    }

    /// Input width.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output width (the fused total for [`PackedLinear::pack_fused`]).
    pub fn n(&self) -> usize {
        self.n
    }

    /// `out[m, n] = x[m, k] · W (+ bias)` over the packed layout,
    /// cache-blocked and register-tiled; bit-identical to [`matmul`]
    /// followed by [`add_bias`] (see the module docs for why).
    pub fn apply(&self, x: &[f32], m: usize, out: &mut [f32]) {
        let (k, n) = (self.k, self.n);
        assert_eq!(x.len(), m * k, "input shape");
        assert_eq!(out.len(), m * n, "output shape");
        for i0 in (0..m).step_by(BLOCK_M) {
            let i1 = (i0 + BLOCK_M).min(m);
            for j0 in (0..n).step_by(BLOCK_N) {
                let j1 = (j0 + BLOCK_N).min(n);
                for i in i0..i1 {
                    let a = &x[i * k..(i + 1) * k];
                    let orow = &mut out[i * n..(i + 1) * n];
                    // 4-wide register tile: four packed weight rows
                    // stream against a single pass over `a`, each output
                    // in its own accumulator walking k in index order
                    let mut j = j0;
                    while j + 4 <= j1 {
                        let w0 = &self.wt[j * k..(j + 1) * k];
                        let w1 = &self.wt[(j + 1) * k..(j + 2) * k];
                        let w2 = &self.wt[(j + 2) * k..(j + 3) * k];
                        let w3 = &self.wt[(j + 3) * k..(j + 4) * k];
                        let (mut s0, mut s1, mut s2, mut s3) =
                            (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                        for p in 0..k {
                            let av = a[p];
                            s0 += av * w0[p];
                            s1 += av * w1[p];
                            s2 += av * w2[p];
                            s3 += av * w3[p];
                        }
                        if self.bias.is_empty() {
                            orow[j] = s0;
                            orow[j + 1] = s1;
                            orow[j + 2] = s2;
                            orow[j + 3] = s3;
                        } else {
                            orow[j] = s0 + self.bias[j];
                            orow[j + 1] = s1 + self.bias[j + 1];
                            orow[j + 2] = s2 + self.bias[j + 2];
                            orow[j + 3] = s3 + self.bias[j + 3];
                        }
                        j += 4;
                    }
                    while j < j1 {
                        let w0 = &self.wt[j * k..(j + 1) * k];
                        let mut s0 = 0.0f32;
                        for p in 0..k {
                            s0 += a[p] * w0[p];
                        }
                        orow[j] = if self.bias.is_empty() { s0 } else { s0 + self.bias[j] };
                        j += 1;
                    }
                }
            }
        }
    }
}

/// Row-major matrix product: `out[m, n] = a[m, k] · b[k, n]`.
///
/// `out` is fully overwritten. The k-loop is innermost and accumulates
/// into an f32 register in index order — the canonical scalar schedule.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "lhs shape");
    assert_eq!(b.len(), k * n, "rhs shape");
    assert_eq!(out.len(), m * n, "out shape");
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            out[i * n + j] = acc;
        }
    }
}

/// Vector-matrix product: `out[n] = x[k] · w[k, n]` (a 1-row [`matmul`]).
pub fn vecmat(x: &[f32], w: &[f32], k: usize, n: usize, out: &mut [f32]) {
    matmul(x, w, 1, k, n, out);
}

/// Add a bias vector to every length-`n` row of `x`.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    assert!(n > 0 && x.len() % n == 0, "rows must be bias-sized");
    for row in x.chunks_exact_mut(n) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// In-place masked softmax over each length-`cols` row of `scores`.
///
/// `mask[j] != 0.0` marks column `j` live; the same mask applies to every
/// row (the attention use: one key-padding mask shared by all queries).
/// Masked columns get probability exactly 0.0. A row whose mask is all
/// zero becomes all zeros — a defined, NaN-free "attend to nothing" row —
/// rather than the NaN a naive `exp / sum` would produce.
pub fn masked_softmax(scores: &mut [f32], rows: usize, cols: usize, mask: &[f32]) {
    assert_eq!(scores.len(), rows * cols, "scores shape");
    assert_eq!(mask.len(), cols, "mask shape");
    for r in 0..rows {
        let row = &mut scores[r * cols..(r + 1) * cols];
        // max over live columns for the usual exp-shift stability
        let mut max = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if mask[j] != 0.0 && v > max {
                max = v;
            }
        }
        if max == f32::NEG_INFINITY {
            row.fill(0.0);
            continue;
        }
        let mut sum = 0.0f32;
        for (j, v) in row.iter_mut().enumerate() {
            if mask[j] != 0.0 {
                *v = (*v - max).exp();
                sum += *v;
            } else {
                *v = 0.0;
            }
        }
        // sum >= 1 because the max column contributes exp(0) = 1
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// In-place layer normalization of each length-`gamma.len()` row of `x`:
/// `x = (x - mean) / sqrt(var + EPS) * gamma + beta`.
pub fn layernorm(x: &mut [f32], gamma: &[f32], beta: &[f32]) {
    let d = gamma.len();
    assert_eq!(beta.len(), d, "gamma/beta shape");
    assert!(d > 0 && x.len() % d == 0, "rows must be d-sized");
    for row in x.chunks_exact_mut(d) {
        let mut mean = 0.0f32;
        for &v in row.iter() {
            mean += v;
        }
        mean /= d as f32;
        let mut var = 0.0f32;
        for &v in row.iter() {
            let c = v - mean;
            var += c * c;
        }
        var /= d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma[j] + beta[j];
        }
    }
}

/// GELU activation (tanh approximation, as in the original BERT/GPT
/// formulation): `0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3)))`.
pub fn gelu(x: f32) -> f32 {
    // sqrt(2/pi), to f32 precision
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// Apply [`gelu`] element-wise.
pub fn gelu_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu(*v);
    }
}

/// Numerically stable softplus `ln(1 + e^x)`: strictly positive, smooth,
/// and asymptotically `x` for large `x` (no overflow).
pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = [1.5, -2.0, 0.25, 7.0, 3.0, -1.0];
        let id = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let mut out = [0.0f32; 6];
        matmul(&a, &id, 2, 3, 3, &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn vecmat_is_one_row_matmul() {
        let x = [1.0, 2.0, 3.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // [3, 2]
        let mut out = [0.0f32; 2];
        vecmat(&x, &w, 3, 2, &mut out);
        assert_eq!(out, [4.0, 5.0]);
    }

    #[test]
    fn add_bias_every_row() {
        let mut x = [1.0, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0]);
        assert_eq!(x, [11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn softmax_unmasked_row_sums_to_one() {
        let mut s = [1.0f32, 2.0, 3.0, 4.0];
        masked_softmax(&mut s, 1, 4, &[1.0; 4]);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // monotone inputs stay monotone
        assert!(s[0] < s[1] && s[1] < s[2] && s[2] < s[3]);
    }

    #[test]
    fn softmax_masked_columns_are_exactly_zero() {
        let mut s = [10.0f32, 999.0, -3.0];
        masked_softmax(&mut s, 1, 3, &[1.0, 0.0, 1.0]);
        assert_eq!(s[1], 0.0, "masked column contributes nothing");
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_fully_masked_row_is_zero_not_nan() {
        let mut s = [5.0f32, -2.0, 0.5];
        masked_softmax(&mut s, 1, 3, &[0.0; 3]);
        assert_eq!(s, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn softmax_is_shift_invariant_and_finite_at_extremes() {
        let mut a = [1e4f32, 1e4 + 1.0];
        masked_softmax(&mut a, 1, 2, &[1.0, 1.0]);
        assert!(a.iter().all(|v| v.is_finite()));
        let mut b = [0.0f32, 1.0];
        masked_softmax(&mut b, 1, 2, &[1.0, 1.0]);
        assert!((a[0] - b[0]).abs() < 1e-6 && (a[1] - b[1]).abs() < 1e-6);
    }

    #[test]
    fn layernorm_normalizes_then_scales() {
        let mut x = [1.0f32, 2.0, 3.0, 4.0];
        layernorm(&mut x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = x.iter().sum::<f32>() / 4.0;
        let var: f32 = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3, "var {var}");
    }

    #[test]
    fn layernorm_zero_vector_yields_beta() {
        let mut x = [0.0f32; 3];
        layernorm(&mut x, &[2.0; 3], &[0.5, -0.5, 1.5]);
        assert_eq!(x, [0.5, -0.5, 1.5]);
    }

    #[test]
    fn gelu_fixed_points_and_sign() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3, "large x passes through");
        assert!(gelu(-10.0).abs() < 1e-3, "large negative x gates to 0");
        assert!(gelu(1.0) > 0.8 && gelu(1.0) < 0.9);
    }

    #[test]
    fn softplus_positive_and_asymptotic() {
        assert!(softplus(-50.0) > 0.0);
        assert!((softplus(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        assert_eq!(softplus(50.0), 50.0);
        assert!(softplus(5.0) > 5.0 && softplus(5.0) < 5.01);
    }

    fn random_matrix(rng: &mut crate::util::Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * 3.0).collect()
    }

    #[test]
    fn packed_apply_bit_equals_naive_matmul_across_tile_boundaries() {
        // shapes straddling every tile edge: smaller than one tile,
        // exactly one tile, and ragged multi-tile remainders
        let mut rng = crate::util::Rng::new(41);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 64, 192),
            (3, 7, 5),
            (16, 64, 64),
            (17, 33, 65),
            (40, 128, 64),
            (33, 16, 130),
        ] {
            let a = random_matrix(&mut rng, m * k);
            let w = random_matrix(&mut rng, k * n);
            let mut naive = vec![0.0f32; m * n];
            matmul(&a, &w, m, k, n, &mut naive);
            let packed = PackedLinear::pack(&w, k, n);
            assert_eq!((packed.k(), packed.n()), (k, n));
            let mut fast = vec![f32::NAN; m * n];
            packed.apply(&a, m, &mut fast);
            for (i, (x, y)) in naive.iter().zip(&fast).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n}) elem {i}");
            }
        }
    }

    #[test]
    fn packed_bias_bit_equals_matmul_then_add_bias() {
        let mut rng = crate::util::Rng::new(42);
        let (m, k, n) = (9usize, 24usize, 70usize);
        let a = random_matrix(&mut rng, m * k);
        let w = random_matrix(&mut rng, k * n);
        let bias = random_matrix(&mut rng, n);
        let mut naive = vec![0.0f32; m * n];
        matmul(&a, &w, m, k, n, &mut naive);
        add_bias(&mut naive, &bias);
        let packed = PackedLinear::pack_with_bias(&w, &bias, k, n);
        let mut fast = vec![f32::NAN; m * n];
        packed.apply(&a, m, &mut fast);
        for (i, (x, y)) in naive.iter().zip(&fast).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "elem {i}");
        }
    }

    #[test]
    fn fused_projection_bit_equals_separate_matmuls() {
        // the Q‖K‖V fusion: one packed apply == three naive matmuls
        let mut rng = crate::util::Rng::new(43);
        let (m, k, d) = (5usize, 32usize, 32usize);
        let a = random_matrix(&mut rng, m * k);
        let wq = random_matrix(&mut rng, k * d);
        let wk = random_matrix(&mut rng, k * d);
        let wv = random_matrix(&mut rng, k * d);
        let fused = PackedLinear::pack_fused(&[(&wq, d), (&wk, d), (&wv, d)], k);
        assert_eq!(fused.n(), 3 * d);
        let mut out = vec![f32::NAN; m * 3 * d];
        fused.apply(&a, m, &mut out);
        for (part, w) in [(0usize, &wq), (1, &wk), (2, &wv)] {
            let mut naive = vec![0.0f32; m * d];
            matmul(&a, w, m, k, d, &mut naive);
            for i in 0..m {
                for j in 0..d {
                    assert_eq!(
                        naive[i * d + j].to_bits(),
                        out[i * 3 * d + part * d + j].to_bits(),
                        "part {part} elem ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_apply_handles_zero_rows() {
        let packed = PackedLinear::pack(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        let mut out: [f32; 0] = [];
        packed.apply(&[], 0, &mut out);
    }
}
