//! Minimal f32 tensor kernels for the pure-Rust attention backend.
//!
//! No BLAS, no dependencies: every kernel is one width-generic
//! algorithm in [`simd::body`](crate::runtime::simd), instantiated here
//! with the portable [`ScalarLanes`] lane type — so the functions in
//! this module *are* the canonical semantics. The runtime-dispatched
//! `_tier` variants ([`matmul_tier`], [`PackedLinear::apply_tier`], …)
//! run the same algorithm over a hardware lane type (AVX2 on x86_64,
//! NEON on aarch64) selected by [`KernelTier`]; because all tiers share
//! the **canonical accumulation order** — element `i` accumulates into
//! lane `i % 8`, tails are zero-padded, lanes reduce through one
//! fixed-shape tree (see `PackedF32::tree_sum`) — a `_tier` call is
//! bit-identical to its plain sibling on every host. Rust never applies
//! fast-math, so `opt-level` does not change produced bits either.
//!
//! Layout tiers on top of the lane tier:
//!
//! * the **naive schedule** ([`matmul`], [`vecmat`], [`add_bias`]) —
//!   reference layout: strided column gathers, no packing;
//! * the **packed schedule** ([`PackedLinear`]) — the hot-loop layout:
//!   the weight matrix is pre-transposed once at model build so every
//!   dot product walks two contiguous slices, the bias add is folded
//!   into the store, several matrices sharing an input fuse into one
//!   projection (Q‖K‖V), and the output space is cache-blocked and
//!   register-tiled.
//!
//! The packed schedule is **bit-identical** to the naive schedule by
//! construction: blocking and tiling only reorder *which output
//! elements* are computed when; every output element still accumulates
//! over the full `k` range in the canonical lane order, and the bias is
//! still one addition after the full reduction — exactly the naive
//! [`matmul`] + [`add_bias`] sequence. (This is also why there is no
//! k-blocking: splitting the accumulation differently would change the
//! rounding.) The unit tests below, `tests/prop_attention.rs` and
//! `tests/prop_kernel_tiers.rs` pin both equivalences bit-for-bit.
//!
//! Numerical contracts the property tests pin down
//! (`tests/prop_attention.rs`):
//!
//! * [`masked_softmax`] rows with at least one live column sum to 1 (up
//!   to rounding) and contain no NaN/inf;
//! * fully-masked rows are **well-defined**: all-zero, not NaN (the
//!   attention layer reads them as "attend to nothing");
//! * [`layernorm`] of an all-zero vector is the bias vector (variance 0
//!   is regularized by `EPS`, never divided through directly).

use crate::runtime::simd::{self, body, KernelTier, ScalarLanes};

/// Variance regularizer for [`layernorm`].
const EPS: f32 = 1e-5;

/// Output-row tile edge of [`PackedLinear::apply`]: `BLOCK_M` input rows
/// (`BLOCK_M × k` floats, ≤ 8 KiB at the model's k ∈ {64, 128}) are
/// reused against each weight tile while it is cache-resident.
pub(crate) const BLOCK_M: usize = 16;

/// Output-column tile edge of [`PackedLinear::apply`]: one tile of packed
/// weight rows (`BLOCK_N × k` floats, 16–32 KiB at the model's shapes)
/// stays L1/L2-resident while every input row of the M-tile streams
/// against it.
pub(crate) const BLOCK_N: usize = 64;

/// `sqrt(2/pi)` to f32 precision — the [`gelu`] tanh-approximation
/// constant, shared with the lane-generic kernel bodies.
pub(crate) const SQRT_2_OVER_PI: f32 = 0.797_884_6;

/// A linear layer packed for the inference hot loop: weights stored
/// **pre-transposed** (`wt[j * k + p] = w[p * n + j]`, i.e. row `j` of
/// `wt` is column `j` of the row-major `[k, n]` matrix `w`), with an
/// optional bias folded into the store. `apply` then computes every
/// output as a dot product of two contiguous slices — no strided walk
/// over the weight matrix — under the cache-blocking described in the
/// module docs, and is bit-identical to naive [`matmul`] (+
/// [`add_bias`]).
pub struct PackedLinear {
    /// Transposed weights, row-major `[n, k]` (read by the lane-generic
    /// kernel bodies).
    pub(crate) wt: Vec<f32>,
    /// Per-output bias; empty = no bias.
    pub(crate) bias: Vec<f32>,
    pub(crate) k: usize,
    pub(crate) n: usize,
}

impl PackedLinear {
    /// Pack a row-major `[k, n]` matrix (no bias).
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedLinear {
        PackedLinear::pack_with_bias(w, &[], k, n)
    }

    /// Pack a row-major `[k, n]` matrix with a length-`n` bias that
    /// `apply` adds after the full accumulation (one addition per
    /// output, exactly like a separate [`add_bias`] pass).
    pub fn pack_with_bias(w: &[f32], bias: &[f32], k: usize, n: usize) -> PackedLinear {
        assert!(k > 0 && n > 0, "degenerate shape");
        assert_eq!(w.len(), k * n, "weight shape");
        assert!(bias.is_empty() || bias.len() == n, "bias shape");
        let mut wt = vec![0.0f32; k * n];
        for p in 0..k {
            for j in 0..n {
                wt[j * k + p] = w[p * n + j];
            }
        }
        PackedLinear { wt, bias: bias.to_vec(), k, n }
    }

    /// Fuse several row-major `[k, n_i]` matrices that share one input
    /// into a single packed `[k, Σ n_i]` projection (the Q‖K‖V fusion):
    /// one `apply` then produces the concatenated outputs, each
    /// bit-identical to its standalone [`matmul`].
    pub fn pack_fused(parts: &[(&[f32], usize)], k: usize) -> PackedLinear {
        assert!(k > 0 && !parts.is_empty(), "degenerate fusion");
        let n: usize = parts.iter().map(|&(_, ni)| ni).sum();
        assert!(n > 0, "degenerate shape");
        let mut wt = Vec::with_capacity(k * n);
        for &(w, ni) in parts {
            assert_eq!(w.len(), k * ni, "fused part shape");
            for j in 0..ni {
                for p in 0..k {
                    wt.push(w[p * ni + j]);
                }
            }
        }
        PackedLinear { wt, bias: Vec::new(), k, n }
    }

    /// Input width.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output width (the fused total for [`PackedLinear::pack_fused`]).
    pub fn n(&self) -> usize {
        self.n
    }

    /// `out[m, n] = x[m, k] · W (+ bias)` over the packed layout,
    /// cache-blocked and register-tiled, in the canonical (scalar-tier)
    /// lane order; bit-identical to [`matmul`] followed by [`add_bias`].
    pub fn apply(&self, x: &[f32], m: usize, out: &mut [f32]) {
        body::packed_apply::<ScalarLanes>(self, x, m, out);
    }

    /// [`PackedLinear::apply`] on the selected [`KernelTier`] —
    /// bit-identical to `apply` on every tier, faster on the vector
    /// ones.
    pub fn apply_tier(&self, tier: KernelTier, x: &[f32], m: usize, out: &mut [f32]) {
        simd::packed_apply(tier, self, x, m, out);
    }
}

/// Row-major matrix product: `out[m, n] = a[m, k] · b[k, n]`.
///
/// `out` is fully overwritten; accumulation follows the canonical lane
/// order (see the module docs). Panics on any slice/shape mismatch.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    body::matmul::<ScalarLanes>(a, b, m, k, n, out);
}

/// [`matmul`] on the selected [`KernelTier`] (bit-identical).
pub fn matmul_tier(
    tier: KernelTier,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    simd::matmul(tier, a, b, m, k, n, out);
}

/// Vector-matrix product: `out[n] = x[k] · w[k, n]` (a 1-row [`matmul`]).
pub fn vecmat(x: &[f32], w: &[f32], k: usize, n: usize, out: &mut [f32]) {
    matmul(x, w, 1, k, n, out);
}

/// [`vecmat`] on the selected [`KernelTier`] (bit-identical).
pub fn vecmat_tier(tier: KernelTier, x: &[f32], w: &[f32], k: usize, n: usize, out: &mut [f32]) {
    matmul_tier(tier, x, w, 1, k, n, out);
}

/// Dot product of two equal-length slices in the canonical lane order —
/// the reduction primitive every matmul output element is built from,
/// exposed for the attention score loop. Panics on length mismatch.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    body::dot::<ScalarLanes>(a, b)
}

/// [`dot`] on the selected [`KernelTier`] (bit-identical).
pub fn dot_tier(tier: KernelTier, a: &[f32], b: &[f32]) -> f32 {
    simd::dot(tier, a, b)
}

/// `dst += s * src` element-wise (the attention value mix). Purely
/// element-wise, so tier-invariant bits by IEEE lane-wise identity.
/// Panics on length mismatch.
pub fn axpy(dst: &mut [f32], s: f32, src: &[f32]) {
    body::axpy::<ScalarLanes>(dst, s, src);
}

/// [`axpy`] on the selected [`KernelTier`] (bit-identical).
pub fn axpy_tier(tier: KernelTier, dst: &mut [f32], s: f32, src: &[f32]) {
    simd::axpy(tier, dst, s, src);
}

/// Add a bias vector to every length-`n` row of `x`. Panics unless
/// `x.len()` is a whole number of bias-sized rows.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    assert!(n > 0 && x.len() % n == 0, "rows must be bias-sized");
    for row in x.chunks_exact_mut(n) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// In-place masked softmax over each length-`cols` row of `scores`.
///
/// `mask[j] != 0.0` marks column `j` live; the same mask applies to every
/// row (the attention use: one key-padding mask shared by all queries).
/// Masked columns get probability exactly 0.0. A row whose mask is all
/// zero becomes all zeros — a defined, NaN-free "attend to nothing" row —
/// rather than the NaN a naive `exp / sum` would produce. The
/// normalizing sum runs in the canonical lane order over the whole row
/// (masked entries are exactly `+0.0` after the exp pass, so including
/// them never changes the sum's bits).
pub fn masked_softmax(scores: &mut [f32], rows: usize, cols: usize, mask: &[f32]) {
    body::masked_softmax::<ScalarLanes>(scores, rows, cols, mask);
}

/// [`masked_softmax`] on the selected [`KernelTier`] (bit-identical).
pub fn masked_softmax_tier(
    tier: KernelTier,
    scores: &mut [f32],
    rows: usize,
    cols: usize,
    mask: &[f32],
) {
    simd::masked_softmax(tier, scores, rows, cols, mask);
}

/// In-place layer normalization of each length-`gamma.len()` row of `x`:
/// `x = (x - mean) / sqrt(var + EPS) * gamma + beta`, with the mean and
/// variance sums in the canonical lane order.
pub fn layernorm(x: &mut [f32], gamma: &[f32], beta: &[f32]) {
    body::layernorm::<ScalarLanes>(x, gamma, beta, EPS);
}

/// [`layernorm`] on the selected [`KernelTier`] (bit-identical).
pub fn layernorm_tier(tier: KernelTier, x: &mut [f32], gamma: &[f32], beta: &[f32]) {
    simd::layernorm(tier, x, gamma, beta, EPS);
}

/// GELU activation (tanh approximation, as in the original BERT/GPT
/// formulation): `0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3)))`.
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// Apply [`gelu`] element-wise.
pub fn gelu_slice(x: &mut [f32]) {
    body::gelu_slice::<ScalarLanes>(x);
}

/// [`gelu_slice`] on the selected [`KernelTier`] (bit-identical — the
/// polynomial runs lane-wise, `tanh` stays a per-lane libm call).
pub fn gelu_slice_tier(tier: KernelTier, x: &mut [f32]) {
    simd::gelu_slice(tier, x);
}

/// Numerically stable softplus `ln(1 + e^x)`: strictly positive, smooth,
/// and asymptotically `x` for large `x` (no overflow).
pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// Apply [`softplus`] element-wise.
pub fn softplus_slice(x: &mut [f32]) {
    body::softplus_slice::<ScalarLanes>(x);
}

/// [`softplus_slice`] on the selected [`KernelTier`] (bit-identical —
/// softplus is branchy per element, so every tier evaluates it per
/// lane).
pub fn softplus_slice_tier(tier: KernelTier, x: &mut [f32]) {
    simd::softplus_slice(tier, x);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = [1.5, -2.0, 0.25, 7.0, 3.0, -1.0];
        let id = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let mut out = [0.0f32; 6];
        matmul(&a, &id, 2, 3, 3, &mut out);
        assert_eq!(out, a);
    }

    /// The canonical accumulation order, written out longhand: element
    /// `i` into accumulator `i % 8`, then the fixed tree
    /// `((s0+s4)+(s2+s6)) + ((s1+s5)+(s3+s7))`. [`dot`] (and therefore
    /// every matmul output element) must match it bit-for-bit — this is
    /// the test that pins the contract documented in `runtime/mod.rs`.
    fn reference_tree_dot(a: &[f32], b: &[f32]) -> f32 {
        let mut s = [0.0f32; 8];
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            s[i % 8] += x * y;
        }
        let q = [s[0] + s[4], s[1] + s[5], s[2] + s[6], s[3] + s[7]];
        let d = [q[0] + q[2], q[1] + q[3]];
        d[0] + d[1]
    }

    #[test]
    fn dot_follows_the_canonical_tree_order() {
        let mut rng = crate::util::Rng::new(7);
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 65, 130] {
            let a: Vec<f32> = (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * 1e3).collect();
            let b: Vec<f32> = (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * 1e3).collect();
            let got = dot(&a, &b);
            let want = reference_tree_dot(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {len}");
        }
    }

    #[test]
    fn matmul_outputs_are_canonical_tree_dots() {
        let mut rng = crate::util::Rng::new(8);
        let (m, k, n) = (3usize, 13usize, 5usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let mut out = vec![0.0f32; m * n];
        matmul(&a, &b, m, k, n, &mut out);
        for i in 0..m {
            for j in 0..n {
                let col: Vec<f32> = (0..k).map(|p| b[p * n + j]).collect();
                let want = reference_tree_dot(&a[i * k..(i + 1) * k], &col);
                assert_eq!(out[i * n + j].to_bits(), want.to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn axpy_known_values_and_empty() {
        let mut dst = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let src = [1.0f32; 9];
        axpy(&mut dst, 0.5, &src);
        assert_eq!(dst, [1.5, 2.5, 3.5, 4.5, 5.5, 6.5, 7.5, 8.5, 9.5]);
        let mut empty: [f32; 0] = [];
        axpy(&mut empty, 2.0, &[]);
    }

    #[test]
    fn vecmat_is_one_row_matmul() {
        let x = [1.0, 2.0, 3.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // [3, 2]
        let mut out = [0.0f32; 2];
        vecmat(&x, &w, 3, 2, &mut out);
        assert_eq!(out, [4.0, 5.0]);
    }

    #[test]
    fn add_bias_every_row() {
        let mut x = [1.0, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0]);
        assert_eq!(x, [11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    #[should_panic(expected = "lhs shape")]
    fn matmul_rejects_bad_lhs() {
        let mut out = [0.0f32; 4];
        matmul(&[1.0; 3], &[1.0; 4], 2, 2, 2, &mut out);
    }

    #[test]
    #[should_panic(expected = "rhs shape")]
    fn matmul_rejects_bad_rhs() {
        let mut out = [0.0f32; 4];
        matmul(&[1.0; 4], &[1.0; 5], 2, 2, 2, &mut out);
    }

    #[test]
    #[should_panic(expected = "out shape")]
    fn vecmat_rejects_bad_out() {
        let mut out = [0.0f32; 3];
        vecmat(&[1.0; 2], &[1.0; 4], 2, 2, &mut out);
    }

    #[test]
    #[should_panic(expected = "dot shape")]
    fn dot_rejects_mismatched_lengths() {
        dot(&[1.0; 3], &[1.0; 4]);
    }

    #[test]
    #[should_panic(expected = "axpy shape")]
    fn axpy_rejects_mismatched_lengths() {
        axpy(&mut [1.0; 3], 1.0, &[1.0; 4]);
    }

    #[test]
    #[should_panic(expected = "rows must be bias-sized")]
    fn add_bias_rejects_ragged_rows() {
        add_bias(&mut [1.0; 5], &[1.0; 2]);
    }

    #[test]
    #[should_panic(expected = "input shape")]
    fn packed_apply_rejects_bad_input() {
        let packed = PackedLinear::pack(&[1.0; 4], 2, 2);
        let mut out = [0.0f32; 2];
        packed.apply(&[1.0; 3], 1, &mut out);
    }

    #[test]
    #[should_panic(expected = "scores shape")]
    fn masked_softmax_rejects_bad_scores() {
        masked_softmax(&mut [0.0; 5], 2, 3, &[1.0; 3]);
    }

    #[test]
    fn softmax_unmasked_row_sums_to_one() {
        let mut s = [1.0f32, 2.0, 3.0, 4.0];
        masked_softmax(&mut s, 1, 4, &[1.0; 4]);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // monotone inputs stay monotone
        assert!(s[0] < s[1] && s[1] < s[2] && s[2] < s[3]);
    }

    #[test]
    fn softmax_masked_columns_are_exactly_zero() {
        let mut s = [10.0f32, 999.0, -3.0];
        masked_softmax(&mut s, 1, 3, &[1.0, 0.0, 1.0]);
        assert_eq!(s[1], 0.0, "masked column contributes nothing");
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_fully_masked_row_is_zero_not_nan() {
        let mut s = [5.0f32, -2.0, 0.5];
        masked_softmax(&mut s, 1, 3, &[0.0; 3]);
        assert_eq!(s, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn softmax_is_shift_invariant_and_finite_at_extremes() {
        let mut a = [1e4f32, 1e4 + 1.0];
        masked_softmax(&mut a, 1, 2, &[1.0, 1.0]);
        assert!(a.iter().all(|v| v.is_finite()));
        let mut b = [0.0f32, 1.0];
        masked_softmax(&mut b, 1, 2, &[1.0, 1.0]);
        assert!((a[0] - b[0]).abs() < 1e-6 && (a[1] - b[1]).abs() < 1e-6);
    }

    #[test]
    fn layernorm_normalizes_then_scales() {
        let mut x = [1.0f32, 2.0, 3.0, 4.0];
        layernorm(&mut x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = x.iter().sum::<f32>() / 4.0;
        let var: f32 = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3, "var {var}");
    }

    #[test]
    fn layernorm_zero_vector_yields_beta() {
        let mut x = [0.0f32; 3];
        layernorm(&mut x, &[2.0; 3], &[0.5, -0.5, 1.5]);
        assert_eq!(x, [0.5, -0.5, 1.5]);
    }

    #[test]
    fn gelu_fixed_points_and_sign() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3, "large x passes through");
        assert!(gelu(-10.0).abs() < 1e-3, "large negative x gates to 0");
        assert!(gelu(1.0) > 0.8 && gelu(1.0) < 0.9);
    }

    #[test]
    fn gelu_slice_matches_scalar_gelu_bitwise() {
        let mut rng = crate::util::Rng::new(9);
        let mut x: Vec<f32> = (0..37).map(|_| (rng.f32() * 2.0 - 1.0) * 8.0).collect();
        let want: Vec<f32> = x.iter().map(|&v| gelu(v)).collect();
        gelu_slice(&mut x);
        for (i, (a, b)) in x.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}");
        }
    }

    #[test]
    fn softplus_positive_and_asymptotic() {
        assert!(softplus(-50.0) > 0.0);
        assert!((softplus(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        assert_eq!(softplus(50.0), 50.0);
        assert!(softplus(5.0) > 5.0 && softplus(5.0) < 5.01);
    }

    #[test]
    fn softplus_slice_matches_scalar_softplus_bitwise() {
        let mut x: Vec<f32> = (0..23).map(|i| (i as f32 - 11.0) * 4.5).collect();
        let want: Vec<f32> = x.iter().map(|&v| softplus(v)).collect();
        softplus_slice(&mut x);
        for (i, (a, b)) in x.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}");
        }
    }

    fn random_matrix(rng: &mut crate::util::Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * 3.0).collect()
    }

    #[test]
    fn packed_apply_bit_equals_naive_matmul_across_tile_boundaries() {
        // shapes straddling every tile edge: smaller than one tile,
        // exactly one tile, and ragged multi-tile remainders
        let mut rng = crate::util::Rng::new(41);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 64, 192),
            (3, 7, 5),
            (16, 64, 64),
            (17, 33, 65),
            (40, 128, 64),
            (33, 16, 130),
        ] {
            let a = random_matrix(&mut rng, m * k);
            let w = random_matrix(&mut rng, k * n);
            let mut naive = vec![0.0f32; m * n];
            matmul(&a, &w, m, k, n, &mut naive);
            let packed = PackedLinear::pack(&w, k, n);
            assert_eq!((packed.k(), packed.n()), (k, n));
            let mut fast = vec![f32::NAN; m * n];
            packed.apply(&a, m, &mut fast);
            for (i, (x, y)) in naive.iter().zip(&fast).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n}) elem {i}");
            }
        }
    }

    #[test]
    fn tier_variants_bit_equal_canonical_on_every_available_tier() {
        // the deep per-tier coverage lives in tests/prop_kernel_tiers.rs;
        // this is the smoke check that the dispatch plumbing itself works
        let mut rng = crate::util::Rng::new(44);
        let (m, k, n) = (5usize, 19usize, 21usize);
        let a = random_matrix(&mut rng, m * k);
        let w = random_matrix(&mut rng, k * n);
        let packed = PackedLinear::pack(&w, k, n);
        let mut want = vec![0.0f32; m * n];
        packed.apply(&a, m, &mut want);
        for tier in [KernelTier::Auto, KernelTier::Scalar, KernelTier::Avx2, KernelTier::Neon] {
            if !tier.available() {
                continue;
            }
            let mut got = vec![f32::NAN; m * n];
            packed.apply_tier(tier, &a, m, &mut got);
            for (i, (x, y)) in want.iter().zip(&got).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{tier} elem {i}");
            }
        }
    }

    #[test]
    fn packed_bias_bit_equals_matmul_then_add_bias() {
        let mut rng = crate::util::Rng::new(42);
        let (m, k, n) = (9usize, 24usize, 70usize);
        let a = random_matrix(&mut rng, m * k);
        let w = random_matrix(&mut rng, k * n);
        let bias = random_matrix(&mut rng, n);
        let mut naive = vec![0.0f32; m * n];
        matmul(&a, &w, m, k, n, &mut naive);
        add_bias(&mut naive, &bias);
        let packed = PackedLinear::pack_with_bias(&w, &bias, k, n);
        let mut fast = vec![f32::NAN; m * n];
        packed.apply(&a, m, &mut fast);
        for (i, (x, y)) in naive.iter().zip(&fast).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "elem {i}");
        }
    }

    #[test]
    fn fused_projection_bit_equals_separate_matmuls() {
        // the Q‖K‖V fusion: one packed apply == three naive matmuls
        let mut rng = crate::util::Rng::new(43);
        let (m, k, d) = (5usize, 32usize, 32usize);
        let a = random_matrix(&mut rng, m * k);
        let wq = random_matrix(&mut rng, k * d);
        let wk = random_matrix(&mut rng, k * d);
        let wv = random_matrix(&mut rng, k * d);
        let fused = PackedLinear::pack_fused(&[(&wq, d), (&wk, d), (&wv, d)], k);
        assert_eq!(fused.n(), 3 * d);
        let mut out = vec![f32::NAN; m * 3 * d];
        fused.apply(&a, m, &mut out);
        for (part, w) in [(0usize, &wq), (1, &wk), (2, &wv)] {
            let mut naive = vec![0.0f32; m * d];
            matmul(&a, w, m, k, d, &mut naive);
            for i in 0..m {
                for j in 0..d {
                    assert_eq!(
                        naive[i * d + j].to_bits(),
                        out[i * 3 * d + part * d + j].to_bits(),
                        "part {part} elem ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_apply_handles_zero_rows() {
        let packed = PackedLinear::pack(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        let mut out: [f32; 0] = [];
        packed.apply(&[], 0, &mut out);
    }
}
