//! A reusable scratch arena for the predictor hot loop.
//!
//! [`Predictor::forward_into`](super::Predictor::forward_into) threads a
//! [`Workspace`] through every forward call so a backend can keep its
//! per-layer scratch buffers alive *across* batches: the engine drivers
//! (`coordinator::stream` stage 3, `DedupState::predict`,
//! `predictor::eval`, the benches) each own one `Workspace` per driving
//! thread, size it implicitly on the first forward, and from then on run
//! **allocation-free in steady state**.
//!
//! The arena is deliberately opaque: each backend stores its own scratch
//! type in the single slot (downcast by `TypeId`), so the `Predictor`
//! trait stays object-safe and backend-agnostic — swapping backends
//! mid-stream simply rebuilds the slot. Contents are scratch only and
//! carry **no numerical state**: a dirty workspace must produce
//! bit-identical predictions to a fresh one (every buffer is fully
//! overwritten or explicitly zeroed before use — property-tested in
//! `tests/prop_attention.rs`).

use std::any::Any;

/// Backend-owned scratch storage; see the module docs. One per driving
/// thread — `Workspace` is `Send` but deliberately not shared.
#[derive(Default)]
pub struct Workspace {
    slot: Option<Box<dyn Any + Send>>,
}

impl Workspace {
    /// An empty arena; backends populate it on first use.
    pub fn new() -> Workspace {
        Workspace { slot: None }
    }

    /// Borrow the resident scratch of type `T`, building it with `make`
    /// on first use or when a different backend type owned the slot.
    pub fn get_or_insert_with<T: Any + Send>(&mut self, make: impl FnOnce() -> T) -> &mut T {
        let fresh = match &self.slot {
            Some(b) => !b.is::<T>(),
            None => true,
        };
        if fresh {
            self.slot = Some(Box::new(make()));
        }
        self.slot
            .as_mut()
            .expect("slot just populated")
            .downcast_mut::<T>()
            .expect("slot type just checked")
    }

    /// Whether the arena currently holds a scratch allocation.
    pub fn is_warm(&self) -> bool {
        self.slot.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_cold_and_warms_on_first_use() {
        let mut ws = Workspace::new();
        assert!(!ws.is_warm());
        let v = ws.get_or_insert_with(|| vec![1u32, 2, 3]);
        assert_eq!(v.len(), 3);
        assert!(ws.is_warm());
    }

    #[test]
    fn same_type_reuses_the_resident_value() {
        let mut ws = Workspace::new();
        ws.get_or_insert_with(|| vec![7u32]).push(8);
        let v = ws.get_or_insert_with(|| -> Vec<u32> { panic!("must not rebuild") });
        assert_eq!(v, &[7, 8]);
    }

    #[test]
    fn different_type_rebuilds_the_slot() {
        let mut ws = Workspace::new();
        ws.get_or_insert_with(|| vec![1u32]);
        let s = ws.get_or_insert_with(|| String::from("fresh"));
        assert_eq!(s, "fresh");
        // and back again: the previous Vec is gone
        let v = ws.get_or_insert_with(Vec::<u32>::new);
        assert!(v.is_empty());
    }
}
