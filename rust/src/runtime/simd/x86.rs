//! The x86_64 AVX2 tier: [`PackedF32`] on `__m256` plus one
//! `#[target_feature(enable = "avx2,fma")]` wrapper per kernel. All
//! `unsafe` in the SIMD layer lives here (and in the NEON sibling).
//!
//! ## Safety contract
//!
//! Every `pub(crate) unsafe fn` below requires **AVX2 and FMA present
//! on the running CPU**. The only callers are the `dispatch!` arms in
//! [`super`], which enter this module exclusively after
//! [`KernelTier::effective`](super::KernelTier::effective) returned
//! [`Avx2`](super::KernelTier::Avx2) — i.e. after
//! `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")`
//! succeeded. The trait methods themselves use `unsafe` only for the
//! intrinsics; memory safety comes from ordinary slice bounds checks
//! (`&src[..LANES]`) taken *before* the unaligned load/store.
//!
//! FMA is part of the tier gate (per the registry definition) but is
//! deliberately **never used for accumulation**: fusing changes
//! rounding, and the canonical semantics are separate `mul` + `add`
//! (see the module docs in [`super`]). Rust emits no fast-math flags,
//! so LLVM will not contract our `mul`/`add` pairs behind our back.

use std::arch::x86_64::*;

use super::{body, PackedF32, LANES};
use crate::runtime::tensor::PackedLinear;

/// Eight f32 lanes in one AVX ymm register.
#[derive(Clone, Copy)]
pub(crate) struct Avx2(__m256);

impl PackedF32 for Avx2 {
    #[inline(always)]
    fn zero() -> Self {
        // SAFETY: callers are inside an avx2-enabled wrapper (module
        // safety contract); same for every intrinsic below.
        Avx2(unsafe { _mm256_setzero_ps() })
    }

    #[inline(always)]
    fn splat(v: f32) -> Self {
        Avx2(unsafe { _mm256_set1_ps(v) })
    }

    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        let src = &src[..LANES]; // bounds check before the raw load
        Avx2(unsafe { _mm256_loadu_ps(src.as_ptr()) })
    }

    #[inline(always)]
    fn load_or(src: &[f32], fill: f32) -> Self {
        let mut a = [fill; LANES];
        let n = src.len().min(LANES);
        a[..n].copy_from_slice(&src[..n]);
        Avx2(unsafe { _mm256_loadu_ps(a.as_ptr()) })
    }

    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        let dst = &mut dst[..LANES]; // bounds check before the raw store
        unsafe { _mm256_storeu_ps(dst.as_mut_ptr(), self.0) }
    }

    #[inline(always)]
    fn to_array(self) -> [f32; LANES] {
        let mut a = [0.0; LANES];
        unsafe { _mm256_storeu_ps(a.as_mut_ptr(), self.0) };
        a
    }

    #[inline(always)]
    fn from_array(a: [f32; LANES]) -> Self {
        Avx2(unsafe { _mm256_loadu_ps(a.as_ptr()) })
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        Avx2(unsafe { _mm256_add_ps(self.0, o.0) })
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        Avx2(unsafe { _mm256_sub_ps(self.0, o.0) })
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        Avx2(unsafe { _mm256_mul_ps(self.0, o.0) })
    }

    #[inline(always)]
    fn tree_sum(self) -> f32 {
        // The canonical tree, stage for stage (PackedF32::tree_sum):
        //   q = low128 + high128            -> [s0+s4, s1+s5, s2+s6, s3+s7]
        //   d = q + movehl(q, q)            -> [q0+q2, q1+q3, ..]
        //       (movehl(q, q) = [q2, q3, q2, q3])
        //   r = d + movehdup(d), lane 0     -> d0 + d1
        //       (movehdup(d) = [d1, d1, d3, d3]; SSE3, implied by avx2)
        unsafe {
            let lo = _mm256_castps256_ps128(self.0);
            let hi = _mm256_extractf128_ps::<1>(self.0);
            let q = _mm_add_ps(lo, hi);
            let d = _mm_add_ps(q, _mm_movehl_ps(q, q));
            let r = _mm_add_ss(d, _mm_movehdup_ps(d));
            _mm_cvtss_f32(r)
        }
    }
}

// One wrapper per kernel: `#[target_feature]` makes the whole
// monomorphized body (generic algorithm + inlined intrinsics) compile
// as AVX2 code in a single feature-enabled frame.
//
// SAFETY (all of them): caller must have verified AVX2+FMA at runtime —
// see the module safety contract.

#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn packed_apply(lin: &PackedLinear, x: &[f32], m: usize, out: &mut [f32]) {
    body::packed_apply::<Avx2>(lin, x, m, out)
}

#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    body::matmul::<Avx2>(a, b, m, k, n, out)
}

#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn masked_softmax(scores: &mut [f32], rows: usize, cols: usize, mask: &[f32]) {
    body::masked_softmax::<Avx2>(scores, rows, cols, mask)
}

#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn layernorm(x: &mut [f32], gamma: &[f32], beta: &[f32], eps: f32) {
    body::layernorm::<Avx2>(x, gamma, beta, eps)
}

#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn gelu_slice(x: &mut [f32]) {
    body::gelu_slice::<Avx2>(x)
}

#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn softplus_slice(x: &mut [f32]) {
    body::softplus_slice::<Avx2>(x)
}

#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    body::dot::<Avx2>(a, b)
}

#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn axpy(dst: &mut [f32], s: f32, src: &[f32]) {
    body::axpy::<Avx2>(dst, s, src)
}

#[cfg(test)]
mod tests {
    use super::super::{KernelTier, ScalarLanes};
    use super::*;

    fn if_avx2() -> bool {
        KernelTier::Avx2.available()
    }

    #[test]
    fn avx2_tree_sum_is_bitwise_scalar_tree_sum() {
        if !if_avx2() {
            return;
        }
        let cases = [
            [1e8f32, 1.0, -1e8, 2.0, 3e-3, 4.0, 0.25, -7.5],
            [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8],
            [-0.0, 0.0, -0.0, 0.0, -0.0, 0.0, -0.0, 0.0],
            [f32::MIN_POSITIVE, 1e-38, -1e-38, 3.0, -3.0, 1e30, -1e30, 7.0],
        ];
        for c in cases {
            // SAFETY: gated on runtime AVX2+FMA detection above.
            let v = unsafe { dot(&c, &[1.0; 8]) };
            let s = ScalarLanes::from_array(c).tree_sum();
            assert_eq!(v.to_bits(), s.to_bits(), "{c:?}");
        }
    }

    #[test]
    fn avx2_lane_ops_match_scalar_bitwise() {
        if !if_avx2() {
            return;
        }
        let a = [1.5f32, -2.25, 3.125, 1e-7, -1e7, 0.0, -0.0, 42.0];
        let b = [0.3f32, 7.0, -0.125, 2e-7, 1e7, -0.0, 0.0, -6.0];
        // SAFETY: gated on runtime AVX2+FMA detection above.
        let mut va = a;
        unsafe { axpy(&mut va, 2.5, &b) };
        let mut sa = a;
        crate::runtime::simd::body::axpy::<ScalarLanes>(&mut sa, 2.5, &b);
        for (x, y) in va.iter().zip(&sa) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
