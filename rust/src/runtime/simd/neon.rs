//! The aarch64 NEON tier: [`PackedF32`] on a pair of `float32x4_t`
//! registers (NEON vectors are 128-bit, so 8 lanes = two of them), plus
//! one `#[target_feature(enable = "neon")]` wrapper per kernel.
//!
//! ## Safety contract
//!
//! NEON is a baseline feature of every aarch64 target Rust compiles
//! for, so the wrappers are unconditionally sound on this architecture;
//! they still go through the same `dispatch!` gate as AVX2 (entered
//! only when [`KernelTier::effective`](super::KernelTier::effective)
//! returned [`Neon`](super::KernelTier::Neon)) to keep one structure
//! across tiers. Memory safety comes from slice bounds checks taken
//! before each raw load/store, exactly as in the x86 module.
//!
//! The halving reduction maps onto NEON directly: lanes `s_i + s_{i+4}`
//! are the `vaddq` of the two registers, `q_j + q_{j+2}` is the add of
//! the low and high 64-bit halves, and the final `d_0 + d_1` is one
//! pairwise add — the same canonical tree as scalar and AVX2, so the
//! produced bits are identical.

use std::arch::aarch64::*;

use super::{body, PackedF32, LANES};
use crate::runtime::tensor::PackedLinear;

/// Eight f32 lanes across two NEON q-registers: lanes 0–3 in `.0`,
/// lanes 4–7 in `.1`.
#[derive(Clone, Copy)]
pub(crate) struct Neon(float32x4_t, float32x4_t);

impl PackedF32 for Neon {
    #[inline(always)]
    fn zero() -> Self {
        // SAFETY: NEON is baseline on aarch64; same for every
        // intrinsic below.
        unsafe { Neon(vdupq_n_f32(0.0), vdupq_n_f32(0.0)) }
    }

    #[inline(always)]
    fn splat(v: f32) -> Self {
        unsafe { Neon(vdupq_n_f32(v), vdupq_n_f32(v)) }
    }

    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        let src = &src[..LANES]; // bounds check before the raw loads
        unsafe { Neon(vld1q_f32(src.as_ptr()), vld1q_f32(src.as_ptr().add(4))) }
    }

    #[inline(always)]
    fn load_or(src: &[f32], fill: f32) -> Self {
        let mut a = [fill; LANES];
        let n = src.len().min(LANES);
        a[..n].copy_from_slice(&src[..n]);
        Neon::load(&a)
    }

    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        let dst = &mut dst[..LANES]; // bounds check before the raw stores
        unsafe {
            vst1q_f32(dst.as_mut_ptr(), self.0);
            vst1q_f32(dst.as_mut_ptr().add(4), self.1);
        }
    }

    #[inline(always)]
    fn to_array(self) -> [f32; LANES] {
        let mut a = [0.0; LANES];
        self.store(&mut a);
        a
    }

    #[inline(always)]
    fn from_array(a: [f32; LANES]) -> Self {
        Neon::load(&a)
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        unsafe { Neon(vaddq_f32(self.0, o.0), vaddq_f32(self.1, o.1)) }
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        unsafe { Neon(vsubq_f32(self.0, o.0), vsubq_f32(self.1, o.1)) }
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        unsafe { Neon(vmulq_f32(self.0, o.0), vmulq_f32(self.1, o.1)) }
    }

    #[inline(always)]
    fn tree_sum(self) -> f32 {
        // The canonical tree, stage for stage (PackedF32::tree_sum):
        //   q = lanes 0..4 + lanes 4..8     -> vaddq of the two registers
        //   d = q.low64 + q.high64          -> [q0+q2, q1+q3]
        //   r = d0 + d1                     -> one pairwise add, lane 0
        unsafe {
            let q = vaddq_f32(self.0, self.1);
            let d = vadd_f32(vget_low_f32(q), vget_high_f32(q));
            vget_lane_f32::<0>(vpadd_f32(d, d))
        }
    }
}

// One wrapper per kernel, mirroring the x86 module: `#[target_feature]`
// keeps the structure identical across tiers even though NEON is
// baseline on aarch64.
//
// SAFETY (all of them): requires NEON, which every aarch64 target has.

#[target_feature(enable = "neon")]
pub(crate) unsafe fn packed_apply(lin: &PackedLinear, x: &[f32], m: usize, out: &mut [f32]) {
    body::packed_apply::<Neon>(lin, x, m, out)
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    body::matmul::<Neon>(a, b, m, k, n, out)
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn masked_softmax(scores: &mut [f32], rows: usize, cols: usize, mask: &[f32]) {
    body::masked_softmax::<Neon>(scores, rows, cols, mask)
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn layernorm(x: &mut [f32], gamma: &[f32], beta: &[f32], eps: f32) {
    body::layernorm::<Neon>(x, gamma, beta, eps)
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn gelu_slice(x: &mut [f32]) {
    body::gelu_slice::<Neon>(x)
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn softplus_slice(x: &mut [f32]) {
    body::softplus_slice::<Neon>(x)
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    body::dot::<Neon>(a, b)
}

#[target_feature(enable = "neon")]
pub(crate) unsafe fn axpy(dst: &mut [f32], s: f32, src: &[f32]) {
    body::axpy::<Neon>(dst, s, src)
}

#[cfg(test)]
mod tests {
    use super::super::ScalarLanes;
    use super::*;

    #[test]
    fn neon_tree_sum_is_bitwise_scalar_tree_sum() {
        let cases = [
            [1e8f32, 1.0, -1e8, 2.0, 3e-3, 4.0, 0.25, -7.5],
            [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8],
            [-0.0, 0.0, -0.0, 0.0, -0.0, 0.0, -0.0, 0.0],
            [f32::MIN_POSITIVE, 1e-38, -1e-38, 3.0, -3.0, 1e30, -1e30, 7.0],
        ];
        for c in cases {
            // SAFETY: NEON is baseline on aarch64.
            let v = unsafe { dot(&c, &[1.0; 8]) };
            let s = ScalarLanes::from_array(c).tree_sum();
            assert_eq!(v.to_bits(), s.to_bits(), "{c:?}");
        }
    }
}
