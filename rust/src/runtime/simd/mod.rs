//! Runtime-dispatched SIMD kernel tiers behind one lane-abstraction
//! trait ([`PackedF32`]), modeled on plonky2's `packed_field` pattern:
//! **one width-generic algorithm, per-architecture lane types, dispatch
//! decided once at runtime**.
//!
//! ## Structure
//!
//! * [`PackedF32`] — an 8-lane f32 vector: loads/stores, lane-wise
//!   `add`/`sub`/`mul`, and the **canonical tree reduction**
//!   ([`PackedF32::tree_sum`]). Three implementations:
//!   [`ScalarLanes`] (portable, always available), `x86::Avx2`
//!   (x86_64, gated on runtime AVX2+FMA detection) and `neon::Neon`
//!   (aarch64, baseline feature).
//! * [`body`] — the kernel algorithms (`packed_apply`, `matmul`,
//!   `masked_softmax`, `layernorm`, `gelu_slice`, `softplus_slice`,
//!   `dot`, `axpy`), written once, generic over `P: PackedF32`, and
//!   marked `#[inline(always)]` so each per-arch wrapper monomorphizes
//!   them with its vector type *inside* a `#[target_feature]` context
//!   (intrinsics only inline into callers with the same features).
//! * [`KernelTier`] — the user-visible selector (`auto | scalar | avx2
//!   | neon`), resolved through `PipelineConfig::effective_kernel_tier`
//!   (CLI `--kernel-tier` > TOML `pipeline.kernel_tier` >
//!   `CAPSIM_KERNEL_TIER` env > auto-detect) and threaded through
//!   `Backend::build_forward` into the attention predictor.
//!
//! ## Bit-exactness
//!
//! Every tier implements the **same canonical accumulation order** (the
//! fixed-shape 8-lane tree documented at [`PackedF32::tree_sum`] — the
//! decision recorded in [`super`]'s contract section), so tier choice
//! changes throughput, never bits: scalar, AVX2 and NEON are mutually
//! bit-identical and identical to `forward_reference`. Two rules keep
//! that true:
//!
//! * **no fused multiply-add in accumulation** — the AVX2 tier detects
//!   FMA (part of the tier gate) but deliberately accumulates with
//!   separate `mul`/`add`, because fusing changes rounding and a
//!   bit-matching scalar tier would then need (slow) libm `fma` calls;
//! * **zero-padded tails are bitwise no-ops** — accumulators start at
//!   `+0.0` and, in round-to-nearest, `x + y` is `-0.0` only when both
//!   operands are `-0.0`, so no accumulator lane can ever become
//!   `-0.0`; adding a padded lane's `+0.0` product therefore preserves
//!   the accumulator bits exactly. (`layernorm`'s variance pass pads
//!   with the row *mean* instead, so padded lanes contribute
//!   `(mean - mean)^2 = +0.0`.)
//!
//! `unsafe` is confined to the per-arch modules ([`x86`], [`neon`]);
//! the dispatchers only enter them after [`KernelTier::effective`] has
//! proven the features present on this CPU.

use std::fmt;
use std::str::FromStr;

use anyhow::{anyhow, Result};

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86;

use crate::runtime::tensor::PackedLinear;

/// Lane count of every tier — the fixed shape of the canonical
/// reduction tree. Not configurable: changing it changes produced bits
/// (see `KERNEL_CONTRACT_VERSION` in [`super`]).
pub const LANES: usize = 8;

/// An 8-lane f32 vector: the lane abstraction every kernel inner loop
/// is generic over. Implementations perform the *same* IEEE operation
/// per lane, so any two tiers produce identical bits for the element
/// they compute — the only ordering freedom is reductions, which
/// [`PackedF32::tree_sum`] pins to one shape.
///
/// Implementations for real vector ISAs construct values only inside
/// `#[target_feature]` wrappers that the dispatchers gate on runtime
/// feature detection.
pub trait PackedF32: Copy {
    /// All lanes `+0.0`.
    fn zero() -> Self;

    /// All lanes `v`.
    fn splat(v: f32) -> Self;

    /// Load the first [`LANES`] elements of `src` (panics if shorter).
    fn load(src: &[f32]) -> Self;

    /// Load up to [`LANES`] leading elements of `src`, padding missing
    /// lanes with `fill` — the tail load (see the module docs for why
    /// `0.0` pads are bitwise no-ops in accumulation).
    fn load_or(src: &[f32], fill: f32) -> Self;

    /// Store all lanes into the first [`LANES`] elements of `dst`
    /// (panics if shorter).
    fn store(self, dst: &mut [f32]);

    /// Lanes as an array (for per-lane scalar math, e.g. libm calls).
    fn to_array(self) -> [f32; LANES];

    /// Rebuild from an array (the inverse of [`PackedF32::to_array`]).
    fn from_array(a: [f32; LANES]) -> Self;

    /// Lane-wise `self + o`.
    fn add(self, o: Self) -> Self;

    /// Lane-wise `self - o`.
    fn sub(self, o: Self) -> Self;

    /// Lane-wise `self * o`.
    fn mul(self, o: Self) -> Self;

    /// The **canonical horizontal reduction** — the one accumulation
    /// order every tier shares. With lanes `s0..s7`:
    ///
    /// ```text
    /// q_i = s_i + s_{i+4}        (i = 0..4)   AVX2: low128 + high128
    /// d_j = q_j + q_{j+2}        (j = 0..2)   AVX2: q + movehl(q)
    /// r   = d_0 + d_1                         AVX2: d + movehdup(d)
    /// ```
    ///
    /// The shape is exactly the cheap 128-bit halving sequence on both
    /// AVX2 and NEON, and trivial to mirror in scalar code.
    fn tree_sum(self) -> f32;
}

/// The portable tier: [`PackedF32`] on a plain `[f32; 8]`. This is the
/// *definition* of the canonical semantics — the naive kernels in
/// [`tensor`](crate::runtime::tensor) and `forward_reference` run the
/// generic bodies with this type, and the vector tiers must match it
/// bit-for-bit.
#[derive(Clone, Copy, Debug)]
pub struct ScalarLanes([f32; LANES]);

impl PackedF32 for ScalarLanes {
    #[inline(always)]
    fn zero() -> Self {
        ScalarLanes([0.0; LANES])
    }

    #[inline(always)]
    fn splat(v: f32) -> Self {
        ScalarLanes([v; LANES])
    }

    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        let mut a = [0.0; LANES];
        a.copy_from_slice(&src[..LANES]);
        ScalarLanes(a)
    }

    #[inline(always)]
    fn load_or(src: &[f32], fill: f32) -> Self {
        let mut a = [fill; LANES];
        let n = src.len().min(LANES);
        a[..n].copy_from_slice(&src[..n]);
        ScalarLanes(a)
    }

    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        dst[..LANES].copy_from_slice(&self.0);
    }

    #[inline(always)]
    fn to_array(self) -> [f32; LANES] {
        self.0
    }

    #[inline(always)]
    fn from_array(a: [f32; LANES]) -> Self {
        ScalarLanes(a)
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        let mut a = self.0;
        for (x, y) in a.iter_mut().zip(o.0) {
            *x += y;
        }
        ScalarLanes(a)
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        let mut a = self.0;
        for (x, y) in a.iter_mut().zip(o.0) {
            *x -= y;
        }
        ScalarLanes(a)
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        let mut a = self.0;
        for (x, y) in a.iter_mut().zip(o.0) {
            *x *= y;
        }
        ScalarLanes(a)
    }

    #[inline(always)]
    fn tree_sum(self) -> f32 {
        let s = self.0;
        let q = [s[0] + s[4], s[1] + s[5], s[2] + s[6], s[3] + s[7]];
        let d = [q[0] + q[2], q[1] + q[3]];
        d[0] + d[1]
    }
}

/// A selectable kernel tier (`--kernel-tier` CLI, `pipeline.kernel_tier`
/// TOML, `CAPSIM_KERNEL_TIER` env; default [`KernelTier::Auto`]). All
/// tiers are bit-identical (see the module docs), so the choice affects
/// throughput only — cache identities and fingerprints never mix it in.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelTier {
    /// Pick the best available tier at runtime ([`KernelTier::detect`]).
    #[default]
    Auto,
    /// The portable [`ScalarLanes`] tier — always available, and the
    /// semantic definition the vector tiers must match.
    Scalar,
    /// x86_64 AVX2 (+FMA detected as part of the gate, but never used
    /// for accumulation — see the module docs).
    Avx2,
    /// aarch64 NEON (a baseline feature of the architecture).
    Neon,
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_detected() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_fma_detected() -> bool {
    false
}

impl KernelTier {
    /// Every tier, registry order (the order `capsim backends` prints).
    pub const ALL: [KernelTier; 4] =
        [KernelTier::Auto, KernelTier::Scalar, KernelTier::Avx2, KernelTier::Neon];

    /// The CLI/TOML/env name.
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Auto => "auto",
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Neon => "neon",
        }
    }

    /// Whether this tier can run on the current host ( `Auto`/`Scalar`
    /// always can; vector tiers need their architecture + CPU features).
    pub fn available(self) -> bool {
        match self {
            KernelTier::Auto | KernelTier::Scalar => true,
            KernelTier::Avx2 => avx2_fma_detected(),
            KernelTier::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// The best concrete tier on this host — what `auto` resolves to.
    pub fn detect() -> KernelTier {
        if KernelTier::Avx2.available() {
            KernelTier::Avx2
        } else if KernelTier::Neon.available() {
            KernelTier::Neon
        } else {
            KernelTier::Scalar
        }
    }

    /// Resolve to a concrete, available tier; a tier forced onto a host
    /// that cannot run it is an error (the strict path config/CLI use).
    pub fn resolve(self) -> Result<KernelTier> {
        match self {
            KernelTier::Auto => Ok(KernelTier::detect()),
            t if t.available() => Ok(t),
            t => Err(anyhow!(
                "kernel tier {t} is not available on this host (auto would pick {})",
                KernelTier::detect()
            )),
        }
    }

    /// Non-failing [`KernelTier::resolve`]: unavailable tiers fall back
    /// to the scalar tier (sound — every tier is bit-identical).
    pub fn effective(self) -> KernelTier {
        self.resolve().unwrap_or(KernelTier::Scalar)
    }
}

impl FromStr for KernelTier {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<KernelTier> {
        for t in KernelTier::ALL {
            if s == t.name() {
                return Ok(t);
            }
        }
        Err(anyhow!("unknown kernel tier {s:?} (expected one of: auto, scalar, avx2, neon)"))
    }
}

impl fmt::Display for KernelTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The CPU features the tier gates consult, with their detection
/// results — what `capsim backends` prints so perf and bug reports name
/// the hardware they ran on.
pub fn cpu_features() -> Vec<(&'static str, bool)> {
    #[cfg(target_arch = "x86_64")]
    {
        vec![
            ("sse2", std::arch::is_x86_feature_detected!("sse2")),
            ("avx", std::arch::is_x86_feature_detected!("avx")),
            ("avx2", std::arch::is_x86_feature_detected!("avx2")),
            ("fma", std::arch::is_x86_feature_detected!("fma")),
            ("avx512f", std::arch::is_x86_feature_detected!("avx512f")),
        ]
    }
    #[cfg(target_arch = "aarch64")]
    {
        vec![("neon", true)]
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Vec::new()
    }
}

/// The width-generic kernel algorithms. Each is `#[inline(always)]` so
/// a `#[target_feature]` wrapper monomorphizing it with a vector lane
/// type gets the intrinsics inlined into one feature-enabled frame.
/// Instantiated with [`ScalarLanes`] they *are* the canonical scalar
/// kernels.
pub(crate) mod body {
    use super::{PackedF32, PackedLinear, LANES};
    use crate::runtime::tensor::{gelu, softplus, BLOCK_M, BLOCK_N, SQRT_2_OVER_PI};

    /// Dot product of two equal-length slices in the canonical order:
    /// element `i` accumulates into lane `i % LANES`, tails are
    /// zero-padded, lanes reduce through the fixed tree.
    #[inline(always)]
    pub(crate) fn dot<P: PackedF32>(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len(), "dot shape");
        let mut acc = P::zero();
        let mut p = 0;
        while p + LANES <= a.len() {
            acc = acc.add(P::load(&a[p..]).mul(P::load(&b[p..])));
            p += LANES;
        }
        if p < a.len() {
            acc = acc.add(P::load_or(&a[p..], 0.0).mul(P::load_or(&b[p..], 0.0)));
        }
        acc.tree_sum()
    }

    /// `dst += s * src`, element-wise (the attention value mix). Purely
    /// element-wise — same bits at any width by IEEE lane-wise identity.
    #[inline(always)]
    pub(crate) fn axpy<P: PackedF32>(dst: &mut [f32], s: f32, src: &[f32]) {
        assert_eq!(dst.len(), src.len(), "axpy shape");
        let sv = P::splat(s);
        let mut j = 0;
        while j + LANES <= dst.len() {
            let v = P::load(&dst[j..]).add(sv.mul(P::load(&src[j..])));
            v.store(&mut dst[j..]);
            j += LANES;
        }
        for (d, &v) in dst[j..].iter_mut().zip(&src[j..]) {
            *d += s * v;
        }
    }

    /// Row-major `out[m, n] = a[m, k] · b[k, n]` in the canonical order.
    /// `b` columns are strided, so chunks are gathered into a lane array
    /// first — every tier performs the identical gather + lane
    /// arithmetic (this is the reference schedule; the production path
    /// uses [`packed_apply`] on pre-transposed weights).
    #[inline(always)]
    pub(crate) fn matmul<P: PackedF32>(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        assert_eq!(a.len(), m * k, "lhs shape");
        assert_eq!(b.len(), k * n, "rhs shape");
        assert_eq!(out.len(), m * n, "out shape");
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..n {
                let mut acc = P::zero();
                let mut p = 0;
                while p + LANES <= k {
                    let mut col = [0.0f32; LANES];
                    for (l, c) in col.iter_mut().enumerate() {
                        *c = b[(p + l) * n + j];
                    }
                    acc = acc.add(P::load(&arow[p..]).mul(P::from_array(col)));
                    p += LANES;
                }
                if p < k {
                    let mut col = [0.0f32; LANES];
                    for (l, c) in col.iter_mut().enumerate().take(k - p) {
                        *c = b[(p + l) * n + j];
                    }
                    acc = acc.add(P::load_or(&arow[p..], 0.0).mul(P::from_array(col)));
                }
                out[i * n + j] = acc.tree_sum();
            }
        }
    }

    /// [`PackedLinear`]'s blocked/tiled apply in the canonical order:
    /// same BLOCK_M × BLOCK_N output blocking and 4-wide register tile
    /// as before, but each of the four accumulators is a lane vector
    /// walking `k` in 8-lane chunks.
    #[inline(always)]
    pub(crate) fn packed_apply<P: PackedF32>(
        lin: &PackedLinear,
        x: &[f32],
        m: usize,
        out: &mut [f32],
    ) {
        let (k, n) = (lin.k, lin.n);
        assert_eq!(x.len(), m * k, "input shape");
        assert_eq!(out.len(), m * n, "output shape");
        for i0 in (0..m).step_by(BLOCK_M) {
            let i1 = (i0 + BLOCK_M).min(m);
            for j0 in (0..n).step_by(BLOCK_N) {
                let j1 = (j0 + BLOCK_N).min(n);
                for i in i0..i1 {
                    let a = &x[i * k..(i + 1) * k];
                    let orow = &mut out[i * n..(i + 1) * n];
                    // 4-wide register tile: four packed weight rows
                    // stream against a single pass over `a`, each output
                    // in its own lane-vector accumulator
                    let mut j = j0;
                    while j + 4 <= j1 {
                        let w0 = &lin.wt[j * k..(j + 1) * k];
                        let w1 = &lin.wt[(j + 1) * k..(j + 2) * k];
                        let w2 = &lin.wt[(j + 2) * k..(j + 3) * k];
                        let w3 = &lin.wt[(j + 3) * k..(j + 4) * k];
                        let (mut s0, mut s1, mut s2, mut s3) =
                            (P::zero(), P::zero(), P::zero(), P::zero());
                        let mut p = 0;
                        while p + LANES <= k {
                            let av = P::load(&a[p..]);
                            s0 = s0.add(av.mul(P::load(&w0[p..])));
                            s1 = s1.add(av.mul(P::load(&w1[p..])));
                            s2 = s2.add(av.mul(P::load(&w2[p..])));
                            s3 = s3.add(av.mul(P::load(&w3[p..])));
                            p += LANES;
                        }
                        if p < k {
                            let av = P::load_or(&a[p..], 0.0);
                            s0 = s0.add(av.mul(P::load_or(&w0[p..], 0.0)));
                            s1 = s1.add(av.mul(P::load_or(&w1[p..], 0.0)));
                            s2 = s2.add(av.mul(P::load_or(&w2[p..], 0.0)));
                            s3 = s3.add(av.mul(P::load_or(&w3[p..], 0.0)));
                        }
                        let (r0, r1, r2, r3) =
                            (s0.tree_sum(), s1.tree_sum(), s2.tree_sum(), s3.tree_sum());
                        if lin.bias.is_empty() {
                            orow[j] = r0;
                            orow[j + 1] = r1;
                            orow[j + 2] = r2;
                            orow[j + 3] = r3;
                        } else {
                            orow[j] = r0 + lin.bias[j];
                            orow[j + 1] = r1 + lin.bias[j + 1];
                            orow[j + 2] = r2 + lin.bias[j + 2];
                            orow[j + 3] = r3 + lin.bias[j + 3];
                        }
                        j += 4;
                    }
                    while j < j1 {
                        let w0 = &lin.wt[j * k..(j + 1) * k];
                        let r = dot::<P>(a, w0);
                        orow[j] = if lin.bias.is_empty() { r } else { r + lin.bias[j] };
                        j += 1;
                    }
                }
            }
        }
    }

    /// In-place masked softmax (see `tensor::masked_softmax` for the
    /// semantics). The max scan is a scalar pass in every tier (max is
    /// order-independent over finite floats) and the exps are scalar
    /// libm calls in every tier (element-wise, so tier-invariant); the
    /// normalizing sum runs in the canonical lane order — masked
    /// columns hold exactly `+0.0` after the exp pass, so including
    /// them is bitwise free.
    #[inline(always)]
    pub(crate) fn masked_softmax<P: PackedF32>(
        scores: &mut [f32],
        rows: usize,
        cols: usize,
        mask: &[f32],
    ) {
        assert_eq!(scores.len(), rows * cols, "scores shape");
        assert_eq!(mask.len(), cols, "mask shape");
        for r in 0..rows {
            let row = &mut scores[r * cols..(r + 1) * cols];
            // max over live columns for the usual exp-shift stability
            let mut max = f32::NEG_INFINITY;
            for (j, &v) in row.iter().enumerate() {
                if mask[j] != 0.0 && v > max {
                    max = v;
                }
            }
            if max == f32::NEG_INFINITY {
                row.fill(0.0);
                continue;
            }
            for (j, v) in row.iter_mut().enumerate() {
                *v = if mask[j] != 0.0 { (*v - max).exp() } else { 0.0 };
            }
            let mut acc = P::zero();
            let mut j = 0;
            while j + LANES <= cols {
                acc = acc.add(P::load(&row[j..]));
                j += LANES;
            }
            if j < cols {
                acc = acc.add(P::load_or(&row[j..], 0.0));
            }
            // sum >= ~1 because the max column contributes exp(0) = 1
            let inv = 1.0 / acc.tree_sum();
            let iv = P::splat(inv);
            let mut j = 0;
            while j + LANES <= cols {
                P::load(&row[j..]).mul(iv).store(&mut row[j..]);
                j += LANES;
            }
            for v in row[j..].iter_mut() {
                *v *= inv;
            }
        }
    }

    /// In-place layer normalization (see `tensor::layernorm`). Mean and
    /// variance sums run in the canonical lane order; the variance tail
    /// pads with `mean` so padded lanes contribute exactly `+0.0`.
    #[inline(always)]
    pub(crate) fn layernorm<P: PackedF32>(x: &mut [f32], gamma: &[f32], beta: &[f32], eps: f32) {
        let d = gamma.len();
        assert_eq!(beta.len(), d, "gamma/beta shape");
        assert!(d > 0 && x.len() % d == 0, "rows must be d-sized");
        for row in x.chunks_exact_mut(d) {
            let mut acc = P::zero();
            let mut j = 0;
            while j + LANES <= d {
                acc = acc.add(P::load(&row[j..]));
                j += LANES;
            }
            if j < d {
                acc = acc.add(P::load_or(&row[j..], 0.0));
            }
            let mean = acc.tree_sum() / d as f32;
            let mv = P::splat(mean);
            let mut acc = P::zero();
            let mut j = 0;
            while j + LANES <= d {
                let c = P::load(&row[j..]).sub(mv);
                acc = acc.add(c.mul(c));
                j += LANES;
            }
            if j < d {
                let c = P::load_or(&row[j..], mean).sub(mv);
                acc = acc.add(c.mul(c));
            }
            let var = acc.tree_sum() / d as f32;
            let inv = 1.0 / (var + eps).sqrt();
            let iv = P::splat(inv);
            let mut j = 0;
            while j + LANES <= d {
                let v = P::load(&row[j..])
                    .sub(mv)
                    .mul(iv)
                    .mul(P::load(&gamma[j..]))
                    .add(P::load(&beta[j..]));
                v.store(&mut row[j..]);
                j += LANES;
            }
            for jj in j..d {
                row[jj] = (row[jj] - mean) * inv * gamma[jj] + beta[jj];
            }
        }
    }

    /// Element-wise GELU. The polynomial and gating arithmetic run
    /// lane-wise (element-wise, tier-invariant bits); `tanh` has no
    /// bit-compatible vector form, so it is a per-lane libm call in
    /// every tier.
    #[inline(always)]
    pub(crate) fn gelu_slice<P: PackedF32>(x: &mut [f32]) {
        let n = x.len();
        let mut j = 0;
        while j + LANES <= n {
            let v = P::load(&x[j..]);
            let x3 = P::splat(0.044_715).mul(v).mul(v).mul(v);
            let inner = P::splat(SQRT_2_OVER_PI).mul(v.add(x3));
            let mut t = inner.to_array();
            for e in t.iter_mut() {
                *e = e.tanh();
            }
            let r = P::splat(0.5).mul(v).mul(P::splat(1.0).add(P::from_array(t)));
            r.store(&mut x[j..]);
            j += LANES;
        }
        for v in x[j..].iter_mut() {
            *v = gelu(*v);
        }
    }

    /// Element-wise softplus. Branchy per element (three numeric
    /// regimes), so every tier evaluates it per lane with the same
    /// scalar function — tier-invariant by construction.
    #[inline(always)]
    pub(crate) fn softplus_slice<P: PackedF32>(x: &mut [f32]) {
        let n = x.len();
        let mut j = 0;
        while j + LANES <= n {
            let mut t = P::load(&x[j..]).to_array();
            for e in t.iter_mut() {
                *e = softplus(*e);
            }
            P::from_array(t).store(&mut x[j..]);
            j += LANES;
        }
        for v in x[j..].iter_mut() {
            *v = softplus(*v);
        }
    }
}

/// Dispatch a kernel to `tier`'s monomorphization. `effective()` first:
/// `Auto` resolves to the detected tier, an unavailable forced tier
/// falls back to scalar — so entering a per-arch module is always
/// backed by a positive runtime feature check (the safety contract of
/// the `unsafe` blocks below).
macro_rules! dispatch {
    ($tier:expr, $kernel:ident ( $($arg:expr),* $(,)? )) => {
        match $tier.effective() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `effective()` returns Avx2 only after
            // `is_x86_feature_detected!` proved AVX2+FMA on this CPU.
            KernelTier::Avx2 => unsafe { x86::$kernel($($arg),*) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is a baseline feature of every aarch64
            // target Rust compiles for.
            KernelTier::Neon => unsafe { neon::$kernel($($arg),*) },
            _ => body::$kernel::<ScalarLanes>($($arg),*),
        }
    };
}

/// [`PackedLinear`] apply on `tier` (see `tensor::PackedLinear::apply`).
pub(crate) fn packed_apply(
    tier: KernelTier,
    lin: &PackedLinear,
    x: &[f32],
    m: usize,
    out: &mut [f32],
) {
    dispatch!(tier, packed_apply(lin, x, m, out))
}

/// Naive-schedule matmul on `tier` (see `tensor::matmul`).
pub(crate) fn matmul(
    tier: KernelTier,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    dispatch!(tier, matmul(a, b, m, k, n, out))
}

/// Masked softmax on `tier` (see `tensor::masked_softmax`).
pub(crate) fn masked_softmax(
    tier: KernelTier,
    scores: &mut [f32],
    rows: usize,
    cols: usize,
    mask: &[f32],
) {
    dispatch!(tier, masked_softmax(scores, rows, cols, mask))
}

/// Layer normalization on `tier` (see `tensor::layernorm`).
pub(crate) fn layernorm(tier: KernelTier, x: &mut [f32], gamma: &[f32], beta: &[f32], eps: f32) {
    dispatch!(tier, layernorm(x, gamma, beta, eps))
}

/// Element-wise GELU on `tier` (see `tensor::gelu_slice`).
pub(crate) fn gelu_slice(tier: KernelTier, x: &mut [f32]) {
    dispatch!(tier, gelu_slice(x))
}

/// Element-wise softplus on `tier` (see `tensor::softplus_slice`).
pub(crate) fn softplus_slice(tier: KernelTier, x: &mut [f32]) {
    dispatch!(tier, softplus_slice(x))
}

/// Canonical-order dot product on `tier` (see `tensor::dot`).
pub(crate) fn dot(tier: KernelTier, a: &[f32], b: &[f32]) -> f32 {
    dispatch!(tier, dot(a, b))
}

/// `dst += s * src` on `tier` (see `tensor::axpy`).
pub(crate) fn axpy(tier: KernelTier, dst: &mut [f32], s: f32, src: &[f32]) {
    dispatch!(tier, axpy(dst, s, src))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_roundtrip() {
        for t in KernelTier::ALL {
            assert_eq!(t.name().parse::<KernelTier>().unwrap(), t);
        }
        assert!("sse".parse::<KernelTier>().is_err());
        assert!("AVX2".parse::<KernelTier>().is_err(), "names are case-sensitive");
    }

    #[test]
    fn auto_resolves_to_an_available_concrete_tier() {
        let t = KernelTier::Auto.resolve().unwrap();
        assert_ne!(t, KernelTier::Auto);
        assert!(t.available());
        assert_eq!(t, KernelTier::detect());
        assert_eq!(KernelTier::Auto.effective(), t);
    }

    #[test]
    fn unavailable_forced_tier_errors_but_effective_falls_back() {
        for t in [KernelTier::Avx2, KernelTier::Neon] {
            if !t.available() {
                assert!(t.resolve().is_err(), "{t}");
                assert_eq!(t.effective(), KernelTier::Scalar, "{t}");
            } else {
                assert_eq!(t.resolve().unwrap(), t, "{t}");
            }
        }
        assert_eq!(KernelTier::Scalar.resolve().unwrap(), KernelTier::Scalar);
    }

    #[test]
    fn scalar_lanes_tree_sum_matches_documented_shape() {
        // values chosen so every association order differs in f32
        let s = ScalarLanes::from_array([1e8, 1.0, -1e8, 2.0, 3e-3, 4.0, 0.25, -7.5]);
        let a = s.to_array();
        let q = [a[0] + a[4], a[1] + a[5], a[2] + a[6], a[3] + a[7]];
        let d = [q[0] + q[2], q[1] + q[3]];
        assert_eq!(s.tree_sum().to_bits(), (d[0] + d[1]).to_bits());
    }

    #[test]
    fn load_or_pads_and_store_roundtrips() {
        let v = ScalarLanes::load_or(&[1.0, 2.0, 3.0], 9.0);
        assert_eq!(v.to_array(), [1.0, 2.0, 3.0, 9.0, 9.0, 9.0, 9.0, 9.0]);
        let mut out = [0.0f32; LANES];
        v.store(&mut out);
        assert_eq!(out, v.to_array());
    }

    #[test]
    fn cpu_features_reports_the_tier_gates() {
        let feats = cpu_features();
        if cfg!(target_arch = "x86_64") {
            let has = |n: &str| feats.iter().any(|&(f, on)| f == n && on);
            assert_eq!(
                KernelTier::Avx2.available(),
                has("avx2") && has("fma"),
                "tier gate must agree with the reported features"
            );
        }
    }
}
