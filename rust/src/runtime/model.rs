//! Compiled model handles: PJRT client + per-variant executables + the
//! resident parameter state the training driver mutates.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{Manifest, ModelGeometry, VariantManifest};
use super::Predictor;

/// A host-side minibatch in the exact layout the AOT entry points expect.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Batch capacity (the compiled batch size this buffer is padded to).
    pub b: usize,
    /// Rows actually carrying data (predictions beyond this are padding).
    pub live: usize,
    pub tokens: Vec<i32>,    // [b, l_clip, l_token]
    pub tok_mask: Vec<f32>,  // [b, l_clip, l_token]
    pub clip_mask: Vec<f32>, // [b, l_clip]
    pub ctx: Vec<i32>,       // [b, m]
    pub target: Vec<f32>,    // [b]
}

impl Batch {
    pub fn zeroed(b: usize, g: &ModelGeometry) -> Batch {
        Batch {
            b,
            live: 0,
            tokens: vec![0; b * g.l_clip * g.l_token],
            tok_mask: vec![0.0; b * g.l_clip * g.l_token],
            clip_mask: vec![0.0; b * g.l_clip],
            ctx: vec![0; b * g.m_rows],
            target: vec![1.0; b],
        }
    }

    /// The four tensor arguments shared by fwd and train entry points:
    /// tokens, tok_mask, clip_mask, ctx (see aot.py's `batch_specs`).
    fn literals(&self, g: &ModelGeometry) -> Result<Vec<Literal>> {
        let b = self.b as i64;
        let lc = g.l_clip as i64;
        let lt = g.l_token as i64;
        let m = g.m_rows as i64;
        Ok(vec![
            Literal::vec1(self.tokens.as_slice()).reshape(&[b, lc, lt])?,
            Literal::vec1(self.tok_mask.as_slice()).reshape(&[b, lc, lt])?,
            Literal::vec1(self.clip_mask.as_slice()).reshape(&[b, lc])?,
            Literal::vec1(self.ctx.as_slice()).reshape(&[b, m])?,
        ])
    }
}

/// The PJRT runtime: one CPU client + the manifest.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Create the CPU client and read the manifest from `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf() })
    }

    /// Compile one HLO-text artifact.
    pub fn compile(&self, file: &str) -> Result<PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e}"))
    }

    /// Load a predictor variant: compiles init + all fwd sizes + train.
    pub fn load_variant(&self, name: &str) -> Result<ModelHandle> {
        let vm: &VariantManifest = self
            .manifest
            .variants
            .get(name)
            .ok_or_else(|| anyhow!("variant {name} not in manifest"))?;
        let init = self.compile(&vm.init_file)?;
        let mut fwd = Vec::new();
        for (&b, f) in &vm.fwd_files {
            fwd.push((b, self.compile(f)?));
        }
        let mut train = None;
        if let Some((&b, f)) = vm.train_files.iter().next() {
            train = Some((b, self.compile(f)?));
        }
        Ok(ModelHandle {
            name: name.to_string(),
            geometry: self.manifest.geometry.clone(),
            param_size: vm.param_size,
            init,
            fwd,
            train,
            params: None,
            momentum: None,
        })
    }
}

/// A loaded predictor with resident parameters.
pub struct ModelHandle {
    pub name: String,
    pub geometry: ModelGeometry,
    pub param_size: usize,
    init: PjRtLoadedExecutable,
    /// (batch size, executable), ascending.
    fwd: Vec<(usize, PjRtLoadedExecutable)>,
    train: Option<(usize, PjRtLoadedExecutable)>,
    /// Current parameters (host literal; the CPU PJRT "device" is host
    /// memory, so literal round-trips are memcpys, not transfers).
    pub params: Option<Literal>,
    pub momentum: Option<Literal>,
}

impl ModelHandle {
    /// Initialize parameters from the AOT init computation.
    pub fn init_params(&mut self, seed: u32) -> Result<()> {
        let out = self
            .init
            .execute::<Literal>(&[Literal::scalar(seed)])
            .map_err(|e| anyhow!("init: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("init fetch: {e}"))?;
        let params = lit.to_tuple1().map_err(|e| anyhow!("init tuple: {e}"))?;
        assert_eq!(params.element_count(), self.param_size);
        self.momentum = Some(
            Literal::vec1(vec![0f32; self.param_size].as_slice())
                .reshape(&[self.param_size as i64])?,
        );
        self.params = Some(params);
        Ok(())
    }

    /// Copy parameters out (checkpointing / transfer-learning).
    pub fn params_vec(&self) -> Result<Vec<f32>> {
        self.params
            .as_ref()
            .ok_or_else(|| anyhow!("params not initialized"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("params read: {e}"))
    }

    /// Load parameters from a host vector (e.g. a fine-tuning base).
    pub fn set_params(&mut self, p: &[f32]) -> Result<()> {
        anyhow::ensure!(p.len() == self.param_size, "param size mismatch");
        self.params = Some(Literal::vec1(p).reshape(&[self.param_size as i64])?);
        self.momentum = Some(
            Literal::vec1(vec![0f32; self.param_size].as_slice())
                .reshape(&[self.param_size as i64])?,
        );
        Ok(())
    }

    /// Largest compiled forward batch size.
    pub fn max_fwd_batch(&self) -> usize {
        self.fwd.last().map(|(b, _)| *b).unwrap_or(1)
    }

    /// The compiled batch the runtime will use for `n` live rows.
    pub fn pick_fwd_batch(&self, n: usize) -> usize {
        for (b, _) in &self.fwd {
            if *b >= n {
                return *b;
            }
        }
        self.max_fwd_batch()
    }

    /// Training batch size.
    pub fn train_batch(&self) -> Option<usize> {
        self.train.as_ref().map(|(b, _)| *b)
    }

    /// Run the forward pass on a batch whose `b` matches a compiled size.
    pub fn forward(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>> {
        let exe = &self
            .fwd
            .iter()
            .find(|(b, _)| *b == batch.b)
            .ok_or_else(|| anyhow!("no fwd executable for batch {}", batch.b))?
            .1;
        let params = self
            .params
            .as_ref()
            .ok_or_else(|| anyhow!("params not initialized"))?;
        // (params, tokens, tok_mask, clip_mask, ctx, time_scale)
        let mut args = vec![params.clone()];
        args.extend(batch.literals(&self.geometry)?);
        args.push(Literal::scalar(time_scale));
        let out = exe
            .execute::<Literal>(&args)
            .map_err(|e| anyhow!("fwd: {e}"))?;
        let pred = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fwd fetch: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("fwd tuple: {e}"))?;
        let mut v = pred.to_vec::<f32>().map_err(|e| anyhow!("fwd read: {e}"))?;
        v.truncate(batch.live);
        Ok(v)
    }

    /// One SGD step; updates resident params/momentum, returns the loss.
    // (kept below the forward path: training is ModelHandle-specific and
    // not part of the backend-agnostic `Predictor` trait)
    pub fn train_step(&mut self, batch: &Batch, lr: f32, time_scale: f32) -> Result<f32> {
        let (tb, exe) = self
            .train
            .as_ref()
            .ok_or_else(|| anyhow!("variant {} has no train entry", self.name))?;
        anyhow::ensure!(batch.b == *tb, "train batch {} != compiled {tb}", batch.b);
        let params = self.params.take().ok_or_else(|| anyhow!("params not init"))?;
        let momentum = self.momentum.take().unwrap();
        // (params, mom, tokens, tok_mask, clip_mask, ctx, target, lr, scale)
        let mut args = vec![params, momentum];
        args.extend(batch.literals(&self.geometry)?);
        args.push(Literal::vec1(batch.target.as_slice()).reshape(&[batch.b as i64])?);
        args.push(Literal::scalar(lr));
        args.push(Literal::scalar(time_scale));
        let out = exe
            .execute::<Literal>(&args)
            .map_err(|e| anyhow!("train: {e}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("train fetch: {e}"))?;
        let (p, m, loss) = tuple
            .to_tuple3()
            .map_err(|e| anyhow!("train tuple: {e}"))?;
        self.params = Some(p);
        self.momentum = Some(m);
        loss.get_first_element::<f32>()
            .map_err(|e| anyhow!("loss read: {e}"))
    }
}

impl Predictor for ModelHandle {
    fn geometry(&self) -> &ModelGeometry {
        &self.geometry
    }

    fn max_fwd_batch(&self) -> usize {
        ModelHandle::max_fwd_batch(self)
    }

    fn pick_fwd_batch(&self, live: usize) -> usize {
        ModelHandle::pick_fwd_batch(self, live)
    }

    fn forward(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>> {
        ModelHandle::forward(self, batch, time_scale)
    }

    fn fingerprint(&self) -> u64 {
        // variant name + parameter shape distinguish models that share a
        // geometry, and the resident weights distinguish training runs —
        // a retrained model must never serve a stale persisted cache
        let mut h = super::fingerprint_geometry(&self.geometry);
        h = super::fingerprint_bytes(h, b"pjrt-attention");
        h = super::fingerprint_bytes(h, self.name.as_bytes());
        h = super::fingerprint_mix(h, self.param_size as u64);
        match self.params_vec() {
            Ok(params) => {
                h = super::fingerprint_mix(h, params.len() as u64);
                for v in params {
                    h = super::fingerprint_mix(h, v.to_bits() as u64);
                }
            }
            // uninitialized/unreadable weights get a distinct marker so
            // they never collide with a real training run
            Err(_) => h = super::fingerprint_mix(h, u64::MAX),
        }
        h
    }
}
