//! The predictor runtime: backends that turn padded clip [`Batch`]es into
//! predicted clip times.
//!
//! Two backends implement the [`Predictor`] trait:
//!
//! * [`ModelHandle`] — the PJRT path: loads the AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them from
//!   the Rust hot path. Python never runs here — the artifacts directory
//!   (HLO text + `manifest.json`) is the entire contract between the
//!   layers (see DESIGN.md §4 and `/opt/xla-example/load_hlo` for the
//!   interchange rationale: HLO *text*, not serialized protos);
//! * [`NativePredictor`] — a dependency-free analytic backend whose
//!   predictions are exact row-local functions of the batch row; used by
//!   the engine equivalence tests and as the `--native` fallback when no
//!   artifacts are built.
//!
//! Everything above this layer (`predictor::eval`, `coordinator`) is
//! generic over [`Predictor`], so backends are interchangeable.

pub mod manifest;
pub mod model;
pub mod native;

pub use manifest::{Manifest, ModelGeometry, VariantManifest};
pub use model::{Batch, ModelHandle, Runtime};
pub use native::NativePredictor;

use anyhow::Result;

/// A forward-only predictor backend.
///
/// Object-safe on purpose: engine code and benches hold `&dyn Predictor` /
/// `Box<dyn Predictor>` so the PJRT and native backends swap freely.
pub trait Predictor {
    /// Model geometry (batch shapes the backend expects).
    fn geometry(&self) -> &ModelGeometry;

    /// Largest supported forward batch capacity.
    fn max_fwd_batch(&self) -> usize;

    /// The batch capacity the backend will use for `live` rows.
    fn pick_fwd_batch(&self, live: usize) -> usize;

    /// Predict clip times for the live rows of `batch` (length
    /// `batch.live`; padding rows are never returned).
    fn forward(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>>;
}
