//! The predictor runtime: backends that turn padded clip [`Batch`]es into
//! predicted clip times.
//!
//! Two backends implement the [`Predictor`] trait:
//!
//! * [`ModelHandle`] — the PJRT path: loads the AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them from
//!   the Rust hot path. Python never runs here — the artifacts directory
//!   (HLO text + `manifest.json`) is the entire contract between the
//!   layers (see DESIGN.md §4 and `/opt/xla-example/load_hlo` for the
//!   interchange rationale: HLO *text*, not serialized protos);
//! * [`NativePredictor`] — a dependency-free analytic backend whose
//!   predictions are exact row-local functions of the batch row; used by
//!   the engine equivalence tests and as the `--native` fallback when no
//!   artifacts are built.
//!
//! Everything above this layer (`predictor::eval`, `coordinator`) is
//! generic over [`Predictor`], so backends are interchangeable.

pub mod manifest;
pub mod model;
pub mod native;

pub use manifest::{Manifest, ModelGeometry, VariantManifest};
pub use model::{Batch, ModelHandle, Runtime};
pub use native::NativePredictor;

use anyhow::Result;

/// One FNV-1a step — the mixing primitive of backend fingerprints.
pub fn fingerprint_mix(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
}

/// Mix a byte string into a fingerprint (backend identity labels).
pub fn fingerprint_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = fingerprint_mix(h, b as u64);
    }
    h
}

/// FNV-1a over every geometry field — the base of each backend's
/// [`Predictor::fingerprint`].
pub fn fingerprint_geometry(g: &ModelGeometry) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for v in [g.vocab_size, g.embed_dim, g.l_token, g.l_clip, g.m_rows, g.train_batch] {
        h = fingerprint_mix(h, v as u64);
    }
    for &b in &g.fwd_batch_sizes {
        h = fingerprint_mix(h, b as u64);
    }
    h
}

/// A forward-only predictor backend.
///
/// Object-safe on purpose: engine code and benches hold `&dyn Predictor` /
/// `Box<dyn Predictor>` so the PJRT and native backends swap freely.
pub trait Predictor {
    /// Model geometry (batch shapes the backend expects).
    fn geometry(&self) -> &ModelGeometry;

    /// Largest supported forward batch capacity.
    fn max_fwd_batch(&self) -> usize;

    /// The batch capacity the backend will use for `live` rows.
    fn pick_fwd_batch(&self, live: usize) -> usize;

    /// Predict clip times for the live rows of `batch` (length
    /// `batch.live`; padding rows are never returned).
    fn forward(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>>;

    /// A stable identity key for caches of this backend's predictions
    /// (the persistent [`ClipCache`](crate::coordinator::ClipCache) is
    /// keyed by `fingerprint + time_scale`). The default hashes the
    /// geometry; backends override it to mix in everything else that
    /// changes predictions — backend kind, variant name, parameter
    /// shape.
    fn fingerprint(&self) -> u64 {
        fingerprint_geometry(self.geometry())
    }
}
