//! The PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs here — the artifacts directory (HLO text +
//! `manifest.json`) is the entire contract between the layers (see
//! DESIGN.md §4 and `/opt/xla-example/load_hlo` for the interchange
//! rationale: HLO *text*, not serialized protos).

pub mod manifest;
pub mod model;

pub use manifest::{Manifest, ModelGeometry, VariantManifest};
pub use model::{Batch, ModelHandle, Runtime};
