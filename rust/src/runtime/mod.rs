//! The predictor runtime: backends that turn padded clip [`Batch`]es into
//! predicted clip times, behind one [`Predictor`] trait and one
//! [`Backend`] registry.
//!
//! ## Backend matrix
//!
//! | backend | type | dependencies | determinism | intended use |
//! |---|---|---|---|---|
//! | [`ModelHandle`] (`pjrt`) | AOT-compiled attention model (HLO text + PJRT C API) | `make artifacts` + an XLA runtime | bit-stable per build; predictions are batch-composition sensitive to ≈1e-3 | trained-accuracy experiments (Figs. 8–11) |
//! | [`NativePredictor`] (`native`) | analytic row-hash stand-in | none | **row-local and bit-exact** across batches/threads/caches | engine equivalence tests, clean-tree smoke runs |
//! | [`AttentionPredictor`] (`attention`) | pure-Rust transformer (token embedding → multi-head self-attention → pooling + context fusion → regression head) | none | **row-local and bit-exact** across batches/threads/caches | realistic inference cost in the measured loop (Fig. 7), CI, anywhere PJRT artifacts are unavailable |
//!
//! Selection is a single [`Backend`] value carried by
//! [`PipelineConfig`](crate::config::PipelineConfig) (`pipeline.backend`
//! TOML key, `--backend` CLI flag; `--native` survives as a deprecating
//! alias) and resolved through [`Backend::build_forward`] /
//! [`Backend::build_trained`]. Everything above this layer
//! (`predictor::eval`, `coordinator`) is generic over [`Predictor`], so
//! backends swap freely.
//!
//! The PJRT path loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path —
//! Python never runs here; the artifacts directory (HLO text +
//! `manifest.json`) is the entire contract between the layers (see
//! DESIGN.md §4). The `attention` backend is the same architecture
//! executed by the kernels in [`tensor`], which is what upgrades
//! "padding invariance ≈ 1e-3" to "padding invariance exact".
//!
//! ## Kernel performance & bit-exactness contract
//!
//! The `attention` backend's production forward
//! ([`Predictor::forward_into`]) is **batched, layout-packed,
//! allocation-free in steady state, and SIMD-dispatched**:
//!
//! * weights are pre-transposed once at model build
//!   ([`tensor::PackedLinear`]) so every matmul inner loop walks
//!   contiguous memory, the Q/K/V projections fuse into one packed
//!   matmul, and the bias add folds into the store;
//! * whole batches run through shared-weight matmuls (`B × l_clip` rows
//!   at once) instead of per-clip kernel calls; only the attention
//!   mixing itself — softmax over one clip's `l_clip × l_clip` score
//!   tile — runs per row, keeping the tile L1-resident;
//! * matmul output space is cache-blocked and register-tiled for
//!   L1/L2;
//! * all per-layer scratch lives in a caller-owned [`Workspace`] arena
//!   (one per driving thread: stream stage 3, `DedupState::predict`,
//!   the eval loop, the benches), sized once from the geometry — the
//!   steady-state forward performs **zero heap allocations**;
//! * every kernel inner loop is width-generic over the [`simd`] lane
//!   abstraction and runs on a runtime-selected [`KernelTier`]
//!   (`scalar` / `avx2` / `neon`, default `auto`; `pipeline.kernel_tier`
//!   TOML key, `--kernel-tier` flag, `CAPSIM_KERNEL_TIER` env).
//!
//! **The canonical accumulation order** (the decision that keeps all of
//! this bit-exact): every reduction — matmul output elements, attention
//! score dots, softmax normalizers, layernorm moments — accumulates
//! element `i` into lane `i % 8` (tails zero-padded), then reduces the
//! 8 lanes through one fixed-shape tree:
//! `((s0+s4)+(s2+s6)) + ((s1+s5)+(s3+s7))`. This order is cheap on
//! every ISA (it is AVX2's and NEON's natural halving sequence) and
//! exactly reproducible in scalar code, so **all tiers — including the
//! scalar tier and [`AttentionPredictor::forward_reference`] — produce
//! identical bits on every host**, and the tier never enters cache
//! identities. Accumulation never uses fused multiply-add (fusing
//! changes rounding; the AVX2 gate requires FMA but the kernels only
//! issue separate `mul`/`add`, which Rust/LLVM never contract), and
//! element-wise transcendentals (`exp`, `tanh`, `ln_1p`) stay per-lane
//! libm calls in every tier.
//!
//! The packed/fused/blocked/batched/SIMD path is therefore
//! **bit-identical** to the row-by-row forward kept as
//! [`AttentionPredictor::forward_reference`] — the oracle that
//! `tests/prop_attention.rs` and `tests/prop_kernel_tiers.rs` pin the
//! production path against (arbitrary batch compositions, paddings,
//! ragged tile edges, fully-masked rows, dirty-workspace reuse, every
//! available tier), and the baseline the `perf_micro` kernel-regression
//! harness measures per-tier speedups against (`BENCH_kernels.json`,
//! uploaded by the CI `perf-smoke` job).
//!
//! [`KERNEL_CONTRACT_VERSION`] names the canonical order; it is mixed
//! into [`Predictor::fingerprint`], so changing the order (as this
//! version-2 tree did to version 1's k-index-order scalar accumulation)
//! cold-starts persisted clip caches exactly once instead of silently
//! serving stale bits.
//!
//! ## Persistence contract
//!
//! Both persisted artifacts — the clip cache and the attention weights —
//! share one container, the `CPIM` image
//! ([`image`](crate::util::image)): a fixed little-endian header
//! carrying format version, [`Predictor::fingerprint`],
//! [`KERNEL_CONTRACT_VERSION`] and a header checksum; fixed-stride
//! records; and a 4096-aligned f32 payload covered by a data digest.
//! Alignment means a mapped image yields zero-copy `&[f32]` views
//! ([`mmap::f32_view`](crate::util::mmap::f32_view)), so a warm start is
//! O(1): parse + checksum the header, map the rest, verify payload
//! bytes the first time they are actually read (weights verify eagerly
//! — every byte feeds the model; the cache defers to first lookup).
//! Key rules: a cache image must match fingerprint, `time_scale` *and*
//! kernel-contract version exactly (its values are produced bits); a
//! weights image survives contract bumps (weights are inputs, not
//! outputs — only the fingerprint self-check is skipped across a bump).
//! Writers publish via unique temp + fsync + atomic rename
//! ([`image::persist_atomic`](crate::util::image::persist_atomic)), and
//! a rename swaps the directory entry, never the mapped inode, so
//! concurrent readers keep a complete old image. Any corruption —
//! truncation, bit flip, hostile header — degrades to a cold start with
//! the offending path in the error; it never panics and never serves a
//! wrong value (`tests/persist_images.rs` drives every truncation and a
//! flip in every byte). The pre-image formats (`CPLC` v1 caches, `CAWB`
//! v1 weights) load read-only for one release and migrate to `CPIM` on
//! the next save.
//!
//! ## Serving architecture
//!
//! The [`serve`](crate::serve) daemon is the runtime's long-lived
//! deployment shape, built as three tiers: a **session layer** owning
//! the client sockets, N **replicated predict loops**, and underneath
//! them **one** weight set built once through [`Backend::build_shared`]
//! (an `Arc<dyn Predictor + Send + Sync>` — weights deserialize exactly
//! once) plus one shared clip cache. A request travels
//!
//! ```text
//!   client ──frame──▶ session layer ──round-robin over──▶ predict loop i
//!                      epoll event loop     N bounded       (private Workspace,
//!                      (1 thread, all       queues; all      BatchRunner and
//!                      sockets; Linux       full → Busy +    BatchAccumulator)
//!                      default) — or one    retry hint            │
//!                      thread per conn;                          ▼
//!                      validate against               SHARED weights + clip cache
//!                      ModelGeometry                  (read-only Arc, one copy)
//!   client ◀─reply── settle: rows routed back per request ◀── forward
//! ```
//!
//! The session layer is selected by
//! [`SessionLayer`](crate::serve::SessionLayer): on Linux the default
//! is a readiness-driven epoll event loop (hand-declared syscalls in
//! [`util::epoll`](crate::util::epoll) — connection count stops being a
//! thread count; an incremental frame decoder makes every byte split
//! equivalent to blocking reads, pinned by `tests/prop_wire_codec.rs`),
//! elsewhere one thread per connection. Both run the identical validate
//! → dispatch → reply sequence and reap idle connections after
//! `idle_timeout_ms`. Replication is cheap because the forward pass is
//! `&self`: all mutable state (workspace arenas, accumulator, routing
//! maps) lives in the loop, so a "replica" is a reference to the one
//! model plus a few KB of private buffers — never a second copy of the
//! weights. Clips from *different* requests fill each loop's
//! accumulator, flushed on batch-full or a small linger deadline, so
//! concurrent small requests ride full batches. All three layers of
//! freedom — which session layer served a request, which replica it
//! landed on, and which batch mix it rode — are only sound because the
//! dependency-free backends are **row-local**: a clip's prediction is a
//! function of that clip alone, never of its batch neighbors or padding
//! (the invariance `tests/prop_attention.rs` pins). Session layer,
//! dispatch, and batch composition therefore change throughput and
//! latency, never answers — which the `tests/serve_e2e.rs` invariance
//! matrix asserts end to end across session layers {epoll, threads} ×
//! loop counts {1, 4} (and {1, 2, 4} on the default layer). The
//! daemon's persistent clip cache reuses the coordinator's concurrent
//! [`ClipCache`](crate::coordinator::ClipCache) (one instance shared by
//! all loops), keyed by [`Predictor::fingerprint`] + `time_scale` like
//! every other warm start; per-loop forward counters surface in
//! `StatsReply::per_loop`. The `pjrt` backend is excluded from serving:
//! its predictions are batch-composition sensitive (≈1e-3) and its
//! runtime handle has no thread-safety contract, either of which would
//! break the replicated bit-identical contract.

pub mod attention;
pub mod backend;
pub mod manifest;
pub mod model;
pub mod native;
pub mod simd;
pub mod tensor;
pub mod workspace;

pub use attention::AttentionPredictor;
pub use backend::{Backend, ATTENTION_WEIGHTS_FILE};
pub use manifest::{Manifest, ModelGeometry, VariantManifest};
pub use model::{Batch, ModelHandle, Runtime};
pub use native::NativePredictor;
pub use simd::{cpu_features, KernelTier};
pub use workspace::Workspace;

use anyhow::Result;

/// Version of the canonical kernel accumulation order (see the contract
/// section above). Mixed into every kernel-executing backend's
/// [`Predictor::fingerprint`]; bump it whenever the canonical order —
/// and therefore every produced bit — changes, so persisted clip caches
/// cold-start cleanly.
///
/// * v1 — k-innermost, index-order scalar accumulation (PRs 3–6).
/// * v2 — fixed-shape 8-lane tree reduction, shared by all SIMD tiers.
pub const KERNEL_CONTRACT_VERSION: u64 = 2;

/// The default model geometry: the `model_config.json` constants every
/// dependency-free backend shares (and `coordinator::golden` locks the
/// dataset to).
pub fn default_geometry() -> ModelGeometry {
    ModelGeometry {
        vocab_size: 512,
        embed_dim: 64,
        l_token: crate::coordinator::golden::L_TOKEN,
        l_clip: crate::coordinator::golden::L_CLIP,
        m_rows: crate::context::M_ROWS,
        train_batch: 32,
        fwd_batch_sizes: vec![1, 8, 32, 128],
    }
}

/// One FNV-1a step — the mixing primitive of backend fingerprints.
pub fn fingerprint_mix(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
}

/// Mix a byte string into a fingerprint (backend identity labels).
pub fn fingerprint_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = fingerprint_mix(h, b as u64);
    }
    h
}

/// FNV-1a over every geometry field — the base of each backend's
/// [`Predictor::fingerprint`].
pub fn fingerprint_geometry(g: &ModelGeometry) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for v in [g.vocab_size, g.embed_dim, g.l_token, g.l_clip, g.m_rows, g.train_batch] {
        h = fingerprint_mix(h, v as u64);
    }
    for &b in &g.fwd_batch_sizes {
        h = fingerprint_mix(h, b as u64);
    }
    h
}

/// A forward-only predictor backend.
///
/// Object-safe on purpose: engine code and benches hold `&dyn Predictor` /
/// `Box<dyn Predictor>` so the PJRT, native and attention backends swap
/// freely.
pub trait Predictor {
    /// Model geometry (batch shapes the backend expects).
    fn geometry(&self) -> &ModelGeometry;

    /// Largest supported forward batch capacity.
    fn max_fwd_batch(&self) -> usize;

    /// The batch capacity the backend will use for `live` rows.
    fn pick_fwd_batch(&self, live: usize) -> usize;

    /// Predict clip times for the live rows of `batch` (length
    /// `batch.live`; padding rows are never returned).
    fn forward(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>>;

    /// [`Predictor::forward`] into a caller-owned buffer, reusing the
    /// scratch arena in `ws` across calls. Semantically (and, for the
    /// row-local backends, bitwise) identical to `forward`; backends
    /// with a real kernel cost override it to run batched,
    /// allocation-free steady-state forwards. `out` is cleared first;
    /// callers keep one `Workspace` + one output buffer per driving
    /// thread. The default delegates to `forward`.
    fn forward_into(
        &self,
        batch: &Batch,
        time_scale: f32,
        ws: &mut Workspace,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let _ = ws;
        out.clear();
        out.extend(self.forward(batch, time_scale)?);
        Ok(())
    }

    /// The kernel tier this backend's production forward runs on, if it
    /// executes the SIMD-dispatched kernels at all (`None` for backends
    /// with no kernel cost, like the analytic `native` stand-in or the
    /// externally-compiled `pjrt` path). Informational — tiers are
    /// bit-identical, so this never affects predictions or cache keys.
    fn kernel_tier(&self) -> Option<KernelTier> {
        None
    }

    /// A stable identity key for caches of this backend's predictions
    /// (the persistent [`ClipCache`](crate::coordinator::ClipCache) is
    /// keyed by `fingerprint + time_scale`). The default hashes the
    /// geometry; backends override it to mix in everything else that
    /// changes predictions — backend kind, variant name, parameter
    /// shape, resident weights.
    fn fingerprint(&self) -> u64 {
        fingerprint_geometry(self.geometry())
    }
}
