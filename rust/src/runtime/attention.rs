//! A dependency-free **pure-Rust attention backend** — the paper's
//! predictor architecture (token embedding → multi-head self-attention
//! over the clip token stream → clip pooling + context fusion → regression
//! head) executed by the f32 kernels in [`super::tensor`], with no PJRT,
//! no XLA and no artifacts directory. The production path runs on a
//! runtime-selected [`KernelTier`] (scalar / AVX2 / NEON); every tier
//! shares the canonical accumulation order, so the tier changes
//! throughput, never bits (see the contract section in [`super`]).
//!
//! Structure of one forward pass (per clip row):
//!
//! ```text
//! tokens[l_clip, l_token] ── embed + masked token-mean ──► X[l_clip, d]
//!                                      + position embedding
//! X ──► N × { MHA(clip padding mask) + LN, FFN(GELU) + LN } ──► X'
//! X' ── masked mean over live instructions ──► clip vector [d]
//! ctx[m] ── embed mean → linear → GELU ──► context vector [d]
//! [clip ‖ ctx] ── linear → GELU → linear ──► s
//! prediction = softplus(s) · time_scale
//! ```
//!
//! Two properties the engine relies on, both **exact** here:
//!
//! * **row locality**: every stage of the forward is row-independent —
//!   the batched matmuls are per-row dot products and the attention
//!   mixing reads only its own clip's tokens, mask and context — so
//!   predictions are bit-identical across batch sizes, padding and
//!   cache states — the invariance the engine-equivalence suite asserts
//!   (the compiled PJRT model only approximates this; see
//!   `tests/prop_attention.rs`);
//! * **determinism**: weights come from a seeded PRNG or a versioned
//!   weights file, and every kernel runs in the fixed canonical
//!   accumulation order on every tier, so the same
//!   `(weights, row, time_scale)` always produces the same bits.
//!
//! The production forward ([`Predictor::forward_into`]) is **batched and
//! allocation-free in steady state**: weights are pre-packed into the
//! transposed/fused [`PackedLinear`] layout at model build, whole
//! batches run through shared-weight matmuls, and all scratch lives in a
//! caller-owned [`Workspace`] arena. Every optimization — packing,
//! fusing, blocking, batching, and the SIMD tier — preserves the
//! canonical per-output-element accumulation order (the 8-lane tree),
//! so the batched path is bit-identical on every tier to the row-by-row
//! forward retained as [`AttentionPredictor::forward_reference`] — the
//! oracle the property suite pins it against and the baseline the
//! `perf_micro` kernel harness measures (see the contract section in
//! [`super`]'s docs). `forward_reference` calls only the plain
//! (canonical-scalar) kernels, so it is tier-independent by
//! construction.
//!
//! Weights can be persisted ([`AttentionPredictor::save`]) and reloaded
//! ([`AttentionPredictor::load`]) through a versioned binary format; the
//! [`Predictor::fingerprint`] mixes every weight bit, so the persistent
//! `ClipCache` cold-starts whenever the weights (or the seed) change.
//! Save, load and fingerprint all read the canonical row-major
//! [`Weights`]; the packed layout is derived state, so the on-disk
//! format and the cache identity are unchanged by the kernel layout.

use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::image::{self, ImageSpec, ImageView};
use crate::util::mmap::{self, Mmap};
use crate::util::Rng;

use super::manifest::ModelGeometry;
use super::model::Batch;
use super::simd::KernelTier;
use super::tensor::{
    add_bias, axpy, axpy_tier, dot, dot_tier, gelu, gelu_slice, gelu_slice_tier, layernorm,
    layernorm_tier, masked_softmax, masked_softmax_tier, matmul, softplus, vecmat, PackedLinear,
};
use super::workspace::Workspace;
use super::Predictor;

/// Magic ("CAWB") of the **legacy** v1 weights file, still readable for
/// one release (saves now emit the `CPIM` image format; see
/// [`AttentionPredictor::save`]).
const WEIGHTS_MAGIC: u32 = 0x4257_4143;
/// Architecture/layout version, mixed into fingerprints; the legacy
/// reader refuses any other value in a CAWB file.
const WEIGHTS_VERSION: u32 = 1;
/// Guard against absurd allocations from corrupt headers.
const MAX_WEIGHT_COUNT: u64 = 1 << 24;
/// Byte stride of one `(tensor index, payload offset, f32 count)` record
/// in a weights image.
const WEIGHTS_RECORD_STRIDE: usize = 24;

/// Attention heads (embed_dim must divide evenly).
pub const DEFAULT_HEADS: usize = 4;
/// Encoder layers.
pub const DEFAULT_LAYERS: usize = 2;
/// FFN hidden multiple (hidden = ffn_mult * embed_dim).
pub const DEFAULT_FFN_MULT: usize = 2;

/// One pre-LN-free (post-norm) transformer encoder layer.
struct EncoderLayer {
    wq: Vec<f32>,    // [d, d]
    wk: Vec<f32>,    // [d, d]
    wv: Vec<f32>,    // [d, d]
    wo: Vec<f32>,    // [d, d]
    ln1_g: Vec<f32>, // [d]
    ln1_b: Vec<f32>, // [d]
    ff1_w: Vec<f32>, // [d, f]
    ff1_b: Vec<f32>, // [f]
    ff2_w: Vec<f32>, // [f, d]
    ff2_b: Vec<f32>, // [d]
    ln2_g: Vec<f32>, // [d]
    ln2_b: Vec<f32>, // [d]
}

/// The full parameter set — the **canonical row-major layout**: the one
/// layout save/load/fingerprint read, and the one the reference forward
/// runs on. The inference layout ([`PackedWeights`]) is derived from it
/// at construction.
struct Weights {
    embed: Vec<f32>,   // [vocab, d] — shared by clip tokens and context
    pos: Vec<f32>,     // [l_clip, d]
    layers: Vec<EncoderLayer>,
    ctx_w: Vec<f32>,   // [d, d]
    ctx_b: Vec<f32>,   // [d]
    head_w1: Vec<f32>, // [2d, d]
    head_b1: Vec<f32>, // [d]
    head_w2: Vec<f32>, // [d]
    head_b2: Vec<f32>, // [1]
}

/// One encoder layer in the packed inference layout: fused Q‖K‖V, plus
/// pre-transposed output/FFN projections with their biases folded in.
/// Layernorm gains/biases stay in [`EncoderLayer`] (read directly).
struct PackedLayer {
    qkv: PackedLinear, // [d -> 3d], bias-free like the unpacked projections
    wo: PackedLinear,  // [d -> d]
    ff1: PackedLinear, // [d -> f] + ff1_b
    ff2: PackedLinear, // [f -> d] + ff2_b
}

/// The packed inference layout derived from [`Weights`] (see the module
/// docs: derived state only — identity and persistence read `Weights`).
struct PackedWeights {
    layers: Vec<PackedLayer>,
    ctx: PackedLinear,   // [d -> d] + ctx_b
    head1: PackedLinear, // [2d -> d] + head_b1
}

impl PackedWeights {
    fn pack(w: &Weights, d: usize, f: usize) -> PackedWeights {
        PackedWeights {
            layers: w
                .layers
                .iter()
                .map(|l| PackedLayer {
                    qkv: PackedLinear::pack_fused(&[(&l.wq, d), (&l.wk, d), (&l.wv, d)], d),
                    wo: PackedLinear::pack(&l.wo, d, d),
                    ff1: PackedLinear::pack_with_bias(&l.ff1_w, &l.ff1_b, d, f),
                    ff2: PackedLinear::pack_with_bias(&l.ff2_w, &l.ff2_b, f, d),
                })
                .collect(),
            ctx: PackedLinear::pack_with_bias(&w.ctx_w, &w.ctx_b, d, d),
            head1: PackedLinear::pack_with_bias(&w.head_w1, &w.head_b1, 2 * d, d),
        }
    }
}

/// Per-forward scratch of the **reference** row-by-row path
/// ([`AttentionPredictor::forward_reference`]), reused across rows of a
/// batch but reallocated per call — the pre-packing cost model the
/// kernel harness baselines against.
struct Scratch {
    x: Vec<f32>,      // [l_clip, d]
    q: Vec<f32>,      // [l_clip, d]
    k: Vec<f32>,      // [l_clip, d]
    v: Vec<f32>,      // [l_clip, d]
    attn: Vec<f32>,   // [l_clip, d]
    scores: Vec<f32>, // [l_clip, l_clip]
    ff: Vec<f32>,     // [l_clip, f]
    tmp: Vec<f32>,    // [l_clip, d]
    clip: Vec<f32>,   // [d]
    ctx: Vec<f32>,    // [d]
    fused: Vec<f32>,  // [2d]
    hidden: Vec<f32>, // [d]
}

impl Scratch {
    fn new(lc: usize, d: usize, f: usize) -> Scratch {
        Scratch {
            x: vec![0.0; lc * d],
            q: vec![0.0; lc * d],
            k: vec![0.0; lc * d],
            v: vec![0.0; lc * d],
            attn: vec![0.0; lc * d],
            scores: vec![0.0; lc * lc],
            ff: vec![0.0; lc * f],
            tmp: vec![0.0; lc * d],
            clip: vec![0.0; d],
            ctx: vec![0.0; d],
            fused: vec![0.0; 2 * d],
            hidden: vec![0.0; d],
        }
    }
}

/// Scratch arena of the batched production forward, stored inside the
/// caller's [`Workspace`]. Grows monotonically to the largest batch seen
/// (`ensure`), so steady-state forwards allocate nothing. Contents carry
/// no numerical state between calls: every live region is fully
/// overwritten or explicitly zeroed before it is read (the
/// dirty-workspace property test pins this).
struct AttnScratch {
    /// Batch-row capacity and model dims the buffers are sized for (a
    /// workspace can outlive one predictor and meet another geometry).
    rows: usize,
    lc: usize,
    d: usize,
    f: usize,
    x: Vec<f32>,      // [b * l_clip, d]
    qkv: Vec<f32>,    // [b * l_clip, 3d] — fused Q‖K‖V
    attn: Vec<f32>,   // [b * l_clip, d]
    tmp: Vec<f32>,    // [b * l_clip, d]
    ff: Vec<f32>,     // [b * l_clip, f]
    scores: Vec<f32>, // [l_clip, l_clip] — one L1-resident tile per row
    clip: Vec<f32>,   // [b, d]
    ctxv: Vec<f32>,   // [b, d]
    fused: Vec<f32>,  // [b, 2d]
    hidden: Vec<f32>, // [b, d]
}

impl AttnScratch {
    fn new() -> AttnScratch {
        AttnScratch {
            rows: 0,
            lc: 0,
            d: 0,
            f: 0,
            x: Vec::new(),
            qkv: Vec::new(),
            attn: Vec::new(),
            tmp: Vec::new(),
            ff: Vec::new(),
            scores: Vec::new(),
            clip: Vec::new(),
            ctxv: Vec::new(),
            fused: Vec::new(),
            hidden: Vec::new(),
        }
    }

    /// Size the buffers for `b` batch rows of the given geometry: grows
    /// monotonically while the geometry is stable, resizes on a
    /// geometry change.
    fn ensure(&mut self, b: usize, lc: usize, d: usize, f: usize) {
        let same_geometry = lc == self.lc && d == self.d && f == self.f;
        if same_geometry && b <= self.rows {
            return;
        }
        let rows = if same_geometry { b.max(self.rows) } else { b };
        let bl = rows * lc;
        self.x.resize(bl * d, 0.0);
        self.qkv.resize(bl * 3 * d, 0.0);
        self.attn.resize(bl * d, 0.0);
        self.tmp.resize(bl * d, 0.0);
        self.ff.resize(bl * f, 0.0);
        self.scores.resize(lc * lc, 0.0);
        self.clip.resize(rows * d, 0.0);
        self.ctxv.resize(rows * d, 0.0);
        self.fused.resize(rows * 2 * d, 0.0);
        self.hidden.resize(rows * d, 0.0);
        self.rows = rows;
        self.lc = lc;
        self.d = d;
        self.f = f;
    }
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn fill_f32(r: &mut impl Read, t: &mut [f32]) -> std::io::Result<()> {
    let mut b = [0u8; 4];
    for v in t.iter_mut() {
        r.read_exact(&mut b)?;
        *v = f32::from_bits(u32::from_le_bytes(b));
    }
    Ok(())
}

/// Decoded weights-file header, shared by the legacy and image readers
/// so the shape and bound validation exists exactly once.
struct WeightsHead {
    geometry: ModelGeometry,
    heads: usize,
    layers: usize,
    ffn_mult: usize,
    seed: u64,
    count: u64,
}

impl WeightsHead {
    /// Bound every dimension before doing arithmetic on it — a corrupt
    /// header can neither overflow the expected-count product nor
    /// provoke a huge allocation — then check the advertised total
    /// against the shape it implies.
    fn validate(&self, path: &Path) -> Result<()> {
        let g = &self.geometry;
        let arch_ok = g.embed_dim > 0
            && self.heads > 0
            && g.embed_dim % self.heads == 0
            && self.layers > 0
            && self.ffn_mult > 0;
        if !arch_ok {
            return Err(anyhow!("{path:?}: inconsistent architecture header"));
        }
        let dims_ok = g.vocab_size <= 1 << 20
            && g.embed_dim <= 1 << 12
            && g.l_token <= 1 << 12
            && g.l_clip <= 1 << 12
            && g.m_rows <= 1 << 16
            && g.train_batch <= 1 << 12
            && self.layers <= 64
            && self.ffn_mult <= 16
            && g.fwd_batch_sizes.iter().all(|&b| b > 0 && b <= 1 << 12);
        if !dims_ok {
            return Err(anyhow!("{path:?}: implausible geometry header"));
        }
        // with the bounds above, every product fits comfortably in u64
        // and the total is capped by MAX_WEIGHT_COUNT
        let d = g.embed_dim as u64;
        let f = self.ffn_mult as u64 * d;
        let per_layer = 4 * d * d + 2 * d + d * f + f + f * d + d + 2 * d;
        let expected = g.vocab_size as u64 * d
            + g.l_clip as u64 * d
            + self.layers as u64 * per_layer
            + (d * d + d)
            + (2 * d * d + d + d + 1);
        if self.count != expected || self.count > MAX_WEIGHT_COUNT {
            return Err(anyhow!(
                "{path:?}: weight count {} does not match header shape ({expected})",
                self.count
            ));
        }
        Ok(())
    }

    /// A zeroed weights skeleton with this header's shape, to be filled
    /// in canonical tensor order. Call [`WeightsHead::validate`] first.
    fn skeleton(&self) -> Weights {
        let d = self.geometry.embed_dim;
        let f = self.ffn_mult * d;
        let layer = || EncoderLayer {
            wq: vec![0.0; d * d],
            wk: vec![0.0; d * d],
            wv: vec![0.0; d * d],
            wo: vec![0.0; d * d],
            ln1_g: vec![0.0; d],
            ln1_b: vec![0.0; d],
            ff1_w: vec![0.0; d * f],
            ff1_b: vec![0.0; f],
            ff2_w: vec![0.0; f * d],
            ff2_b: vec![0.0; d],
            ln2_g: vec![0.0; d],
            ln2_b: vec![0.0; d],
        };
        Weights {
            embed: vec![0.0; self.geometry.vocab_size * d],
            pos: vec![0.0; self.geometry.l_clip * d],
            layers: (0..self.layers).map(|_| layer()).collect(),
            ctx_w: vec![0.0; d * d],
            ctx_b: vec![0.0; d],
            head_w1: vec![0.0; 2 * d * d],
            head_b1: vec![0.0; d],
            head_w2: vec![0.0; d],
            head_b2: vec![0.0; 1],
        }
    }
}

/// Every tensor of `w` in canonical order, mutably — the write-side twin
/// of `AttentionPredictor::tensors`, used by both loaders to fill a
/// skeleton.
fn tensors_mut(w: &mut Weights) -> Vec<&mut [f32]> {
    let mut out: Vec<&mut [f32]> = vec![w.embed.as_mut_slice(), w.pos.as_mut_slice()];
    for l in &mut w.layers {
        out.extend([
            l.wq.as_mut_slice(),
            l.wk.as_mut_slice(),
            l.wv.as_mut_slice(),
            l.wo.as_mut_slice(),
            l.ln1_g.as_mut_slice(),
            l.ln1_b.as_mut_slice(),
            l.ff1_w.as_mut_slice(),
            l.ff1_b.as_mut_slice(),
            l.ff2_w.as_mut_slice(),
            l.ff2_b.as_mut_slice(),
            l.ln2_g.as_mut_slice(),
            l.ln2_b.as_mut_slice(),
        ]);
    }
    out.extend([
        w.ctx_w.as_mut_slice(),
        w.ctx_b.as_mut_slice(),
        w.head_w1.as_mut_slice(),
        w.head_b1.as_mut_slice(),
        w.head_w2.as_mut_slice(),
        w.head_b2.as_mut_slice(),
    ]);
    out
}

/// Deterministic pure-Rust attention predictor; see the module docs.
pub struct AttentionPredictor {
    geometry: ModelGeometry,
    heads: usize,
    ffn_mult: usize,
    /// Seed the weights were drawn from (provenance label; file loads
    /// carry the seed of the run that saved them).
    seed: u64,
    /// Canonical row-major parameters (identity + persistence).
    w: Weights,
    /// Derived packed inference layout (never saved or fingerprinted).
    packed: PackedWeights,
    /// Kernel tier of the batched production path — always a concrete,
    /// available tier (`effective()`-resolved at construction /
    /// [`AttentionPredictor::with_tier`]). Never part of the identity:
    /// all tiers are bit-identical, so predictions and cache keys do
    /// not depend on it.
    tier: KernelTier,
}

impl AttentionPredictor {
    /// Build a predictor from its canonical weights, deriving the packed
    /// inference layout — the single constructor every entry point
    /// funnels through.
    fn from_weights(
        geometry: ModelGeometry,
        heads: usize,
        ffn_mult: usize,
        seed: u64,
        w: Weights,
    ) -> AttentionPredictor {
        let d = geometry.embed_dim;
        let packed = PackedWeights::pack(&w, d, ffn_mult * d);
        let tier = KernelTier::Auto.effective();
        AttentionPredictor { geometry, heads, ffn_mult, seed, w, packed, tier }
    }

    /// Select the kernel tier of the batched production path (builder
    /// style; `Auto` and unavailable tiers resolve through
    /// [`KernelTier::effective`]). Bit-identical on every tier.
    pub fn with_tier(mut self, tier: KernelTier) -> AttentionPredictor {
        self.tier = tier.effective();
        self
    }

    /// Deterministically initialized weights for `geometry` drawn from
    /// `seed` (uniform, 1/sqrt(fan_in)-scaled; layernorm gains 1).
    pub fn seeded(geometry: ModelGeometry, seed: u64) -> AttentionPredictor {
        let d = geometry.embed_dim;
        assert!(d > 0 && d % DEFAULT_HEADS == 0, "embed_dim must divide heads");
        let f = DEFAULT_FFN_MULT * d;
        let mut rng = Rng::new(seed ^ 0xA77E_4710_4BAC_83D5);
        let mut uniform = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
        };
        let proj = 1.0 / (d as f32).sqrt();
        let embed = uniform(geometry.vocab_size * d, 0.05);
        let pos = uniform(geometry.l_clip * d, 0.05);
        let layers = (0..DEFAULT_LAYERS)
            .map(|_| EncoderLayer {
                wq: uniform(d * d, proj),
                wk: uniform(d * d, proj),
                wv: uniform(d * d, proj),
                wo: uniform(d * d, proj),
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                ff1_w: uniform(d * f, proj),
                ff1_b: vec![0.0; f],
                ff2_w: uniform(f * d, 1.0 / (f as f32).sqrt()),
                ff2_b: vec![0.0; d],
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
            })
            .collect();
        let ctx_w = uniform(d * d, proj);
        let head_w1 = uniform(2 * d * d, 1.0 / (2.0 * d as f32).sqrt());
        let head_w2 = uniform(d, proj);
        AttentionPredictor::from_weights(
            geometry,
            DEFAULT_HEADS,
            DEFAULT_FFN_MULT,
            seed,
            Weights {
                embed,
                pos,
                layers,
                ctx_w,
                ctx_b: vec![0.0; d],
                head_w1,
                head_b1: vec![0.0; d],
                head_w2,
                head_b2: vec![0.5],
            },
        )
    }

    /// Default geometry (the `model_config.json` constants) with the
    /// default pipeline seed.
    pub fn with_defaults() -> AttentionPredictor {
        AttentionPredictor::seeded(super::default_geometry(), 42)
    }

    /// The seed the resident weights were drawn from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Every tensor in canonical (save/fingerprint) order.
    fn tensors(&self) -> Vec<&[f32]> {
        let mut out: Vec<&[f32]> = vec![self.w.embed.as_slice(), self.w.pos.as_slice()];
        for l in &self.w.layers {
            out.extend([
                l.wq.as_slice(),
                l.wk.as_slice(),
                l.wv.as_slice(),
                l.wo.as_slice(),
                l.ln1_g.as_slice(),
                l.ln1_b.as_slice(),
                l.ff1_w.as_slice(),
                l.ff1_b.as_slice(),
                l.ff2_w.as_slice(),
                l.ff2_b.as_slice(),
                l.ln2_g.as_slice(),
                l.ln2_b.as_slice(),
            ]);
        }
        out.extend([
            self.w.ctx_w.as_slice(),
            self.w.ctx_b.as_slice(),
            self.w.head_w1.as_slice(),
            self.w.head_b1.as_slice(),
            self.w.head_w2.as_slice(),
            self.w.head_b2.as_slice(),
        ]);
        out
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors().iter().map(|t| t.len()).sum()
    }

    /// Persist the weights as a `CPIM` image (kind = weights): the
    /// geometry/architecture header in the checksummed meta blob, one
    /// `(index, payload offset, f32 count)` record per tensor in
    /// canonical order, a segment-aligned little-endian f32 payload, and
    /// the live [`Predictor::fingerprint`] in the header as a load-time
    /// self-check. Published via the shared unique-temp + fsync +
    /// atomic-rename discipline, so a crashed or racing writer never
    /// leaves a torn file behind. [`AttentionPredictor::load`] still
    /// reads the legacy `CAWB` v1 stream for one release.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let g = &self.geometry;
        let mut meta = Vec::new();
        for v in [g.vocab_size, g.embed_dim, g.l_token, g.l_clip, g.m_rows, g.train_batch] {
            meta.extend_from_slice(&(v as u32).to_le_bytes());
        }
        meta.extend_from_slice(&(g.fwd_batch_sizes.len() as u32).to_le_bytes());
        for &b in &g.fwd_batch_sizes {
            meta.extend_from_slice(&(b as u32).to_le_bytes());
        }
        for v in [self.heads, self.w.layers.len(), self.ffn_mult] {
            meta.extend_from_slice(&(v as u32).to_le_bytes());
        }
        meta.extend_from_slice(&self.seed.to_le_bytes());
        meta.extend_from_slice(&(self.param_count() as u64).to_le_bytes());

        let tensors = self.tensors();
        let mut records = Vec::with_capacity(tensors.len() * WEIGHTS_RECORD_STRIDE);
        let mut payload = Vec::with_capacity(self.param_count() * 4);
        for (i, t) in tensors.iter().enumerate() {
            records.extend_from_slice(&(i as u64).to_le_bytes());
            records.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            records.extend_from_slice(&(t.len() as u64).to_le_bytes());
            for &v in *t {
                payload.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        image::persist_atomic(path, |w| {
            image::write_image(
                w,
                &ImageSpec {
                    kind: image::KIND_WEIGHTS,
                    fingerprint: Predictor::fingerprint(self),
                    kernel_contract: super::KERNEL_CONTRACT_VERSION,
                    time_scale_bits: 0,
                    meta: &meta,
                    record_stride: WEIGHTS_RECORD_STRIDE as u32,
                    records: &records,
                    payload: &payload,
                },
            )
        })
    }

    /// Load persisted weights. A `CPIM` image is mmap'd, its data digest
    /// verified **eagerly** (the payload is bounded by
    /// [`MAX_WEIGHT_COUNT`], so the O(data) check is cheap and no byte is
    /// ever trusted unverified), and its f32 payload copied once into
    /// place through zero-copy [`mmap::f32_view`] slices; a legacy `CAWB`
    /// v1 stream still parses for one release. Wrong magic/version,
    /// inconsistent shapes, truncated or bit-flipped data are refused
    /// with the offending path in the message — callers cold-start,
    /// never construct a wrong predictor.
    pub fn load(path: &Path) -> Result<AttentionPredictor> {
        let map = Mmap::open(path).map_err(|e| anyhow!("opening {path:?}: {e}"))?;
        let bytes = map.bytes();
        if bytes.len() >= 4 && u32::from_le_bytes(bytes[0..4].try_into().unwrap()) == WEIGHTS_MAGIC
        {
            return Self::load_legacy_v1(path, bytes);
        }
        let view = ImageView::parse(bytes).map_err(|m| anyhow!("{path:?}: {m}"))?;
        Self::load_image(path, &view)
    }

    /// The `CPIM` weights reader; `view` has already passed the O(1)
    /// header/bounds validation of [`ImageView::parse`].
    fn load_image(path: &Path, view: &ImageView<'_>) -> Result<AttentionPredictor> {
        if view.kind != image::KIND_WEIGHTS {
            return Err(anyhow!("{path:?}: not a weights image (kind {})", view.kind));
        }
        if view.record_stride as usize != WEIGHTS_RECORD_STRIDE {
            return Err(anyhow!("{path:?}: unexpected weights record stride"));
        }
        if !view.verify_data() {
            return Err(anyhow!("{path:?}: weights data digest mismatch"));
        }
        let head = (|| -> std::io::Result<WeightsHead> {
            let mut r = std::io::Cursor::new(view.meta);
            let vocab_size = read_u32(&mut r)? as usize;
            let embed_dim = read_u32(&mut r)? as usize;
            let l_token = read_u32(&mut r)? as usize;
            let l_clip = read_u32(&mut r)? as usize;
            let m_rows = read_u32(&mut r)? as usize;
            let train_batch = read_u32(&mut r)? as usize;
            let n_fwd = read_u32(&mut r)? as usize;
            if n_fwd > 64 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "implausible fwd batch list",
                ));
            }
            let mut fwd_batch_sizes = Vec::with_capacity(n_fwd);
            for _ in 0..n_fwd {
                fwd_batch_sizes.push(read_u32(&mut r)? as usize);
            }
            let heads = read_u32(&mut r)? as usize;
            let layers = read_u32(&mut r)? as usize;
            let ffn_mult = read_u32(&mut r)? as usize;
            let seed = read_u64(&mut r)?;
            let count = read_u64(&mut r)?;
            if r.position() != view.meta.len() as u64 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "trailing bytes after weights header",
                ));
            }
            let geometry = ModelGeometry {
                vocab_size,
                embed_dim,
                l_token,
                l_clip,
                m_rows,
                train_batch,
                fwd_batch_sizes,
            };
            Ok(WeightsHead { geometry, heads, layers, ffn_mult, seed, count })
        })()
        .map_err(|e| anyhow!("{path:?}: bad weights meta: {e}"))?;
        head.validate(path)?;

        let mut w = head.skeleton();
        {
            let mut tensors = tensors_mut(&mut w);
            if view.n_records != tensors.len() as u64 {
                return Err(anyhow!(
                    "{path:?}: {} tensor records, header shape implies {}",
                    view.n_records,
                    tensors.len()
                ));
            }
            for (i, t) in tensors.iter_mut().enumerate() {
                let rec = view.record(i);
                let idx = u64::from_le_bytes(rec[0..8].try_into().unwrap());
                let off = u64::from_le_bytes(rec[8..16].try_into().unwrap());
                let n = u64::from_le_bytes(rec[16..24].try_into().unwrap());
                if idx != i as u64 || n != t.len() as u64 {
                    return Err(anyhow!(
                        "{path:?}: tensor record {i} disagrees with the header shape"
                    ));
                }
                let start = usize::try_from(off)
                    .ok()
                    .filter(|&s| s <= view.payload.len())
                    .ok_or_else(|| anyhow!("{path:?}: tensor record {i} out of payload bounds"))?;
                let end = start
                    .checked_add(t.len() * 4)
                    .filter(|&e| e <= view.payload.len())
                    .ok_or_else(|| anyhow!("{path:?}: tensor record {i} out of payload bounds"))?;
                let src = &view.payload[start..end];
                match mmap::f32_view(src) {
                    Some(s) => t.copy_from_slice(s),
                    // the payload section is segment-aligned, so only a
                    // hostile in-payload offset lands here; decode
                    // portably instead of refusing
                    None => {
                        for (dst, c) in t.iter_mut().zip(src.chunks_exact(4)) {
                            *dst = f32::from_bits(u32::from_le_bytes(c.try_into().unwrap()));
                        }
                    }
                }
            }
        }
        let out =
            AttentionPredictor::from_weights(head.geometry, head.heads, head.ffn_mult, head.seed, w);
        debug_assert_eq!(out.param_count() as u64, head.count);
        // The stored fingerprint is a self-check of the reconstructed
        // predictor. It mixes KERNEL_CONTRACT_VERSION, so it is only
        // comparable when the image was written under the same contract;
        // weights themselves stay valid across contract bumps.
        if view.kernel_contract == super::KERNEL_CONTRACT_VERSION
            && Predictor::fingerprint(&out) != view.fingerprint
        {
            return Err(anyhow!("{path:?}: weights fingerprint self-check failed"));
        }
        Ok(out)
    }

    /// The legacy `CAWB` v1 reader (sequential f32 stream), kept for the
    /// one-release migration window; saves always re-emit the image
    /// format.
    fn load_legacy_v1(path: &Path, bytes: &[u8]) -> Result<AttentionPredictor> {
        let trunc = |e: std::io::Error| anyhow!("{path:?}: truncated weights file: {e}");
        let mut r = std::io::Cursor::new(bytes);
        if read_u32(&mut r).map_err(trunc)? != WEIGHTS_MAGIC {
            return Err(anyhow!("{path:?}: not an attention weights file"));
        }
        if read_u32(&mut r).map_err(trunc)? != WEIGHTS_VERSION {
            return Err(anyhow!("{path:?}: unsupported weights version"));
        }
        let vocab_size = read_u32(&mut r).map_err(trunc)? as usize;
        let embed_dim = read_u32(&mut r).map_err(trunc)? as usize;
        let l_token = read_u32(&mut r).map_err(trunc)? as usize;
        let l_clip = read_u32(&mut r).map_err(trunc)? as usize;
        let m_rows = read_u32(&mut r).map_err(trunc)? as usize;
        let train_batch = read_u32(&mut r).map_err(trunc)? as usize;
        let n_fwd = read_u32(&mut r).map_err(trunc)? as usize;
        if n_fwd > 64 {
            return Err(anyhow!("{path:?}: implausible fwd batch list"));
        }
        let mut fwd_batch_sizes = Vec::with_capacity(n_fwd);
        for _ in 0..n_fwd {
            fwd_batch_sizes.push(read_u32(&mut r).map_err(trunc)? as usize);
        }
        let heads = read_u32(&mut r).map_err(trunc)? as usize;
        let layers = read_u32(&mut r).map_err(trunc)? as usize;
        let ffn_mult = read_u32(&mut r).map_err(trunc)? as usize;
        let seed = read_u64(&mut r).map_err(trunc)?;
        let count = read_u64(&mut r).map_err(trunc)?;
        let geometry = ModelGeometry {
            vocab_size,
            embed_dim,
            l_token,
            l_clip,
            m_rows,
            train_batch,
            fwd_batch_sizes,
        };
        let head = WeightsHead { geometry, heads, layers, ffn_mult, seed, count };
        head.validate(path)?;

        // fill a zeroed skeleton tensor by tensor in canonical order,
        // then pack for inference
        let mut w = head.skeleton();
        for t in tensors_mut(&mut w) {
            fill_f32(&mut r, t).map_err(trunc)?;
        }
        let out =
            AttentionPredictor::from_weights(head.geometry, head.heads, head.ffn_mult, head.seed, w);
        debug_assert_eq!(out.param_count() as u64, head.count);
        Ok(out)
    }

    /// One **reference-path** encoder layer over `x` (`[l_clip, d]`)
    /// under the clip padding `mask` (`[l_clip]`). Masked *keys* receive
    /// zero attention, so live positions never read padding content;
    /// masked positions' own outputs are computed but ignored by the
    /// pooling stage.
    fn encoder_layer_ref(&self, lw: &EncoderLayer, mask: &[f32], s: &mut Scratch) {
        let lc = self.geometry.l_clip;
        let d = self.geometry.embed_dim;
        let hd = d / self.heads;
        let scale = 1.0 / (hd as f32).sqrt();

        matmul(&s.x, &lw.wq, lc, d, d, &mut s.q);
        matmul(&s.x, &lw.wk, lc, d, d, &mut s.k);
        matmul(&s.x, &lw.wv, lc, d, d, &mut s.v);
        s.attn.fill(0.0);
        for h in 0..self.heads {
            let o = h * hd;
            for i in 0..lc {
                let q = &s.q[i * d + o..i * d + o + hd];
                for j in 0..lc {
                    let k = &s.k[j * d + o..j * d + o + hd];
                    s.scores[i * lc + j] = dot(q, k) * scale;
                }
            }
            masked_softmax(&mut s.scores, lc, lc, mask);
            for i in 0..lc {
                for j in 0..lc {
                    let p = s.scores[i * lc + j];
                    if p == 0.0 {
                        continue;
                    }
                    let v = &s.v[j * d + o..j * d + o + hd];
                    axpy(&mut s.attn[i * d + o..i * d + o + hd], p, v);
                }
            }
        }
        matmul(&s.attn, &lw.wo, lc, d, d, &mut s.tmp);
        for (a, &b) in s.x.iter_mut().zip(s.tmp.iter()) {
            *a += b;
        }
        layernorm(&mut s.x, &lw.ln1_g, &lw.ln1_b);

        let f = self.ffn_mult * d;
        matmul(&s.x, &lw.ff1_w, lc, d, f, &mut s.ff);
        add_bias(&mut s.ff, &lw.ff1_b);
        gelu_slice(&mut s.ff);
        matmul(&s.ff, &lw.ff2_w, lc, f, d, &mut s.tmp);
        add_bias(&mut s.tmp, &lw.ff2_b);
        for (a, &b) in s.x.iter_mut().zip(s.tmp.iter()) {
            *a += b;
        }
        layernorm(&mut s.x, &lw.ln2_g, &lw.ln2_b);
    }

    /// Price one live row through the reference path; pure function of
    /// that row's tokens, masks and context (never of the batch
    /// composition — see the module docs).
    fn row_forward_ref(&self, batch: &Batch, r: usize, time_scale: f32, s: &mut Scratch) -> f32 {
        let g = &self.geometry;
        let (lc, lt, d) = (g.l_clip, g.l_token, g.embed_dim);
        let row_tokens = lc * lt;
        let mask = &batch.clip_mask[r * lc..(r + 1) * lc];

        // token embedding + masked token-mean per instruction + position
        s.x.fill(0.0);
        for i in 0..lc {
            if mask[i] == 0.0 {
                continue;
            }
            let mut live = 0.0f32;
            for t in 0..lt {
                let idx = r * row_tokens + i * lt + t;
                if batch.tok_mask[idx] == 0.0 {
                    continue;
                }
                let tok = (batch.tokens[idx].max(0) as usize).min(g.vocab_size - 1);
                for c in 0..d {
                    s.x[i * d + c] += self.w.embed[tok * d + c];
                }
                live += 1.0;
            }
            if live > 0.0 {
                let inv = 1.0 / live;
                for c in 0..d {
                    s.x[i * d + c] *= inv;
                }
            }
            for c in 0..d {
                s.x[i * d + c] += self.w.pos[i * d + c];
            }
        }

        for lw in &self.w.layers {
            self.encoder_layer_ref(lw, mask, s);
        }

        // masked mean pooling over live instructions
        s.clip.fill(0.0);
        let mut live = 0.0f32;
        for i in 0..lc {
            if mask[i] == 0.0 {
                continue;
            }
            for c in 0..d {
                s.clip[c] += s.x[i * d + c];
            }
            live += 1.0;
        }
        if live > 0.0 {
            let inv = 1.0 / live;
            for v in s.clip.iter_mut() {
                *v *= inv;
            }
        }

        // context fusion: embed mean over the M context rows → linear →
        // GELU
        s.ctx.fill(0.0);
        for m in 0..g.m_rows {
            let tok = (batch.ctx[r * g.m_rows + m].max(0) as usize).min(g.vocab_size - 1);
            for c in 0..d {
                s.ctx[c] += self.w.embed[tok * d + c];
            }
        }
        let inv = 1.0 / g.m_rows.max(1) as f32;
        for v in s.ctx.iter_mut() {
            *v *= inv;
        }
        s.fused[..d].copy_from_slice(&s.clip);
        vecmat(&s.ctx, &self.w.ctx_w, d, d, &mut s.hidden);
        for c in 0..d {
            s.fused[d + c] = gelu(s.hidden[c] + self.w.ctx_b[c]);
        }

        // regression head
        vecmat(&s.fused, &self.w.head_w1, 2 * d, d, &mut s.hidden);
        add_bias(&mut s.hidden, &self.w.head_b1);
        gelu_slice(&mut s.hidden);
        let out = self.w.head_b2[0] + dot(&s.hidden, &self.w.head_w2);
        (softplus(out) * time_scale).max(1e-3)
    }

    /// The original (PR 3) row-by-row scalar forward: naive `matmul` on
    /// the row-major weights, per-call scratch. Kept as the
    /// **bit-exactness oracle** the property suite pins the batched
    /// production path against, and as the baseline the `perf_micro`
    /// kernel-regression harness measures speedups from. Never used by
    /// the engine.
    pub fn forward_reference(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(
            batch.live <= batch.b,
            "live rows {} exceed batch capacity {}",
            batch.live,
            batch.b
        );
        let g = &self.geometry;
        let mut scratch = Scratch::new(g.l_clip, g.embed_dim, self.ffn_mult * g.embed_dim);
        Ok((0..batch.live)
            .map(|r| self.row_forward_ref(batch, r, time_scale, &mut scratch))
            .collect())
    }

    /// Token embedding + masked token-mean + position for every live
    /// row, into `s.x` (`[b * l_clip, d]`, zeroed here) — the batched
    /// path's stage 1. Pure gather; identical per-element arithmetic to
    /// the reference path's embedding stage.
    fn embed_batch(&self, batch: &Batch, b: usize, s: &mut AttnScratch) {
        let g = &self.geometry;
        let (lc, lt, d) = (g.l_clip, g.l_token, g.embed_dim);
        let row_tokens = lc * lt;
        s.x[..b * lc * d].fill(0.0);
        for r in 0..b {
            let x = &mut s.x[r * lc * d..(r + 1) * lc * d];
            let mask = &batch.clip_mask[r * lc..(r + 1) * lc];
            for i in 0..lc {
                if mask[i] == 0.0 {
                    continue;
                }
                let mut live = 0.0f32;
                for t in 0..lt {
                    let idx = r * row_tokens + i * lt + t;
                    if batch.tok_mask[idx] == 0.0 {
                        continue;
                    }
                    let tok = (batch.tokens[idx].max(0) as usize).min(g.vocab_size - 1);
                    for c in 0..d {
                        x[i * d + c] += self.w.embed[tok * d + c];
                    }
                    live += 1.0;
                }
                if live > 0.0 {
                    let inv = 1.0 / live;
                    for c in 0..d {
                        x[i * d + c] *= inv;
                    }
                }
                for c in 0..d {
                    x[i * d + c] += self.w.pos[i * d + c];
                }
            }
        }
    }

    /// One encoder layer over all `b` rows at once: the Q‖K‖V, output
    /// and FFN projections run as single packed matmuls over `b * l_clip`
    /// token rows; only the attention mixing (scores → masked softmax →
    /// value mix, one `l_clip × l_clip` tile) runs per clip row, under
    /// that row's padding mask. Per-element arithmetic — and therefore
    /// every produced bit — matches [`AttentionPredictor::encoder_layer_ref`].
    fn encoder_layer_batched(
        &self,
        batch: &Batch,
        b: usize,
        lw: &EncoderLayer,
        pw: &PackedLayer,
        s: &mut AttnScratch,
    ) {
        let g = &self.geometry;
        let (lc, d) = (g.l_clip, g.embed_dim);
        let f = self.ffn_mult * d;
        let bl = b * lc;
        let hd = d / self.heads;
        let scale = 1.0 / (hd as f32).sqrt();

        // fused QKV projection: one packed matmul over every token row
        pw.qkv.apply_tier(self.tier, &s.x[..bl * d], bl, &mut s.qkv[..bl * 3 * d]);

        // attention mixing per clip row — the only row-scoped stage
        s.attn[..bl * d].fill(0.0);
        for r in 0..b {
            let mask = &batch.clip_mask[r * lc..(r + 1) * lc];
            let qkv = &s.qkv[r * lc * 3 * d..(r + 1) * lc * 3 * d];
            let attn = &mut s.attn[r * lc * d..(r + 1) * lc * d];
            for h in 0..self.heads {
                let o = h * hd;
                for i in 0..lc {
                    let q = &qkv[i * 3 * d + o..i * 3 * d + o + hd];
                    for j in 0..lc {
                        let k = &qkv[j * 3 * d + d + o..j * 3 * d + d + o + hd];
                        s.scores[i * lc + j] = dot_tier(self.tier, q, k) * scale;
                    }
                }
                masked_softmax_tier(self.tier, &mut s.scores, lc, lc, mask);
                for i in 0..lc {
                    for j in 0..lc {
                        let p = s.scores[i * lc + j];
                        if p == 0.0 {
                            continue;
                        }
                        let v = &qkv[j * 3 * d + 2 * d + o..j * 3 * d + 2 * d + o + hd];
                        axpy_tier(self.tier, &mut attn[i * d + o..i * d + o + hd], p, v);
                    }
                }
            }
        }

        // output projection + residual + LN over all rows at once
        pw.wo.apply_tier(self.tier, &s.attn[..bl * d], bl, &mut s.tmp[..bl * d]);
        for (a, &t) in s.x[..bl * d].iter_mut().zip(&s.tmp[..bl * d]) {
            *a += t;
        }
        layernorm_tier(self.tier, &mut s.x[..bl * d], &lw.ln1_g, &lw.ln1_b);

        // FFN as two packed matmuls (biases folded into the stores)
        pw.ff1.apply_tier(self.tier, &s.x[..bl * d], bl, &mut s.ff[..bl * f]);
        gelu_slice_tier(self.tier, &mut s.ff[..bl * f]);
        pw.ff2.apply_tier(self.tier, &s.ff[..bl * f], bl, &mut s.tmp[..bl * d]);
        for (a, &t) in s.x[..bl * d].iter_mut().zip(&s.tmp[..bl * d]) {
            *a += t;
        }
        layernorm_tier(self.tier, &mut s.x[..bl * d], &lw.ln2_g, &lw.ln2_b);
    }
}

impl Predictor for AttentionPredictor {
    fn geometry(&self) -> &ModelGeometry {
        &self.geometry
    }

    fn max_fwd_batch(&self) -> usize {
        self.geometry.fwd_batch_sizes.last().copied().unwrap_or(1)
    }

    fn pick_fwd_batch(&self, live: usize) -> usize {
        for &b in &self.geometry.fwd_batch_sizes {
            if b >= live {
                return b;
            }
        }
        self.max_fwd_batch()
    }

    fn forward(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>> {
        // one-shot convenience over the batched path: same bits as a
        // caller-owned workspace, minus the steady-state reuse
        let mut ws = Workspace::new();
        let mut out = Vec::with_capacity(batch.live);
        self.forward_into(batch, time_scale, &mut ws, &mut out)?;
        Ok(out)
    }

    /// The production forward: batched, packed, allocation-free in
    /// steady state — bit-identical to
    /// [`AttentionPredictor::forward_reference`] (see the module docs).
    fn forward_into(
        &self,
        batch: &Batch,
        time_scale: f32,
        ws: &mut Workspace,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(
            batch.live <= batch.b,
            "live rows {} exceed batch capacity {}",
            batch.live,
            batch.b
        );
        out.clear();
        let b = batch.live;
        if b == 0 {
            return Ok(());
        }
        let g = &self.geometry;
        let (lc, d) = (g.l_clip, g.embed_dim);
        let f = self.ffn_mult * d;
        let s = ws.get_or_insert_with(AttnScratch::new);
        s.ensure(b, lc, d, f);

        self.embed_batch(batch, b, s);
        for (lw, pw) in self.w.layers.iter().zip(&self.packed.layers) {
            self.encoder_layer_batched(batch, b, lw, pw, s);
        }

        // masked mean pooling over live instructions, per row
        s.clip[..b * d].fill(0.0);
        for r in 0..b {
            let mask = &batch.clip_mask[r * lc..(r + 1) * lc];
            let x = &s.x[r * lc * d..(r + 1) * lc * d];
            let clip = &mut s.clip[r * d..(r + 1) * d];
            let mut live = 0.0f32;
            for i in 0..lc {
                if mask[i] == 0.0 {
                    continue;
                }
                for c in 0..d {
                    clip[c] += x[i * d + c];
                }
                live += 1.0;
            }
            if live > 0.0 {
                let inv = 1.0 / live;
                for v in clip.iter_mut() {
                    *v *= inv;
                }
            }
        }

        // context fusion: embed mean per row, then one packed matmul
        // (ctx_b folded in) and the GELU gate into the fused vector
        s.ctxv[..b * d].fill(0.0);
        let inv = 1.0 / g.m_rows.max(1) as f32;
        for r in 0..b {
            let ctx = &mut s.ctxv[r * d..(r + 1) * d];
            for m in 0..g.m_rows {
                let tok = (batch.ctx[r * g.m_rows + m].max(0) as usize).min(g.vocab_size - 1);
                for c in 0..d {
                    ctx[c] += self.w.embed[tok * d + c];
                }
            }
            for v in ctx.iter_mut() {
                *v *= inv;
            }
        }
        self.packed.ctx.apply_tier(self.tier, &s.ctxv[..b * d], b, &mut s.hidden[..b * d]);
        for r in 0..b {
            let fused = &mut s.fused[r * 2 * d..(r + 1) * 2 * d];
            fused[..d].copy_from_slice(&s.clip[r * d..(r + 1) * d]);
            for c in 0..d {
                fused[d + c] = gelu(s.hidden[r * d + c]);
            }
        }

        // regression head: packed matmul (head_b1 folded in) + GELU +
        // per-row dot with the output vector
        self.packed.head1.apply_tier(self.tier, &s.fused[..b * 2 * d], b, &mut s.hidden[..b * d]);
        gelu_slice_tier(self.tier, &mut s.hidden[..b * d]);
        for r in 0..b {
            let h = &s.hidden[r * d..(r + 1) * d];
            let v = self.w.head_b2[0] + dot_tier(self.tier, h, &self.w.head_w2);
            out.push((softplus(v) * time_scale).max(1e-3));
        }
        Ok(())
    }

    fn kernel_tier(&self) -> Option<KernelTier> {
        Some(self.tier)
    }

    fn fingerprint(&self) -> u64 {
        // kind + architecture + every weight bit: retraining, reseeding
        // or editing the weights file must cold-start persisted caches.
        // KERNEL_CONTRACT_VERSION (not the tier — tiers are
        // bit-identical) covers changes to the canonical accumulation
        // order itself, which change every prediction's bits.
        let mut h = super::fingerprint_geometry(&self.geometry);
        h = super::fingerprint_bytes(h, b"attention-rs");
        h = super::fingerprint_mix(h, WEIGHTS_VERSION as u64);
        h = super::fingerprint_mix(h, super::KERNEL_CONTRACT_VERSION);
        for v in [self.heads, self.w.layers.len(), self.ffn_mult] {
            h = super::fingerprint_mix(h, v as u64);
        }
        for t in self.tensors() {
            for &v in t {
                h = super::fingerprint_mix(h, v.to_bits() as u64);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ClipSample;
    use crate::predictor::build_batch;

    /// A small geometry so unit tests stay fast in debug builds.
    fn small_geometry() -> ModelGeometry {
        ModelGeometry {
            vocab_size: 64,
            embed_dim: 16,
            l_token: 4,
            l_clip: 8,
            m_rows: 6,
            train_batch: 4,
            fwd_batch_sizes: vec![1, 4, 8],
        }
    }

    fn sample(g: &ModelGeometry, fill: u16, len: u16, ctx_fill: u16) -> ClipSample {
        ClipSample {
            tokens: (0..len as usize * g.l_token)
                .map(|i| if i % g.l_token == 0 { 1 } else { fill })
                .collect(),
            len,
            ctx: vec![ctx_fill; g.m_rows],
            time: 10.0,
            key: 1,
            bench: 0,
        }
    }

    #[test]
    fn predictions_positive_finite_and_scaled() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 7);
        let s = sample(&g, 20, 5, 30);
        let b = build_batch(&[&s], 1, &g);
        let out = p.forward(&b, 50.0).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].is_finite() && out[0] > 0.0);
        let out2 = p.forward(&b, 100.0).unwrap();
        assert!((out2[0] - 2.0 * out[0]).abs() / out[0] < 1e-4, "linear in time_scale");
    }

    #[test]
    fn batch_and_padding_invariance_is_exact() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 11);
        let samples: Vec<ClipSample> =
            (0..5).map(|i| sample(&g, 10 + i as u16, 2 + i as u16, 40 + i as u16)).collect();
        let refs: Vec<&ClipSample> = samples.iter().collect();
        let full = p.forward(&build_batch(&refs, 8, &g), 40.0).unwrap();
        assert_eq!(full.len(), 5);
        for (i, s) in samples.iter().enumerate() {
            let one = p.forward(&build_batch(&[s], 1, &g), 40.0).unwrap();
            assert_eq!(one[0].to_bits(), full[i].to_bits(), "row {i}");
        }
    }

    #[test]
    fn batched_forward_matches_reference_bitwise() {
        // the packed/fused/workspace production path vs the PR-3 scalar
        // oracle, including an empty clip in the mix
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 21);
        let samples: Vec<ClipSample> =
            (0..6).map(|i| sample(&g, 5 + i as u16, (i % 7) as u16, 9 + i as u16)).collect();
        let refs: Vec<&ClipSample> = samples.iter().collect();
        let batch = build_batch(&refs, 8, &g);
        let a = p.forward_reference(&batch, 40.0).unwrap();
        let b = p.forward(&batch, 40.0).unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "row {i}");
        }
    }

    #[test]
    fn forced_tiers_match_reference_bitwise() {
        // every available tier must produce the oracle's exact bits;
        // the broad coverage lives in tests/prop_kernel_tiers.rs
        let g = small_geometry();
        let samples: Vec<ClipSample> =
            (0..5).map(|i| sample(&g, 3 + i as u16, (i % 5) as u16, 2 + i as u16)).collect();
        let refs: Vec<&ClipSample> = samples.iter().collect();
        let batch = build_batch(&refs, 8, &g);
        let oracle = AttentionPredictor::seeded(g.clone(), 33)
            .forward_reference(&batch, 40.0)
            .unwrap();
        for tier in [KernelTier::Auto, KernelTier::Scalar, KernelTier::Avx2, KernelTier::Neon] {
            if !tier.available() {
                continue;
            }
            let p = AttentionPredictor::seeded(g.clone(), 33).with_tier(tier);
            assert_ne!(Predictor::kernel_tier(&p), Some(KernelTier::Auto), "tier resolves");
            let got = p.forward(&batch, 40.0).unwrap();
            for (i, (x, y)) in oracle.iter().zip(&got).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{tier} row {i}");
            }
        }
    }

    #[test]
    fn workspace_survives_geometry_changes() {
        // a workspace sized by one model must serve a model of another
        // geometry (resize) and then the first again, bit-identically
        let g_small = small_geometry();
        let p_small = AttentionPredictor::seeded(g_small.clone(), 3);
        let p_big = AttentionPredictor::with_defaults();
        let g_big = p_big.geometry().clone();
        let mut ws = Workspace::new();
        let mut out: Vec<f32> = Vec::new();

        let s_small = sample(&g_small, 4, 3, 7);
        let b_small = build_batch(&[&s_small], 1, &g_small);
        p_small.forward_into(&b_small, 40.0, &mut ws, &mut out).unwrap();
        let first = out[0];

        let s_big = sample(&g_big, 9, 5, 2);
        let b_big = build_batch(&[&s_big], 1, &g_big);
        p_big.forward_into(&b_big, 40.0, &mut ws, &mut out).unwrap();
        assert!(out[0].is_finite() && out[0] > 0.0);

        p_small.forward_into(&b_small, 40.0, &mut ws, &mut out).unwrap();
        assert_eq!(first.to_bits(), out[0].to_bits(), "geometry swap corrupted scratch");
    }

    #[test]
    fn tokens_and_context_both_matter() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 3);
        let base = p
            .forward(&build_batch(&[&sample(&g, 20, 6, 30)], 1, &g), 30.0)
            .unwrap()[0];
        let diff_tok = p
            .forward(&build_batch(&[&sample(&g, 21, 6, 30)], 1, &g), 30.0)
            .unwrap()[0];
        let diff_ctx = p
            .forward(&build_batch(&[&sample(&g, 20, 6, 31)], 1, &g), 30.0)
            .unwrap()[0];
        assert_ne!(base.to_bits(), diff_tok.to_bits());
        assert_ne!(base.to_bits(), diff_ctx.to_bits());
    }

    #[test]
    fn empty_clip_is_well_defined() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 5);
        let s = sample(&g, 0, 0, 12);
        let b = build_batch(&[&s], 1, &g);
        let out = p.forward(&b, 40.0).unwrap();
        assert!(out[0].is_finite() && out[0] > 0.0, "no NaN from a fully-masked clip");
    }

    #[test]
    fn seeds_change_predictions_and_fingerprints() {
        let g = small_geometry();
        let a = AttentionPredictor::seeded(g.clone(), 1);
        let b = AttentionPredictor::seeded(g.clone(), 2);
        let c = AttentionPredictor::seeded(g.clone(), 1);
        assert_eq!(a.fingerprint(), c.fingerprint(), "same seed, same identity");
        assert_ne!(a.fingerprint(), b.fingerprint(), "seed is part of the identity");
        let s = sample(&g, 9, 4, 21);
        let batch = build_batch(&[&s], 1, &g);
        let pa = a.forward(&batch, 40.0).unwrap()[0];
        let pb = b.forward(&batch, 40.0).unwrap()[0];
        let pc = c.forward(&batch, 40.0).unwrap()[0];
        assert_eq!(pa.to_bits(), pc.to_bits());
        assert_ne!(pa.to_bits(), pb.to_bits());
    }

    #[test]
    fn fingerprint_distinct_from_native_backend() {
        let p = AttentionPredictor::with_defaults();
        let n = crate::runtime::NativePredictor::with_defaults();
        assert_ne!(
            Predictor::fingerprint(&p),
            Predictor::fingerprint(&n),
            "persisted caches must cold-start across backends"
        );
    }

    #[test]
    fn save_load_roundtrip_preserves_identity() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 99);
        let dir = std::env::temp_dir().join("capsim_attn_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("attention.bin");
        p.save(&path).unwrap();
        let q = AttentionPredictor::load(&path).unwrap();
        assert_eq!(q.seed(), 99);
        assert_eq!(q.param_count(), p.param_count());
        assert_eq!(Predictor::fingerprint(&q), Predictor::fingerprint(&p));
        let s = sample(&g, 17, 6, 8);
        let batch = build_batch(&[&s], 1, &g);
        let a = p.forward(&batch, 40.0).unwrap()[0];
        let b = q.forward(&batch, 40.0).unwrap()[0];
        assert_eq!(a.to_bits(), b.to_bits(), "loaded weights predict identically");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_refuses_garbage_and_truncation() {
        let dir = std::env::temp_dir().join("capsim_attn_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("attention.bin");
        std::fs::write(&path, b"not a weights file").unwrap();
        assert!(AttentionPredictor::load(&path).is_err());
        // valid header, truncated body
        let p = AttentionPredictor::seeded(small_geometry(), 1);
        p.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(AttentionPredictor::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_refuses_corrupt_batch_sizes() {
        // the weight count is independent of the fwd batch list, so a
        // flipped byte there passes the count check; the dimension
        // guard must still refuse it (a 0 would panic the accumulator,
        // a huge value would over-allocate batches)
        let dir = std::env::temp_dir().join("capsim_attn_bad_fwd");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("attention.bin");
        let p = AttentionPredictor::seeded(small_geometry(), 1);
        for corrupt in [0u32, u32::MAX] {
            p.save(&path).unwrap();
            let mut bytes = std::fs::read(&path).unwrap();
            // meta layout: six geometry u32s, n_fwd, then the fwd batch
            // sizes — first entry at meta offset 28. Re-seal the header
            // checksum after the patch so the dimension guard itself,
            // not the checksum, is what refuses the file.
            let off = image::HEADER_LEN + 28;
            bytes[off..off + 4].copy_from_slice(&corrupt.to_le_bytes());
            let meta_len = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
            let reseal = image::digest64(&[
                &bytes[..88],
                &bytes[image::HEADER_LEN..image::HEADER_LEN + meta_len],
            ]);
            bytes[88..96].copy_from_slice(&reseal.to_le_bytes());
            std::fs::write(&path, &bytes).unwrap();
            assert!(AttentionPredictor::load(&path).is_err(), "fwd size {corrupt}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_v1_weights_still_load() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 7);
        // hand-write the CAWB v1 stream exactly as the previous release's
        // writer produced it
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&WEIGHTS_MAGIC.to_le_bytes());
        bytes.extend_from_slice(&WEIGHTS_VERSION.to_le_bytes());
        for v in [g.vocab_size, g.embed_dim, g.l_token, g.l_clip, g.m_rows, g.train_batch] {
            bytes.extend_from_slice(&(v as u32).to_le_bytes());
        }
        bytes.extend_from_slice(&(g.fwd_batch_sizes.len() as u32).to_le_bytes());
        for &b in &g.fwd_batch_sizes {
            bytes.extend_from_slice(&(b as u32).to_le_bytes());
        }
        for v in [p.heads, p.w.layers.len(), p.ffn_mult] {
            bytes.extend_from_slice(&(v as u32).to_le_bytes());
        }
        bytes.extend_from_slice(&p.seed.to_le_bytes());
        bytes.extend_from_slice(&(p.param_count() as u64).to_le_bytes());
        for t in p.tensors() {
            for &v in t {
                bytes.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        let dir = std::env::temp_dir().join("capsim_attn_legacy");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("attention_v1.bin");
        std::fs::write(&path, &bytes).unwrap();
        let q = AttentionPredictor::load(&path).unwrap();
        assert_eq!(
            Predictor::fingerprint(&q),
            Predictor::fingerprint(&p),
            "legacy load is identity-preserving"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn default_geometry_matches_dataset_constants() {
        let p = AttentionPredictor::with_defaults();
        let g = p.geometry();
        assert_eq!(g.l_token, crate::coordinator::golden::L_TOKEN);
        assert_eq!(g.l_clip, crate::coordinator::golden::L_CLIP);
        assert_eq!(g.m_rows, crate::context::M_ROWS);
        assert!(g.vocab_size >= crate::tokenizer::vocab::VOCAB_USED as usize);
        assert_eq!(g.embed_dim % DEFAULT_HEADS, 0);
    }
}
