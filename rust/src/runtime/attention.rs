//! A dependency-free **pure-Rust attention backend** — the paper's
//! predictor architecture (token embedding → multi-head self-attention
//! over the clip token stream → clip pooling + context fusion → regression
//! head) executed by the scalar f32 kernels in [`super::tensor`], with no
//! PJRT, no XLA and no artifacts directory.
//!
//! Structure of one forward pass (per clip row):
//!
//! ```text
//! tokens[l_clip, l_token] ── embed + masked token-mean ──► X[l_clip, d]
//!                                      + position embedding
//! X ──► N × { MHA(clip padding mask) + LN, FFN(GELU) + LN } ──► X'
//! X' ── masked mean over live instructions ──► clip vector [d]
//! ctx[m] ── embed mean → linear → GELU ──► context vector [d]
//! [clip ‖ ctx] ── linear → GELU → linear ──► s
//! prediction = softplus(s) · time_scale
//! ```
//!
//! Two properties the engine relies on, both **exact** here:
//!
//! * **row locality**: each row of a [`Batch`] is processed by an
//!   independent loop that reads only that row's tokens, masks and
//!   context, so predictions are bit-identical across batch sizes,
//!   padding and cache states — the invariance the engine-equivalence
//!   suite asserts (the compiled PJRT model only approximates this;
//!   see `tests/prop_attention.rs`);
//! * **determinism**: weights come from a seeded PRNG or a versioned
//!   weights file, and every kernel runs in a fixed scalar order, so the
//!   same `(weights, row, time_scale)` always produces the same bits.
//!
//! Weights can be persisted ([`AttentionPredictor::save`]) and reloaded
//! ([`AttentionPredictor::load`]) through a versioned binary format; the
//! [`Predictor::fingerprint`] mixes every weight bit, so the persistent
//! `ClipCache` cold-starts whenever the weights (or the seed) change.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::Rng;

use super::manifest::ModelGeometry;
use super::model::Batch;
use super::tensor::{
    add_bias, gelu, gelu_slice, layernorm, masked_softmax, matmul, softplus, vecmat,
};
use super::Predictor;

/// On-disk magic ("CAWB") of a persisted weights file.
const WEIGHTS_MAGIC: u32 = 0x4257_4143;
/// Bump on any architecture or layout change; old files are refused.
const WEIGHTS_VERSION: u32 = 1;
/// Guard against absurd allocations from corrupt headers.
const MAX_WEIGHT_COUNT: u64 = 1 << 24;

/// Attention heads (embed_dim must divide evenly).
pub const DEFAULT_HEADS: usize = 4;
/// Encoder layers.
pub const DEFAULT_LAYERS: usize = 2;
/// FFN hidden multiple (hidden = ffn_mult * embed_dim).
pub const DEFAULT_FFN_MULT: usize = 2;

/// One pre-LN-free (post-norm) transformer encoder layer.
struct EncoderLayer {
    wq: Vec<f32>,    // [d, d]
    wk: Vec<f32>,    // [d, d]
    wv: Vec<f32>,    // [d, d]
    wo: Vec<f32>,    // [d, d]
    ln1_g: Vec<f32>, // [d]
    ln1_b: Vec<f32>, // [d]
    ff1_w: Vec<f32>, // [d, f]
    ff1_b: Vec<f32>, // [f]
    ff2_w: Vec<f32>, // [f, d]
    ff2_b: Vec<f32>, // [d]
    ln2_g: Vec<f32>, // [d]
    ln2_b: Vec<f32>, // [d]
}

/// The full parameter set.
struct Weights {
    embed: Vec<f32>,   // [vocab, d] — shared by clip tokens and context
    pos: Vec<f32>,     // [l_clip, d]
    layers: Vec<EncoderLayer>,
    ctx_w: Vec<f32>,   // [d, d]
    ctx_b: Vec<f32>,   // [d]
    head_w1: Vec<f32>, // [2d, d]
    head_b1: Vec<f32>, // [d]
    head_w2: Vec<f32>, // [d]
    head_b2: Vec<f32>, // [1]
}

/// Per-forward scratch buffers, reused across rows of a batch.
struct Scratch {
    x: Vec<f32>,      // [l_clip, d]
    q: Vec<f32>,      // [l_clip, d]
    k: Vec<f32>,      // [l_clip, d]
    v: Vec<f32>,      // [l_clip, d]
    attn: Vec<f32>,   // [l_clip, d]
    scores: Vec<f32>, // [l_clip, l_clip]
    ff: Vec<f32>,     // [l_clip, f]
    tmp: Vec<f32>,    // [l_clip, d]
    clip: Vec<f32>,   // [d]
    ctx: Vec<f32>,    // [d]
    fused: Vec<f32>,  // [2d]
    hidden: Vec<f32>, // [d]
}

impl Scratch {
    fn new(lc: usize, d: usize, f: usize) -> Scratch {
        Scratch {
            x: vec![0.0; lc * d],
            q: vec![0.0; lc * d],
            k: vec![0.0; lc * d],
            v: vec![0.0; lc * d],
            attn: vec![0.0; lc * d],
            scores: vec![0.0; lc * lc],
            ff: vec![0.0; lc * f],
            tmp: vec![0.0; lc * d],
            clip: vec![0.0; d],
            ctx: vec![0.0; d],
            fused: vec![0.0; 2 * d],
            hidden: vec![0.0; d],
        }
    }
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn fill_f32(r: &mut impl Read, t: &mut [f32]) -> std::io::Result<()> {
    let mut b = [0u8; 4];
    for v in t.iter_mut() {
        r.read_exact(&mut b)?;
        *v = f32::from_bits(u32::from_le_bytes(b));
    }
    Ok(())
}

/// Deterministic pure-Rust attention predictor; see the module docs.
pub struct AttentionPredictor {
    geometry: ModelGeometry,
    heads: usize,
    ffn_mult: usize,
    /// Seed the weights were drawn from (provenance label; file loads
    /// carry the seed of the run that saved them).
    seed: u64,
    w: Weights,
}

impl AttentionPredictor {
    /// Deterministically initialized weights for `geometry` drawn from
    /// `seed` (uniform, 1/sqrt(fan_in)-scaled; layernorm gains 1).
    pub fn seeded(geometry: ModelGeometry, seed: u64) -> AttentionPredictor {
        let d = geometry.embed_dim;
        assert!(d > 0 && d % DEFAULT_HEADS == 0, "embed_dim must divide heads");
        let f = DEFAULT_FFN_MULT * d;
        let mut rng = Rng::new(seed ^ 0xA77E_4710_4BAC_83D5);
        let mut uniform = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
        };
        let proj = 1.0 / (d as f32).sqrt();
        let embed = uniform(geometry.vocab_size * d, 0.05);
        let pos = uniform(geometry.l_clip * d, 0.05);
        let layers = (0..DEFAULT_LAYERS)
            .map(|_| EncoderLayer {
                wq: uniform(d * d, proj),
                wk: uniform(d * d, proj),
                wv: uniform(d * d, proj),
                wo: uniform(d * d, proj),
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                ff1_w: uniform(d * f, proj),
                ff1_b: vec![0.0; f],
                ff2_w: uniform(f * d, 1.0 / (f as f32).sqrt()),
                ff2_b: vec![0.0; d],
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
            })
            .collect();
        let ctx_w = uniform(d * d, proj);
        let head_w1 = uniform(2 * d * d, 1.0 / (2.0 * d as f32).sqrt());
        let head_w2 = uniform(d, proj);
        AttentionPredictor {
            geometry,
            heads: DEFAULT_HEADS,
            ffn_mult: DEFAULT_FFN_MULT,
            seed,
            w: Weights {
                embed,
                pos,
                layers,
                ctx_w,
                ctx_b: vec![0.0; d],
                head_w1,
                head_b1: vec![0.0; d],
                head_w2,
                head_b2: vec![0.5],
            },
        }
    }

    /// Default geometry (the `model_config.json` constants) with the
    /// default pipeline seed.
    pub fn with_defaults() -> AttentionPredictor {
        AttentionPredictor::seeded(super::default_geometry(), 42)
    }

    /// The seed the resident weights were drawn from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Every tensor in canonical (save/fingerprint) order.
    fn tensors(&self) -> Vec<&[f32]> {
        let mut out: Vec<&[f32]> = vec![self.w.embed.as_slice(), self.w.pos.as_slice()];
        for l in &self.w.layers {
            out.extend([
                l.wq.as_slice(),
                l.wk.as_slice(),
                l.wv.as_slice(),
                l.wo.as_slice(),
                l.ln1_g.as_slice(),
                l.ln1_b.as_slice(),
                l.ff1_w.as_slice(),
                l.ff1_b.as_slice(),
                l.ff2_w.as_slice(),
                l.ff2_b.as_slice(),
                l.ln2_g.as_slice(),
                l.ln2_b.as_slice(),
            ]);
        }
        out.extend([
            self.w.ctx_w.as_slice(),
            self.w.ctx_b.as_slice(),
            self.w.head_w1.as_slice(),
            self.w.head_b1.as_slice(),
            self.w.head_w2.as_slice(),
            self.w.head_b2.as_slice(),
        ]);
        out
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors().iter().map(|t| t.len()).sum()
    }

    /// Persist the weights (versioned; see [`AttentionPredictor::load`]).
    /// Writes a sibling temp file and renames, like the clip cache.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let tmp = path.with_extension("tmp");
        {
            let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            w.write_all(&WEIGHTS_MAGIC.to_le_bytes())?;
            w.write_all(&WEIGHTS_VERSION.to_le_bytes())?;
            let g = &self.geometry;
            for v in [g.vocab_size, g.embed_dim, g.l_token, g.l_clip, g.m_rows, g.train_batch] {
                w.write_all(&(v as u32).to_le_bytes())?;
            }
            w.write_all(&(g.fwd_batch_sizes.len() as u32).to_le_bytes())?;
            for &b in &g.fwd_batch_sizes {
                w.write_all(&(b as u32).to_le_bytes())?;
            }
            for v in [self.heads, self.w.layers.len(), self.ffn_mult] {
                w.write_all(&(v as u32).to_le_bytes())?;
            }
            w.write_all(&self.seed.to_le_bytes())?;
            w.write_all(&(self.param_count() as u64).to_le_bytes())?;
            for t in self.tensors() {
                for &v in t {
                    w.write_all(&v.to_bits().to_le_bytes())?;
                }
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path)
    }

    /// Load a persisted weights file, refusing wrong magic/version,
    /// inconsistent shapes, or truncated data.
    pub fn load(path: &Path) -> Result<AttentionPredictor> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).map_err(|e| anyhow!("opening {path:?}: {e}"))?,
        );
        if read_u32(&mut r)? != WEIGHTS_MAGIC {
            return Err(anyhow!("{path:?}: not an attention weights file"));
        }
        if read_u32(&mut r)? != WEIGHTS_VERSION {
            return Err(anyhow!("{path:?}: unsupported weights version"));
        }
        let vocab_size = read_u32(&mut r)? as usize;
        let embed_dim = read_u32(&mut r)? as usize;
        let l_token = read_u32(&mut r)? as usize;
        let l_clip = read_u32(&mut r)? as usize;
        let m_rows = read_u32(&mut r)? as usize;
        let train_batch = read_u32(&mut r)? as usize;
        let n_fwd = read_u32(&mut r)? as usize;
        if n_fwd > 64 {
            return Err(anyhow!("{path:?}: implausible fwd batch list"));
        }
        let mut fwd_batch_sizes = Vec::with_capacity(n_fwd);
        for _ in 0..n_fwd {
            fwd_batch_sizes.push(read_u32(&mut r)? as usize);
        }
        let heads = read_u32(&mut r)? as usize;
        let layers = read_u32(&mut r)? as usize;
        let ffn_mult = read_u32(&mut r)? as usize;
        let seed = read_u64(&mut r)?;
        let count = read_u64(&mut r)?;
        let arch_ok =
            embed_dim > 0 && heads > 0 && embed_dim % heads == 0 && layers > 0 && ffn_mult > 0;
        if !arch_ok {
            return Err(anyhow!("{path:?}: inconsistent architecture header"));
        }
        // bound every dimension before doing arithmetic on it, so a
        // corrupt header can neither overflow the `expected` product
        // below nor provoke a huge allocation
        let dims_ok = vocab_size <= 1 << 20
            && embed_dim <= 1 << 12
            && l_token <= 1 << 12
            && l_clip <= 1 << 12
            && m_rows <= 1 << 16
            && train_batch <= 1 << 12
            && layers <= 64
            && ffn_mult <= 16
            && fwd_batch_sizes.iter().all(|&b| b > 0 && b <= 1 << 12);
        if !dims_ok {
            return Err(anyhow!("{path:?}: implausible geometry header"));
        }

        // validate the advertised total against the header shape BEFORE
        // allocating anything (with the bounds above, every product fits
        // comfortably in u64 and the total is capped by MAX_WEIGHT_COUNT)
        let d = embed_dim as u64;
        let f = ffn_mult as u64 * d;
        let per_layer = 4 * d * d + 2 * d + d * f + f + f * d + d + 2 * d;
        let expected = vocab_size as u64 * d
            + l_clip as u64 * d
            + layers as u64 * per_layer
            + (d * d + d)
            + (2 * d * d + d + d + 1);
        if count != expected || count > MAX_WEIGHT_COUNT {
            return Err(anyhow!(
                "{path:?}: weight count {count} does not match header shape ({expected})"
            ));
        }
        let geometry = ModelGeometry {
            vocab_size,
            embed_dim,
            l_token,
            l_clip,
            m_rows,
            train_batch,
            fwd_batch_sizes,
        };

        // build a zeroed skeleton with the recorded shape, then fill
        // tensor by tensor in canonical order
        let d = embed_dim;
        let f = ffn_mult * d;
        let layer = || EncoderLayer {
            wq: vec![0.0; d * d],
            wk: vec![0.0; d * d],
            wv: vec![0.0; d * d],
            wo: vec![0.0; d * d],
            ln1_g: vec![0.0; d],
            ln1_b: vec![0.0; d],
            ff1_w: vec![0.0; d * f],
            ff1_b: vec![0.0; f],
            ff2_w: vec![0.0; f * d],
            ff2_b: vec![0.0; d],
            ln2_g: vec![0.0; d],
            ln2_b: vec![0.0; d],
        };
        let mut out = AttentionPredictor {
            geometry,
            heads,
            ffn_mult,
            seed,
            w: Weights {
                embed: vec![0.0; vocab_size * d],
                pos: vec![0.0; l_clip * d],
                layers: (0..layers).map(|_| layer()).collect(),
                ctx_w: vec![0.0; d * d],
                ctx_b: vec![0.0; d],
                head_w1: vec![0.0; 2 * d * d],
                head_b1: vec![0.0; d],
                head_w2: vec![0.0; d],
                head_b2: vec![0.0; 1],
            },
        };
        debug_assert_eq!(out.param_count() as u64, count);
        fill_f32(&mut r, &mut out.w.embed)?;
        fill_f32(&mut r, &mut out.w.pos)?;
        for l in &mut out.w.layers {
            fill_f32(&mut r, &mut l.wq)?;
            fill_f32(&mut r, &mut l.wk)?;
            fill_f32(&mut r, &mut l.wv)?;
            fill_f32(&mut r, &mut l.wo)?;
            fill_f32(&mut r, &mut l.ln1_g)?;
            fill_f32(&mut r, &mut l.ln1_b)?;
            fill_f32(&mut r, &mut l.ff1_w)?;
            fill_f32(&mut r, &mut l.ff1_b)?;
            fill_f32(&mut r, &mut l.ff2_w)?;
            fill_f32(&mut r, &mut l.ff2_b)?;
            fill_f32(&mut r, &mut l.ln2_g)?;
            fill_f32(&mut r, &mut l.ln2_b)?;
        }
        fill_f32(&mut r, &mut out.w.ctx_w)?;
        fill_f32(&mut r, &mut out.w.ctx_b)?;
        fill_f32(&mut r, &mut out.w.head_w1)?;
        fill_f32(&mut r, &mut out.w.head_b1)?;
        fill_f32(&mut r, &mut out.w.head_w2)?;
        fill_f32(&mut r, &mut out.w.head_b2)?;
        Ok(out)
    }

    /// One encoder layer over `x` (`[l_clip, d]`) under the clip padding
    /// `mask` (`[l_clip]`). Masked *keys* receive zero attention, so live
    /// positions never read padding content; masked positions' own
    /// outputs are computed but ignored by the pooling stage.
    fn encoder_layer(&self, lw: &EncoderLayer, mask: &[f32], s: &mut Scratch) {
        let lc = self.geometry.l_clip;
        let d = self.geometry.embed_dim;
        let hd = d / self.heads;
        let scale = 1.0 / (hd as f32).sqrt();

        matmul(&s.x, &lw.wq, lc, d, d, &mut s.q);
        matmul(&s.x, &lw.wk, lc, d, d, &mut s.k);
        matmul(&s.x, &lw.wv, lc, d, d, &mut s.v);
        s.attn.fill(0.0);
        for h in 0..self.heads {
            let o = h * hd;
            for i in 0..lc {
                for j in 0..lc {
                    let mut dot = 0.0f32;
                    for c in 0..hd {
                        dot += s.q[i * d + o + c] * s.k[j * d + o + c];
                    }
                    s.scores[i * lc + j] = dot * scale;
                }
            }
            masked_softmax(&mut s.scores, lc, lc, mask);
            for i in 0..lc {
                for j in 0..lc {
                    let p = s.scores[i * lc + j];
                    if p == 0.0 {
                        continue;
                    }
                    for c in 0..hd {
                        s.attn[i * d + o + c] += p * s.v[j * d + o + c];
                    }
                }
            }
        }
        matmul(&s.attn, &lw.wo, lc, d, d, &mut s.tmp);
        for (a, &b) in s.x.iter_mut().zip(s.tmp.iter()) {
            *a += b;
        }
        layernorm(&mut s.x, &lw.ln1_g, &lw.ln1_b);

        let f = self.ffn_mult * d;
        matmul(&s.x, &lw.ff1_w, lc, d, f, &mut s.ff);
        add_bias(&mut s.ff, &lw.ff1_b);
        gelu_slice(&mut s.ff);
        matmul(&s.ff, &lw.ff2_w, lc, f, d, &mut s.tmp);
        add_bias(&mut s.tmp, &lw.ff2_b);
        for (a, &b) in s.x.iter_mut().zip(s.tmp.iter()) {
            *a += b;
        }
        layernorm(&mut s.x, &lw.ln2_g, &lw.ln2_b);
    }

    /// Price one live row; pure function of that row's tokens, masks and
    /// context (never of the batch composition — see the module docs).
    fn row_forward(&self, batch: &Batch, r: usize, time_scale: f32, s: &mut Scratch) -> f32 {
        let g = &self.geometry;
        let (lc, lt, d) = (g.l_clip, g.l_token, g.embed_dim);
        let row_tokens = lc * lt;
        let mask = &batch.clip_mask[r * lc..(r + 1) * lc];

        // token embedding + masked token-mean per instruction + position
        s.x.fill(0.0);
        for i in 0..lc {
            if mask[i] == 0.0 {
                continue;
            }
            let mut live = 0.0f32;
            for t in 0..lt {
                let idx = r * row_tokens + i * lt + t;
                if batch.tok_mask[idx] == 0.0 {
                    continue;
                }
                let tok = (batch.tokens[idx].max(0) as usize).min(g.vocab_size - 1);
                for c in 0..d {
                    s.x[i * d + c] += self.w.embed[tok * d + c];
                }
                live += 1.0;
            }
            if live > 0.0 {
                let inv = 1.0 / live;
                for c in 0..d {
                    s.x[i * d + c] *= inv;
                }
            }
            for c in 0..d {
                s.x[i * d + c] += self.w.pos[i * d + c];
            }
        }

        for lw in &self.w.layers {
            self.encoder_layer(lw, mask, s);
        }

        // masked mean pooling over live instructions
        s.clip.fill(0.0);
        let mut live = 0.0f32;
        for i in 0..lc {
            if mask[i] == 0.0 {
                continue;
            }
            for c in 0..d {
                s.clip[c] += s.x[i * d + c];
            }
            live += 1.0;
        }
        if live > 0.0 {
            let inv = 1.0 / live;
            for v in s.clip.iter_mut() {
                *v *= inv;
            }
        }

        // context fusion: embed mean over the M context rows → linear →
        // GELU
        s.ctx.fill(0.0);
        for m in 0..g.m_rows {
            let tok = (batch.ctx[r * g.m_rows + m].max(0) as usize).min(g.vocab_size - 1);
            for c in 0..d {
                s.ctx[c] += self.w.embed[tok * d + c];
            }
        }
        let inv = 1.0 / g.m_rows.max(1) as f32;
        for v in s.ctx.iter_mut() {
            *v *= inv;
        }
        s.fused[..d].copy_from_slice(&s.clip);
        vecmat(&s.ctx, &self.w.ctx_w, d, d, &mut s.hidden);
        for c in 0..d {
            s.fused[d + c] = gelu(s.hidden[c] + self.w.ctx_b[c]);
        }

        // regression head
        vecmat(&s.fused, &self.w.head_w1, 2 * d, d, &mut s.hidden);
        add_bias(&mut s.hidden, &self.w.head_b1);
        gelu_slice(&mut s.hidden);
        let mut out = self.w.head_b2[0];
        for c in 0..d {
            out += s.hidden[c] * self.w.head_w2[c];
        }
        (softplus(out) * time_scale).max(1e-3)
    }
}

impl Predictor for AttentionPredictor {
    fn geometry(&self) -> &ModelGeometry {
        &self.geometry
    }

    fn max_fwd_batch(&self) -> usize {
        self.geometry.fwd_batch_sizes.last().copied().unwrap_or(1)
    }

    fn pick_fwd_batch(&self, live: usize) -> usize {
        for &b in &self.geometry.fwd_batch_sizes {
            if b >= live {
                return b;
            }
        }
        self.max_fwd_batch()
    }

    fn forward(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(
            batch.live <= batch.b,
            "live rows {} exceed batch capacity {}",
            batch.live,
            batch.b
        );
        let g = &self.geometry;
        let mut scratch = Scratch::new(g.l_clip, g.embed_dim, self.ffn_mult * g.embed_dim);
        Ok((0..batch.live)
            .map(|r| self.row_forward(batch, r, time_scale, &mut scratch))
            .collect())
    }

    fn fingerprint(&self) -> u64 {
        // kind + architecture + every weight bit: retraining, reseeding
        // or editing the weights file must cold-start persisted caches
        let mut h = super::fingerprint_geometry(&self.geometry);
        h = super::fingerprint_bytes(h, b"attention-rs");
        h = super::fingerprint_mix(h, WEIGHTS_VERSION as u64);
        for v in [self.heads, self.w.layers.len(), self.ffn_mult] {
            h = super::fingerprint_mix(h, v as u64);
        }
        for t in self.tensors() {
            for &v in t {
                h = super::fingerprint_mix(h, v.to_bits() as u64);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ClipSample;
    use crate::predictor::build_batch;

    /// A small geometry so unit tests stay fast in debug builds.
    fn small_geometry() -> ModelGeometry {
        ModelGeometry {
            vocab_size: 64,
            embed_dim: 16,
            l_token: 4,
            l_clip: 8,
            m_rows: 6,
            train_batch: 4,
            fwd_batch_sizes: vec![1, 4, 8],
        }
    }

    fn sample(g: &ModelGeometry, fill: u16, len: u16, ctx_fill: u16) -> ClipSample {
        ClipSample {
            tokens: (0..len as usize * g.l_token)
                .map(|i| if i % g.l_token == 0 { 1 } else { fill })
                .collect(),
            len,
            ctx: vec![ctx_fill; g.m_rows],
            time: 10.0,
            key: 1,
            bench: 0,
        }
    }

    #[test]
    fn predictions_positive_finite_and_scaled() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 7);
        let s = sample(&g, 20, 5, 30);
        let b = build_batch(&[&s], 1, &g);
        let out = p.forward(&b, 50.0).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].is_finite() && out[0] > 0.0);
        let out2 = p.forward(&b, 100.0).unwrap();
        assert!((out2[0] - 2.0 * out[0]).abs() / out[0] < 1e-4, "linear in time_scale");
    }

    #[test]
    fn batch_and_padding_invariance_is_exact() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 11);
        let samples: Vec<ClipSample> =
            (0..5).map(|i| sample(&g, 10 + i as u16, 2 + i as u16, 40 + i as u16)).collect();
        let refs: Vec<&ClipSample> = samples.iter().collect();
        let full = p.forward(&build_batch(&refs, 8, &g), 40.0).unwrap();
        assert_eq!(full.len(), 5);
        for (i, s) in samples.iter().enumerate() {
            let one = p.forward(&build_batch(&[s], 1, &g), 40.0).unwrap();
            assert_eq!(one[0].to_bits(), full[i].to_bits(), "row {i}");
        }
    }

    #[test]
    fn tokens_and_context_both_matter() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 3);
        let base = p
            .forward(&build_batch(&[&sample(&g, 20, 6, 30)], 1, &g), 30.0)
            .unwrap()[0];
        let diff_tok = p
            .forward(&build_batch(&[&sample(&g, 21, 6, 30)], 1, &g), 30.0)
            .unwrap()[0];
        let diff_ctx = p
            .forward(&build_batch(&[&sample(&g, 20, 6, 31)], 1, &g), 30.0)
            .unwrap()[0];
        assert_ne!(base.to_bits(), diff_tok.to_bits());
        assert_ne!(base.to_bits(), diff_ctx.to_bits());
    }

    #[test]
    fn empty_clip_is_well_defined() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 5);
        let s = sample(&g, 0, 0, 12);
        let b = build_batch(&[&s], 1, &g);
        let out = p.forward(&b, 40.0).unwrap();
        assert!(out[0].is_finite() && out[0] > 0.0, "no NaN from a fully-masked clip");
    }

    #[test]
    fn seeds_change_predictions_and_fingerprints() {
        let g = small_geometry();
        let a = AttentionPredictor::seeded(g.clone(), 1);
        let b = AttentionPredictor::seeded(g.clone(), 2);
        let c = AttentionPredictor::seeded(g.clone(), 1);
        assert_eq!(a.fingerprint(), c.fingerprint(), "same seed, same identity");
        assert_ne!(a.fingerprint(), b.fingerprint(), "seed is part of the identity");
        let s = sample(&g, 9, 4, 21);
        let batch = build_batch(&[&s], 1, &g);
        let pa = a.forward(&batch, 40.0).unwrap()[0];
        let pb = b.forward(&batch, 40.0).unwrap()[0];
        let pc = c.forward(&batch, 40.0).unwrap()[0];
        assert_eq!(pa.to_bits(), pc.to_bits());
        assert_ne!(pa.to_bits(), pb.to_bits());
    }

    #[test]
    fn fingerprint_distinct_from_native_backend() {
        let p = AttentionPredictor::with_defaults();
        let n = crate::runtime::NativePredictor::with_defaults();
        assert_ne!(
            Predictor::fingerprint(&p),
            Predictor::fingerprint(&n),
            "persisted caches must cold-start across backends"
        );
    }

    #[test]
    fn save_load_roundtrip_preserves_identity() {
        let g = small_geometry();
        let p = AttentionPredictor::seeded(g.clone(), 99);
        let dir = std::env::temp_dir().join("capsim_attn_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("attention.bin");
        p.save(&path).unwrap();
        let q = AttentionPredictor::load(&path).unwrap();
        assert_eq!(q.seed(), 99);
        assert_eq!(q.param_count(), p.param_count());
        assert_eq!(Predictor::fingerprint(&q), Predictor::fingerprint(&p));
        let s = sample(&g, 17, 6, 8);
        let batch = build_batch(&[&s], 1, &g);
        let a = p.forward(&batch, 40.0).unwrap()[0];
        let b = q.forward(&batch, 40.0).unwrap()[0];
        assert_eq!(a.to_bits(), b.to_bits(), "loaded weights predict identically");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_refuses_garbage_and_truncation() {
        let dir = std::env::temp_dir().join("capsim_attn_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("attention.bin");
        std::fs::write(&path, b"not a weights file").unwrap();
        assert!(AttentionPredictor::load(&path).is_err());
        // valid header, truncated body
        let p = AttentionPredictor::seeded(small_geometry(), 1);
        p.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(AttentionPredictor::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_refuses_corrupt_batch_sizes() {
        // the weight count is independent of the fwd batch list, so a
        // flipped byte there passes the count check; the dimension
        // guard must still refuse it (a 0 would panic the accumulator,
        // a huge value would over-allocate batches)
        let dir = std::env::temp_dir().join("capsim_attn_bad_fwd");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("attention.bin");
        let p = AttentionPredictor::seeded(small_geometry(), 1);
        for corrupt in [0u32, u32::MAX] {
            p.save(&path).unwrap();
            let mut bytes = std::fs::read(&path).unwrap();
            // header layout: magic, version, six geometry u32s, n_fwd,
            // then the fwd batch sizes — first entry at byte 36
            bytes[36..40].copy_from_slice(&corrupt.to_le_bytes());
            std::fs::write(&path, &bytes).unwrap();
            assert!(AttentionPredictor::load(&path).is_err(), "fwd size {corrupt}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn default_geometry_matches_dataset_constants() {
        let p = AttentionPredictor::with_defaults();
        let g = p.geometry();
        assert_eq!(g.l_token, crate::coordinator::golden::L_TOKEN);
        assert_eq!(g.l_clip, crate::coordinator::golden::L_CLIP);
        assert_eq!(g.m_rows, crate::context::M_ROWS);
        assert!(g.vocab_size >= crate::tokenizer::vocab::VOCAB_USED as usize);
        assert_eq!(g.embed_dim % DEFAULT_HEADS, 0);
    }
}
