//! The runtime backend registry: one place that knows every predictor
//! backend, how to name it, and how to construct it.
//!
//! Before this module, backend choice was an ad-hoc `--native` boolean
//! threaded by hand through the CLI and every bench. Now a single
//! [`Backend`] value lives in [`PipelineConfig`](crate::config::PipelineConfig)
//! (TOML `pipeline.backend`, CLI `--backend`, with `--native` kept as a
//! deprecating alias) and every construction site — `capsim compare`, the
//! suite engines, the benches, the equivalence tests — resolves it here.
//!
//! | backend     | engine                  | dependencies            | deterministic |
//! |-------------|-------------------------|-------------------------|---------------|
//! | `pjrt`      | AOT-compiled XLA (HLO)  | `make artifacts` + PJRT | per-build     |
//! | `native`    | analytic row hash       | none                    | bit-exact     |
//! | `attention` | pure-Rust transformer   | none                    | bit-exact     |
//!
//! `native` and `attention` are **row-local** (a prediction depends only
//! on its own batch row), which is what makes the engine-equivalence
//! suite's bit-identical assertions meaningful; `attention` is the real
//! model architecture and therefore the backend that puts a realistic
//! inference cost into the measured loop (Fig. 7).

use std::fmt;
use std::path::Path;
use std::str::FromStr;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::PipelineConfig;
use crate::dataset::Dataset;
use crate::predictor::{train, TrainParams};

use super::{AttentionPredictor, NativePredictor, Predictor, Runtime};

/// File name of the persisted attention weights inside the artifacts
/// directory (see [`AttentionPredictor::save`]).
pub const ATTENTION_WEIGHTS_FILE: &str = "attention.bin";

/// A predictor backend selector; see the module docs for the matrix.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled attention model executed through PJRT (needs
    /// `make artifacts`).
    #[default]
    Pjrt,
    /// Dependency-free analytic stand-in (exact row-local hash cost).
    Native,
    /// Dependency-free pure-Rust attention model
    /// ([`AttentionPredictor`]).
    Attention,
}

impl Backend {
    /// Every registered backend, registry order.
    pub const ALL: [Backend; 3] = [Backend::Pjrt, Backend::Native, Backend::Attention];

    /// The CLI/TOML name.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Pjrt => "pjrt",
            Backend::Native => "native",
            Backend::Attention => "attention",
        }
    }

    /// Whether the backend needs the AOT artifacts directory to exist.
    pub fn requires_artifacts(self) -> bool {
        matches!(self, Backend::Pjrt)
    }

    /// Construct a forward-only predictor.
    ///
    /// * `Native` — the analytic stand-in, no inputs beyond the default
    ///   geometry;
    /// * `Attention` — loads `artifacts/attention.bin` when present
    ///   (versioned weights file), else seeds weights deterministically
    ///   from `cfg.seed`; runs its kernels on the tier resolved by
    ///   [`PipelineConfig::effective_kernel_tier`] (config/CLI/env,
    ///   default auto-detect) — an explicitly forced tier that is
    ///   unavailable on this host is an error here, not a silent
    ///   fallback;
    /// * `Pjrt` — loads the AOT artifacts and initializes (untrained)
    ///   parameters from `cfg.seed`; use [`Backend::build_trained`] for
    ///   a trained model.
    pub fn build_forward(self, cfg: &PipelineConfig) -> Result<Box<dyn Predictor>> {
        match self {
            Backend::Native => Ok(Box::new(NativePredictor::with_defaults())),
            Backend::Attention => Ok(Box::new(build_attention(cfg)?)),
            Backend::Pjrt => {
                let rt = Runtime::load(Path::new(&cfg.artifacts))?;
                let mut model = rt.load_variant("capsim")?;
                model.init_params(cfg.seed as u32)?;
                Ok(Box::new(model))
            }
        }
    }

    /// Construct a forward-only predictor that can be **shared
    /// read-only across threads** — the form the replicated serve
    /// predict loops need. Same construction rules as
    /// [`Backend::build_forward`] (weights deserialize once; replicas
    /// are references, not copies), but the return type carries the
    /// `Send + Sync` bounds. `Native` and `Attention` are plain-data
    /// models whose forward pass is `&self` over a caller-owned
    /// workspace, so sharing is free; `Pjrt` holds a foreign runtime
    /// handle with no thread-safety contract and is refused.
    pub fn build_shared(self, cfg: &PipelineConfig) -> Result<Arc<dyn Predictor + Send + Sync>> {
        match self {
            Backend::Native => Ok(Arc::new(NativePredictor::with_defaults())),
            Backend::Attention => Ok(Arc::new(build_attention(cfg)?)),
            Backend::Pjrt => Err(anyhow!(
                "the pjrt backend cannot be shared across predict loops \
                 (its runtime handle is not thread-safe); use --backend attention or native"
            )),
        }
    }

    /// Construct a predictor ready for end-to-end comparison runs,
    /// together with its `time_scale`.
    ///
    /// For `Pjrt` this trains `variant` for `steps` SGD steps on a
    /// Method-1 split of `ds` and returns the fitted time scale; the
    /// training-free backends return immediately with the dataset mean
    /// as the scale (the same convention `--native` used).
    pub fn build_trained(
        self,
        cfg: &PipelineConfig,
        ds: &Dataset,
        steps: usize,
        variant: &str,
    ) -> Result<(Box<dyn Predictor>, f32)> {
        match self {
            Backend::Pjrt => {
                let rt = Runtime::load(Path::new(&cfg.artifacts))?;
                let mut model = rt.load_variant(variant)?;
                model.init_params(cfg.seed as u32)?;
                let (tr, va, _) = ds.split(cfg.seed);
                // the config seed drives the minibatch shuffle (so
                // pipeline.seed reproduces a training run end-to-end);
                // patience matches the bench driver's long-run setting
                let params = TrainParams {
                    steps,
                    lr: cfg.lr,
                    seed: cfg.seed,
                    patience: 10_000,
                    ..Default::default()
                };
                let log = train(&mut model, ds, &tr, &va, &params)?;
                let ts = log.time_scale;
                let model: Box<dyn Predictor> = Box::new(model);
                Ok((model, ts))
            }
            _ => Ok((self.build_forward(cfg)?, ds.mean_time() as f32)),
        }
    }
}

/// Build the pure-Rust attention predictor: load
/// `artifacts/attention.bin` when present (refusing a geometry that
/// does not match the dataset constants), else seed deterministically
/// from `cfg.seed`; kernels run on the resolved tier. Shared by
/// [`Backend::build_forward`] and [`Backend::build_shared`] so both
/// paths construct bit-identical models.
fn build_attention(cfg: &PipelineConfig) -> Result<AttentionPredictor> {
    let tier = cfg.effective_kernel_tier()?;
    let path = Path::new(&cfg.artifacts).join(ATTENTION_WEIGHTS_FILE);
    if path.is_file() {
        let p = AttentionPredictor::load(&path)?;
        // the dataset is sliced/tokenized with the default geometry
        // constants, so a weights file from another shape must be
        // refused, not silently preferred (mirrors the PJRT manifest
        // re-validation)
        let (g, want) = (p.geometry(), super::default_geometry());
        if g.l_token != want.l_token
            || g.l_clip != want.l_clip
            || g.m_rows != want.m_rows
            || g.vocab_size < want.vocab_size
        {
            return Err(anyhow!(
                "{path:?}: weights geometry (l_token {}, l_clip {}, m {}, vocab {}) \
                 does not match the dataset constants (l_token {}, l_clip {}, m {}, \
                 vocab >= {})",
                g.l_token,
                g.l_clip,
                g.m_rows,
                g.vocab_size,
                want.l_token,
                want.l_clip,
                want.m_rows,
                want.vocab_size
            ));
        }
        Ok(p.with_tier(tier))
    } else {
        let g = super::default_geometry();
        Ok(AttentionPredictor::seeded(g, cfg.seed).with_tier(tier))
    }
}

impl FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Backend> {
        for b in Backend::ALL {
            if s == b.name() {
                return Ok(b);
            }
        }
        Err(anyhow!("unknown backend {s:?} (expected one of: pjrt, native, attention)"))
    }
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A config whose artifacts directory is guaranteed empty, so the
    /// seeded-weights path is exercised even on a tree where someone
    /// saved a real `artifacts/attention.bin`.
    fn cfg_without_artifacts() -> PipelineConfig {
        let mut cfg = PipelineConfig::default();
        cfg.artifacts = std::env::temp_dir()
            .join("capsim-no-artifacts")
            .to_str()
            .unwrap()
            .to_string();
        cfg
    }

    #[test]
    fn names_roundtrip_through_parse() {
        for b in Backend::ALL {
            assert_eq!(b.name().parse::<Backend>().unwrap(), b);
        }
        assert!("hlo".parse::<Backend>().is_err());
        assert!("Native".parse::<Backend>().is_err(), "names are case-sensitive");
    }

    #[test]
    fn default_is_pjrt() {
        assert_eq!(Backend::default(), Backend::Pjrt);
        assert!(Backend::Pjrt.requires_artifacts());
        assert!(!Backend::Native.requires_artifacts());
        assert!(!Backend::Attention.requires_artifacts());
    }

    #[test]
    fn native_and_attention_build_without_artifacts() {
        let cfg = cfg_without_artifacts();
        let n = Backend::Native.build_forward(&cfg).unwrap();
        let a = Backend::Attention.build_forward(&cfg).unwrap();
        assert_eq!(n.geometry().l_clip, a.geometry().l_clip);
        assert_ne!(n.fingerprint(), a.fingerprint(), "backends must never share a cache key");
    }

    #[test]
    fn build_forward_honors_a_forced_kernel_tier() {
        use crate::runtime::KernelTier;
        let mut cfg = cfg_without_artifacts();
        cfg.kernel_tier = KernelTier::Scalar;
        let p = Backend::Attention.build_forward(&cfg).unwrap();
        assert_eq!(p.kernel_tier(), Some(KernelTier::Scalar));
        // the analytic stand-in runs no kernels, so it reports no tier
        let n = Backend::Native.build_forward(&cfg).unwrap();
        assert_eq!(n.kernel_tier(), None);
        // auto resolves to a concrete, available tier (which one can
        // depend on the CAPSIM_KERNEL_TIER env override — see
        // tests/prop_kernel_tiers.rs for the pinned-env dispatch test)
        cfg.kernel_tier = KernelTier::Auto;
        let a = Backend::Attention.build_forward(&cfg).unwrap();
        let t = a.kernel_tier().expect("attention reports its tier");
        assert_ne!(t, KernelTier::Auto);
        assert!(t.available());
    }

    #[test]
    fn build_shared_matches_build_forward_and_refuses_pjrt() {
        let cfg = cfg_without_artifacts();
        for b in [Backend::Native, Backend::Attention] {
            let boxed = b.build_forward(&cfg).unwrap();
            let shared = b.build_shared(&cfg).unwrap();
            assert_eq!(
                boxed.fingerprint(),
                shared.fingerprint(),
                "{b}: shared replicas must hit the same cache identity"
            );
        }
        let err = Backend::Pjrt.build_shared(&cfg).unwrap_err();
        assert!(err.to_string().contains("cannot be shared"));
        // the bound the replicated predict loops rely on, checked at
        // compile time: a shared model crosses threads read-only
        fn assert_send_sync<T: Send + Sync + ?Sized>() {}
        assert_send_sync::<AttentionPredictor>();
        assert_send_sync::<NativePredictor>();
    }

    #[test]
    fn attention_build_is_deterministic_per_seed() {
        let mut cfg = cfg_without_artifacts();
        let a = Backend::Attention.build_forward(&cfg).unwrap();
        let b = Backend::Attention.build_forward(&cfg).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
        cfg.seed = 77;
        let c = Backend::Attention.build_forward(&cfg).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint(), "seed changes the identity");
    }

    #[test]
    fn attention_build_refuses_a_mismatched_geometry_file() {
        let dir = std::env::temp_dir().join("capsim_backend_bad_geometry");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(ATTENTION_WEIGHTS_FILE);
        let mut g = crate::runtime::default_geometry();
        g.l_clip = 8; // not the dataset's clip capacity
        AttentionPredictor::seeded(g, 1).save(&path).unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.artifacts = dir.to_str().unwrap().to_string();
        let err = Backend::Attention.build_forward(&cfg).unwrap_err();
        assert!(err.to_string().contains("does not match the dataset constants"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn attention_build_prefers_a_weights_file() {
        let dir = std::env::temp_dir().join("capsim_backend_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(ATTENTION_WEIGHTS_FILE);
        let saved = AttentionPredictor::seeded(crate::runtime::default_geometry(), 1234);
        saved.save(&path).unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.artifacts = dir.to_str().unwrap().to_string();
        cfg.seed = 42; // different seed: the file must win
        let built = Backend::Attention.build_forward(&cfg).unwrap();
        assert_eq!(built.fingerprint(), Predictor::fingerprint(&saved));
        let _ = std::fs::remove_file(&path);
    }
}
