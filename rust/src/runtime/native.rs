//! A pure-Rust analytic predictor backend.
//!
//! The PJRT backend ([`super::ModelHandle`]) needs AOT-compiled artifacts
//! and an XLA runtime; this backend needs neither. It prices a clip with a
//! deterministic, **row-local** analytic function of the batch row — every
//! prediction depends only on that row's tokens and context, never on the
//! batch composition — which gives it two properties the compiled PJRT
//! model only approximates (the pure-Rust [`super::AttentionPredictor`]
//! shares both exactly):
//!
//! * **padding/batch invariance is exact**: a clip predicts the same value
//!   in a batch of 1 or 256, cold or warm — which is what lets the engine
//!   equivalence tests assert *bit-identical* results across thread counts
//!   and cache states;
//! * **no load-time dependencies**: `capsim compare --backend native` and
//!   the Fig.-7 bench work on a clean tree with no `make artifacts`.
//!
//! The analytic cost is a stand-in, not a trained model: each instruction
//! contributes a hash-derived pseudo-latency, the clip's register context
//! modulates the total a few percent, and `time_scale` sets the output
//! magnitude (as it does for the compiled model).

use anyhow::Result;

use super::manifest::ModelGeometry;
use super::model::Batch;
use super::workspace::Workspace;
use super::Predictor;

/// Deterministic analytic predictor; see the module docs.
#[derive(Clone, Debug)]
pub struct NativePredictor {
    geometry: ModelGeometry,
}

impl NativePredictor {
    pub fn new(geometry: ModelGeometry) -> NativePredictor {
        NativePredictor { geometry }
    }

    /// Geometry matching the AOT `model_config.json` defaults (and the
    /// `coordinator::golden` dataset constants).
    pub fn with_defaults() -> NativePredictor {
        NativePredictor::new(super::default_geometry())
    }

    /// Price one live row. Pure function of the row's tokens + context.
    fn row_cost(&self, batch: &Batch, r: usize, time_scale: f32) -> f32 {
        let g = &self.geometry;
        let row_tokens = g.l_clip * g.l_token;
        let mut cost: f32 = 1.0;
        let mut insts: f32 = 0.0;
        for i in 0..g.l_clip {
            if batch.clip_mask[r * g.l_clip + i] == 0.0 {
                continue;
            }
            insts += 1.0;
            let mut inst_cost: f32 = 0.25;
            for t in 0..g.l_token {
                let tok = batch.tokens[r * row_tokens + i * g.l_token + t] as u32;
                if tok == 0 {
                    continue;
                }
                // hash-derived pseudo-latency in [0, 0.5) per token
                let h = tok.wrapping_mul(0x9E37_79B9) >> 24;
                inst_cost += h as f32 * (1.0 / 512.0);
            }
            cost += inst_cost;
        }
        // context modulation: +/-10% from an FNV hash of the context row
        let mut seed: u32 = 0x811C_9DC5;
        for m in 0..g.m_rows {
            seed = (seed ^ batch.ctx[r * g.m_rows + m] as u32).wrapping_mul(16_777_619);
        }
        let modulation = 0.9 + (seed >> 24) as f32 * (0.2 / 256.0);
        // normalize so a typical clip lands near time_scale
        let norm = insts.max(1.0) * 0.75 + 1.0;
        (cost / norm * modulation * time_scale).max(1e-3)
    }
}

impl Predictor for NativePredictor {
    fn geometry(&self) -> &ModelGeometry {
        &self.geometry
    }

    fn max_fwd_batch(&self) -> usize {
        self.geometry.fwd_batch_sizes.last().copied().unwrap_or(1)
    }

    fn pick_fwd_batch(&self, live: usize) -> usize {
        for &b in &self.geometry.fwd_batch_sizes {
            if b >= live {
                return b;
            }
        }
        self.max_fwd_batch()
    }

    fn forward(&self, batch: &Batch, time_scale: f32) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(batch.live);
        self.forward_into(batch, time_scale, &mut Workspace::new(), &mut out)?;
        Ok(out)
    }

    /// The analytic backend needs no scratch, but it adopts the batched
    /// entry point so engine drivers run one allocation-free call path
    /// regardless of backend.
    fn forward_into(
        &self,
        batch: &Batch,
        time_scale: f32,
        _ws: &mut Workspace,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(
            batch.live <= batch.b,
            "live rows {} exceed batch capacity {}",
            batch.live,
            batch.b
        );
        out.clear();
        out.extend((0..batch.live).map(|r| self.row_cost(batch, r, time_scale)));
        Ok(())
    }

    fn fingerprint(&self) -> u64 {
        // the analytic backend has no parameters: kind + geometry is the
        // whole identity
        super::fingerprint_bytes(
            super::fingerprint_geometry(&self.geometry),
            b"native-analytic",
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ClipSample;
    use crate::predictor::build_batch;

    fn sample(fill: u16, len: u16, ctx_fill: u16) -> ClipSample {
        let g = NativePredictor::with_defaults().geometry.clone();
        ClipSample {
            tokens: (0..len as usize * g.l_token)
                .map(|i| if i % g.l_token == 0 { 1 } else { fill })
                .collect(),
            len,
            ctx: vec![ctx_fill; g.m_rows],
            time: 10.0,
            key: 1,
            bench: 0,
        }
    }

    #[test]
    fn predictions_positive_finite_and_scaled() {
        let p = NativePredictor::with_defaults();
        let g = p.geometry.clone();
        let s = sample(20, 8, 200);
        let b = build_batch(&[&s], 1, &g);
        let out = p.forward(&b, 50.0).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].is_finite() && out[0] > 0.0);
        // doubling time_scale doubles the prediction (pure scale factor)
        let out2 = p.forward(&b, 100.0).unwrap();
        assert!((out2[0] - 2.0 * out[0]).abs() < 1e-3);
    }

    #[test]
    fn batch_and_padding_invariance_is_exact() {
        let p = NativePredictor::with_defaults();
        let g = p.geometry.clone();
        let samples: Vec<ClipSample> =
            (0..5).map(|i| sample(15 + i as u16, 4 + i as u16, 150 + i as u16)).collect();
        let refs: Vec<&ClipSample> = samples.iter().collect();
        let full = p.forward(&build_batch(&refs, 8, &g), 40.0).unwrap();
        assert_eq!(full.len(), 5);
        for (i, s) in samples.iter().enumerate() {
            let one = p.forward(&build_batch(&[s], 1, &g), 40.0).unwrap();
            assert_eq!(one[0].to_bits(), full[i].to_bits(), "row {i}");
        }
    }

    #[test]
    fn tokens_and_context_both_matter() {
        let p = NativePredictor::with_defaults();
        let g = p.geometry.clone();
        let base = p
            .forward(&build_batch(&[&sample(20, 6, 200)], 1, &g), 30.0)
            .unwrap()[0];
        let diff_tok = p
            .forward(&build_batch(&[&sample(21, 6, 200)], 1, &g), 30.0)
            .unwrap()[0];
        let diff_ctx = p
            .forward(&build_batch(&[&sample(20, 6, 201)], 1, &g), 30.0)
            .unwrap()[0];
        assert_ne!(base.to_bits(), diff_tok.to_bits());
        assert_ne!(base.to_bits(), diff_ctx.to_bits());
    }

    #[test]
    fn fingerprint_is_stable_and_geometry_sensitive() {
        let a = NativePredictor::with_defaults();
        let b = NativePredictor::with_defaults();
        assert_eq!(a.fingerprint(), b.fingerprint(), "same backend, same key");
        let mut g = a.geometry.clone();
        g.l_clip += 1;
        let c = NativePredictor::new(g);
        assert_ne!(a.fingerprint(), c.fingerprint(), "geometry changes the key");
        assert_ne!(
            a.fingerprint(),
            crate::runtime::fingerprint_geometry(&a.geometry),
            "the backend-kind label is mixed in"
        );
    }

    #[test]
    fn geometry_matches_dataset_constants() {
        let g = NativePredictor::with_defaults().geometry.clone();
        assert_eq!(g.l_token, crate::coordinator::golden::L_TOKEN);
        assert_eq!(g.l_clip, crate::coordinator::golden::L_CLIP);
        assert_eq!(g.m_rows, crate::context::M_ROWS);
        assert!(g.vocab_size >= crate::tokenizer::vocab::VOCAB_USED as usize);
    }
}
