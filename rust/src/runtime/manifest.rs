//! `artifacts/manifest.json` — the AOT contract written by `aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Model geometry shared by every variant (from `model_config.json`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelGeometry {
    pub vocab_size: usize,
    pub embed_dim: usize,
    pub l_token: usize,
    pub l_clip: usize,
    pub m_rows: usize,
    pub train_batch: usize,
    pub fwd_batch_sizes: Vec<usize>,
}

/// One exported predictor variant.
#[derive(Clone, Debug)]
pub struct VariantManifest {
    pub param_size: usize,
    pub init_file: String,
    /// batch size -> fwd HLO file.
    pub fwd_files: BTreeMap<usize, String>,
    /// batch size -> train HLO file.
    pub train_files: BTreeMap<usize, String>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub geometry: ModelGeometry,
    pub variants: BTreeMap<String, VariantManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let doc = json::parse(&src).map_err(|e| anyhow!("manifest parse: {e}"))?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<Manifest> {
        let cfg = doc.get("config");
        let need = |j: &Json, k: &str| -> Result<usize> {
            j.get(k)
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing config.{k}"))
        };
        let geometry = ModelGeometry {
            vocab_size: need(cfg, "vocab_size")?,
            embed_dim: need(cfg, "embed_dim")?,
            l_token: need(cfg, "l_token")?,
            l_clip: need(cfg, "l_clip")?,
            m_rows: doc
                .get("m_rows")
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing m_rows"))?,
            train_batch: need(cfg, "train_batch")?,
            fwd_batch_sizes: cfg
                .get("fwd_batch_sizes")
                .as_arr()
                .ok_or_else(|| anyhow!("missing fwd_batch_sizes"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
        };

        let mut variants = BTreeMap::new();
        let vs = doc
            .get("variants")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing variants"))?;
        for (name, v) in vs {
            let files = v.get("files");
            let mut fwd_files = BTreeMap::new();
            if let Some(o) = files.get("fwd").as_obj() {
                for (b, f) in o {
                    fwd_files.insert(
                        b.parse::<usize>().context("fwd batch key")?,
                        f.as_str().unwrap_or_default().to_string(),
                    );
                }
            }
            let mut train_files = BTreeMap::new();
            if let Some(o) = files.get("train").as_obj() {
                for (b, f) in o {
                    train_files.insert(
                        b.parse::<usize>().context("train batch key")?,
                        f.as_str().unwrap_or_default().to_string(),
                    );
                }
            }
            let init_file = files
                .get("init")
                .as_str()
                .ok_or_else(|| anyhow!("variant {name} missing init"))?
                .to_string();
            let param_size = v
                .get("param_size")
                .as_usize()
                .ok_or_else(|| anyhow!("variant {name} missing param_size"))?;
            if fwd_files.is_empty() {
                bail!("variant {name} has no fwd entry points");
            }
            variants.insert(
                name.clone(),
                VariantManifest { param_size, init_file, fwd_files, train_files },
            );
        }
        Ok(Manifest { geometry, variants })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc() -> Json {
        json::parse(
            r#"{
              "config": {"vocab_size": 512, "embed_dim": 64, "l_token": 16,
                         "l_clip": 32, "train_batch": 32,
                         "fwd_batch_sizes": [1, 8, 32]},
              "m_rows": 90,
              "variants": {
                "capsim": {
                  "param_size": 190721,
                  "files": {"init": "capsim_init.hlo.txt",
                            "fwd": {"1": "f1", "8": "f8", "32": "f32"},
                            "train": {"32": "t32"}}
                }
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_geometry_and_variants() {
        let m = Manifest::from_json(&doc()).unwrap();
        assert_eq!(m.geometry.l_clip, 32);
        assert_eq!(m.geometry.m_rows, 90);
        assert_eq!(m.geometry.fwd_batch_sizes, vec![1, 8, 32]);
        let v = &m.variants["capsim"];
        assert_eq!(v.param_size, 190721);
        assert_eq!(v.fwd_files[&8], "f8");
        assert_eq!(v.train_files[&32], "t32");
    }

    #[test]
    fn missing_fields_error() {
        let bad = json::parse(r#"{"config": {}, "variants": {}}"#).unwrap();
        assert!(Manifest::from_json(&bad).is_err());
    }

    #[test]
    fn geometry_matches_rust_side_constants() {
        let m = Manifest::from_json(&doc()).unwrap();
        // context module must agree with the exported M
        assert_eq!(m.geometry.m_rows, crate::context::M_ROWS);
        // tokenizer vocabulary must fit the embedding table
        assert!(
            (crate::tokenizer::vocab::VOCAB_USED as usize) <= m.geometry.vocab_size
        );
    }
}
