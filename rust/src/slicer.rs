//! Algorithm 1 — generating the code-trace-clip set from an instruction
//! trace (paper §IV-A).
//!
//! A clip boundary requires (1) at least `l_min` instructions in the clip
//! and (2) a *change in commit time* between consecutive instructions, so
//! that instructions retiring in the same cycle are never split and every
//! clip has a well-defined runtime (`TimePrev − TimeBegin`).
//!
//! At inference time no commit times exist (that is the whole point of
//! CAPSim); [`slice_fixed`] produces fixed-length fragments instead — the
//! training-time boundary rule exists only to make labels exact.

use crate::functional::TraceRecord;

/// A clip: `records[start .. start+len]` with its golden runtime in cycles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Clip {
    pub start: usize,
    pub len: usize,
    /// Golden execution time (cycles); 0 when unknown (inference slicing).
    pub time: u64,
}

impl Clip {
    pub fn records<'a>(&self, trace: &'a [TraceRecord]) -> &'a [TraceRecord] {
        &trace[self.start..self.start + self.len]
    }
}

/// Algorithm 1, faithfully: returns the clip set with commit-time labels.
///
/// `commit_cycle[i]` is the O3 commit cycle of `trace[i]` (monotone
/// nondecreasing). The trailing partial clip is dropped, exactly as the
/// pseudocode's final `InstNow` never lands in an emitted clip.
pub fn slice_labeled(trace_len: usize, commit_cycle: &[u64], l_min: usize) -> Vec<Clip> {
    assert_eq!(trace_len, commit_cycle.len());
    let mut clips = Vec::new();
    if trace_len == 0 {
        return clips;
    }

    let mut start = 0usize; // first record of the current clip
    let mut block_length = 0usize;
    let mut time_prev: u64 = commit_cycle[0];
    let mut time_begin: u64 = 0;

    // The pseudocode appends InstPrev (= trace[i-1]) on iteration i and
    // tests the boundary with TimeNow = trace[i].CommitTime. Equivalent
    // index form: clip gains record i-1; boundary closes the clip at i-1.
    for i in 1..trace_len {
        let time_now = commit_cycle[i];
        block_length += 1;
        if block_length >= l_min && time_now != time_prev {
            clips.push(Clip { start, len: block_length, time: time_prev - time_begin });
            time_begin = time_prev;
            start = i;
            block_length = 0;
        }
        time_prev = time_now;
    }
    clips
}

/// Inference-time slicing: fixed `l_min`-sized fragments (no labels).
/// The trailing fragment shorter than `l_min` is dropped to mirror the
/// training distribution.
pub fn slice_fixed(trace_len: usize, l_min: usize) -> Vec<Clip> {
    (0..trace_len / l_min)
        .map(|k| Clip { start: k * l_min, len: l_min, time: 0 })
        .collect()
}

/// Fixed-length slicing WITH labels: clip `k`'s time is the telescoping
/// commit-cycle delta across its boundary, so per-interval sums are exact
/// just like Algorithm 1's. Used when the training distribution must match
/// the inference-time fixed slicing (`TrainSlicing::Fixed` in the config);
/// the trade-off vs Algorithm 1 is boundary noise from same-cycle commit
/// groups being split.
pub fn slice_fixed_labeled(commit_cycle: &[u64], l_min: usize) -> Vec<Clip> {
    let n = commit_cycle.len() / l_min;
    let mut clips = Vec::with_capacity(n);
    let mut time_begin = 0u64;
    for k in 0..n {
        let end = (k + 1) * l_min - 1;
        let t = commit_cycle[end];
        clips.push(Clip { start: k * l_min, len: l_min, time: t.saturating_sub(time_begin).max(1) });
        time_begin = t;
    }
    clips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    /// Synthetic monotone commit times with plateaus (same-cycle commits).
    fn commit_times(rng: &mut Rng, n: usize) -> Vec<u64> {
        let mut t = 10u64;
        (0..n)
            .map(|_| {
                if rng.chance(0.6) {
                    t += rng.below(4) + 1;
                }
                t
            })
            .collect()
    }

    #[test]
    fn clips_cover_prefix_without_overlap() {
        let mut rng = Rng::new(1);
        let cc = commit_times(&mut rng, 5_000);
        let clips = slice_labeled(cc.len(), &cc, 100);
        assert!(!clips.is_empty());
        let mut expect_start = 0;
        for c in &clips {
            assert_eq!(c.start, expect_start, "clips must tile the trace");
            assert!(c.len >= 100, "min length violated: {}", c.len);
            expect_start = c.start + c.len;
        }
        assert!(expect_start <= cc.len());
    }

    #[test]
    fn clip_times_are_commit_deltas() {
        let mut rng = Rng::new(2);
        let cc = commit_times(&mut rng, 3_000);
        let clips = slice_labeled(cc.len(), &cc, 50);
        // sum of clip times telescopes to (last boundary - first boundary)
        let total: u64 = clips.iter().map(|c| c.time).sum();
        let last = clips.last().unwrap();
        let boundary = cc[last.start + last.len - 1];
        assert_eq!(total, boundary - 0, "telescoping sum");
        for c in &clips {
            assert!(c.time > 0, "boundary rule guarantees nonzero time");
        }
    }

    #[test]
    fn never_splits_same_cycle_commits() {
        let mut rng = Rng::new(3);
        let cc = commit_times(&mut rng, 2_000);
        for c in slice_labeled(cc.len(), &cc, 20) {
            let boundary_idx = c.start + c.len; // first record of next clip
            if boundary_idx < cc.len() {
                assert_ne!(
                    cc[boundary_idx], cc[boundary_idx - 1],
                    "boundary must sit on a commit-time change"
                );
            }
        }
    }

    #[test]
    fn l_min_one_splits_at_every_time_change() {
        let cc = vec![1, 1, 2, 2, 2, 5, 7];
        let clips = slice_labeled(cc.len(), &cc, 1);
        // boundaries after indices 1 (1->2), 4 (2->5), 5 (5->7)
        assert_eq!(clips.len(), 3);
        assert_eq!(clips[0], Clip { start: 0, len: 2, time: 1 });
        assert_eq!(clips[1], Clip { start: 2, len: 3, time: 1 });
        assert_eq!(clips[2], Clip { start: 5, len: 1, time: 3 });
    }

    #[test]
    fn empty_and_short_traces() {
        assert!(slice_labeled(0, &[], 10).is_empty());
        let cc = vec![1, 2, 3];
        assert!(slice_labeled(3, &cc, 100).is_empty(), "too short for l_min");
    }

    #[test]
    fn fixed_slicing_uniform() {
        let clips = slice_fixed(105, 32);
        assert_eq!(clips.len(), 3);
        for (k, c) in clips.iter().enumerate() {
            assert_eq!(c.start, k * 32);
            assert_eq!(c.len, 32);
            assert_eq!(c.time, 0);
        }
    }

    #[test]
    fn fixed_labeled_telescopes() {
        let mut rng = Rng::new(4);
        let cc = commit_times(&mut rng, 1_000);
        let clips = slice_fixed_labeled(&cc, 32);
        assert_eq!(clips.len(), 1_000 / 32);
        let total: u64 = clips.iter().map(|c| c.time).sum();
        let last = clips.last().unwrap();
        assert_eq!(total, cc[last.start + last.len - 1]);
        for c in &clips {
            assert_eq!(c.len, 32);
            assert!(c.time >= 1);
        }
    }

    #[test]
    fn prop_clip_invariants_hold() {
        prop::check_res(
            "slicer invariants",
            64,
            |r| {
                let n = 200 + r.range(0, 3000);
                let lm = 1 + r.range(0, 64);
                let mut rng = Rng::new(r.next_u64());
                (commit_times(&mut rng, n), lm)
            },
            |(cc, lm)| {
                let clips = slice_labeled(cc.len(), cc, *lm);
                let mut pos = 0;
                for c in &clips {
                    if c.start != pos {
                        return Err(format!("gap at {}", c.start));
                    }
                    if c.len < *lm {
                        return Err(format!("short clip {}", c.len));
                    }
                    pos += c.len;
                }
                Ok(())
            },
        );
    }
}
