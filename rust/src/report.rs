//! Table and series emitters: every bench prints the paper's rows through
//! these (ASCII for the console, CSV next to it for plotting).

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned ASCII table.
    pub fn ascii(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {:<w$} |", c, w = w);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers, &widths);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep, &widths);
        for row in &self.rows {
            line(&mut out, row, &widths);
        }
        out
    }

    /// Render as CSV (quoted only when needed).
    pub fn csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(esc).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Print to stdout and also save CSV under `reports/<name>.csv`.
    pub fn emit(&self, name: &str) {
        println!("{}", self.ascii());
        let dir = Path::new("reports");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{name}.csv")), self.csv());
        }
    }
}

/// A named (x, y) series — figure data (loss curves, distributions).
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Series { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// CSV with `x,<name>` header.
    pub fn csv(&self) -> String {
        let mut out = format!("x,{}\n", self.name);
        for (x, y) in &self.points {
            let _ = writeln!(out, "{x},{y}");
        }
        out
    }

    /// A crude console sparkline (log-friendly visualization of a curve).
    pub fn sparkline(&self, width: usize) -> String {
        if self.points.is_empty() {
            return String::new();
        }
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let ys: Vec<f64> = self.points.iter().map(|p| p.1).collect();
        let (lo, hi) = ys
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &y| {
                (l.min(y), h.max(y))
            });
        let n = ys.len();
        let step = (n as f64 / width as f64).max(1.0);
        let mut s = String::new();
        let mut i = 0.0;
        while (i as usize) < n && s.chars().count() < width {
            let y = ys[i as usize];
            let t = if hi > lo { (y - lo) / (hi - lo) } else { 0.5 };
            s.push(BARS[((t * 7.0).round() as usize).min(7)]);
            i += step;
        }
        s
    }

    pub fn emit(&self, name: &str) {
        println!("{}: {}", self.name, self.sparkline(60));
        let dir = Path::new("reports");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{name}.csv")), self.csv());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_aligns_columns() {
        let mut t = Table::new("T", &["name", "v"]);
        t.row(vec!["short".into(), "1".into()]);
        t.row(vec!["a-much-longer-name".into(), "22".into()]);
        let s = t.ascii();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].contains("name"));
        // all body lines same width
        assert_eq!(lines[2].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    fn series_sparkline_monotone() {
        let mut s = Series::new("loss");
        for i in 0..100 {
            s.push(i as f64, 100.0 - i as f64);
        }
        let sl = s.sparkline(20);
        assert_eq!(sl.chars().count(), 20);
        assert!(sl.starts_with('█'));
        assert!(sl.ends_with('▁'));
    }
}
