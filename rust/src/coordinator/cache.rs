//! Cross-benchmark clip cache — a sharded concurrent map from
//! [`fast_clip_key`](crate::tokenizer::standardize::fast_clip_key) to the
//! predicted clip time.
//!
//! The 24 workloads are compositions of a shared kernel library, so
//! identical `l_min`-instruction clips recur *across* benchmarks, not just
//! across the intervals of one benchmark. Holding one [`ClipCache`] across
//! a whole suite run means each unique clip is sent through the predictor
//! once per suite instead of once per benchmark (and its tokenization is
//! skipped wherever the scan can already see the key — in the cache, or
//! in the suite engine's pending set).
//!
//! Concurrency/determinism contract (what makes `threads=N` bit-identical
//! to `threads=1`): the parallel interval-scan stage only *reads* the
//! cache ([`ClipCache::contains`]); all inserts happen in the sequential
//! resolve stage of `coordinator::modes`, in deterministic first-appearance
//! order. Shards are plain `RwLock`s, so concurrent readers never block
//! each other on disjoint shards and the scan stage stays lock-cheap.
//!
//! Cached values are predictions, so a cache is only meaningful for one
//! `(backend, parameters, time_scale)` combination — callers hold one
//! cache per trained model, exactly like an inference-server result cache.
//! The on-disk format ([`ClipCache::save`] / [`ClipCache::load`]) encodes
//! that: a versioned header carries the model fingerprint
//! ([`Predictor::fingerprint`](crate::runtime::Predictor::fingerprint))
//! and the `time_scale` bits, and a load with a mismatched key (or a
//! corrupt/truncated file) is refused so callers fall back to a cold
//! start ([`ClipCache::load_or_cold`]).
//! Dedup is content-keyed (paper §IV-B): `fast_clip_key` hashes decoded
//! instruction fields, not register values, so a cached prediction
//! carries the register context of the key's first sighting. Repeating a
//! run of the same composition is bit-identical cold vs. warm; changing
//! the composition (a benchmark alone vs. after a sibling sharing clips)
//! may canonicalize a shared key to a different first context.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// On-disk header magic ("CPLC") of a persisted clip cache.
const FILE_MAGIC: u32 = 0x434C_5043;
/// Bump on any incompatible layout change; old files then cold-start.
const FILE_VERSION: u32 = 1;

/// Hit/miss counters observed so far (monotone; see [`ClipCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Sharded concurrent `fast_clip_key -> predicted cycles` map.
pub struct ClipCache {
    shards: Vec<RwLock<HashMap<u64, f64>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for ClipCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ClipCache {
    /// A cache with the default shard count.
    pub fn new() -> ClipCache {
        ClipCache::with_shards(16)
    }

    /// A cache with `n` shards (rounded up to a power of two, min 1).
    pub fn with_shards(n: usize) -> ClipCache {
        let n = n.max(1).next_power_of_two();
        ClipCache {
            shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    #[inline]
    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, f64>> {
        // Fibonacci-hash the key so shard choice is independent of any
        // structure in the FNV clip keys; shards.len() is a power of two.
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let i = (h >> 32) as usize & (self.shards.len() - 1);
        &self.shards[i]
    }

    /// Read-only membership probe (no stats side effects) — safe to call
    /// from the parallel scan stage.
    pub fn contains(&self, key: u64) -> bool {
        self.shard(key).read().unwrap().contains_key(&key)
    }

    /// Look up a predicted time; counts a hit or a miss.
    pub fn get(&self, key: u64) -> Option<f64> {
        let v = self.shard(key).read().unwrap().get(&key).copied();
        match v {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        v
    }

    /// Insert (or overwrite) a predicted time.
    pub fn insert(&self, key: u64, time: f64) {
        self.shard(key).write().unwrap().insert(key, time);
    }

    /// Number of cached unique clips.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss counters accumulated so far.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Drop all entries **and** reset the hit/miss counters: after a
    /// warm-start invalidation the cache reports a fresh hit rate
    /// instead of one skewed by lookups against the discarded contents.
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    /// Snapshot of all entries, sorted by key — deterministic bytes for
    /// [`save`](ClipCache::save) regardless of insertion or shard order.
    pub fn entries(&self) -> Vec<(u64, f64)> {
        let mut out: Vec<(u64, f64)> = Vec::with_capacity(self.len());
        for s in &self.shards {
            out.extend(s.read().unwrap().iter().map(|(&k, &v)| (k, v)));
        }
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Persist the cache for cross-process warm starts. The header keys
    /// the file to one `(model fingerprint, time_scale)` combination —
    /// the same contract as the in-memory cache. Writes a sibling temp
    /// file and renames it, so a crashed writer never leaves a
    /// half-written cache behind. Returns the number of entries saved.
    pub fn save(&self, path: &Path, fingerprint: u64, time_scale: f32) -> std::io::Result<usize> {
        let entries = self.entries();
        let tmp = path.with_extension("tmp");
        {
            let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            w.write_all(&FILE_MAGIC.to_le_bytes())?;
            w.write_all(&FILE_VERSION.to_le_bytes())?;
            w.write_all(&fingerprint.to_le_bytes())?;
            w.write_all(&time_scale.to_bits().to_le_bytes())?;
            w.write_all(&(entries.len() as u64).to_le_bytes())?;
            for &(k, v) in &entries {
                w.write_all(&k.to_le_bytes())?;
                w.write_all(&v.to_bits().to_le_bytes())?;
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(entries.len())
    }

    /// Load a persisted cache, verifying the version and the
    /// `(fingerprint, time_scale)` key. Corrupt, truncated, or
    /// mismatched files return `Err` (callers cold-start; see
    /// [`load_or_cold`](ClipCache::load_or_cold)).
    pub fn load(path: &Path, fingerprint: u64, time_scale: f32) -> std::io::Result<ClipCache> {
        fn bad(msg: &str) -> std::io::Error {
            std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
        }
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != FILE_MAGIC {
            return Err(bad("not a clip-cache file"));
        }
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != FILE_VERSION {
            return Err(bad("unsupported clip-cache version"));
        }
        r.read_exact(&mut b8)?;
        if u64::from_le_bytes(b8) != fingerprint {
            return Err(bad("model fingerprint mismatch"));
        }
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != time_scale.to_bits() {
            return Err(bad("time_scale mismatch"));
        }
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        let cache = ClipCache::new();
        for _ in 0..n {
            r.read_exact(&mut b8)?;
            let k = u64::from_le_bytes(b8);
            r.read_exact(&mut b8)?;
            cache.insert(k, f64::from_bits(u64::from_le_bytes(b8)));
        }
        Ok(cache)
    }

    /// [`load`](ClipCache::load) with a cold-start fallback: a missing,
    /// corrupt, or mismatched-key file yields a fresh empty cache.
    /// Returns `(cache, warm)` where `warm` says the load succeeded.
    pub fn load_or_cold(path: &Path, fingerprint: u64, time_scale: f32) -> (ClipCache, bool) {
        match Self::load(path, fingerprint, time_scale) {
            Ok(c) => (c, true),
            Err(_) => (ClipCache::new(), false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let c = ClipCache::new();
        assert!(!c.contains(42));
        assert_eq!(c.get(42), None);
        c.insert(42, 123.5);
        assert!(c.contains(42));
        assert_eq!(c.get(42), Some(123.5));
        assert_eq!(c.len(), 1);
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
    }

    #[test]
    fn shards_cover_key_space() {
        let c = ClipCache::with_shards(4);
        for k in 0..1000u64 {
            c.insert(k.wrapping_mul(0x1234_5678_9ABC_DEF1), k as f64);
        }
        assert_eq!(c.len(), 1000);
        // every shard should have received a share
        for s in &c.shards {
            assert!(!s.read().unwrap().is_empty());
        }
    }

    #[test]
    fn concurrent_reads_while_inserting_elsewhere() {
        let c = ClipCache::new();
        for k in 0..64u64 {
            c.insert(k, k as f64);
        }
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for k in 0..64u64 {
                        assert!(c.contains(k));
                    }
                });
            }
        });
        assert_eq!(c.len(), 64);
    }

    #[test]
    fn clear_resets_contents_and_counters() {
        let c = ClipCache::new();
        c.insert(1, 2.0);
        let _ = c.get(1);
        let _ = c.get(2);
        assert_eq!((c.stats().hits, c.stats().misses), (1, 1));
        c.clear();
        assert!(c.is_empty());
        // hit-rate reporting after a warm-start invalidation starts fresh
        assert_eq!(c.stats(), CacheStats::default());
        assert_eq!(c.stats().hit_rate(), 0.0);
    }

    #[test]
    fn save_load_roundtrip_with_matching_key() {
        let dir = std::env::temp_dir().join("capsim_cache_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        for k in 0..300u64 {
            c.insert(k.wrapping_mul(0x9E37_79B9_7F4A_7C15), k as f64 * 1.5 + 0.25);
        }
        let saved = c.save(&path, 0xFEED_BEEF, 40.0).unwrap();
        assert_eq!(saved, 300);
        let loaded = ClipCache::load(&path, 0xFEED_BEEF, 40.0).unwrap();
        assert_eq!(loaded.len(), c.len());
        assert_eq!(loaded.entries(), c.entries(), "values survive bit-exactly");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_refuses_mismatched_key_or_garbage() {
        let dir = std::env::temp_dir().join("capsim_cache_mismatch");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        c.insert(7, 1.25);
        c.save(&path, 1234, 40.0).unwrap();
        assert!(ClipCache::load(&path, 4321, 40.0).is_err(), "fingerprint mismatch");
        assert!(ClipCache::load(&path, 1234, 41.0).is_err(), "time_scale mismatch");
        assert!(ClipCache::load(&path, 1234, 40.0).is_ok());
        // corrupt / truncated files fall back cold
        std::fs::write(&path, b"not a cache").unwrap();
        let (cold, warm) = ClipCache::load_or_cold(&path, 1234, 40.0);
        assert!(!warm && cold.is_empty());
        // missing file falls back cold too
        let (cold, warm) = ClipCache::load_or_cold(&dir.join("absent.bin"), 1234, 40.0);
        assert!(!warm && cold.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_is_deterministic_across_insertion_orders() {
        let dir = std::env::temp_dir().join("capsim_cache_det");
        std::fs::create_dir_all(&dir).unwrap();
        let (pa, pb) = (dir.join("a.bin"), dir.join("b.bin"));
        let a = ClipCache::new();
        let b = ClipCache::new();
        for k in 0..100u64 {
            a.insert(k, k as f64);
            b.insert(99 - k, (99 - k) as f64);
        }
        a.save(&pa, 1, 2.0).unwrap();
        b.save(&pb, 1, 2.0).unwrap();
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }

    #[test]
    fn hit_rate() {
        let c = ClipCache::new();
        c.insert(7, 1.0);
        let _ = c.get(7);
        let _ = c.get(8);
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-12);
    }
}
