//! Cross-benchmark clip cache — a sharded concurrent map from
//! [`fast_clip_key`](crate::tokenizer::standardize::fast_clip_key) to the
//! predicted clip time.
//!
//! The 24 workloads are compositions of a shared kernel library, so
//! identical `l_min`-instruction clips recur *across* benchmarks, not just
//! across the intervals of one benchmark. Holding one [`ClipCache`] across
//! a whole suite run means each unique clip is sent through the predictor
//! once per suite instead of once per benchmark (and its tokenization is
//! skipped wherever the scan can already see the key — in the cache, or
//! in the suite engine's pending set).
//!
//! Concurrency/determinism contract (what makes `threads=N` bit-identical
//! to `threads=1`): the parallel interval-scan stage only *reads* the
//! cache ([`ClipCache::contains`]); all inserts happen in the sequential
//! resolve stage of `coordinator::modes`, in deterministic first-appearance
//! order. Shards are plain `RwLock`s, so concurrent readers never block
//! each other on disjoint shards and the scan stage stays lock-cheap.
//!
//! Cached values are predictions, so a cache is only meaningful for one
//! `(backend, parameters, time_scale)` combination — callers hold one
//! cache per trained model, exactly like an inference-server result cache.
//! The on-disk format ([`ClipCache::save`] / [`ClipCache::load`]) encodes
//! that: a versioned header carries the model fingerprint
//! ([`Predictor::fingerprint`](crate::runtime::Predictor::fingerprint))
//! and the `time_scale` bits, and a load with a mismatched key (or a
//! corrupt/truncated file) is refused so callers fall back to a cold
//! start ([`ClipCache::load_or_cold`]).
//! The cache can be **bounded** ([`ClipCache::bounded`], wired to
//! `pipeline.cache_max_entries` / `--cache-max-entries`): when an insert
//! would exceed the bound, the oldest-inserted entries are evicted — on
//! insert and again before [`ClipCache::save`] — and counted in
//! [`CacheStats::evictions`]. The default bound is far above what any
//! current suite produces, so eviction only engages on long-lived
//! persistent caches; `0` disables the bound entirely.
//!
//! Dedup is content-keyed (paper §IV-B): `fast_clip_key` hashes decoded
//! instruction fields, not register values, so a cached prediction
//! carries the register context of the key's first sighting. Repeating a
//! run of the same composition is bit-identical cold vs. warm; changing
//! the composition (a benchmark alone vs. after a sibling sharing clips)
//! may canonicalize a shared key to a different first context.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock};

/// On-disk header magic ("CPLC") of a persisted clip cache.
const FILE_MAGIC: u32 = 0x434C_5043;
/// Bump on any incompatible layout change; old files then cold-start.
const FILE_VERSION: u32 = 1;

/// Per-process counter folded into temp-file names so concurrent
/// [`ClipCache::save`] calls (threads in one process, or several
/// processes via the pid component) never share a temp file.
static SAVE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Hit/miss/eviction counters observed so far (monotone; see
/// [`ClipCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries dropped by the size bound (see [`ClipCache::bounded`]).
    pub evictions: u64,
}

impl CacheStats {
    /// Total lookups observed (hits + misses).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// One-line hit-rate summary — the warm-start reporting format
    /// shared by `capsim compare` and the Fig.-7 bench, so call sites
    /// stop re-deriving percentages from the raw counters.
    pub fn hit_line(&self) -> String {
        format!(
            "{:.1}% ({} hits / {} lookups)",
            100.0 * self.hit_rate(),
            self.hits,
            self.lookups()
        )
    }
}

/// Sharded concurrent `fast_clip_key -> predicted cycles` map, with an
/// optional entry bound (oldest-inserted eviction).
pub struct ClipCache {
    shards: Vec<RwLock<HashMap<u64, f64>>>,
    /// Maximum resident entries; `0` = unbounded.
    max_entries: usize,
    /// Resident entry count (kept in sync with the shards so the bound
    /// check never has to scan).
    count: AtomicUsize,
    /// Keys in first-insertion order — the eviction queue. Only
    /// [`insert`](ClipCache::insert) (sequential in the engine's resolve
    /// stage) and [`clear`](ClipCache::clear) touch it; the parallel
    /// scan stage's `contains`/`get` reads never take this lock.
    order: Mutex<VecDeque<u64>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for ClipCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ClipCache {
    /// An unbounded cache with the default shard count.
    pub fn new() -> ClipCache {
        ClipCache::with_shards(16)
    }

    /// A cache bounded to `max_entries` resident clips (`0` =
    /// unbounded). When an insert would exceed the bound, the
    /// **oldest-inserted** entries are evicted (and counted in
    /// [`CacheStats::evictions`]); the same trim runs before
    /// [`save`](ClipCache::save). Eviction order is insertion order, and
    /// the engine inserts sequentially in its deterministic resolve
    /// stage, so evictions are schedule-independent too.
    pub fn bounded(max_entries: usize) -> ClipCache {
        let mut c = ClipCache::new();
        c.max_entries = max_entries;
        c
    }

    /// A cache with `n` shards (rounded up to a power of two, min 1).
    pub fn with_shards(n: usize) -> ClipCache {
        let n = n.max(1).next_power_of_two();
        ClipCache {
            shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(),
            max_entries: 0,
            count: AtomicUsize::new(0),
            order: Mutex::new(VecDeque::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The configured entry bound (`0` = unbounded).
    pub fn max_entries(&self) -> usize {
        self.max_entries
    }

    /// Whether inserts may evict entries. The streamed engine — whose
    /// stage-3 inserts run concurrently with its scans — combines this
    /// with a worst-case headroom check to decide whether a scan's
    /// `contains` observation is **stable** until the merge resolves it;
    /// when it is not, scans keep payloads for cached keys too and the
    /// merge falls back to re-pricing from the run's own first-sighting
    /// payload. Evicting a cached clip that a later run (or benchmark)
    /// would have reused re-canonicalizes it to that run's first
    /// sighting — the same content-keyed rule a changed run composition
    /// already follows (see the module docs) — and shifts dedup
    /// accounting; it never orphans a clip or fails a run. The
    /// phase-barrier paths complete every read before any insert, so
    /// they never need the headroom check.
    pub fn may_evict(&self) -> bool {
        self.max_entries > 0
    }

    #[inline]
    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, f64>> {
        // Fibonacci-hash the key so shard choice is independent of any
        // structure in the FNV clip keys; shards.len() is a power of two.
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let i = (h >> 32) as usize & (self.shards.len() - 1);
        &self.shards[i]
    }

    /// Read-only membership probe (no stats side effects) — safe to call
    /// from the parallel scan stage.
    pub fn contains(&self, key: u64) -> bool {
        self.shard(key).read().unwrap().contains_key(&key)
    }

    /// Look up a predicted time; counts a hit or a miss.
    pub fn get(&self, key: u64) -> Option<f64> {
        let v = self.shard(key).read().unwrap().get(&key).copied();
        match v {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        v
    }

    /// Insert (or overwrite) a predicted time. A fresh key joins the
    /// back of the eviction queue; overwrites keep the key's original
    /// insertion age. May evict the oldest entries when a bound is set.
    pub fn insert(&self, key: u64, time: f64) {
        let fresh = self.shard(key).write().unwrap().insert(key, time).is_none();
        if fresh {
            self.order.lock().unwrap().push_back(key);
            self.count.fetch_add(1, Ordering::Relaxed);
            self.enforce_bound();
        }
    }

    /// Evict oldest-inserted entries until the bound is respected.
    /// Shard locks are never held while waiting on the queue lock (and
    /// vice versa is take-then-release), so readers stay wait-free on
    /// disjoint shards.
    fn enforce_bound(&self) {
        if self.max_entries == 0 {
            return;
        }
        while self.count.load(Ordering::Relaxed) > self.max_entries {
            let oldest = self.order.lock().unwrap().pop_front();
            match oldest {
                Some(key) => {
                    if self.shard(key).write().unwrap().remove(&key).is_some() {
                        self.count.fetch_sub(1, Ordering::Relaxed);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
    }

    /// Number of cached unique clips.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss/eviction counters accumulated so far.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Drop all entries **and** reset the counters: after a warm-start
    /// invalidation the cache reports a fresh hit rate instead of one
    /// skewed by lookups against the discarded contents.
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
        self.order.lock().unwrap().clear();
        self.count.store(0, Ordering::Relaxed);
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
    }

    /// Snapshot of all entries, sorted by key — deterministic bytes for
    /// [`save`](ClipCache::save) regardless of insertion or shard order.
    pub fn entries(&self) -> Vec<(u64, f64)> {
        let mut out: Vec<(u64, f64)> = Vec::with_capacity(self.len());
        for s in &self.shards {
            out.extend(s.read().unwrap().iter().map(|(&k, &v)| (k, v)));
        }
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Persist the cache for cross-process warm starts. The header keys
    /// the file to one `(model fingerprint, time_scale)` combination —
    /// the same contract as the in-memory cache. The size bound is
    /// enforced on the **snapshot**, so a bounded cache never persists
    /// more than `max_entries` clips even when inserts race the save.
    /// Writes a uniquely-named sibling temp file (pid + sequence — a
    /// fixed name would let two concurrent savers interleave writes and
    /// rename a torn image over the good cache), fsyncs it, and renames
    /// it into place, so a crashed or racing writer never leaves a
    /// half-written cache behind. Returns the number of entries saved.
    pub fn save(&self, path: &Path, fingerprint: u64, time_scale: f32) -> std::io::Result<usize> {
        self.enforce_bound();
        let mut entries = self.entries();
        // Inserts racing this save can grow the snapshot past the bound
        // between enforce_bound() and entries(); trim the snapshot itself
        // (key order — the same rule `load_bounded` applies to an
        // oversized file) so the promise holds under any schedule.
        if self.max_entries > 0 && entries.len() > self.max_entries {
            entries.truncate(self.max_entries);
        }
        // `with_extension("tmp")` would *replace* the final extension, so
        // `clips.cache` and `clips.other` collide on one `clips.tmp`;
        // append to the full file name instead.
        let seq = SAVE_SEQ.fetch_add(1, Ordering::Relaxed);
        let mut tmp_name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
        tmp_name.push(format!(".{}.{}.tmp", std::process::id(), seq));
        let tmp = path.with_file_name(tmp_name);
        let write = (|| -> std::io::Result<()> {
            let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            w.write_all(&FILE_MAGIC.to_le_bytes())?;
            w.write_all(&FILE_VERSION.to_le_bytes())?;
            w.write_all(&fingerprint.to_le_bytes())?;
            w.write_all(&time_scale.to_bits().to_le_bytes())?;
            w.write_all(&(entries.len() as u64).to_le_bytes())?;
            for &(k, v) in &entries {
                w.write_all(&k.to_le_bytes())?;
                w.write_all(&v.to_bits().to_le_bytes())?;
            }
            // fsync before rename: without it a crash shortly after the
            // rename can leave a file whose *name* is durable but whose
            // bytes are not — exactly the torn cache the temp-file dance
            // is meant to rule out.
            w.into_inner().map_err(|e| e.into_error())?.sync_all()?;
            std::fs::rename(&tmp, path)
        })();
        if write.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        write?;
        Ok(entries.len())
    }

    /// Load a persisted cache, verifying the version and the
    /// `(fingerprint, time_scale)` key. Corrupt, truncated, or
    /// mismatched files return `Err` (callers cold-start; see
    /// [`load_or_cold`](ClipCache::load_or_cold)). The loaded cache is
    /// unbounded; use [`load_bounded`](ClipCache::load_bounded) to apply
    /// an entry bound.
    pub fn load(path: &Path, fingerprint: u64, time_scale: f32) -> std::io::Result<ClipCache> {
        Self::load_bounded(path, fingerprint, time_scale, 0)
    }

    /// [`load`](ClipCache::load) into a cache bounded to `max_entries`
    /// (`0` = unbounded). A file holding more than `max_entries` clips
    /// is trimmed during the load (file order, which is key order — the
    /// on-disk format does not record insertion age).
    pub fn load_bounded(
        path: &Path,
        fingerprint: u64,
        time_scale: f32,
        max_entries: usize,
    ) -> std::io::Result<ClipCache> {
        fn bad(msg: &str) -> std::io::Error {
            std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
        }
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != FILE_MAGIC {
            return Err(bad("not a clip-cache file"));
        }
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != FILE_VERSION {
            return Err(bad("unsupported clip-cache version"));
        }
        r.read_exact(&mut b8)?;
        if u64::from_le_bytes(b8) != fingerprint {
            return Err(bad("model fingerprint mismatch"));
        }
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != time_scale.to_bits() {
            return Err(bad("time_scale mismatch"));
        }
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        let cache = ClipCache::bounded(max_entries);
        for _ in 0..n {
            r.read_exact(&mut b8)?;
            let k = u64::from_le_bytes(b8);
            r.read_exact(&mut b8)?;
            cache.insert(k, f64::from_bits(u64::from_le_bytes(b8)));
        }
        // loading is plumbing, not cache traffic: start the counters
        // fresh (evictions included) so stats describe the run ahead
        cache.hits.store(0, Ordering::Relaxed);
        cache.misses.store(0, Ordering::Relaxed);
        cache.evictions.store(0, Ordering::Relaxed);
        Ok(cache)
    }

    /// [`load`](ClipCache::load) with a cold-start fallback: a missing,
    /// corrupt, or mismatched-key file yields a fresh empty cache.
    /// Returns `(cache, warm)` where `warm` says the load succeeded.
    pub fn load_or_cold(path: &Path, fingerprint: u64, time_scale: f32) -> (ClipCache, bool) {
        Self::load_or_cold_bounded(path, fingerprint, time_scale, 0)
    }

    /// [`load_bounded`](ClipCache::load_bounded) with the same
    /// cold-start fallback; the fallback cache carries the bound too.
    pub fn load_or_cold_bounded(
        path: &Path,
        fingerprint: u64,
        time_scale: f32,
        max_entries: usize,
    ) -> (ClipCache, bool) {
        match Self::load_bounded(path, fingerprint, time_scale, max_entries) {
            Ok(c) => (c, true),
            Err(_) => (ClipCache::bounded(max_entries), false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let c = ClipCache::new();
        assert!(!c.contains(42));
        assert_eq!(c.get(42), None);
        c.insert(42, 123.5);
        assert!(c.contains(42));
        assert_eq!(c.get(42), Some(123.5));
        assert_eq!(c.len(), 1);
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
    }

    #[test]
    fn shards_cover_key_space() {
        let c = ClipCache::with_shards(4);
        for k in 0..1000u64 {
            c.insert(k.wrapping_mul(0x1234_5678_9ABC_DEF1), k as f64);
        }
        assert_eq!(c.len(), 1000);
        // every shard should have received a share
        for s in &c.shards {
            assert!(!s.read().unwrap().is_empty());
        }
    }

    #[test]
    fn concurrent_reads_while_inserting_elsewhere() {
        let c = ClipCache::new();
        for k in 0..64u64 {
            c.insert(k, k as f64);
        }
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for k in 0..64u64 {
                        assert!(c.contains(k));
                    }
                });
            }
        });
        assert_eq!(c.len(), 64);
    }

    #[test]
    fn clear_resets_contents_and_counters() {
        let c = ClipCache::new();
        c.insert(1, 2.0);
        let _ = c.get(1);
        let _ = c.get(2);
        assert_eq!((c.stats().hits, c.stats().misses), (1, 1));
        c.clear();
        assert!(c.is_empty());
        // hit-rate reporting after a warm-start invalidation starts fresh
        assert_eq!(c.stats(), CacheStats::default());
        assert_eq!(c.stats().hit_rate(), 0.0);
    }

    #[test]
    fn save_load_roundtrip_with_matching_key() {
        let dir = std::env::temp_dir().join("capsim_cache_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        for k in 0..300u64 {
            c.insert(k.wrapping_mul(0x9E37_79B9_7F4A_7C15), k as f64 * 1.5 + 0.25);
        }
        let saved = c.save(&path, 0xFEED_BEEF, 40.0).unwrap();
        assert_eq!(saved, 300);
        let loaded = ClipCache::load(&path, 0xFEED_BEEF, 40.0).unwrap();
        assert_eq!(loaded.len(), c.len());
        assert_eq!(loaded.entries(), c.entries(), "values survive bit-exactly");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_refuses_mismatched_key_or_garbage() {
        let dir = std::env::temp_dir().join("capsim_cache_mismatch");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        c.insert(7, 1.25);
        c.save(&path, 1234, 40.0).unwrap();
        assert!(ClipCache::load(&path, 4321, 40.0).is_err(), "fingerprint mismatch");
        assert!(ClipCache::load(&path, 1234, 41.0).is_err(), "time_scale mismatch");
        assert!(ClipCache::load(&path, 1234, 40.0).is_ok());
        // corrupt / truncated files fall back cold
        std::fs::write(&path, b"not a cache").unwrap();
        let (cold, warm) = ClipCache::load_or_cold(&path, 1234, 40.0);
        assert!(!warm && cold.is_empty());
        // missing file falls back cold too
        let (cold, warm) = ClipCache::load_or_cold(&dir.join("absent.bin"), 1234, 40.0);
        assert!(!warm && cold.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_is_deterministic_across_insertion_orders() {
        let dir = std::env::temp_dir().join("capsim_cache_det");
        std::fs::create_dir_all(&dir).unwrap();
        let (pa, pb) = (dir.join("a.bin"), dir.join("b.bin"));
        let a = ClipCache::new();
        let b = ClipCache::new();
        for k in 0..100u64 {
            a.insert(k, k as f64);
            b.insert(99 - k, (99 - k) as f64);
        }
        a.save(&pa, 1, 2.0).unwrap();
        b.save(&pb, 1, 2.0).unwrap();
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }

    #[test]
    fn bounded_cache_evicts_oldest_inserted() {
        let c = ClipCache::bounded(3);
        for k in 1..=5u64 {
            c.insert(k, k as f64);
        }
        assert_eq!(c.len(), 3);
        assert!(!c.contains(1) && !c.contains(2), "oldest two evicted");
        assert!(c.contains(3) && c.contains(4) && c.contains(5));
        assert_eq!(c.stats().evictions, 2);
        // an evicted key can come back; the now-oldest entry makes room
        c.insert(1, 10.0);
        assert!(c.contains(1) && !c.contains(3));
        assert_eq!(c.stats().evictions, 3);
    }

    #[test]
    fn overwrite_keeps_age_and_never_evicts() {
        let c = ClipCache::bounded(2);
        c.insert(7, 1.0);
        c.insert(8, 2.0);
        c.insert(7, 3.0); // overwrite: no growth, no eviction
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.get(7), Some(3.0));
        // 7 kept its original (oldest) insertion age, so it goes first
        c.insert(9, 4.0);
        assert!(!c.contains(7) && c.contains(8) && c.contains(9));
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let c = ClipCache::new();
        assert_eq!(c.max_entries(), 0);
        for k in 0..5_000u64 {
            c.insert(k, k as f64);
        }
        assert_eq!(c.len(), 5_000);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn save_respects_the_bound_and_bounded_load_trims() {
        let dir = std::env::temp_dir().join("capsim_cache_bound_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::bounded(10);
        for k in 0..25u64 {
            c.insert(k, k as f64);
        }
        let saved = c.save(&path, 1, 2.0).unwrap();
        assert_eq!(saved, 10, "save never persists beyond the bound");
        // loading into a smaller bound trims during the load and starts
        // the counters fresh
        let small = ClipCache::load_bounded(&path, 1, 2.0, 4).unwrap();
        assert_eq!(small.len(), 4);
        assert_eq!(small.stats(), CacheStats::default());
        // cold-start fallback carries the bound
        let (cold, warm) = ClipCache::load_or_cold_bounded(&path, 999, 2.0, 4);
        assert!(!warm && cold.is_empty());
        assert_eq!(cold.max_entries(), 4);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clear_resets_eviction_state() {
        let c = ClipCache::bounded(2);
        for k in 0..5u64 {
            c.insert(k, k as f64);
        }
        assert!(c.stats().evictions > 0);
        c.clear();
        assert_eq!(c.stats(), CacheStats::default());
        // the eviction queue was cleared too: refilling works cleanly
        c.insert(1, 1.0);
        c.insert(2, 2.0);
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 0);
    }

    /// Concurrent saves to one path must never produce a torn file: each
    /// writer gets a unique temp file, so every rename publishes one
    /// writer's complete image. Pre-fix, the shared `clips.tmp` sibling
    /// let writers interleave bytes (corrupt loads) or race the rename
    /// (spurious `NotFound` save errors).
    #[test]
    fn concurrent_saves_to_one_path_never_corrupt_it() {
        let dir = std::env::temp_dir().join("capsim_cache_save_race");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let counts = [50usize, 100, 150, 200];
        let caches: Vec<ClipCache> = counts
            .iter()
            .map(|&n| {
                let c = ClipCache::new();
                for k in 0..n as u64 {
                    c.insert(k, k as f64 + 0.5);
                }
                c
            })
            .collect();
        caches[0].save(&path, 77, 4.0).unwrap();
        std::thread::scope(|s| {
            for c in &caches {
                let path = &path;
                s.spawn(move || {
                    for _ in 0..10 {
                        c.save(path, 77, 4.0).unwrap();
                    }
                });
            }
            for _ in 0..50 {
                let loaded = ClipCache::load(&path, 77, 4.0).unwrap();
                assert!(
                    counts.contains(&loaded.len()),
                    "load saw a torn image: {} entries",
                    loaded.len()
                );
            }
        });
        let loaded = ClipCache::load(&path, 77, 4.0).unwrap();
        assert!(counts.contains(&loaded.len()));
        let _ = std::fs::remove_file(&path);
    }

    /// Same-stem caches with different extensions must not share a temp
    /// file (`with_extension("tmp")` folded `clips.cache` and
    /// `clips.other` onto one `clips.tmp`); and no `.tmp` litter may
    /// survive a successful save.
    #[test]
    fn sibling_caches_with_distinct_extensions_do_not_collide() {
        let dir = std::env::temp_dir().join("capsim_cache_ext_collide");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let pa = dir.join("clips.cache");
        let pb = dir.join("clips.other");
        let a = ClipCache::new();
        let b = ClipCache::new();
        for k in 0..100u64 {
            a.insert(k, k as f64);
        }
        for k in 0..200u64 {
            b.insert(k, k as f64 * 2.0);
        }
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..10 {
                    a.save(&pa, 5, 1.0).unwrap();
                }
            });
            s.spawn(|| {
                for _ in 0..10 {
                    b.save(&pb, 5, 1.0).unwrap();
                }
            });
        });
        let la = ClipCache::load(&pa, 5, 1.0).unwrap();
        let lb = ClipCache::load(&pb, 5, 1.0).unwrap();
        assert_eq!(la.len(), 100);
        assert_eq!(lb.len(), 200);
        assert_eq!(la.entries(), a.entries());
        assert_eq!(lb.entries(), b.entries());
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name();
            let name = name.to_string_lossy().into_owned();
            assert!(!name.ends_with(".tmp"), "leftover temp file {name}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The bound holds on the persisted file even when inserts race the
    /// save: the snapshot itself is trimmed, not just the live map.
    #[test]
    fn bounded_save_never_exceeds_bound_under_racing_inserts() {
        let dir = std::env::temp_dir().join("capsim_cache_bound_race");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let bound = 64usize;
        let c = ClipCache::bounded(bound);
        let finished = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..3u64 {
                let c = &c;
                let finished = &finished;
                s.spawn(move || {
                    let mut rng = crate::util::Rng::new(0xBEEF ^ t);
                    for _ in 0..2_000 {
                        c.insert(rng.next_u64(), 1.0);
                    }
                    finished.fetch_add(1, Ordering::Relaxed);
                });
            }
            // save continuously while the inserters hammer the cache
            while finished.load(Ordering::Relaxed) < 3 {
                let saved = c.save(&path, 11, 2.5).unwrap();
                assert!(saved <= bound, "save persisted {saved} > bound {bound}");
            }
        });
        let saved = c.save(&path, 11, 2.5).unwrap();
        assert!(saved <= bound);
        let loaded = ClipCache::load(&path, 11, 2.5).unwrap();
        assert!(loaded.len() <= bound);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn hit_rate() {
        let c = ClipCache::new();
        c.insert(7, 1.0);
        let _ = c.get(7);
        let _ = c.get(8);
        let st = c.stats();
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(st.lookups(), 2);
        assert_eq!(st.hit_line(), "50.0% (1 hits / 2 lookups)");
    }
}
