//! Cross-benchmark clip cache — a sharded concurrent map from
//! [`fast_clip_key`](crate::tokenizer::standardize::fast_clip_key) to the
//! predicted clip time.
//!
//! The 24 workloads are compositions of a shared kernel library, so
//! identical `l_min`-instruction clips recur *across* benchmarks, not just
//! across the intervals of one benchmark. Holding one [`ClipCache`] across
//! a whole suite run means each unique clip is sent through the predictor
//! once per suite instead of once per benchmark (and its tokenization is
//! skipped wherever the scan can already see the key — in the cache, or
//! in the suite engine's pending set).
//!
//! Concurrency/determinism contract (what makes `threads=N` bit-identical
//! to `threads=1`): the parallel interval-scan stage only *reads* the
//! cache ([`ClipCache::contains`]); all inserts happen in the sequential
//! resolve stage of `coordinator::modes`, in deterministic first-appearance
//! order. Shards are plain `RwLock`s, so concurrent readers never block
//! each other on disjoint shards and the scan stage stays lock-cheap.
//!
//! Cached values are predictions, so a cache is only meaningful for one
//! `(backend, parameters, time_scale)` combination — callers hold one
//! cache per trained model, exactly like an inference-server result cache.
//! Dedup is content-keyed (paper §IV-B): `fast_clip_key` hashes decoded
//! instruction fields, not register values, so a cached prediction
//! carries the register context of the key's first sighting. Repeating a
//! run of the same composition is bit-identical cold vs. warm; changing
//! the composition (a benchmark alone vs. after a sibling sharing clips)
//! may canonicalize a shared key to a different first context.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Hit/miss counters observed so far (monotone; see [`ClipCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Sharded concurrent `fast_clip_key -> predicted cycles` map.
pub struct ClipCache {
    shards: Vec<RwLock<HashMap<u64, f64>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for ClipCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ClipCache {
    /// A cache with the default shard count.
    pub fn new() -> ClipCache {
        ClipCache::with_shards(16)
    }

    /// A cache with `n` shards (rounded up to a power of two, min 1).
    pub fn with_shards(n: usize) -> ClipCache {
        let n = n.max(1).next_power_of_two();
        ClipCache {
            shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    #[inline]
    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, f64>> {
        // Fibonacci-hash the key so shard choice is independent of any
        // structure in the FNV clip keys; shards.len() is a power of two.
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let i = (h >> 32) as usize & (self.shards.len() - 1);
        &self.shards[i]
    }

    /// Read-only membership probe (no stats side effects) — safe to call
    /// from the parallel scan stage.
    pub fn contains(&self, key: u64) -> bool {
        self.shard(key).read().unwrap().contains_key(&key)
    }

    /// Look up a predicted time; counts a hit or a miss.
    pub fn get(&self, key: u64) -> Option<f64> {
        let v = self.shard(key).read().unwrap().get(&key).copied();
        match v {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        v
    }

    /// Insert (or overwrite) a predicted time.
    pub fn insert(&self, key: u64, time: f64) {
        self.shard(key).write().unwrap().insert(key, time);
    }

    /// Number of cached unique clips.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss counters accumulated so far.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Drop all entries (counters are kept; they describe lookups, not
    /// contents).
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let c = ClipCache::new();
        assert!(!c.contains(42));
        assert_eq!(c.get(42), None);
        c.insert(42, 123.5);
        assert!(c.contains(42));
        assert_eq!(c.get(42), Some(123.5));
        assert_eq!(c.len(), 1);
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
    }

    #[test]
    fn shards_cover_key_space() {
        let c = ClipCache::with_shards(4);
        for k in 0..1000u64 {
            c.insert(k.wrapping_mul(0x1234_5678_9ABC_DEF1), k as f64);
        }
        assert_eq!(c.len(), 1000);
        // every shard should have received a share
        for s in &c.shards {
            assert!(!s.read().unwrap().is_empty());
        }
    }

    #[test]
    fn concurrent_reads_while_inserting_elsewhere() {
        let c = ClipCache::new();
        for k in 0..64u64 {
            c.insert(k, k as f64);
        }
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for k in 0..64u64 {
                        assert!(c.contains(k));
                    }
                });
            }
        });
        assert_eq!(c.len(), 64);
    }

    #[test]
    fn clear_resets_contents_not_counters() {
        let c = ClipCache::new();
        c.insert(1, 2.0);
        let _ = c.get(1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn hit_rate() {
        let c = ClipCache::new();
        c.insert(7, 1.0);
        let _ = c.get(7);
        let _ = c.get(8);
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-12);
    }
}
