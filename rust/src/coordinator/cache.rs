//! Cross-benchmark clip cache — a sharded concurrent map from
//! [`fast_clip_key`](crate::tokenizer::standardize::fast_clip_key) to the
//! predicted clip time.
//!
//! The 24 workloads are compositions of a shared kernel library, so
//! identical `l_min`-instruction clips recur *across* benchmarks, not just
//! across the intervals of one benchmark. Holding one [`ClipCache`] across
//! a whole suite run means each unique clip is sent through the predictor
//! once per suite instead of once per benchmark (and its tokenization is
//! skipped wherever the scan can already see the key — in the cache, or
//! in the suite engine's pending set).
//!
//! Concurrency/determinism contract (what makes `threads=N` bit-identical
//! to `threads=1`): the parallel interval-scan stage only *reads* the
//! cache ([`ClipCache::contains`]); all inserts happen in the sequential
//! resolve stage of `coordinator::modes`, in deterministic first-appearance
//! order. Shards are plain `RwLock`s, so concurrent readers never block
//! each other on disjoint shards and the scan stage stays lock-cheap.
//!
//! Cached values are predictions, so a cache is only meaningful for one
//! `(backend, parameters, time_scale)` combination — callers hold one
//! cache per trained model, exactly like an inference-server result cache.
//! The on-disk format ([`ClipCache::save`] / [`ClipCache::load`]) encodes
//! that: a checksummed header carries the model fingerprint
//! ([`Predictor::fingerprint`](crate::runtime::Predictor::fingerprint)),
//! the `time_scale` bits and the kernel-contract version, and a load with
//! a mismatched key (or a corrupt/truncated file) is refused so callers
//! fall back to a cold start ([`ClipCache::load_or_cold`]).
//!
//! **Two-tier residency.** [`ClipCache::save`] writes a `CPIM` image
//! ([`crate::util::image`]): sorted fixed-stride records behind a
//! checksummed header. [`ClipCache::load`] mmaps that image as a
//! **frozen read-only tier** consulted before the mutable sharded tier —
//! open-to-serving is O(1) regardless of entry count, and N processes
//! warm-starting from one image share a single set of physical pages.
//! Inserts always land in the mutable tier (and skip keys the frozen
//! tier already serves); the entry bound governs each tier separately —
//! the frozen tier is trimmed to the bound at load (key-order prefix,
//! the same rule an oversized legacy file followed) and eviction bounds
//! the mutable tier. The image's O(entries) data digest is deferred to
//! the *first lookup* (keeping the open path O(1)) and checked exactly
//! once before any frozen byte is trusted: a bad digest permanently
//! disables the tier, so corruption degrades to misses — never a wrong
//! prediction. The legacy `CPLC` v1 format still loads (parsed into the
//! mutable tier) for one release so existing caches migrate on their
//! next save; see the "Persistence formats" section of the README.
//! The cache can be **bounded** ([`ClipCache::bounded`], wired to
//! `pipeline.cache_max_entries` / `--cache-max-entries`): when an insert
//! would exceed the bound, the oldest-inserted entries are evicted — on
//! insert and again before [`ClipCache::save`] — and counted in
//! [`CacheStats::evictions`]. The default bound is far above what any
//! current suite produces, so eviction only engages on long-lived
//! persistent caches; `0` disables the bound entirely.
//!
//! Dedup is content-keyed (paper §IV-B): `fast_clip_key` hashes decoded
//! instruction fields, not register values, so a cached prediction
//! carries the register context of the key's first sighting. Repeating a
//! run of the same composition is bit-identical cold vs. warm; changing
//! the composition (a benchmark alone vs. after a sibling sharing clips)
//! may canonicalize a shared key to a different first context.

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Mutex, Once, RwLock};

use crate::runtime::KERNEL_CONTRACT_VERSION;
use crate::util::image::{self, ImageSpec, ImageView};
use crate::util::mmap::Mmap;

/// Header magic ("CPLC") of the **legacy** v1 persisted clip cache,
/// still readable for one release (see [`ClipCache::save_legacy_v1`]).
/// Public so format-reporting tools (`capsim backends`) can recognize a
/// not-yet-migrated cache file.
pub const FILE_MAGIC: u32 = 0x434C_5043;
/// The legacy format's version; anything else in a CPLC file cold-starts.
const FILE_VERSION: u32 = 1;
/// Byte stride of one `(key u64, f64 bits)` record in a cache image.
const RECORD_STRIDE: usize = 16;

/// Hit/miss/eviction counters observed so far (monotone; see
/// [`ClipCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries dropped by the size bound (see [`ClipCache::bounded`]).
    pub evictions: u64,
}

impl CacheStats {
    /// Total lookups observed (hits + misses).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// One-line hit-rate summary — the warm-start reporting format
    /// shared by `capsim compare` and the Fig.-7 bench, so call sites
    /// stop re-deriving percentages from the raw counters.
    pub fn hit_line(&self) -> String {
        format!(
            "{:.1}% ({} hits / {} lookups)",
            100.0 * self.hit_rate(),
            self.hits,
            self.lookups()
        )
    }
}

/// Where a cache's persisted contents live — reported by
/// `capsim backends` and the `serve --stats` reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheSource {
    /// No persisted image contributed (cold start, or the frozen tier
    /// was disabled by a failed digest / `clear`).
    Cold,
    /// Entries were parsed into the mutable heap tier (legacy `CPLC` v1
    /// migration, or an explicit heap load).
    Heap,
    /// A `CPIM` image is mmap-frozen as the read-only tier.
    Frozen,
}

impl CacheSource {
    /// Stable wire/report encoding (0 cold, 1 heap, 2 frozen).
    pub fn code(self) -> u64 {
        match self {
            CacheSource::Cold => 0,
            CacheSource::Heap => 1,
            CacheSource::Frozen => 2,
        }
    }

    /// Decode [`code`](CacheSource::code) (wire → enum); unknown codes
    /// read as `Cold`.
    pub fn from_code(code: u64) -> CacheSource {
        match code {
            1 => CacheSource::Heap,
            2 => CacheSource::Frozen,
            _ => CacheSource::Cold,
        }
    }

    /// Human label used by `capsim backends` / `serve --stats`.
    pub fn label(self) -> &'static str {
        match self {
            CacheSource::Cold => "cold (no persistent image)",
            CacheSource::Heap => "heap-loaded",
            CacheSource::Frozen => "mmap-frozen",
        }
    }
}

/// Frozen-tier verification states (see [`Frozen::state`]).
const FROZEN_UNVERIFIED: u8 = 0;
const FROZEN_LIVE: u8 = 1;
const FROZEN_DEAD: u8 = 2;

/// The read-only mmap tier: sorted fixed-stride records served straight
/// from the mapped image, shared across every process that opened it.
struct Frozen {
    map: Mmap,
    /// Absolute byte offset of the records section in the image.
    records_off: usize,
    /// Records the image holds (the digest covers all of them).
    n_total: usize,
    /// Records lookups may see — `min(n_total, bound)`, a key-order
    /// prefix, matching the trim rule loads always applied.
    n_visible: usize,
    /// Payload section position (empty for cache images, but the digest
    /// definition covers it).
    payload_off: usize,
    payload_len: usize,
    data_digest: u64,
    /// Runs the one-time O(entries) digest check on first use, so the
    /// *open* path stays O(1) while no frozen byte is ever trusted
    /// unverified.
    verify: Once,
    /// `FROZEN_UNVERIFIED` until the digest check runs; then
    /// `FROZEN_LIVE` or `FROZEN_DEAD`. [`ClipCache::clear`] also stores
    /// `FROZEN_DEAD`, which wins over a (later or racing) verification.
    state: AtomicU8,
}

impl Frozen {
    fn ensure_verified(&self) {
        self.verify.call_once(|| {
            let b = self.map.bytes();
            let records = &b[self.records_off..self.records_off + self.n_total * RECORD_STRIDE];
            let payload = &b[self.payload_off..self.payload_off + self.payload_len];
            let ok = image::digest64(&[records, payload]) == self.data_digest;
            if !ok {
                eprintln!(
                    "warning: clip cache image failed its data digest; \
                     disabling the frozen tier (cold start)"
                );
            }
            let next = if ok { FROZEN_LIVE } else { FROZEN_DEAD };
            // compare_exchange so a concurrent kill() is never overridden
            let _ = self.state.compare_exchange(
                FROZEN_UNVERIFIED,
                next,
                Ordering::AcqRel,
                Ordering::Relaxed,
            );
        });
    }

    fn live(&self) -> bool {
        self.ensure_verified();
        self.state.load(Ordering::Acquire) == FROZEN_LIVE
    }

    /// Permanently disable the tier (warm-start invalidation).
    fn kill(&self) {
        self.state.store(FROZEN_DEAD, Ordering::Release);
    }

    fn dead(&self) -> bool {
        self.state.load(Ordering::Acquire) == FROZEN_DEAD
    }

    /// Binary-search the sorted record prefix, straight off the mapping.
    fn lookup(&self, key: u64) -> Option<f64> {
        if !self.live() {
            return None;
        }
        let b = self.map.bytes();
        let (mut lo, mut hi) = (0usize, self.n_visible);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let off = self.records_off + mid * RECORD_STRIDE;
            let k = u64::from_le_bytes(b[off..off + 8].try_into().unwrap());
            match k.cmp(&key) {
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
                std::cmp::Ordering::Equal => {
                    let v = u64::from_le_bytes(b[off + 8..off + 16].try_into().unwrap());
                    return Some(f64::from_bits(v));
                }
            }
        }
        None
    }

    /// All visible records (empty when the tier is dead).
    fn visible_entries(&self) -> Vec<(u64, f64)> {
        if !self.live() {
            return Vec::new();
        }
        let b = self.map.bytes();
        (0..self.n_visible)
            .map(|i| {
                let off = self.records_off + i * RECORD_STRIDE;
                (
                    u64::from_le_bytes(b[off..off + 8].try_into().unwrap()),
                    f64::from_bits(u64::from_le_bytes(b[off + 8..off + 16].try_into().unwrap())),
                )
            })
            .collect()
    }
}

/// Sharded concurrent `fast_clip_key -> predicted cycles` map, with an
/// optional entry bound (oldest-inserted eviction) and an optional
/// frozen read-only mmap tier (see the module docs).
pub struct ClipCache {
    /// Read-only tier consulted before the shards; never evicts.
    frozen: Option<Frozen>,
    /// Where the persisted contents came from (raw; see [`ClipCache::source`]).
    loaded_from: CacheSource,
    shards: Vec<RwLock<HashMap<u64, f64>>>,
    /// Maximum resident entries; `0` = unbounded.
    max_entries: usize,
    /// Resident entry count (kept in sync with the shards so the bound
    /// check never has to scan).
    count: AtomicUsize,
    /// Keys in first-insertion order — the eviction queue. Only
    /// [`insert`](ClipCache::insert) (sequential in the engine's resolve
    /// stage) and [`clear`](ClipCache::clear) touch it; the parallel
    /// scan stage's `contains`/`get` reads never take this lock.
    order: Mutex<VecDeque<u64>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for ClipCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ClipCache {
    /// An unbounded cache with the default shard count.
    pub fn new() -> ClipCache {
        ClipCache::with_shards(16)
    }

    /// A cache bounded to `max_entries` resident clips (`0` =
    /// unbounded). When an insert would exceed the bound, the
    /// **oldest-inserted** entries are evicted (and counted in
    /// [`CacheStats::evictions`]); the same trim runs before
    /// [`save`](ClipCache::save). Eviction order is insertion order, and
    /// the engine inserts sequentially in its deterministic resolve
    /// stage, so evictions are schedule-independent too.
    pub fn bounded(max_entries: usize) -> ClipCache {
        let mut c = ClipCache::new();
        c.max_entries = max_entries;
        c
    }

    /// A cache with `n` shards (rounded up to a power of two, min 1).
    pub fn with_shards(n: usize) -> ClipCache {
        let n = n.max(1).next_power_of_two();
        ClipCache {
            frozen: None,
            loaded_from: CacheSource::Cold,
            shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(),
            max_entries: 0,
            count: AtomicUsize::new(0),
            order: Mutex::new(VecDeque::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The configured entry bound (`0` = unbounded).
    pub fn max_entries(&self) -> usize {
        self.max_entries
    }

    /// Whether inserts may evict entries. The streamed engine — whose
    /// stage-3 inserts run concurrently with its scans — combines this
    /// with a worst-case headroom check to decide whether a scan's
    /// `contains` observation is **stable** until the merge resolves it;
    /// when it is not, scans keep payloads for cached keys too and the
    /// merge falls back to re-pricing from the run's own first-sighting
    /// payload. Evicting a cached clip that a later run (or benchmark)
    /// would have reused re-canonicalizes it to that run's first
    /// sighting — the same content-keyed rule a changed run composition
    /// already follows (see the module docs) — and shifts dedup
    /// accounting; it never orphans a clip or fails a run. The
    /// phase-barrier paths complete every read before any insert, so
    /// they never need the headroom check.
    pub fn may_evict(&self) -> bool {
        self.max_entries > 0
    }

    #[inline]
    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, f64>> {
        // Fibonacci-hash the key so shard choice is independent of any
        // structure in the FNV clip keys; shards.len() is a power of two.
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let i = (h >> 32) as usize & (self.shards.len() - 1);
        &self.shards[i]
    }

    /// Read-only membership probe (no stats side effects) — safe to call
    /// from the parallel scan stage. Consults the frozen tier first;
    /// frozen entries can never be evicted, so their `contains`
    /// observations are stable by construction.
    pub fn contains(&self, key: u64) -> bool {
        if let Some(f) = &self.frozen {
            if f.lookup(key).is_some() {
                return true;
            }
        }
        self.shard(key).read().unwrap().contains_key(&key)
    }

    /// Look up a predicted time; counts a hit or a miss. The frozen
    /// mmap tier answers first (lock-free), then the mutable shards.
    pub fn get(&self, key: u64) -> Option<f64> {
        if let Some(f) = &self.frozen {
            if let Some(v) = f.lookup(key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(v);
            }
        }
        let v = self.shard(key).read().unwrap().get(&key).copied();
        match v {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        v
    }

    /// Insert (or overwrite) a predicted time. A fresh key joins the
    /// back of the eviction queue; overwrites keep the key's original
    /// insertion age. May evict the oldest entries when a bound is set.
    /// Keys the frozen tier already serves are skipped: by the
    /// determinism contract the value is identical, and a mutable
    /// duplicate would only double-count and churn the eviction queue.
    pub fn insert(&self, key: u64, time: f64) {
        if let Some(f) = &self.frozen {
            if f.lookup(key).is_some() {
                return;
            }
        }
        let fresh = self.shard(key).write().unwrap().insert(key, time).is_none();
        if fresh {
            self.order.lock().unwrap().push_back(key);
            self.count.fetch_add(1, Ordering::Relaxed);
            self.enforce_bound();
        }
    }

    /// Evict oldest-inserted entries until the bound is respected.
    /// Shard locks are never held while waiting on the queue lock (and
    /// vice versa is take-then-release), so readers stay wait-free on
    /// disjoint shards.
    fn enforce_bound(&self) {
        if self.max_entries == 0 {
            return;
        }
        while self.count.load(Ordering::Relaxed) > self.max_entries {
            let oldest = self.order.lock().unwrap().pop_front();
            match oldest {
                Some(key) => {
                    if self.shard(key).write().unwrap().remove(&key).is_some() {
                        self.count.fetch_sub(1, Ordering::Relaxed);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
    }

    /// Number of cached unique clips across both tiers. (The mutable
    /// tier never duplicates a frozen key — `insert` skips those.)
    pub fn len(&self) -> usize {
        self.frozen_len() + self.shards.iter().map(|s| s.read().unwrap().len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries served by the frozen mmap tier (0 when absent or
    /// disabled). Reported before the lazy digest check runs — the
    /// header count — and drops to 0 if that check later fails.
    pub fn frozen_len(&self) -> usize {
        match &self.frozen {
            Some(f) if !f.dead() => f.n_visible,
            _ => 0,
        }
    }

    /// Where the persisted contents live *now*: a frozen tier that was
    /// disabled (failed digest, or [`clear`](ClipCache::clear)) reports
    /// [`CacheSource::Cold`] again.
    pub fn source(&self) -> CacheSource {
        match self.loaded_from {
            CacheSource::Frozen if self.frozen.as_ref().is_none_or(|f| f.dead()) => {
                CacheSource::Cold
            }
            s => s,
        }
    }

    /// Whether the frozen tier's bytes are a real shared mapping (vs the
    /// portable heap-read fallback inside [`Mmap`]). Reporting only.
    pub fn frozen_mapped(&self) -> bool {
        self.frozen.as_ref().is_some_and(|f| f.map.is_mapped())
    }

    /// Hit/miss/eviction counters accumulated so far.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Drop all entries **and** reset the counters: after a warm-start
    /// invalidation the cache reports a fresh hit rate instead of one
    /// skewed by lookups against the discarded contents. The frozen
    /// tier is permanently disabled (the mapping itself is read-only).
    pub fn clear(&self) {
        if let Some(f) = &self.frozen {
            f.kill();
        }
        for s in &self.shards {
            s.write().unwrap().clear();
        }
        self.order.lock().unwrap().clear();
        self.count.store(0, Ordering::Relaxed);
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
    }

    /// Snapshot of all entries across both tiers, sorted by key —
    /// deterministic bytes for [`save`](ClipCache::save) regardless of
    /// insertion or shard order. Should a key ever exist in both tiers,
    /// the mutable value wins (it is the newer write).
    pub fn entries(&self) -> Vec<(u64, f64)> {
        let mut out: Vec<(u64, f64)> = Vec::with_capacity(self.len());
        if let Some(f) = &self.frozen {
            for (k, v) in f.visible_entries() {
                if !self.shard(k).read().unwrap().contains_key(&k) {
                    out.push((k, v));
                }
            }
        }
        for s in &self.shards {
            out.extend(s.read().unwrap().iter().map(|(&k, &v)| (k, v)));
        }
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Persist the cache (both tiers merged) for cross-process warm
    /// starts, as a `CPIM` image: checksummed header keyed to one
    /// `(model fingerprint, time_scale, kernel contract)` combination,
    /// sorted 16-byte records, data digest. The size bound is enforced
    /// on the **snapshot**, so a bounded cache never persists more than
    /// `max_entries` clips even when inserts race the save.
    /// Writes a uniquely-named sibling temp file (pid + sequence — a
    /// fixed name would let two concurrent savers interleave writes and
    /// rename a torn image over the good cache), fsyncs it, and renames
    /// it into place, so a crashed or racing writer never leaves a
    /// half-written cache behind. Returns the number of entries saved.
    pub fn save(&self, path: &Path, fingerprint: u64, time_scale: f32) -> std::io::Result<usize> {
        self.enforce_bound();
        let mut entries = self.entries();
        // Inserts racing this save can grow the snapshot past the bound
        // between enforce_bound() and entries(); trim the snapshot itself
        // (key order — the same rule `load_bounded` applies to an
        // oversized file) so the promise holds under any schedule.
        if self.max_entries > 0 && entries.len() > self.max_entries {
            entries.truncate(self.max_entries);
        }
        let mut records = Vec::with_capacity(entries.len() * RECORD_STRIDE);
        for &(k, v) in &entries {
            records.extend_from_slice(&k.to_le_bytes());
            records.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        image::persist_atomic(path, |w| {
            image::write_image(
                w,
                &ImageSpec {
                    kind: image::KIND_CLIP_CACHE,
                    fingerprint,
                    kernel_contract: KERNEL_CONTRACT_VERSION,
                    time_scale_bits: time_scale.to_bits(),
                    meta: &[],
                    record_stride: RECORD_STRIDE as u32,
                    records: &records,
                    payload: &[],
                },
            )
        })?;
        Ok(entries.len())
    }

    /// The **legacy v1** (`CPLC`) writer, retained only so tests and the
    /// persist bench can prove the one-time migration path: v1 files
    /// still load (into the heap tier) for one release, after which
    /// every save re-emits the image format above.
    pub fn save_legacy_v1(
        &self,
        path: &Path,
        fingerprint: u64,
        time_scale: f32,
    ) -> std::io::Result<usize> {
        self.enforce_bound();
        let mut entries = self.entries();
        if self.max_entries > 0 && entries.len() > self.max_entries {
            entries.truncate(self.max_entries);
        }
        image::persist_atomic(path, |w| {
            w.write_all(&FILE_MAGIC.to_le_bytes())?;
            w.write_all(&FILE_VERSION.to_le_bytes())?;
            w.write_all(&fingerprint.to_le_bytes())?;
            w.write_all(&time_scale.to_bits().to_le_bytes())?;
            w.write_all(&(entries.len() as u64).to_le_bytes())?;
            for &(k, v) in &entries {
                w.write_all(&k.to_le_bytes())?;
                w.write_all(&v.to_bits().to_le_bytes())?;
            }
            Ok(())
        })?;
        Ok(entries.len())
    }

    /// Load a persisted cache, verifying the checksummed header and the
    /// `(fingerprint, time_scale, kernel contract)` key. A `CPIM` image
    /// becomes the frozen mmap tier (O(1), zero-copy); a legacy `CPLC`
    /// v1 file is parsed into the mutable tier (one-time migration).
    /// Corrupt, truncated, or mismatched files return `Err` with the
    /// offending path in the message (callers cold-start; see
    /// [`load_or_cold`](ClipCache::load_or_cold)). The loaded cache is
    /// unbounded; use [`load_bounded`](ClipCache::load_bounded) to apply
    /// an entry bound.
    pub fn load(path: &Path, fingerprint: u64, time_scale: f32) -> std::io::Result<ClipCache> {
        Self::load_bounded(path, fingerprint, time_scale, 0)
    }

    /// [`load`](ClipCache::load) into a cache bounded to `max_entries`
    /// (`0` = unbounded). A file holding more than `max_entries` clips
    /// is trimmed during the load: the frozen tier exposes a key-order
    /// prefix; a legacy file replays its inserts under the bound.
    pub fn load_bounded(
        path: &Path,
        fingerprint: u64,
        time_scale: f32,
        max_entries: usize,
    ) -> std::io::Result<ClipCache> {
        Self::load_image(path, fingerprint, time_scale, max_entries, true)
    }

    /// [`load_bounded`](ClipCache::load_bounded) forced onto the heap:
    /// image records are digest-verified eagerly and copied into the
    /// mutable tier instead of being mmap-frozen. This is the
    /// `cache_mmap = false` escape hatch and the oracle the equivalence
    /// tests compare the frozen tier against.
    pub fn load_heap_bounded(
        path: &Path,
        fingerprint: u64,
        time_scale: f32,
        max_entries: usize,
    ) -> std::io::Result<ClipCache> {
        Self::load_image(path, fingerprint, time_scale, max_entries, false)
    }

    fn load_image(
        path: &Path,
        fingerprint: u64,
        time_scale: f32,
        max_entries: usize,
        frozen_tier: bool,
    ) -> std::io::Result<ClipCache> {
        let bad = |msg: &str| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{}: {msg}", path.display()),
            )
        };
        let map = Mmap::open(path).map_err(|e| {
            std::io::Error::new(e.kind(), format!("{}: {e}", path.display()))
        })?;
        let parsed = {
            let bytes = map.bytes();
            if bytes.len() >= 8
                && u32::from_le_bytes(bytes[0..4].try_into().unwrap()) == FILE_MAGIC
            {
                return Self::load_legacy_v1(path, bytes, fingerprint, time_scale, max_entries);
            }
            let view = ImageView::parse(bytes).map_err(|m| bad(&m))?;
            if view.kind != image::KIND_CLIP_CACHE {
                return Err(bad("image is not a clip cache"));
            }
            if view.record_stride as usize != RECORD_STRIDE {
                return Err(bad("unexpected clip-cache record stride"));
            }
            if view.fingerprint != fingerprint {
                return Err(bad("model fingerprint mismatch"));
            }
            if view.time_scale_bits != time_scale.to_bits() {
                return Err(bad("time_scale mismatch"));
            }
            if view.kernel_contract != KERNEL_CONTRACT_VERSION {
                return Err(bad("kernel contract version mismatch"));
            }
            let n_total = view.n_records as usize;
            let n_visible = if max_entries > 0 { n_total.min(max_entries) } else { n_total };
            if !frozen_tier {
                // heap mode: pay the O(entries) digest + copy up front
                if !view.verify_data() {
                    return Err(bad("data digest mismatch"));
                }
                let mut cache = ClipCache::bounded(max_entries);
                cache.loaded_from = CacheSource::Heap;
                for i in 0..n_visible {
                    let r = view.record(i);
                    cache.insert(
                        u64::from_le_bytes(r[0..8].try_into().unwrap()),
                        f64::from_bits(u64::from_le_bytes(r[8..16].try_into().unwrap())),
                    );
                }
                cache.reset_counters();
                return Ok(cache);
            }
            let base = bytes.as_ptr() as usize;
            (
                view.records.as_ptr() as usize - base,
                n_total,
                n_visible,
                view.payload.as_ptr() as usize - base,
                view.payload.len(),
                view.data_digest,
            )
        };
        let (records_off, n_total, n_visible, payload_off, payload_len, data_digest) = parsed;
        let mut cache = ClipCache::bounded(max_entries);
        cache.loaded_from = CacheSource::Frozen;
        cache.frozen = Some(Frozen {
            map,
            records_off,
            n_total,
            n_visible,
            payload_off,
            payload_len,
            data_digest,
            verify: Once::new(),
            state: AtomicU8::new(FROZEN_UNVERIFIED),
        });
        Ok(cache)
    }

    /// Parse the legacy `CPLC` v1 byte layout into the mutable tier.
    /// This path exists for exactly one release: the next save re-emits
    /// the image format, completing the migration.
    fn load_legacy_v1(
        path: &Path,
        bytes: &[u8],
        fingerprint: u64,
        time_scale: f32,
        max_entries: usize,
    ) -> std::io::Result<ClipCache> {
        let bad = |msg: &str| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{}: {msg}", path.display()),
            )
        };
        let u32_at = |o: usize| {
            bytes.get(o..o + 4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
        };
        let u64_at = |o: usize| {
            bytes.get(o..o + 8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
        };
        if u32_at(4) != Some(FILE_VERSION) {
            return Err(bad("unsupported clip-cache version"));
        }
        if u64_at(8) != Some(fingerprint) {
            return Err(bad("model fingerprint mismatch"));
        }
        if u32_at(16) != Some(time_scale.to_bits()) {
            return Err(bad("time_scale mismatch"));
        }
        let n = u64_at(20).ok_or_else(|| bad("truncated clip-cache file"))? as usize;
        let body = &bytes[28.min(bytes.len())..];
        if n.checked_mul(RECORD_STRIDE).is_none_or(|need| body.len() < need) {
            return Err(bad("truncated clip-cache file"));
        }
        let mut cache = ClipCache::bounded(max_entries);
        cache.loaded_from = CacheSource::Heap;
        for i in 0..n {
            let off = i * RECORD_STRIDE;
            cache.insert(
                u64::from_le_bytes(body[off..off + 8].try_into().unwrap()),
                f64::from_bits(u64::from_le_bytes(body[off + 8..off + 16].try_into().unwrap())),
            );
        }
        cache.reset_counters();
        Ok(cache)
    }

    /// Loading is plumbing, not cache traffic: start the counters fresh
    /// (evictions included) so stats describe the run ahead.
    fn reset_counters(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
    }

    /// [`load`](ClipCache::load) with a cold-start fallback: a missing,
    /// corrupt, or mismatched-key file yields a fresh empty cache.
    /// Returns `(cache, warm)` where `warm` says the load succeeded.
    pub fn load_or_cold(path: &Path, fingerprint: u64, time_scale: f32) -> (ClipCache, bool) {
        Self::load_or_cold_bounded(path, fingerprint, time_scale, 0)
    }

    /// [`load_bounded`](ClipCache::load_bounded) with the same
    /// cold-start fallback; the fallback cache carries the bound too.
    /// When a file exists but is unusable, the (path-carrying) reason is
    /// logged to stderr so the cold start is actionable instead of
    /// silent; a merely missing file stays quiet.
    pub fn load_or_cold_bounded(
        path: &Path,
        fingerprint: u64,
        time_scale: f32,
        max_entries: usize,
    ) -> (ClipCache, bool) {
        Self::load_or_cold_bounded_with(path, fingerprint, time_scale, max_entries, true)
    }

    /// [`load_or_cold_bounded`](ClipCache::load_or_cold_bounded) with an
    /// explicit residency choice: `mmap = false` forces the heap tier
    /// (the `cache_mmap = false` / `--cache-heap` escape hatch).
    pub fn load_or_cold_bounded_with(
        path: &Path,
        fingerprint: u64,
        time_scale: f32,
        max_entries: usize,
        mmap: bool,
    ) -> (ClipCache, bool) {
        let loaded = if mmap {
            Self::load_bounded(path, fingerprint, time_scale, max_entries)
        } else {
            Self::load_heap_bounded(path, fingerprint, time_scale, max_entries)
        };
        match loaded {
            Ok(c) => (c, true),
            Err(e) => {
                if e.kind() != std::io::ErrorKind::NotFound {
                    eprintln!("warning: cold-starting clip cache: {e}");
                }
                (ClipCache::bounded(max_entries), false)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let c = ClipCache::new();
        assert!(!c.contains(42));
        assert_eq!(c.get(42), None);
        c.insert(42, 123.5);
        assert!(c.contains(42));
        assert_eq!(c.get(42), Some(123.5));
        assert_eq!(c.len(), 1);
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
    }

    #[test]
    fn shards_cover_key_space() {
        let c = ClipCache::with_shards(4);
        for k in 0..1000u64 {
            c.insert(k.wrapping_mul(0x1234_5678_9ABC_DEF1), k as f64);
        }
        assert_eq!(c.len(), 1000);
        // every shard should have received a share
        for s in &c.shards {
            assert!(!s.read().unwrap().is_empty());
        }
    }

    #[test]
    fn concurrent_reads_while_inserting_elsewhere() {
        let c = ClipCache::new();
        for k in 0..64u64 {
            c.insert(k, k as f64);
        }
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for k in 0..64u64 {
                        assert!(c.contains(k));
                    }
                });
            }
        });
        assert_eq!(c.len(), 64);
    }

    #[test]
    fn clear_resets_contents_and_counters() {
        let c = ClipCache::new();
        c.insert(1, 2.0);
        let _ = c.get(1);
        let _ = c.get(2);
        assert_eq!((c.stats().hits, c.stats().misses), (1, 1));
        c.clear();
        assert!(c.is_empty());
        // hit-rate reporting after a warm-start invalidation starts fresh
        assert_eq!(c.stats(), CacheStats::default());
        assert_eq!(c.stats().hit_rate(), 0.0);
    }

    #[test]
    fn save_load_roundtrip_with_matching_key() {
        let dir = std::env::temp_dir().join("capsim_cache_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        for k in 0..300u64 {
            c.insert(k.wrapping_mul(0x9E37_79B9_7F4A_7C15), k as f64 * 1.5 + 0.25);
        }
        let saved = c.save(&path, 0xFEED_BEEF, 40.0).unwrap();
        assert_eq!(saved, 300);
        let loaded = ClipCache::load(&path, 0xFEED_BEEF, 40.0).unwrap();
        assert_eq!(loaded.len(), c.len());
        assert_eq!(loaded.entries(), c.entries(), "values survive bit-exactly");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_refuses_mismatched_key_or_garbage() {
        let dir = std::env::temp_dir().join("capsim_cache_mismatch");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        c.insert(7, 1.25);
        c.save(&path, 1234, 40.0).unwrap();
        assert!(ClipCache::load(&path, 4321, 40.0).is_err(), "fingerprint mismatch");
        assert!(ClipCache::load(&path, 1234, 41.0).is_err(), "time_scale mismatch");
        assert!(ClipCache::load(&path, 1234, 40.0).is_ok());
        // corrupt / truncated files fall back cold
        std::fs::write(&path, b"not a cache").unwrap();
        let (cold, warm) = ClipCache::load_or_cold(&path, 1234, 40.0);
        assert!(!warm && cold.is_empty());
        // missing file falls back cold too
        let (cold, warm) = ClipCache::load_or_cold(&dir.join("absent.bin"), 1234, 40.0);
        assert!(!warm && cold.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_is_deterministic_across_insertion_orders() {
        let dir = std::env::temp_dir().join("capsim_cache_det");
        std::fs::create_dir_all(&dir).unwrap();
        let (pa, pb) = (dir.join("a.bin"), dir.join("b.bin"));
        let a = ClipCache::new();
        let b = ClipCache::new();
        for k in 0..100u64 {
            a.insert(k, k as f64);
            b.insert(99 - k, (99 - k) as f64);
        }
        a.save(&pa, 1, 2.0).unwrap();
        b.save(&pb, 1, 2.0).unwrap();
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }

    #[test]
    fn bounded_cache_evicts_oldest_inserted() {
        let c = ClipCache::bounded(3);
        for k in 1..=5u64 {
            c.insert(k, k as f64);
        }
        assert_eq!(c.len(), 3);
        assert!(!c.contains(1) && !c.contains(2), "oldest two evicted");
        assert!(c.contains(3) && c.contains(4) && c.contains(5));
        assert_eq!(c.stats().evictions, 2);
        // an evicted key can come back; the now-oldest entry makes room
        c.insert(1, 10.0);
        assert!(c.contains(1) && !c.contains(3));
        assert_eq!(c.stats().evictions, 3);
    }

    #[test]
    fn overwrite_keeps_age_and_never_evicts() {
        let c = ClipCache::bounded(2);
        c.insert(7, 1.0);
        c.insert(8, 2.0);
        c.insert(7, 3.0); // overwrite: no growth, no eviction
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.get(7), Some(3.0));
        // 7 kept its original (oldest) insertion age, so it goes first
        c.insert(9, 4.0);
        assert!(!c.contains(7) && c.contains(8) && c.contains(9));
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let c = ClipCache::new();
        assert_eq!(c.max_entries(), 0);
        for k in 0..5_000u64 {
            c.insert(k, k as f64);
        }
        assert_eq!(c.len(), 5_000);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn save_respects_the_bound_and_bounded_load_trims() {
        let dir = std::env::temp_dir().join("capsim_cache_bound_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::bounded(10);
        for k in 0..25u64 {
            c.insert(k, k as f64);
        }
        let saved = c.save(&path, 1, 2.0).unwrap();
        assert_eq!(saved, 10, "save never persists beyond the bound");
        // loading into a smaller bound trims during the load and starts
        // the counters fresh
        let small = ClipCache::load_bounded(&path, 1, 2.0, 4).unwrap();
        assert_eq!(small.len(), 4);
        assert_eq!(small.stats(), CacheStats::default());
        // cold-start fallback carries the bound
        let (cold, warm) = ClipCache::load_or_cold_bounded(&path, 999, 2.0, 4);
        assert!(!warm && cold.is_empty());
        assert_eq!(cold.max_entries(), 4);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clear_resets_eviction_state() {
        let c = ClipCache::bounded(2);
        for k in 0..5u64 {
            c.insert(k, k as f64);
        }
        assert!(c.stats().evictions > 0);
        c.clear();
        assert_eq!(c.stats(), CacheStats::default());
        // the eviction queue was cleared too: refilling works cleanly
        c.insert(1, 1.0);
        c.insert(2, 2.0);
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 0);
    }

    /// Concurrent saves to one path must never produce a torn file: each
    /// writer gets a unique temp file, so every rename publishes one
    /// writer's complete image. Pre-fix, the shared `clips.tmp` sibling
    /// let writers interleave bytes (corrupt loads) or race the rename
    /// (spurious `NotFound` save errors).
    #[test]
    fn concurrent_saves_to_one_path_never_corrupt_it() {
        let dir = std::env::temp_dir().join("capsim_cache_save_race");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let counts = [50usize, 100, 150, 200];
        let caches: Vec<ClipCache> = counts
            .iter()
            .map(|&n| {
                let c = ClipCache::new();
                for k in 0..n as u64 {
                    c.insert(k, k as f64 + 0.5);
                }
                c
            })
            .collect();
        caches[0].save(&path, 77, 4.0).unwrap();
        std::thread::scope(|s| {
            for c in &caches {
                let path = &path;
                s.spawn(move || {
                    for _ in 0..10 {
                        c.save(path, 77, 4.0).unwrap();
                    }
                });
            }
            for _ in 0..50 {
                let loaded = ClipCache::load(&path, 77, 4.0).unwrap();
                assert!(
                    counts.contains(&loaded.len()),
                    "load saw a torn image: {} entries",
                    loaded.len()
                );
            }
        });
        let loaded = ClipCache::load(&path, 77, 4.0).unwrap();
        assert!(counts.contains(&loaded.len()));
        let _ = std::fs::remove_file(&path);
    }

    /// Same-stem caches with different extensions must not share a temp
    /// file (`with_extension("tmp")` folded `clips.cache` and
    /// `clips.other` onto one `clips.tmp`); and no `.tmp` litter may
    /// survive a successful save.
    #[test]
    fn sibling_caches_with_distinct_extensions_do_not_collide() {
        let dir = std::env::temp_dir().join("capsim_cache_ext_collide");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let pa = dir.join("clips.cache");
        let pb = dir.join("clips.other");
        let a = ClipCache::new();
        let b = ClipCache::new();
        for k in 0..100u64 {
            a.insert(k, k as f64);
        }
        for k in 0..200u64 {
            b.insert(k, k as f64 * 2.0);
        }
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..10 {
                    a.save(&pa, 5, 1.0).unwrap();
                }
            });
            s.spawn(|| {
                for _ in 0..10 {
                    b.save(&pb, 5, 1.0).unwrap();
                }
            });
        });
        let la = ClipCache::load(&pa, 5, 1.0).unwrap();
        let lb = ClipCache::load(&pb, 5, 1.0).unwrap();
        assert_eq!(la.len(), 100);
        assert_eq!(lb.len(), 200);
        assert_eq!(la.entries(), a.entries());
        assert_eq!(lb.entries(), b.entries());
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name();
            let name = name.to_string_lossy().into_owned();
            assert!(!name.ends_with(".tmp"), "leftover temp file {name}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The bound holds on the persisted file even when inserts race the
    /// save: the snapshot itself is trimmed, not just the live map.
    #[test]
    fn bounded_save_never_exceeds_bound_under_racing_inserts() {
        let dir = std::env::temp_dir().join("capsim_cache_bound_race");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let bound = 64usize;
        let c = ClipCache::bounded(bound);
        let finished = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..3u64 {
                let c = &c;
                let finished = &finished;
                s.spawn(move || {
                    let mut rng = crate::util::Rng::new(0xBEEF ^ t);
                    for _ in 0..2_000 {
                        c.insert(rng.next_u64(), 1.0);
                    }
                    finished.fetch_add(1, Ordering::Relaxed);
                });
            }
            // save continuously while the inserters hammer the cache
            while finished.load(Ordering::Relaxed) < 3 {
                let saved = c.save(&path, 11, 2.5).unwrap();
                assert!(saved <= bound, "save persisted {saved} > bound {bound}");
            }
        });
        let saved = c.save(&path, 11, 2.5).unwrap();
        assert!(saved <= bound);
        let loaded = ClipCache::load(&path, 11, 2.5).unwrap();
        assert!(loaded.len() <= bound);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn hit_rate() {
        let c = ClipCache::new();
        c.insert(7, 1.0);
        let _ = c.get(7);
        let _ = c.get(8);
        let st = c.stats();
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(st.lookups(), 2);
        assert_eq!(st.hit_line(), "50.0% (1 hits / 2 lookups)");
    }

    #[test]
    fn frozen_and_heap_loads_serve_bit_identical_values() {
        let dir = std::env::temp_dir().join("capsim_cache_frozen_eq");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        for k in 0..500u64 {
            c.insert(k.wrapping_mul(0x9E37_79B9_7F4A_7C15), k as f64 * 0.125 - 3.0);
        }
        c.save(&path, 9, 40.0).unwrap();
        let frozen = ClipCache::load(&path, 9, 40.0).unwrap();
        let heap = ClipCache::load_heap_bounded(&path, 9, 40.0, 0).unwrap();
        assert_eq!(frozen.source(), CacheSource::Frozen);
        assert_eq!(heap.source(), CacheSource::Heap);
        assert_eq!(frozen.frozen_len(), 500);
        assert_eq!(heap.frozen_len(), 0);
        for (k, v) in c.entries() {
            assert_eq!(frozen.get(k).map(f64::to_bits), Some(v.to_bits()));
            assert_eq!(heap.get(k).map(f64::to_bits), Some(v.to_bits()));
        }
        assert_eq!(frozen.entries(), heap.entries());
        assert_eq!(frozen.get(1), None, "absent keys miss in the frozen tier");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn insert_skips_frozen_keys_and_merged_save_roundtrips() {
        let dir = std::env::temp_dir().join("capsim_cache_frozen_merge");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        c.insert(1, 1.0);
        c.insert(2, 2.0);
        c.save(&path, 5, 2.0).unwrap();
        let warm = ClipCache::load(&path, 5, 2.0).unwrap();
        // the determinism contract says a frozen key's value is already
        // canonical; a racing re-insert must not shadow it
        warm.insert(1, 99.0);
        assert_eq!(warm.get(1), Some(1.0));
        warm.insert(50, 5.5);
        assert_eq!(warm.get(50), Some(5.5));
        assert_eq!(warm.len(), 3);
        assert_eq!(warm.entries(), vec![(1, 1.0), (2, 2.0), (50, 5.5)]);
        // a merged save re-freezes both tiers' entries
        let merged = dir.join("merged.bin");
        assert_eq!(warm.save(&merged, 5, 2.0).unwrap(), 3);
        let reloaded = ClipCache::load(&merged, 5, 2.0).unwrap();
        assert_eq!(reloaded.source(), CacheSource::Frozen);
        assert_eq!(reloaded.entries(), warm.entries());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&merged);
    }

    #[test]
    fn bounded_image_load_exposes_a_key_order_prefix() {
        let dir = std::env::temp_dir().join("capsim_cache_frozen_bound");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        for k in 0..20u64 {
            c.insert(k, k as f64);
        }
        c.save(&path, 1, 2.0).unwrap();
        let small = ClipCache::load_bounded(&path, 1, 2.0, 5).unwrap();
        assert_eq!(small.frozen_len(), 5);
        assert_eq!(small.len(), 5);
        assert_eq!(small.entries(), (0..5).map(|k| (k as u64, k as f64)).collect::<Vec<_>>());
        assert_eq!(small.get(7), None, "beyond the bound is invisible");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clear_kills_the_frozen_tier() {
        let dir = std::env::temp_dir().join("capsim_cache_frozen_clear");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        c.insert(3, 3.0);
        c.save(&path, 2, 4.0).unwrap();
        let warm = ClipCache::load(&path, 2, 4.0).unwrap();
        assert_eq!(warm.source(), CacheSource::Frozen);
        warm.clear();
        assert!(warm.is_empty());
        assert_eq!(warm.frozen_len(), 0);
        assert_eq!(warm.source(), CacheSource::Cold);
        assert_eq!(warm.get(3), None);
        // the dead tier no longer shadows inserts
        warm.insert(3, 30.0);
        assert_eq!(warm.get(3), Some(30.0));
        let _ = std::fs::remove_file(&path);
    }

    /// A bit flip in the records section passes the O(1) header check
    /// (by design — the open path is size-independent) but the one-time
    /// digest check on first use must disable the tier: every lookup
    /// misses, nothing ever serves a wrong value.
    #[test]
    fn corrupt_records_degrade_to_misses_never_wrong_values() {
        let dir = std::env::temp_dir().join("capsim_cache_frozen_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        for k in 0..100u64 {
            c.insert(k, k as f64 + 0.5);
        }
        c.save(&path, 8, 16.0).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let records_off = u64::from_le_bytes(bytes[48..56].try_into().unwrap()) as usize;
        bytes[records_off + 8] ^= 0x01; // flip one value bit of record 0
        std::fs::write(&path, &bytes).unwrap();
        let warm = ClipCache::load(&path, 8, 16.0).unwrap();
        assert_eq!(warm.source(), CacheSource::Frozen, "open is O(1), digest is deferred");
        for k in 0..100u64 {
            assert_eq!(warm.get(k), None, "a corrupt tier must miss, not serve garbage");
        }
        assert_eq!(warm.source(), CacheSource::Cold);
        assert_eq!(warm.frozen_len(), 0);
        assert!(warm.entries().is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_v1_cache_loads_once_then_migrates_to_the_image_format() {
        let dir = std::env::temp_dir().join("capsim_cache_legacy");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("clip_cache.bin");
        let c = ClipCache::new();
        for k in 0..50u64 {
            c.insert(k * 3, k as f64 * 0.5);
        }
        c.save_legacy_v1(&path, 3, 7.5).unwrap();
        let loaded = ClipCache::load(&path, 3, 7.5).unwrap();
        assert_eq!(loaded.source(), CacheSource::Heap);
        assert!(!loaded.frozen_mapped());
        assert_eq!(loaded.frozen_len(), 0);
        assert_eq!(loaded.entries(), c.entries());
        // the identity key still guards the legacy format
        assert!(ClipCache::load(&path, 4, 7.5).is_err(), "fingerprint mismatch");
        assert!(ClipCache::load(&path, 3, 8.5).is_err(), "time_scale mismatch");
        // the next save re-emits the image format, completing migration
        loaded.save(&path, 3, 7.5).unwrap();
        assert_eq!(image::peek_format(&path).unwrap(), (image::IMAGE_MAGIC, image::IMAGE_VERSION));
        let migrated = ClipCache::load(&path, 3, 7.5).unwrap();
        assert_eq!(migrated.source(), CacheSource::Frozen);
        assert_eq!(migrated.entries(), c.entries());
        let _ = std::fs::remove_file(&path);
    }
}
