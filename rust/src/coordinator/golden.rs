//! Golden-label dataset construction (Fig. 2): SimPoint → functional
//! trace → O3 commit times → Algorithm-1 slicing → Fig.-5 tokenization +
//! Fig.-6 context annotation.

use crate::config::{PipelineConfig, TrainSlicing};
use crate::context::{context_tokens, REGISTER_SPEC};
use crate::dataset::{ClipSample, Dataset};
use crate::isa::RegFile;
use crate::o3::O3Core;
use crate::simpoint::{choose_simpoints, profile, Checkpoint, SelectedInterval};
use crate::slicer::{slice_fixed_labeled, slice_labeled};
use crate::tokenizer::standardize::{clip_key, tokenize_clip};
use crate::workloads::Benchmark;

/// Per-benchmark SimPoint outcome (Table II's row: checkpoint count etc.).
pub struct BenchProfile {
    pub name: &'static str,
    pub set_no: u8,
    pub tag_string: String,
    /// Total intervals in the profile.
    pub n_intervals: usize,
    /// Selected representative intervals (the "CKP Num" of Table II).
    pub selected: Vec<SelectedInterval>,
    /// Whole-program dynamic instruction count.
    pub total_insts: u64,
}

/// Model geometry constants the dataset must match (kept in lock-step
/// with `model_config.json`; the runtime re-validates at load, and
/// [`runtime::default_geometry`](crate::runtime::default_geometry) —
/// the shape every registry backend shares — is built from them).
pub const L_TOKEN: usize = 16;
pub const L_CLIP: usize = 32;

/// Build the labelled dataset for one benchmark. Returns the samples and
/// the SimPoint profile (reused later by the mode runners).
pub fn build_bench_dataset(
    bench_idx: usize,
    bench: &Benchmark,
    cfg: &PipelineConfig,
) -> (Dataset, BenchProfile) {
    let mut ds = Dataset::new(L_TOKEN, L_CLIP, crate::context::M_ROWS);
    let prof = profile(&bench.program, &cfg.simpoint);
    let selected = choose_simpoints(&prof, &cfg.simpoint);

    let mut core = O3Core::new(cfg.o3.clone());
    for sel in &selected {
        // functional replay: warmup + interval
        let mut cpu = sel.checkpoint.restore();
        let warm = cfg.simpoint.warmup_insts;
        let total = warm + cfg.simpoint.interval_insts;
        let trace = cpu.run_trace(total);
        if trace.len() <= warm as usize {
            continue; // program ended inside warmup
        }

        // golden timing (cold microarch state per restore, like gem5)
        core.reset();
        let o3 = core.simulate(&trace);

        // slicing over the measured (post-warmup) portion
        let w = warm as usize;
        let interval_cc = &o3.commit_cycle[w..];
        let clips = match cfg.train_slicing {
            TrainSlicing::Algo1 => {
                slice_labeled(trace.len() - w, interval_cc, cfg.l_min)
            }
            TrainSlicing::Fixed => slice_fixed_labeled(interval_cc, cfg.l_min),
        };

        // capture context register snapshots at clip starts
        let starts: Vec<usize> = clips.iter().map(|c| w + c.start).collect();
        let ctxs = snapshots_at(&sel.checkpoint, &starts);

        for (clip, ctx_regs) in clips.iter().zip(&ctxs) {
            let recs = &trace[w + clip.start..w + clip.start + clip.len];
            let tokens = tokenize_clip(recs, L_TOKEN);
            let key = clip_key(&tokens);
            ds.push(ClipSample {
                len: clip.len as u16,
                tokens,
                ctx: context_tokens(ctx_regs, &REGISTER_SPEC),
                time: clip.time as f32,
                key,
                bench: bench_idx as u16,
            });
        }
    }

    let bp = BenchProfile {
        name: bench.name,
        set_no: bench.set_no,
        tag_string: bench.tag_string(),
        n_intervals: prof.intervals.len(),
        selected,
        total_insts: prof.total_insts,
    };
    (ds, bp)
}

/// Replay from a checkpoint and snapshot the register file just before
/// executing the instruction at each (sorted, ascending) dynamic index.
pub fn snapshots_at(ck: &Checkpoint, starts: &[usize]) -> Vec<RegFile> {
    let mut cpu = ck.restore();
    let mut out = Vec::with_capacity(starts.len());
    let mut executed: usize = 0;
    for &s in starts {
        debug_assert!(s >= executed);
        while executed < s && !cpu.halted {
            cpu.step();
            executed += 1;
        }
        out.push(cpu.regs.clone());
    }
    out
}

/// Build the full-suite dataset (merging per-benchmark datasets in suite
/// order) plus the per-benchmark profiles. `threads` parallelizes across
/// benchmarks through the same streaming stage graph the suite engines
/// use ([`stream::ordered_stream`](super::stream)): O3 golden-label jobs
/// fan out over the worker pool and a sequence-ordered merge folds each
/// benchmark's dataset in as soon as it (and all its predecessors) are
/// done, while later benchmarks are still simulating. The bounded
/// channel keeps at most a few finished datasets in flight, and the
/// merged result is byte-identical for every thread count.
pub fn build_dataset(
    benches: &[Benchmark],
    cfg: &PipelineConfig,
    threads: usize,
) -> (Dataset, Vec<BenchProfile>) {
    let jobs: Vec<(usize, &Benchmark)> = benches.iter().enumerate().collect();
    let mut all = Dataset::new(L_TOKEN, L_CLIP, crate::context::M_ROWS);
    let mut profiles = Vec::new();
    super::stream::ordered_stream(
        jobs,
        threads,
        threads.max(1) * 2,
        |(i, b)| build_bench_dataset(i, b, cfg),
        |_seq, (ds, bp)| {
            all.dropped_long += ds.dropped_long;
            all.samples.extend(ds.samples);
            profiles.push(bp);
        },
    );
    (all, profiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{suite, Scale};

    fn test_cfg() -> PipelineConfig {
        let mut c = PipelineConfig::default();
        c.simpoint.interval_insts = 8_000;
        c.simpoint.warmup_insts = 1_000;
        c.simpoint.max_k = 3;
        c.l_min = 24;
        c
    }

    #[test]
    fn single_bench_dataset_has_valid_samples() {
        let benches = suite(Scale::Test);
        let cfg = test_cfg();
        let (ds, bp) = build_bench_dataset(0, &benches[0], &cfg);
        assert!(!ds.is_empty(), "perlbench analog must yield clips");
        assert!(bp.n_intervals >= 1);
        assert!(!bp.selected.is_empty());
        for s in &ds.samples {
            assert!(s.len as usize >= cfg.l_min);
            assert!(s.len as usize <= L_CLIP);
            assert!(s.time >= 1.0, "clip time must be positive cycles");
            assert_eq!(s.ctx.len(), crate::context::M_ROWS);
            assert_eq!(s.tokens.len(), s.len as usize * L_TOKEN);
            assert_eq!(s.bench, 0);
        }
    }

    #[test]
    fn snapshots_match_direct_replay() {
        let benches = suite(Scale::Test);
        let cfg = test_cfg();
        let prof = profile(&benches[2].program, &cfg.simpoint);
        let sel = choose_simpoints(&prof, &cfg.simpoint);
        let ck = &sel[0].checkpoint;
        let snaps = snapshots_at(ck, &[0, 10, 50]);
        assert_eq!(snaps[0], ck.regs);
        // direct replay to 10
        let mut cpu = ck.restore();
        for _ in 0..10 {
            cpu.step();
        }
        assert_eq!(snaps[1], cpu.regs);
    }

    #[test]
    fn contexts_differ_across_clips() {
        let benches = suite(Scale::Test);
        let cfg = test_cfg();
        let (ds, _) = build_bench_dataset(3, &benches[3], &cfg);
        assert!(ds.len() >= 2);
        // at least some pair of samples must have different contexts
        // (registers evolve across a real program)
        let distinct = ds
            .samples
            .windows(2)
            .filter(|w| w[0].ctx != w[1].ctx)
            .count();
        assert!(distinct > 0, "contexts should evolve");
    }

    #[test]
    fn build_dataset_is_thread_count_invariant() {
        // the streamed merge folds benchmarks in sequence order, so the
        // dataset bytes must not depend on worker scheduling
        let benches: Vec<_> = suite(Scale::Test).into_iter().take(4).collect();
        let cfg = test_cfg();
        let (a, pa) = build_dataset(&benches, &cfg, 1);
        let (b, pb) = build_dataset(&benches, &cfg, 4);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.dropped_long, b.dropped_long);
        assert_eq!(pa.len(), pb.len());
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.n_intervals, y.n_intervals);
            assert_eq!(x.selected.len(), y.selected.len());
        }
    }

    #[test]
    fn multi_bench_merge_keeps_indices() {
        let benches: Vec<_> = suite(Scale::Test).into_iter().take(3).collect();
        let cfg = test_cfg();
        let (ds, profiles) = build_dataset(&benches, &cfg, 2);
        assert_eq!(profiles.len(), 3);
        let mut seen = std::collections::HashSet::new();
        for s in &ds.samples {
            seen.insert(s.bench);
        }
        assert!(seen.contains(&0) && seen.contains(&1) && seen.contains(&2));
    }
}
