//! The CAPSim coordinator — the paper's Fig.-1 workflow, both sides:
//!
//! * **gem5 mode** (left): restore every SimPoint checkpoint into the O3
//!   cycle-level model and measure interval cycles — slow but golden;
//! * **CAPSim mode** (right): restore the same checkpoints into the fast
//!   functional simulator, slice the trace into clips, annotate with the
//!   register context, and predict clip times with the AOT-compiled
//!   attention model, summing to interval estimates.
//!
//! [`golden`] builds the labelled training dataset (functional trace + O3
//! commit times + Algorithm-1 slicing + Fig.-5/6 tokenization);
//! [`modes`] runs the two modes and the Fig.-7 wall-clock comparison;
//! [`pool`] is the std-thread worker pool used to parallelize independent
//! per-benchmark work (the offline crate set has no rayon).

pub mod golden;
pub mod modes;
pub mod pool;

pub use golden::{build_dataset, build_bench_dataset, BenchProfile};
pub use modes::{capsim_mode, gem5_mode, CapsimRun, Gem5Run};
pub use pool::parallel_map;
