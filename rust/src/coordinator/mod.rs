//! The CAPSim coordinator — the paper's Fig.-1 workflow, both sides, run
//! by a sharded parallel engine:
//!
//! * **gem5 mode** (left): restore every SimPoint checkpoint into the O3
//!   cycle-level model and measure interval cycles — slow but golden;
//! * **CAPSim mode** (right): restore the same checkpoints into the fast
//!   functional simulator, slice the trace into clips, annotate with the
//!   register context, and predict clip times with the attention model,
//!   summing to interval estimates.
//!
//! Both modes fan per-interval work out over [`pool`] (the `threads` knob
//! of `PipelineConfig`) with a deterministic input-order merge, so
//! multi-threaded results are bit-identical to `threads = 1`. [`cache`]
//! holds the cross-benchmark clip cache that dedups identical clips across
//! the whole suite — and can persist to disk, keyed by model fingerprint +
//! `time_scale`, for warm starts across processes; [`engine`] drives
//! entire suites through one shared cache (and can fill inference batches
//! across benchmark boundaries); [`stream`] is the streaming
//! stage-pipelined engine that overlaps scan/tokenize, batch fill and
//! inference as concurrent stages connected by bounded channels, with
//! benchmark-level fan-out; [`golden`] builds the labelled training
//! dataset (functional trace + O3 commit times + Algorithm-1 slicing +
//! Fig.-5/6 tokenization), routed through the same stage graph;
//! [`modes`] implements the two modes themselves.

pub mod cache;
pub mod engine;
pub mod golden;
pub mod modes;
pub mod pool;
pub mod stream;

pub use cache::{CacheSource, CacheStats, ClipCache};
pub use engine::{capsim_suite, gem5_suite, SuiteBatching, SuiteRun};
pub use golden::{build_bench_dataset, build_dataset, BenchProfile};
pub use modes::{capsim_mode, gem5_mode, CapsimRun, Gem5Run};
pub use pool::parallel_map;
pub use stream::{capsim_suite_streamed, gem5_suite_streamed, StageTimes};
