//! Suite-level drivers for the sharded engine: run all benchmarks through
//! one shared [`ClipCache`] so clip dedup — and, in
//! [`SuiteBatching::CrossBench`] mode, inference batch assembly — spans
//! benchmark boundaries.
//!
//! The Fig.-7 accounting this enables: with per-benchmark dedup only
//! (`cache = None` per run), each benchmark re-predicts every clip it
//! shares with its siblings; with the shared cache the suite-wide
//! `clips_unique` drops to the number of *globally* unique clips, which is
//! strictly smaller whenever workloads share kernels.

use std::time::Instant;

use anyhow::Result;

use crate::config::PipelineConfig;
use crate::runtime::Predictor;

use super::cache::ClipCache;
use super::golden::BenchProfile;
use super::modes::{
    capsim_mode, extrapolate, gem5_mode, scan_intervals, CapsimRun, DedupState, Gem5Run,
};

/// How inference batches are assembled across the suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SuiteBatching {
    /// Each benchmark predicts its own new unique clips as soon as it is
    /// scanned (per-benchmark wall times stay meaningful; the final batch
    /// of each benchmark may be partial).
    PerBench,
    /// Scan every benchmark first, then predict all new unique clips in
    /// one accumulator pass — batches fill across benchmark boundaries,
    /// so only the suite's single final batch can be partial, and every
    /// batch runs through one reused predictor
    /// [`Workspace`](crate::runtime::Workspace) (allocation-free steady
    /// state). Per-run `wall_s` then covers the scan stage only;
    /// inference time is reported once in [`SuiteRun::wall_s`].
    CrossBench,
    /// Run the suite through the streaming stage-pipelined engine
    /// ([`stream`](super::stream)): scan, batch fill and inference
    /// overlap as concurrent stages connected by bounded channels, with
    /// benchmark-level fan-out over one shared worker pool. The
    /// sequence-ordered merge keeps results bit-identical to
    /// [`CrossBench`](SuiteBatching::CrossBench) (row-local backends).
    /// Per-run `wall_s` reports the benchmark's summed scan busy
    /// seconds; stage accounting lands in [`SuiteRun::stages`].
    Streamed,
}

/// Aggregate result of a suite run.
#[derive(Clone, Debug)]
pub struct SuiteRun {
    /// Per-benchmark results, suite order.
    pub runs: Vec<CapsimRun>,
    /// Total clip occurrences across the suite.
    pub clips_total: usize,
    /// Unique clips sent to the model across the whole suite.
    pub clips_unique: usize,
    /// Distinct per-benchmark clips served by dedup instead of inference.
    pub cache_hits: usize,
    /// Whole-suite wall-clock seconds (scan + inference).
    pub wall_s: f64,
    /// Per-stage accounting — present for [`SuiteBatching::Streamed`]
    /// runs, `None` for the phase-barrier paths.
    pub stages: Option<super::stream::StageTimes>,
}

/// gem5 mode over a whole suite (no clip pipeline, so no cache; listed
/// here for symmetry and for the Fig.-7 thread sweeps).
pub fn gem5_suite(profiles: &[BenchProfile], cfg: &PipelineConfig) -> Vec<Gem5Run> {
    profiles
        .iter()
        .map(|p| gem5_mode(&p.selected, p.n_intervals, cfg))
        .collect()
}

/// CAPSim mode over a whole suite with cross-benchmark clip dedup.
pub fn capsim_suite<P: Predictor + ?Sized>(
    profiles: &[BenchProfile],
    cfg: &PipelineConfig,
    model: &P,
    time_scale: f32,
    cache: &ClipCache,
    batching: SuiteBatching,
) -> Result<SuiteRun> {
    if batching == SuiteBatching::Streamed {
        return super::stream::capsim_suite_streamed(profiles, cfg, model, time_scale, cache);
    }
    let t0 = Instant::now();
    let mut runs: Vec<CapsimRun> = Vec::with_capacity(profiles.len());
    match batching {
        SuiteBatching::Streamed => unreachable!("dispatched above"),
        SuiteBatching::PerBench => {
            for p in profiles {
                runs.push(capsim_mode(
                    &p.selected,
                    p.n_intervals,
                    cfg,
                    model,
                    time_scale,
                    Some(cache),
                )?);
            }
        }
        SuiteBatching::CrossBench => {
            anyhow::ensure!(
                cfg.l_min <= super::golden::L_CLIP,
                "l_min {} exceeds the model's clip capacity {}",
                cfg.l_min,
                super::golden::L_CLIP
            );
            let mut state = DedupState::new();
            let mut scanned = Vec::with_capacity(profiles.len());
            for p in profiles {
                let s0 = Instant::now();
                // hand each scan the keys already pending from earlier
                // benchmarks so it skips rebuilding their payloads
                let mut scans =
                    scan_intervals(&p.selected, cfg, Some(cache), Some(state.pending_keys()));
                let stats = state.collect(&mut scans, Some(cache));
                scanned.push((scans, stats, s0.elapsed().as_secs_f64()));
            }
            // one accumulator pass over every new unique clip in the suite
            state.predict(model, time_scale, Some(cache))?;
            for (p, (scans, stats, scan_s)) in profiles.iter().zip(scanned) {
                let interval_cycles = state.interval_cycles(&scans);
                let weights: Vec<f64> = p.selected.iter().map(|s| s.weight).collect();
                runs.push(CapsimRun {
                    total_cycles: extrapolate(&weights, &interval_cycles, p.n_intervals),
                    interval_cycles,
                    wall_s: scan_s,
                    clips_total: stats.clips_total,
                    clips_unique: stats.clips_unique,
                    cache_hits: stats.cache_hits,
                });
            }
        }
    }
    Ok(SuiteRun {
        clips_total: runs.iter().map(|r| r.clips_total).sum(),
        clips_unique: runs.iter().map(|r| r.clips_unique).sum(),
        cache_hits: runs.iter().map(|r| r.cache_hits).sum(),
        wall_s: t0.elapsed().as_secs_f64(),
        stages: None,
        runs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativePredictor;
    use crate::simpoint::{choose_simpoints, profile};
    use crate::workloads::{suite, Scale};

    fn test_cfg() -> PipelineConfig {
        let mut c = PipelineConfig::default();
        c.simpoint.interval_insts = 8_000;
        c.simpoint.warmup_insts = 1_000;
        c.simpoint.max_k = 2;
        c.l_min = 24;
        c
    }

    fn profiles_for(indices: &[usize], cfg: &PipelineConfig) -> Vec<BenchProfile> {
        let benches = suite(Scale::Test);
        indices
            .iter()
            .map(|&i| {
                let prof = profile(&benches[i].program, &cfg.simpoint);
                let selected = choose_simpoints(&prof, &cfg.simpoint);
                BenchProfile {
                    name: benches[i].name,
                    set_no: benches[i].set_no,
                    tag_string: benches[i].tag_string(),
                    n_intervals: prof.intervals.len(),
                    selected,
                    total_insts: prof.total_insts,
                }
            })
            .collect()
    }

    #[test]
    fn per_bench_and_cross_bench_agree_on_cycles() {
        let cfg = test_cfg();
        let profiles = profiles_for(&[0, 1, 2], &cfg);
        let model = NativePredictor::with_defaults();
        let a = capsim_suite(
            &profiles,
            &cfg,
            &model,
            40.0,
            &ClipCache::new(),
            SuiteBatching::PerBench,
        )
        .unwrap();
        let b = capsim_suite(
            &profiles,
            &cfg,
            &model,
            40.0,
            &ClipCache::new(),
            SuiteBatching::CrossBench,
        )
        .unwrap();
        assert_eq!(a.runs.len(), b.runs.len());
        for (ra, rb) in a.runs.iter().zip(&b.runs) {
            let abits: Vec<u64> = ra.interval_cycles.iter().map(|c| c.to_bits()).collect();
            let bbits: Vec<u64> = rb.interval_cycles.iter().map(|c| c.to_bits()).collect();
            assert_eq!(abits, bbits, "batching strategy must not change results");
            assert_eq!(ra.total_cycles.to_bits(), rb.total_cycles.to_bits());
        }
        assert_eq!(a.clips_unique, b.clips_unique);
        assert_eq!(a.clips_total, b.clips_total);
    }

    #[test]
    fn duplicate_benchmarks_dedup_across_the_suite() {
        let cfg = test_cfg();
        // the same benchmark twice: the second contributes zero new clips
        let profiles = profiles_for(&[5, 5], &cfg);
        let model = NativePredictor::with_defaults();
        let run = capsim_suite(
            &profiles,
            &cfg,
            &model,
            40.0,
            &ClipCache::new(),
            SuiteBatching::PerBench,
        )
        .unwrap();
        assert!(run.runs[0].clips_unique > 0);
        assert_eq!(run.runs[1].clips_unique, 0);
        assert_eq!(run.runs[1].cache_hits, run.runs[0].clips_unique);
        let a: Vec<u64> = run.runs[0].interval_cycles.iter().map(|c| c.to_bits()).collect();
        let b: Vec<u64> = run.runs[1].interval_cycles.iter().map(|c| c.to_bits()).collect();
        assert_eq!(a, b, "identical program, identical predictions");
    }

    #[test]
    fn suite_accepts_any_dependency_free_registry_backend() {
        // the suite drivers are generic over the registry's backends;
        // both dependency-free ones run end-to-end through one cache
        // (artifacts pointed somewhere empty so a saved attention.bin
        // cannot change the weights under the test)
        let mut cfg = test_cfg();
        cfg.artifacts = "no-such-artifacts-dir".to_string();
        let profiles = profiles_for(&[0], &cfg);
        for be in [crate::runtime::Backend::Native, crate::runtime::Backend::Attention] {
            let model = be.build_forward(&cfg).unwrap();
            let run = capsim_suite(
                &profiles,
                &cfg,
                model.as_ref(),
                40.0,
                &ClipCache::new(),
                SuiteBatching::PerBench,
            )
            .unwrap();
            assert_eq!(run.runs.len(), 1, "{be}");
            assert!(run.runs[0].total_cycles > 0.0, "{be}");
            assert!(run.clips_unique > 0, "{be}");
        }
    }

    #[test]
    fn tiny_bounded_cache_evicts_without_breaking_the_run() {
        // a bound far below the working set forces evictions *during*
        // the run. In a streamed run every in-run key is resolved from
        // the run's own pred map (the cache is only a cross-run/warm
        // source), so results stay bit-identical to the unbounded run;
        // in PerBench mode cross-benchmark reuse goes *through* the
        // cache, so evicting a shared key legitimately re-canonicalizes
        // it to the next benchmark's first-sighting context (the same
        // content-keyed rule a different run composition follows) — so
        // there we assert completion + bound + eviction, not bitwise
        // equality. Nothing may panic in either path, including the
        // streamed one where stage-3 eviction races the scans.
        let cfg = test_cfg();
        let profiles = profiles_for(&[0, 1], &cfg);
        let model = NativePredictor::with_defaults();
        let unbounded = capsim_suite(
            &profiles,
            &cfg,
            &model,
            40.0,
            &ClipCache::new(),
            SuiteBatching::Streamed,
        )
        .unwrap();

        let tiny = ClipCache::bounded(4);
        let streamed =
            capsim_suite(&profiles, &cfg, &model, 40.0, &tiny, SuiteBatching::Streamed)
                .unwrap();
        for (ra, rb) in unbounded.runs.iter().zip(&streamed.runs) {
            let abits: Vec<u64> = ra.interval_cycles.iter().map(|c| c.to_bits()).collect();
            let bbits: Vec<u64> = rb.interval_cycles.iter().map(|c| c.to_bits()).collect();
            assert_eq!(abits, bbits, "streamed: eviction changed an in-run prediction");
        }
        assert!(tiny.len() <= 4, "streamed: bound respected");
        assert!(tiny.stats().evictions > 0, "streamed: pressure must evict");

        let tiny = ClipCache::bounded(4);
        let per_bench =
            capsim_suite(&profiles, &cfg, &model, 40.0, &tiny, SuiteBatching::PerBench)
                .unwrap();
        assert_eq!(per_bench.runs.len(), 2);
        assert!(per_bench.runs.iter().all(|r| r.total_cycles > 0.0));
        assert_eq!(per_bench.clips_total, unbounded.clips_total);
        assert!(tiny.len() <= 4, "per-bench: bound respected");
        assert!(tiny.stats().evictions > 0, "per-bench: pressure must evict");
    }

    #[test]
    fn gem5_suite_matches_individual_runs() {
        let cfg = test_cfg();
        let profiles = profiles_for(&[3, 7], &cfg);
        let all = gem5_suite(&profiles, &cfg);
        assert_eq!(all.len(), 2);
        for (run, p) in all.iter().zip(&profiles) {
            let solo = gem5_mode(&p.selected, p.n_intervals, &cfg);
            assert_eq!(run.interval_cycles, solo.interval_cycles);
        }
    }
}
