//! The streaming, stage-pipelined suite engine.
//!
//! PR 1's engine still ran each phase behind a barrier: scan (and
//! tokenize) *everything*, then predict batch after batch. This module
//! removes the barriers: checkpoint-restore/functional-scan,
//! slice+tokenize, [`BatchAccumulator`] fill, [`Predictor::forward`] and
//! the result merge run as **concurrent stages connected by bounded
//! channels**, and the whole suite's scan jobs — every (benchmark,
//! interval) pair from all 24 workloads — feed one shared stage graph
//! instead of running suites serially:
//!
//! ```text
//!   scan jobs (bench × interval, all benchmarks, one shared pool)
//!     │
//!     ├─ worker 1..N ── restore → warm-up → slice → tokenize   [stage 1]
//!     │        (seq, IntervalScan, busy_s)
//!     ▼  sync_channel(queue_depth)                 ── backpressure ──
//!   merge thread                                               [stage 2]
//!     reorder to sequence order → clip dedup (interval / benchmark /
//!     suite / shared ClipCache) → BatchAccumulator fill
//!     │        Batch | Tail | Bench summary
//!     ▼  sync_channel(batch_depth)                 ── backpressure ──
//!   caller thread                                              [stage 3]
//!     Predictor::forward → resolve into pred map + shared ClipCache
//!     → sequence-ordered per-benchmark result merge
//! ```
//!
//! Determinism is the same hard contract as [`modes`](super::modes):
//! workers finish in any order, but the merge stage consumes scans in
//! **sequence-number order** (bench-major, interval-minor — exactly the
//! sequential suite order), so dedup decisions, canonical-payload choice
//! and batch composition are those of the phase-barrier
//! [`SuiteBatching::CrossBench`](super::engine::SuiteBatching) path. With
//! a row-local backend, `threads = N`, any queue depth, and any stage
//! interleaving are **bit-identical** to the sequential path (proved in
//! `tests/engine_equivalence.rs`).
//!
//! Why the canonical payload survives the races: the merge needs a
//! tokenized payload for a key `K` only at `K`'s *first* appearance in
//! sequence order, say scan `i`. The shared cache can only contain `K`
//! after the merge has processed some scan referencing `K` — and no scan
//! before `i` does — so when the worker scanned `i`, `K` was not in the
//! cache and the payload was built. Later scans may build duplicate
//! payloads (they raced the resolve); the merge drops them unread.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::PipelineConfig;
use crate::dataset::ClipSample;
use crate::predictor::{BatchAccumulator, BatchRunner};
use crate::runtime::{Batch, Predictor};
use crate::simpoint::SelectedInterval;

use super::cache::ClipCache;
use super::engine::SuiteRun;
use super::golden::{BenchProfile, L_CLIP};
use super::modes::{
    extrapolate, scan_one, simulate_interval, CapsimRun, CollectStats, Gem5Run, IntervalScan,
};

/// Wall-clock accounting of one streamed run's pipeline stages.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    /// Summed busy seconds across all scan workers (stage 1).
    pub scan_busy_s: f64,
    /// Busy seconds of the predict stage (stage 3 forwards + resolves).
    pub predict_busy_s: f64,
    /// End-to-end wall seconds of the streamed run.
    pub wall_s: f64,
}

impl StageTimes {
    /// How much stage work the pipeline overlapped:
    /// `(scan + predict) / wall`. Values above 1 mean scanning and
    /// inference genuinely ran concurrently; ≈ 1 means they serialized.
    pub fn overlap(&self) -> f64 {
        (self.scan_busy_s + self.predict_busy_s) / self.wall_s.max(1e-9)
    }
}

/// Fan `jobs` out over `threads` workers and hand each result to
/// `consume` on the **caller's** thread in exact input order, while later
/// jobs are still running — the building block of the stage graph above
/// (the scan stage of [`capsim_suite_streamed`], and used directly by
/// [`gem5_suite_streamed`] and
/// [`golden::build_dataset`](super::golden::build_dataset), which have no
/// predict stage). Backpressure is a hard bound: a worker may not *start*
/// job `i` until `i` is within `depth + threads` of the merge frontier,
/// so at most `depth + threads` results exist at any moment (queued,
/// reorder-held, or being computed) no matter how unlucky the
/// scheduling — a slow sequence-first job cannot make the reorder buffer
/// absorb the whole run. With `threads <= 1` it degrades to a sequential
/// loop with identical results — the same contract as
/// [`pool::parallel_map`](super::pool).
pub(crate) fn ordered_stream<J, R, F>(
    jobs: Vec<J>,
    threads: usize,
    depth: usize,
    worker: F,
    mut consume: impl FnMut(usize, R),
) where
    J: Send,
    R: Send,
    F: Fn(J) -> R + Sync,
{
    let n = jobs.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for (i, job) in jobs.into_iter().enumerate() {
            consume(i, worker(job));
        }
        return;
    }
    let window = depth.max(1) + threads;
    let slots: Vec<Mutex<Option<J>>> =
        jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let next = AtomicUsize::new(0);
    // admission gate: number of results consumed so far; job i may start
    // once i < floor + window. The job the consumer is waiting for is
    // always admitted (floor < floor + window), so the gate cannot
    // deadlock, and the reorder buffer holds < window results.
    let floor = (Mutex::new(0usize), Condvar::new());
    let (tx, rx) = sync_channel::<(usize, R)>(depth.max(1));
    let (slots, next, worker, floor) = (&slots, &next, &worker, &floor);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                {
                    let mut f = floor.0.lock().unwrap();
                    while i >= *f + window {
                        f = floor.1.wait(f).unwrap();
                    }
                }
                let job = slots[i].lock().unwrap().take().unwrap();
                let r = worker(job);
                if tx.send((i, r)).is_err() {
                    break; // consumer went away
                }
            });
        }
        drop(tx);
        // sequence-ordered merge on the caller thread, overlapping the
        // still-running workers
        let mut held: HashMap<usize, R> = HashMap::new();
        let mut want = 0usize;
        for (i, r) in rx {
            held.insert(i, r);
            while let Some(r) = held.remove(&want) {
                consume(want, r);
                want += 1;
            }
            {
                let mut f = floor.0.lock().unwrap();
                if want > *f {
                    *f = want;
                    floor.1.notify_all();
                }
            }
        }
    });
}

/// gem5 mode over a whole suite through the stream graph: every
/// (benchmark, interval) O3 restore job from all benchmarks feeds one
/// worker pool, and the sequence-ordered merge assembles per-benchmark
/// results. gem5 mode has no predict stage, so the graph has two stages.
///
/// `Gem5Run::wall_s` reports the benchmark's summed per-interval busy
/// seconds (its serialized cost): per-benchmark wall clocks are not
/// observable once benchmarks interleave on one shared pool.
pub fn gem5_suite_streamed(profiles: &[BenchProfile], cfg: &PipelineConfig) -> Vec<Gem5Run> {
    let mut jobs: Vec<&SelectedInterval> = Vec::new();
    let mut bench_of: Vec<usize> = Vec::new();
    for (b, p) in profiles.iter().enumerate() {
        for sel in &p.selected {
            jobs.push(sel);
            bench_of.push(b);
        }
    }
    let mut cycles: Vec<Vec<u64>> = profiles
        .iter()
        .map(|p| Vec::with_capacity(p.selected.len()))
        .collect();
    let mut busy = vec![0.0f64; profiles.len()];
    ordered_stream(
        jobs,
        cfg.effective_threads(),
        cfg.effective_queue_depth(),
        |sel| {
            let t0 = Instant::now();
            let c = simulate_interval(sel, cfg);
            (c, t0.elapsed().as_secs_f64())
        },
        |seq, (c, dur)| {
            let b = bench_of[seq];
            cycles[b].push(c);
            busy[b] += dur;
        },
    );
    profiles
        .iter()
        .zip(cycles)
        .zip(busy)
        .map(|((p, interval_cycles), wall_s)| {
            let weights: Vec<f64> = p.selected.iter().map(|s| s.weight).collect();
            let as_f64: Vec<f64> = interval_cycles.iter().map(|&c| c as f64).collect();
            Gem5Run {
                total_cycles: extrapolate(&weights, &as_f64, p.n_intervals),
                interval_cycles,
                wall_s,
            }
        })
        .collect()
}

/// One finished benchmark's merge summary (stage 2 → stage 3), suite
/// order.
#[derive(Default)]
struct BenchOut {
    /// `(key, occurrences)` per interval, interval order.
    refs: Vec<Vec<(u64, u64)>>,
    stats: CollectStats,
    /// Keys this benchmark resolved from the pre-warmed cache, with
    /// their cached predictions (first sighting in the suite only).
    cached: Vec<(u64, f64)>,
    /// Summed busy seconds of this benchmark's interval scans.
    scan_busy_s: f64,
}

/// Stage-2 → stage-3 traffic.
enum WorkItem {
    /// A full inference batch (accumulator fill), composition in
    /// deterministic push order.
    Batch(Vec<u64>, Batch),
    /// The suite-final partial remainder; the predict stage pads it to
    /// the smallest compiled capacity that fits (`pick_fwd_batch`),
    /// exactly like the sequential tail flush.
    Tail(Vec<(u64, ClipSample)>),
    /// One finished benchmark, suite order.
    Bench(BenchOut),
}

/// Stage-2 state: the sequence-ordered clip dedup + batch fill, making
/// exactly the decisions of the sequential `DedupState::collect` /
/// `predict` pair, but emitting work downstream as soon as it is ready.
struct Merge<'a> {
    tx: SyncSender<WorkItem>,
    cache: &'a ClipCache,
    /// `last_seq[b]` = scans up to and including benchmark `b`.
    last_seq: &'a [usize],
    nbench: usize,
    acc: BatchAccumulator,
    /// Keys pended or cache-resolved anywhere in this run.
    seen_suite: HashSet<u64>,
    /// Keys seen in the current benchmark (reset per benchmark).
    seen_bench: HashSet<u64>,
    out: BenchOut,
    cur_b: usize,
    /// Set when the predict stage disappeared (terminal error there):
    /// the merge keeps draining scans without sending, so the scan
    /// workers finish cleanly instead of blocking on a dead channel.
    dead: bool,
}

impl Merge<'_> {
    fn send(&mut self, item: WorkItem) {
        if !self.dead && self.tx.send(item).is_err() {
            self.dead = true;
        }
    }

    /// Emit every benchmark whose scan range is complete after
    /// `consumed` scans (including benchmarks with no intervals).
    fn emit_finished_benches(&mut self, consumed: usize) {
        while self.cur_b < self.nbench && consumed >= self.last_seq[self.cur_b] {
            let done = std::mem::take(&mut self.out);
            self.seen_bench.clear();
            self.send(WorkItem::Bench(done));
            self.cur_b += 1;
        }
    }

    /// Fold the next in-sequence scan into the dedup state and the
    /// batch accumulator.
    fn process(&mut self, mut scan: IntervalScan, dur: f64) {
        self.out.scan_busy_s += dur;
        // first-in-sequence-order payload wins, as in the sequential
        // merge; duplicates from racing workers are dropped unread
        let mut local: HashMap<u64, ClipSample> = HashMap::new();
        for (key, sample) in scan.fresh.drain(..) {
            local.entry(key).or_insert(sample);
        }
        for &(key, count) in &scan.refs {
            self.out.stats.clips_total += count as usize;
            if !self.seen_bench.insert(key) {
                continue; // earlier interval of this benchmark owns it
            }
            if self.seen_suite.contains(&key) {
                self.out.stats.cache_hits += 1; // earlier benchmark
                continue;
            }
            if let Some(v) = self.cache.get(key) {
                self.seen_suite.insert(key);
                self.out.stats.cache_hits += 1;
                self.out.cached.push((key, v));
                continue;
            }
            let sample = local
                .remove(&key)
                .expect("uncached key must carry a scan payload");
            self.seen_suite.insert(key);
            self.out.stats.clips_unique += 1;
            if let Some((keys, batch)) = self.acc.push(key, sample) {
                self.send(WorkItem::Batch(keys, batch));
            }
        }
        self.out.refs.push(scan.refs);
    }

    /// Trailing benchmark boundaries + the partial tail, then hang up
    /// (dropping `tx` tells stage 3 the stream is complete).
    fn finish(mut self, consumed: usize) {
        self.emit_finished_benches(consumed);
        let tail = self.acc.drain();
        if !tail.is_empty() {
            self.send(WorkItem::Tail(tail));
        }
    }
}

/// CAPSim mode over a whole suite through the streaming stage-pipelined
/// engine (see the module docs for the stage graph). Scan, batch fill
/// and inference overlap; all benchmarks fan out over one worker pool
/// and feed one shared [`ClipCache`] + cross-benchmark batch stream.
///
/// Results are bit-identical to
/// [`SuiteBatching::CrossBench`](super::engine::SuiteBatching) with a
/// row-local backend. Per-run `wall_s` reports the benchmark's summed
/// scan busy seconds; the suite-wide stage accounting lands in
/// [`SuiteRun::stages`].
pub fn capsim_suite_streamed<P: Predictor + ?Sized>(
    profiles: &[BenchProfile],
    cfg: &PipelineConfig,
    model: &P,
    time_scale: f32,
    cache: &ClipCache,
) -> Result<SuiteRun> {
    anyhow::ensure!(
        cfg.l_min <= L_CLIP,
        "l_min {} exceeds the model's clip capacity {L_CLIP}",
        cfg.l_min
    );
    let t0 = Instant::now();
    let cap = model.max_fwd_batch();
    let geometry = model.geometry().clone();
    let nbench = profiles.len();

    // flatten every benchmark's scan jobs into one bench-major sequence;
    // sequence order == the sequential CrossBench processing order.
    // last_seq[b] = number of scans up to and including benchmark b.
    let mut jobs: Vec<&SelectedInterval> = Vec::new();
    let mut last_seq: Vec<usize> = Vec::with_capacity(nbench);
    for p in profiles {
        jobs.extend(p.selected.iter());
        last_seq.push(jobs.len());
    }
    let threads = cfg.effective_threads();
    let queue_depth = cfg.effective_queue_depth();
    let (tx_work, rx_work) = sync_channel::<WorkItem>(cfg.effective_batch_depth().max(1));

    // Unlike the phase-barrier paths, stage-3 inserts (and therefore
    // bounded-cache evictions) run concurrently with the scans, so a
    // scan's `contains` observation is only stable when this run cannot
    // possibly push the cache over its bound: every insert is a new
    // unique clip, and a run of J scan jobs can discover at most
    // J * (interval_insts / l_min + 1) of those. When eviction is
    // possible, scans keep payloads for cached keys too and the merge
    // falls back to re-pricing from this run's first-sighting payload —
    // the same content-keyed canonicalization a cold run of this
    // composition would use (in-run keys always resolve from the run's
    // own pred map, so only reuse of *warm* entries can shift under
    // eviction pressure).
    let worst_new =
        jobs.len() as u64 * (cfg.simpoint.interval_insts / cfg.l_min.max(1) as u64 + 1);
    let cache_stable = !cache.may_evict()
        || cache.len() as u64 + worst_new <= cache.max_entries() as u64;

    let mut outs: Vec<BenchOut> = Vec::with_capacity(nbench);
    let mut pred: HashMap<u64, f64> = HashMap::new();
    let mut predict_busy = 0.0f64;
    let mut failure: Option<anyhow::Error> = None;

    let last_seq = &last_seq;
    std::thread::scope(|s| {
        // stages 1 + 2 on a dedicated merge thread: ordered_stream fans
        // the scan jobs out (stage 1, reads the cache, never writes it)
        // and delivers each IntervalScan to the Merge in sequence order
        // (stage 2), which ships batches/summaries downstream
        s.spawn(move || {
            let mut merge = Merge {
                tx: tx_work,
                cache,
                last_seq: last_seq.as_slice(),
                nbench,
                acc: BatchAccumulator::new(cap, geometry),
                seen_suite: HashSet::new(),
                seen_bench: HashSet::new(),
                out: BenchOut::default(),
                cur_b: 0,
                dead: false,
            };
            let mut consumed = 0usize;
            ordered_stream(
                jobs,
                threads,
                queue_depth,
                |sel| {
                    let s0 = Instant::now();
                    let scan = scan_one(sel, cfg, Some(cache), cache_stable, None, None);
                    (scan, s0.elapsed().as_secs_f64())
                },
                |seq, (scan, dur)| {
                    merge.emit_finished_benches(seq);
                    merge.process(scan, dur);
                    consumed = seq + 1;
                },
            );
            merge.finish(consumed);
            // the Merge's tx drops here -> stage 3 sees end-of-stream
        });

        // stage 3: predict + resolve on the caller thread (the model
        // never crosses a thread boundary, so `P` needs no `Sync`). One
        // BatchRunner (workspace + prediction buffer) lives for the
        // whole run, so steady-state forwards allocate nothing.
        let mut runner = BatchRunner::new();
        for item in rx_work {
            match item {
                WorkItem::Batch(keys, batch) => {
                    let p0 = Instant::now();
                    match runner.forward(model, &batch, time_scale) {
                        Ok(preds) => {
                            for (&k, &v) in keys.iter().zip(preds) {
                                pred.insert(k, v as f64);
                                cache.insert(k, v as f64);
                            }
                            predict_busy += p0.elapsed().as_secs_f64();
                        }
                        Err(e) => {
                            failure = Some(e);
                            break;
                        }
                    }
                }
                WorkItem::Tail(clips) => {
                    let p0 = Instant::now();
                    match runner.forward_tail(model, &clips, time_scale) {
                        Ok(preds) => {
                            for (&(k, _), &v) in clips.iter().zip(preds) {
                                pred.insert(k, v as f64);
                                cache.insert(k, v as f64);
                            }
                            predict_busy += p0.elapsed().as_secs_f64();
                        }
                        Err(e) => {
                            failure = Some(e);
                            break;
                        }
                    }
                }
                WorkItem::Bench(out) => outs.push(out),
            }
        }
        // rx_work dropped at loop exit: on an early break the merge
        // thread's next send fails and the whole pipeline unwinds
    });
    if let Some(e) = failure {
        return Err(e);
    }

    // sequence-ordered result merge: every referenced key is resolved by
    // now (fresh keys through stage 3, pre-warmed keys via `cached`)
    let mut scan_busy = 0.0f64;
    let mut runs: Vec<CapsimRun> = Vec::with_capacity(nbench);
    for (p, out) in profiles.iter().zip(outs) {
        for (k, v) in out.cached {
            pred.insert(k, v);
        }
        let interval_cycles: Vec<f64> = out
            .refs
            .iter()
            .map(|refs| {
                refs.iter()
                    .map(|&(key, count)| {
                        let v = pred
                            .get(&key)
                            .copied()
                            .expect("every referenced clip is resolved");
                        v * count as f64
                    })
                    .sum()
            })
            .collect();
        let weights: Vec<f64> = p.selected.iter().map(|s| s.weight).collect();
        scan_busy += out.scan_busy_s;
        runs.push(CapsimRun {
            total_cycles: extrapolate(&weights, &interval_cycles, p.n_intervals),
            interval_cycles,
            wall_s: out.scan_busy_s,
            clips_total: out.stats.clips_total,
            clips_unique: out.stats.clips_unique,
            cache_hits: out.stats.cache_hits,
        });
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(SuiteRun {
        clips_total: runs.iter().map(|r| r.clips_total).sum(),
        clips_unique: runs.iter().map(|r| r.clips_unique).sum(),
        cache_hits: runs.iter().map(|r| r.cache_hits).sum(),
        wall_s,
        stages: Some(StageTimes {
            scan_busy_s: scan_busy,
            predict_busy_s: predict_busy,
            wall_s,
        }),
        runs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{capsim_suite, gem5_suite, SuiteBatching};
    use crate::runtime::NativePredictor;
    use crate::simpoint::{choose_simpoints, profile};
    use crate::workloads::{suite, Scale};

    fn test_cfg() -> PipelineConfig {
        let mut c = PipelineConfig::default();
        c.simpoint.interval_insts = 8_000;
        c.simpoint.warmup_insts = 1_000;
        c.simpoint.max_k = 2;
        c.l_min = 24;
        c
    }

    fn profiles_for(indices: &[usize], cfg: &PipelineConfig) -> Vec<BenchProfile> {
        let benches = suite(Scale::Test);
        indices
            .iter()
            .map(|&i| {
                let prof = profile(&benches[i].program, &cfg.simpoint);
                let selected = choose_simpoints(&prof, &cfg.simpoint);
                BenchProfile {
                    name: benches[i].name,
                    set_no: benches[i].set_no,
                    tag_string: benches[i].tag_string(),
                    n_intervals: prof.intervals.len(),
                    selected,
                    total_insts: prof.total_insts,
                }
            })
            .collect()
    }

    #[test]
    fn ordered_stream_preserves_order() {
        for threads in [1usize, 3, 8] {
            let mut seen = Vec::new();
            ordered_stream(
                (0..50).collect::<Vec<i32>>(),
                threads,
                2,
                |x| x * 3,
                |seq, r| seen.push((seq, r)),
            );
            let want: Vec<(usize, i32)> = (0..50).map(|x| (x as usize, x as i32 * 3)).collect();
            assert_eq!(seen, want, "threads = {threads}");
        }
    }

    #[test]
    fn ordered_stream_empty_input() {
        let mut calls = 0usize;
        ordered_stream(Vec::<i32>::new(), 4, 2, |x| x, |_, _| calls += 1);
        assert_eq!(calls, 0);
    }

    #[test]
    fn ordered_stream_slow_consumer_does_not_deadlock() {
        // depth 1 with a consumer slower than the workers exercises the
        // backpressure path
        let mut out = Vec::new();
        ordered_stream(
            (0..20).collect::<Vec<u64>>(),
            4,
            1,
            |x| x + 1,
            |_, r| {
                std::thread::sleep(std::time::Duration::from_millis(1));
                out.push(r);
            },
        );
        assert_eq!(out, (1..=20).collect::<Vec<u64>>());
    }

    #[test]
    fn ordered_stream_slow_first_job_stays_ordered() {
        // job 0 finishes last: the admission gate bounds the reorder
        // buffer while later workers wait, and order still holds
        let mut out = Vec::new();
        ordered_stream(
            (0..40).collect::<Vec<u64>>(),
            4,
            2,
            |x| {
                if x == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(30));
                }
                x
            },
            |_, r| out.push(r),
        );
        assert_eq!(out, (0..40).collect::<Vec<u64>>());
    }

    #[test]
    fn streamed_matches_cross_bench_bitwise() {
        let mut cfg = test_cfg();
        let profiles = profiles_for(&[0, 1, 5, 5], &cfg);
        let model = NativePredictor::with_defaults();
        cfg.threads = 1;
        let base = capsim_suite(
            &profiles,
            &cfg,
            &model,
            40.0,
            &ClipCache::new(),
            SuiteBatching::CrossBench,
        )
        .unwrap();
        for threads in [1usize, 4] {
            cfg.threads = threads;
            let run = capsim_suite_streamed(&profiles, &cfg, &model, 40.0, &ClipCache::new())
                .unwrap();
            assert_eq!(base.runs.len(), run.runs.len());
            for (ra, rb) in base.runs.iter().zip(&run.runs) {
                let abits: Vec<u64> = ra.interval_cycles.iter().map(|c| c.to_bits()).collect();
                let bbits: Vec<u64> = rb.interval_cycles.iter().map(|c| c.to_bits()).collect();
                assert_eq!(abits, bbits, "threads = {threads}");
                assert_eq!(ra.total_cycles.to_bits(), rb.total_cycles.to_bits());
                assert_eq!(ra.clips_total, rb.clips_total);
                assert_eq!(ra.clips_unique, rb.clips_unique);
                assert_eq!(ra.cache_hits, rb.cache_hits);
            }
            assert_eq!(base.clips_unique, run.clips_unique);
            assert!(run.stages.is_some());
        }
    }

    #[test]
    fn streamed_gem5_matches_serial_suite() {
        let mut cfg = test_cfg();
        let profiles = profiles_for(&[2, 3, 7], &cfg);
        cfg.threads = 1;
        let serial = gem5_suite(&profiles, &cfg);
        for threads in [1usize, 4] {
            cfg.threads = threads;
            let streamed = gem5_suite_streamed(&profiles, &cfg);
            assert_eq!(serial.len(), streamed.len());
            for (a, b) in serial.iter().zip(&streamed) {
                assert_eq!(a.interval_cycles, b.interval_cycles, "threads = {threads}");
                assert_eq!(a.total_cycles.to_bits(), b.total_cycles.to_bits());
            }
        }
    }

    #[test]
    fn streamed_attention_backend_matches_cross_bench_bitwise() {
        // the registry's pure-Rust attention backend is row-local, so
        // the streamed stage graph must reproduce the phase-barrier
        // path bit-for-bit, exactly like the analytic stand-in
        // (artifacts pointed somewhere empty so a saved attention.bin
        // cannot change the weights under the test)
        let mut cfg = test_cfg();
        cfg.artifacts = "no-such-artifacts-dir".to_string();
        let profiles = profiles_for(&[3], &cfg);
        let model = crate::runtime::Backend::Attention.build_forward(&cfg).unwrap();
        let base = capsim_suite(
            &profiles,
            &cfg,
            model.as_ref(),
            40.0,
            &ClipCache::new(),
            SuiteBatching::CrossBench,
        )
        .unwrap();
        let run =
            capsim_suite_streamed(&profiles, &cfg, model.as_ref(), 40.0, &ClipCache::new())
                .unwrap();
        for (ra, rb) in base.runs.iter().zip(&run.runs) {
            let abits: Vec<u64> = ra.interval_cycles.iter().map(|c| c.to_bits()).collect();
            let bbits: Vec<u64> = rb.interval_cycles.iter().map(|c| c.to_bits()).collect();
            assert_eq!(abits, bbits);
        }
        assert_eq!(base.clips_unique, run.clips_unique);
    }

    #[test]
    fn streamed_warm_cache_predicts_nothing_new() {
        let cfg = test_cfg();
        let profiles = profiles_for(&[4, 6], &cfg);
        let model = NativePredictor::with_defaults();
        let cache = ClipCache::new();
        let cold = capsim_suite_streamed(&profiles, &cfg, &model, 40.0, &cache).unwrap();
        assert!(cold.clips_unique > 0);
        assert_eq!(cache.len(), cold.clips_unique);
        let warm = capsim_suite_streamed(&profiles, &cfg, &model, 40.0, &cache).unwrap();
        assert_eq!(warm.clips_unique, 0);
        for (rc, rw) in cold.runs.iter().zip(&warm.runs) {
            let cbits: Vec<u64> = rc.interval_cycles.iter().map(|c| c.to_bits()).collect();
            let wbits: Vec<u64> = rw.interval_cycles.iter().map(|c| c.to_bits()).collect();
            assert_eq!(cbits, wbits);
        }
    }

    #[test]
    fn streamed_empty_suite_is_fine() {
        let cfg = test_cfg();
        let model = NativePredictor::with_defaults();
        let run = capsim_suite_streamed(&[], &cfg, &model, 40.0, &ClipCache::new()).unwrap();
        assert!(run.runs.is_empty());
        assert_eq!(run.clips_total, 0);
    }
}
