//! The two simulation modes of Fig. 1 and the Fig.-7 speed comparison —
//! reworked as a **sharded parallel engine**.
//!
//! Both modes fan their per-interval work (checkpoint restore → functional
//! trace → O3 simulate / slice+tokenize) out over
//! [`pool::parallel_map`](super::pool::parallel_map) using the
//! `threads` knob of [`PipelineConfig`]. Determinism is a hard contract:
//!
//! * the parallel stage produces one [`IntervalScan`] per interval,
//!   returned in **input order** regardless of scheduling;
//! * every stateful step — clip dedup, canonical-context selection, batch
//!   assembly, cache insertion — happens in a **sequential merge** over
//!   those ordered scans;
//!
//! so `threads = N` is bit-identical to `threads = 1`.
//!
//! Clip dedup is layered: each interval scan dedups locally, the merge
//! dedups across intervals, and an optional cross-benchmark
//! [`ClipCache`](super::cache::ClipCache) dedups across the whole suite so
//! a clip shared by several workloads is tokenized and predicted once.
//! New unique clips are pooled through a
//! [`BatchAccumulator`](crate::predictor::BatchAccumulator), so inference
//! runs on full batches accumulated across intervals (and, via
//! [`engine`](super::engine), across benchmarks).

use std::collections::hash_map::Entry;
use std::collections::{HashMap, HashSet};
use std::time::Instant;

use anyhow::Result;

use crate::config::PipelineConfig;
use crate::context::{context_tokens, REGISTER_SPEC};
use crate::dataset::ClipSample;
use crate::functional::TraceRecord;
use crate::o3::O3Core;
use crate::predictor::{BatchAccumulator, BatchRunner};
use crate::runtime::Predictor;
use crate::simpoint::SelectedInterval;
use crate::tokenizer::standardize::{fast_clip_key, tokenize_clip};

use super::cache::ClipCache;
use super::golden::{L_CLIP, L_TOKEN};
use super::pool;

/// gem5-mode result for one benchmark.
#[derive(Clone, Debug)]
pub struct Gem5Run {
    /// Measured cycles per selected interval (post-warmup portion).
    pub interval_cycles: Vec<u64>,
    /// SimPoint-extrapolated whole-program cycles.
    pub total_cycles: f64,
    /// Wall-clock seconds of the restore+simulate work.
    pub wall_s: f64,
}

/// CAPSim-mode result for one benchmark.
#[derive(Clone, Debug)]
pub struct CapsimRun {
    /// Predicted cycles per selected interval.
    pub interval_cycles: Vec<f64>,
    /// SimPoint-extrapolated whole-program cycles.
    pub total_cycles: f64,
    /// Wall-clock seconds. For [`capsim_mode`] runs this covers the whole
    /// pipeline (functional trace + slicing + inference); for runs
    /// produced by `engine::capsim_suite` with `SuiteBatching::CrossBench`
    /// it covers the scan stage only — inference is deferred suite-wide
    /// and reported once in `SuiteRun::wall_s`.
    pub wall_s: f64,
    /// Total clip occurrences across the intervals.
    pub clips_total: usize,
    /// Unique clips actually tokenized + sent to the model by this run
    /// (clips already resolved by the cross-benchmark cache don't count).
    pub clips_unique: usize,
    /// Distinct clips this run resolved from the shared cache (or from an
    /// earlier benchmark in the same suite run) instead of predicting.
    pub cache_hits: usize,
}

pub(crate) fn extrapolate(weights: &[f64], cycles: &[f64], n_intervals: usize) -> f64 {
    // SimPoint: total ≈ n_intervals * Σ weight_c * cycles(rep_c)
    n_intervals as f64
        * weights
            .iter()
            .zip(cycles)
            .map(|(w, c)| w * c)
            .sum::<f64>()
}

/// One gem5-mode interval job: fresh cold core, checkpoint restore,
/// warm-up + measured simulation — shared by [`gem5_mode`]'s pool
/// fan-out and the streaming engine's scan stage
/// ([`stream::gem5_suite_streamed`](super::stream::gem5_suite_streamed)).
pub(crate) fn simulate_interval(sel: &SelectedInterval, cfg: &PipelineConfig) -> u64 {
    let warm = cfg.simpoint.warmup_insts;
    let mut core = O3Core::new(cfg.o3.clone());
    let mut cpu = sel.checkpoint.restore();
    let trace = cpu.run_trace(warm + cfg.simpoint.interval_insts);
    let r = core.simulate(&trace);
    // measured portion = everything after the warm-up instructions;
    // if the program ended inside warm-up, fall back to full cycles
    let measured = if trace.len() > warm as usize {
        r.stats.cycles - r.commit_cycle[warm as usize]
    } else {
        r.stats.cycles
    };
    measured.max(1)
}

/// Restore every selected checkpoint into the O3 model (the paper's
/// conventional flow, Fig. 1 left). Intervals are independent, so they
/// fan out over the worker pool; each job gets a fresh (cold) core,
/// exactly like the sequential flow's `reset()` before each restore.
pub fn gem5_mode(
    selected: &[SelectedInterval],
    n_intervals: usize,
    cfg: &PipelineConfig,
) -> Gem5Run {
    let t0 = Instant::now();
    let jobs: Vec<&SelectedInterval> = selected.iter().collect();
    let interval_cycles =
        pool::parallel_map(jobs, cfg.effective_threads(), |sel| simulate_interval(sel, cfg));
    let weights: Vec<f64> = selected.iter().map(|s| s.weight).collect();
    let cycles: Vec<f64> = interval_cycles.iter().map(|&c| c as f64).collect();
    Gem5Run {
        total_cycles: extrapolate(&weights, &cycles, n_intervals),
        interval_cycles,
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

/// One interval's scan output: clip keys with occurrence counts in
/// first-appearance order, plus payloads (tokens + context) for keys that
/// were absent from the shared cache at scan time.
pub(crate) struct IntervalScan {
    /// `(fast_clip_key, occurrences)`, first-appearance order.
    pub refs: Vec<(u64, u64)>,
    /// Tokenized payloads for locally-first-seen, uncached keys,
    /// first-appearance order.
    pub fresh: Vec<(u64, ClipSample)>,
}

/// The parallel stage: restore + warm-up + slice one interval into
/// `l_min`-instruction clips. Reads the cache (and the optional
/// `known` key set — clips already pending elsewhere in the suite),
/// never writes either. `bench_seen` is the sequential fast path's
/// cross-interval seen-set: a key an *earlier* interval already carries
/// a payload for needs no second tokenization (only valid when
/// intervals run in order — with parallel workers it would make the
/// canonical context schedule-dependent).
///
/// `cache_stable` says a `contains` observation is guaranteed to still
/// hold when the merge resolves this scan — true for the phase-barrier
/// paths (every cache read completes before any insert runs) and for
/// streamed runs whose cache cannot evict mid-run; when false, payloads
/// are kept for cached keys too, as the merge's eviction fallback.
pub(crate) fn scan_one(
    sel: &SelectedInterval,
    cfg: &PipelineConfig,
    cache: Option<&ClipCache>,
    cache_stable: bool,
    known: Option<&HashSet<u64>>,
    mut bench_seen: Option<&mut HashSet<u64>>,
) -> IntervalScan {
    let warm = cfg.simpoint.warmup_insts;
    // capsim_mode/capsim_suite validate l_min <= L_CLIP before fanning out
    let l_min = cfg.l_min as u64;

    let mut cpu = sel.checkpoint.restore();
    // fast-forward through warm-up (no records kept)
    cpu.run_with(warm, |_| {});

    let mut order: Vec<u64> = Vec::new();
    let mut counts: HashMap<u64, u64> = HashMap::new();
    let mut fresh: Vec<(u64, ClipSample)> = Vec::new();
    let mut window: Vec<TraceRecord> = Vec::with_capacity(l_min as usize);
    let mut clip_regs = cpu.regs.clone();
    let mut executed = 0u64;

    while executed < cfg.simpoint.interval_insts && !cpu.halted {
        if window.is_empty() {
            clip_regs = cpu.regs.clone(); // context at clip start
        }
        window.push(*cpu.step().record());
        executed += 1;
        if window.len() as u64 == l_min {
            let key = fast_clip_key(&window);
            match counts.entry(key) {
                Entry::Occupied(mut e) => *e.get_mut() += 1,
                Entry::Vacant(e) => {
                    e.insert(1);
                    order.push(key);
                    // tokenize only on local first sight of a key that is
                    // neither stably cached, pending in the suite, nor
                    // already carried by an earlier interval of this
                    // benchmark
                    let resolved_elsewhere =
                        cache.map_or(false, |c| cache_stable && c.contains(key))
                            || known.map_or(false, |k| k.contains(&key))
                            || bench_seen.as_deref().map_or(false, |s| s.contains(&key));
                    if !resolved_elsewhere {
                        if let Some(seen) = bench_seen.as_deref_mut() {
                            seen.insert(key);
                        }
                        fresh.push((
                            key,
                            ClipSample {
                                len: window.len() as u16,
                                tokens: tokenize_clip(&window, L_TOKEN),
                                ctx: context_tokens(&clip_regs, &REGISTER_SPEC),
                                time: 0.0,
                                key,
                                bench: 0,
                            },
                        ));
                    }
                }
            }
            window.clear();
        }
    }

    IntervalScan {
        refs: order.into_iter().map(|k| (k, counts[&k])).collect(),
        fresh,
    }
}

/// Fan the interval scans out over the worker pool; results come back in
/// input order, so everything downstream is schedule-independent.
/// `known` is a read-only snapshot of keys already pending elsewhere
/// (the suite engine's cross-benchmark accumulator) whose payloads need
/// not be rebuilt.
pub(crate) fn scan_intervals(
    selected: &[SelectedInterval],
    cfg: &PipelineConfig,
    cache: Option<&ClipCache>,
    known: Option<&HashSet<u64>>,
) -> Vec<IntervalScan> {
    let threads = cfg.effective_threads();
    if threads <= 1 {
        // sequential fast path: intervals run in order, so later intervals
        // can skip tokenizing keys an earlier one already carries — the
        // same cross-interval dedup the pre-sharding code did. Results are
        // identical to the parallel path (collect() drops the duplicate
        // payloads the parallel scans would have produced).
        let mut seen: HashSet<u64> = HashSet::new();
        return selected
            .iter()
            .map(|sel| scan_one(sel, cfg, cache, true, known, Some(&mut seen)))
            .collect();
    }
    let jobs: Vec<&SelectedInterval> = selected.iter().collect();
    // the phase-barrier callers complete every cache read before any
    // insert runs, so a `contains` observation is always stable here
    pool::parallel_map(jobs, threads, |sel| {
        scan_one(sel, cfg, cache, true, known, None)
    })
}

/// Sequential dedup + prediction state. One instance spans a single
/// benchmark in [`capsim_mode`], or a whole suite in
/// [`engine::capsim_suite`](super::engine::capsim_suite) (which is what
/// amortizes shared clips across benchmarks).
pub(crate) struct DedupState {
    /// key -> resolved predicted cycles.
    pred: HashMap<u64, f64>,
    /// New unique clips awaiting inference, in deterministic merge order.
    pending: Vec<(u64, ClipSample)>,
    pending_keys: HashSet<u64>,
}

/// Per-benchmark dedup accounting from [`DedupState::collect`].
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct CollectStats {
    pub clips_total: usize,
    pub clips_unique: usize,
    pub cache_hits: usize,
}

impl DedupState {
    pub(crate) fn new() -> DedupState {
        DedupState {
            pred: HashMap::new(),
            pending: Vec::new(),
            pending_keys: HashSet::new(),
        }
    }

    /// Keys currently awaiting inference — handed to later scans as the
    /// `known` set so they skip rebuilding payloads for them.
    pub(crate) fn pending_keys(&self) -> &HashSet<u64> {
        &self.pending_keys
    }

    /// Fold one benchmark's ordered interval scans into the dedup state.
    /// Strictly sequential and deterministic: the canonical payload (and
    /// therefore the context matrix) for a key is its first appearance in
    /// (interval order, position order).
    pub(crate) fn collect(
        &mut self,
        scans: &mut [IntervalScan],
        cache: Option<&ClipCache>,
    ) -> CollectStats {
        // move payloads out of the scans (first interval wins; duplicate
        // payloads from concurrently-scanned intervals are dropped here,
        // freeing their token buffers immediately)
        let mut payload: HashMap<u64, ClipSample> = HashMap::new();
        for scan in scans.iter_mut() {
            for (key, sample) in scan.fresh.drain(..) {
                payload.entry(key).or_insert(sample);
            }
        }
        let mut seen: HashSet<u64> = HashSet::new();
        let mut stats = CollectStats {
            clips_total: 0,
            clips_unique: 0,
            cache_hits: 0,
        };
        for scan in scans.iter() {
            for &(key, count) in &scan.refs {
                stats.clips_total += count as usize;
                if !seen.insert(key) {
                    continue; // earlier interval of this benchmark owns it
                }
                if self.pred.contains_key(&key) || self.pending_keys.contains(&key) {
                    stats.cache_hits += 1; // earlier benchmark owns it
                    continue;
                }
                if let Some(c) = cache {
                    if let Some(v) = c.get(key) {
                        self.pred.insert(key, v);
                        stats.cache_hits += 1;
                        continue;
                    }
                }
                let sample = payload
                    .remove(&key)
                    .expect("uncached key must carry a scan payload");
                self.pending.push((key, sample));
                self.pending_keys.insert(key);
                stats.clips_unique += 1;
            }
        }
        stats
    }

    /// Predict all pending unique clips in full accumulator batches,
    /// resolving them into the state (and the shared cache, if any).
    pub(crate) fn predict<P: Predictor + ?Sized>(
        &mut self,
        model: &P,
        time_scale: f32,
        cache: Option<&ClipCache>,
    ) -> Result<()> {
        let pending = std::mem::take(&mut self.pending);
        self.pending_keys.clear();
        if pending.is_empty() {
            return Ok(());
        }
        let mut acc = BatchAccumulator::new(model.max_fwd_batch(), model.geometry().clone());
        // one BatchRunner (workspace + prediction buffer) for every batch
        // of the run: steady-state forwards reuse the same scratch arena
        let mut runner = BatchRunner::new();
        for (key, sample) in pending {
            if let Some((keys, batch)) = acc.push(key, sample) {
                let preds = runner.forward(model, &batch, time_scale)?;
                self.resolve(&keys, preds, cache);
            }
        }
        // tail batch: the smallest compiled size that fits, not full cap
        let tail = acc.drain();
        if !tail.is_empty() {
            let keys: Vec<u64> = tail.iter().map(|&(k, _)| k).collect();
            let preds = runner.forward_tail(model, &tail, time_scale)?;
            self.resolve(&keys, preds, cache);
        }
        Ok(())
    }

    fn resolve(&mut self, keys: &[u64], preds: &[f32], cache: Option<&ClipCache>) {
        debug_assert_eq!(keys.len(), preds.len());
        for (&key, &p) in keys.iter().zip(preds) {
            let v = p as f64;
            self.pred.insert(key, v);
            if let Some(c) = cache {
                c.insert(key, v);
            }
        }
    }

    /// Sum resolved clip times per interval (occurrence-weighted).
    pub(crate) fn interval_cycles(&self, scans: &[IntervalScan]) -> Vec<f64> {
        scans
            .iter()
            .map(|scan| {
                scan.refs
                    .iter()
                    .map(|&(key, count)| {
                        let p = self
                            .pred
                            .get(&key)
                            .copied()
                            .expect("every referenced clip is resolved");
                        p * count as f64
                    })
                    .sum()
            })
            .collect()
    }
}

/// CAPSim mode (Fig. 1 right), sharded: the per-interval functional pass
/// (restore → trace → slice → tokenize-on-first-sight) fans out over the
/// pool, then a sequential merge dedups clips — against earlier intervals
/// and, through `cache`, against every benchmark processed before this
/// one — and predicts only the new unique clips in full batches.
///
/// Dedup is **content-keyed** (paper §IV-B): clips with the same
/// `fast_clip_key` share one prediction, computed from the context of the
/// key's *first sighting* — first in (interval, position) order within a
/// run, and suite-global when a shared cache spans benchmarks. With a
/// row-local backend (`--backend native` or `--backend attention`; the
/// pure-Rust transformer is row-local too) results are bit-identical
/// across `threads` settings, and repeating a run of the same
/// composition against a warm cache is bit-identical to its cold run;
/// runs of *different* compositions (a benchmark alone vs. after a
/// sibling that shares clips) may canonicalize a shared key to a
/// different first-sighting context, exactly as content-keyed dedup
/// prescribes. With the compiled PJRT model (`--backend pjrt`), thread
/// counts are still bit-identical and batch composition is
/// padding-invariant (≈1e-3 relative).
pub fn capsim_mode<P: Predictor + ?Sized>(
    selected: &[SelectedInterval],
    n_intervals: usize,
    cfg: &PipelineConfig,
    model: &P,
    time_scale: f32,
    cache: Option<&ClipCache>,
) -> Result<CapsimRun> {
    anyhow::ensure!(
        cfg.l_min <= L_CLIP,
        "l_min {} exceeds the model's clip capacity {L_CLIP}",
        cfg.l_min
    );
    let t0 = Instant::now();
    let mut scans = scan_intervals(selected, cfg, cache, None);
    let mut state = DedupState::new();
    let stats = state.collect(&mut scans, cache);
    state.predict(model, time_scale, cache)?;
    let interval_cycles = state.interval_cycles(&scans);
    let weights: Vec<f64> = selected.iter().map(|s| s.weight).collect();
    Ok(CapsimRun {
        total_cycles: extrapolate(&weights, &interval_cycles, n_intervals),
        interval_cycles,
        wall_s: t0.elapsed().as_secs_f64(),
        clips_total: stats.clips_total,
        clips_unique: stats.clips_unique,
        cache_hits: stats.cache_hits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::golden::build_bench_dataset;
    use crate::runtime::NativePredictor;
    use crate::simpoint::{choose_simpoints, profile};
    use crate::workloads::{suite, Scale};

    fn test_cfg() -> PipelineConfig {
        let mut c = PipelineConfig::default();
        c.simpoint.interval_insts = 8_000;
        c.simpoint.warmup_insts = 1_000;
        c.simpoint.max_k = 3;
        c.l_min = 24;
        c
    }

    fn selected_for(bench_idx: usize, cfg: &PipelineConfig) -> (Vec<SelectedInterval>, usize) {
        let benches = suite(Scale::Test);
        let prof = profile(&benches[bench_idx].program, &cfg.simpoint);
        let sel = choose_simpoints(&prof, &cfg.simpoint);
        let n = prof.intervals.len();
        (sel, n)
    }

    #[test]
    fn gem5_mode_produces_positive_cycles() {
        let benches = suite(Scale::Test);
        let cfg = test_cfg();
        let (_, bp) = build_bench_dataset(0, &benches[0], &cfg);
        let run = gem5_mode(&bp.selected, bp.n_intervals, &cfg);
        assert_eq!(run.interval_cycles.len(), bp.selected.len());
        assert!(run.interval_cycles.iter().all(|&c| c > 0));
        assert!(run.total_cycles > 0.0);
        assert!(run.wall_s > 0.0);
    }

    #[test]
    fn extrapolation_weights_sum() {
        // two intervals, equal weights 0.5 -> mean * n
        let v = extrapolate(&[0.5, 0.5], &[100.0, 300.0], 10);
        assert_eq!(v, 2000.0);
    }

    #[test]
    fn gem5_total_roughly_matches_full_simulation() {
        // For a small uniform benchmark, the SimPoint extrapolation should
        // land within ~35% of simulating the entire program.
        let benches = suite(Scale::Test);
        let cfg = test_cfg();
        let b = &benches[23]; // 999.specrand: near-uniform behaviour
        let (_, bp) = build_bench_dataset(23, b, &cfg);
        let run = gem5_mode(&bp.selected, bp.n_intervals, &cfg);

        let mut cpu = crate::functional::AtomicCpu::load(&b.program);
        let full = cpu.run_trace(5_000_000);
        let mut core = O3Core::new(cfg.o3.clone());
        let golden = core.simulate(&full).stats.cycles as f64;
        let rel = (run.total_cycles - golden).abs() / golden;
        assert!(rel < 0.35, "extrapolation off by {rel:.2}");
    }

    #[test]
    fn gem5_mode_thread_count_is_bit_identical() {
        let mut cfg = test_cfg();
        let (sel, n) = selected_for(2, &cfg);
        cfg.threads = 1;
        let a = gem5_mode(&sel, n, &cfg);
        cfg.threads = 4;
        let b = gem5_mode(&sel, n, &cfg);
        assert_eq!(a.interval_cycles, b.interval_cycles);
        assert_eq!(a.total_cycles.to_bits(), b.total_cycles.to_bits());
    }

    #[test]
    fn capsim_mode_native_runs_and_dedups() {
        let cfg = test_cfg();
        let (sel, n) = selected_for(0, &cfg);
        let model = NativePredictor::with_defaults();
        let run = capsim_mode(&sel, n, &cfg, &model, 40.0, None).unwrap();
        assert_eq!(run.interval_cycles.len(), sel.len());
        assert!(run.interval_cycles.iter().all(|&c| c > 0.0));
        assert!(run.total_cycles > 0.0);
        assert!(run.clips_unique > 0);
        assert!(run.clips_unique <= run.clips_total);
        assert_eq!(run.cache_hits, 0, "no cache was supplied");
    }

    #[test]
    fn capsim_mode_attention_backend_is_thread_invariant() {
        // the registry's pure-Rust attention backend rides the same
        // engine contract as the analytic stand-in: bit-identical
        // across thread counts. The artifacts dir is pointed somewhere
        // empty so a saved attention.bin cannot change the weights.
        let mut cfg = test_cfg();
        cfg.artifacts = "no-such-artifacts-dir".to_string();
        let (sel, n) = selected_for(1, &cfg);
        let model = crate::runtime::Backend::Attention.build_forward(&cfg).unwrap();
        cfg.threads = 1;
        let a = capsim_mode(&sel, n, &cfg, model.as_ref(), 40.0, None).unwrap();
        cfg.threads = 4;
        let b = capsim_mode(&sel, n, &cfg, model.as_ref(), 40.0, None).unwrap();
        let abits: Vec<u64> = a.interval_cycles.iter().map(|c| c.to_bits()).collect();
        let bbits: Vec<u64> = b.interval_cycles.iter().map(|c| c.to_bits()).collect();
        assert_eq!(abits, bbits);
        assert!(a.total_cycles > 0.0);
    }

    #[test]
    fn capsim_mode_thread_count_is_bit_identical() {
        let mut cfg = test_cfg();
        let (sel, n) = selected_for(3, &cfg);
        let model = NativePredictor::with_defaults();
        cfg.threads = 1;
        let a = capsim_mode(&sel, n, &cfg, &model, 40.0, None).unwrap();
        cfg.threads = 4;
        let b = capsim_mode(&sel, n, &cfg, &model, 40.0, None).unwrap();
        let abits: Vec<u64> = a.interval_cycles.iter().map(|c| c.to_bits()).collect();
        let bbits: Vec<u64> = b.interval_cycles.iter().map(|c| c.to_bits()).collect();
        assert_eq!(abits, bbits);
        assert_eq!(a.total_cycles.to_bits(), b.total_cycles.to_bits());
        assert_eq!(a.clips_unique, b.clips_unique);
    }

    #[test]
    fn warm_cache_reuses_every_clip_and_matches_cold() {
        let cfg = test_cfg();
        let (sel, n) = selected_for(1, &cfg);
        let model = NativePredictor::with_defaults();
        let cache = ClipCache::new();
        let cold = capsim_mode(&sel, n, &cfg, &model, 40.0, Some(&cache)).unwrap();
        assert!(cold.clips_unique > 0);
        assert_eq!(cache.len(), cold.clips_unique);
        let warm = capsim_mode(&sel, n, &cfg, &model, 40.0, Some(&cache)).unwrap();
        assert_eq!(warm.clips_unique, 0, "warm run predicts nothing new");
        assert_eq!(warm.cache_hits, cold.clips_unique + cold.cache_hits);
        let cbits: Vec<u64> = cold.interval_cycles.iter().map(|c| c.to_bits()).collect();
        let wbits: Vec<u64> = warm.interval_cycles.iter().map(|c| c.to_bits()).collect();
        assert_eq!(cbits, wbits, "cache must never change predictions");
    }
}
