//! The two simulation modes of Fig. 1 and the Fig.-7 speed comparison.

use std::time::Instant;

use anyhow::Result;

use crate::config::PipelineConfig;
use crate::context::{context_tokens, REGISTER_SPEC};
use crate::dataset::{ClipSample, Dataset};
use crate::o3::O3Core;
use crate::predictor::predict_all;
use crate::runtime::ModelHandle;
use crate::simpoint::SelectedInterval;

use crate::tokenizer::standardize::{fast_clip_key, tokenize_clip};

use super::golden::{L_CLIP, L_TOKEN};

/// gem5-mode result for one benchmark.
#[derive(Clone, Debug)]
pub struct Gem5Run {
    /// Measured cycles per selected interval (post-warmup portion).
    pub interval_cycles: Vec<u64>,
    /// SimPoint-extrapolated whole-program cycles.
    pub total_cycles: f64,
    /// Wall-clock seconds of the restore+simulate work.
    pub wall_s: f64,
}

/// CAPSim-mode result for one benchmark.
#[derive(Clone, Debug)]
pub struct CapsimRun {
    /// Predicted cycles per selected interval.
    pub interval_cycles: Vec<f64>,
    /// SimPoint-extrapolated whole-program cycles.
    pub total_cycles: f64,
    /// Wall-clock seconds (functional trace + slicing + inference).
    pub wall_s: f64,
    /// Total clips vs unique clips actually sent to the model.
    pub clips_total: usize,
    pub clips_unique: usize,
}

fn extrapolate(weights: &[f64], cycles: &[f64], n_intervals: usize) -> f64 {
    // SimPoint: total ≈ n_intervals * Σ weight_c * cycles(rep_c)
    n_intervals as f64
        * weights
            .iter()
            .zip(cycles)
            .map(|(w, c)| w * c)
            .sum::<f64>()
}

/// Restore every selected checkpoint into the O3 model (the paper's
/// conventional flow, Fig. 1 left).
pub fn gem5_mode(
    selected: &[SelectedInterval],
    n_intervals: usize,
    cfg: &PipelineConfig,
) -> Gem5Run {
    let t0 = Instant::now();
    let mut core = O3Core::new(cfg.o3.clone());
    let warm = cfg.simpoint.warmup_insts;
    let mut interval_cycles = Vec::with_capacity(selected.len());
    for sel in selected {
        let mut cpu = sel.checkpoint.restore();
        let trace = cpu.run_trace(warm + cfg.simpoint.interval_insts);
        core.reset();
        let r = core.simulate(&trace);
        // measured portion = everything after the warm-up instructions;
        // if the program ended inside warm-up, fall back to full cycles
        let measured = if trace.len() > warm as usize {
            r.stats.cycles - r.commit_cycle[warm as usize]
        } else {
            r.stats.cycles
        };
        interval_cycles.push(measured.max(1));
    }
    let weights: Vec<f64> = selected.iter().map(|s| s.weight).collect();
    let cycles: Vec<f64> = interval_cycles.iter().map(|&c| c as f64).collect();
    Gem5Run {
        total_cycles: extrapolate(&weights, &cycles, n_intervals),
        interval_cycles,
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

/// CAPSim mode (Fig. 1 right): ONE functional pass per interval producing
/// fixed-length clips with register snapshots at their starts; clips are
/// deduplicated by a raw-field content key so only first-seen clips are
/// tokenized, then predicted in batches and summed per interval.
pub fn capsim_mode(
    selected: &[SelectedInterval],
    n_intervals: usize,
    cfg: &PipelineConfig,
    model: &ModelHandle,
    time_scale: f32,
) -> Result<CapsimRun> {
    let t0 = Instant::now();
    let warm = cfg.simpoint.warmup_insts;
    let l_min = cfg.l_min as u64;

    // one dedup space across the whole benchmark: identical loop bodies
    // recur across intervals, and the predictor only needs each once
    let mut unique = Dataset::new(L_TOKEN, L_CLIP, crate::context::M_ROWS);
    let mut key_slot: std::collections::HashMap<u64, usize> = Default::default();
    // per interval: (slot, occurrence-count) pairs
    let mut interval_refs: Vec<Vec<(usize, u64)>> = Vec::with_capacity(selected.len());
    let mut window: Vec<crate::functional::TraceRecord> =
        Vec::with_capacity(cfg.l_min);

    for sel in selected {
        let mut cpu = sel.checkpoint.restore();
        // fast-forward through warm-up (no records kept)
        cpu.run_with(warm, |_| {});

        let mut counts: std::collections::HashMap<usize, u64> = Default::default();
        let mut executed = 0u64;
        window.clear();
        let mut clip_regs = cpu.regs.clone();
        while executed < cfg.simpoint.interval_insts && !cpu.halted {
            if window.is_empty() {
                clip_regs = cpu.regs.clone(); // context at clip start
            }
            window.push(*cpu.step().record());
            executed += 1;
            if window.len() as u64 == l_min {
                let key = fast_clip_key(&window);
                let slot = match key_slot.entry(key) {
                    std::collections::hash_map::Entry::Occupied(e) => *e.get(),
                    std::collections::hash_map::Entry::Vacant(e) => {
                        // first sighting: tokenize + context-annotate
                        let tokens = tokenize_clip(&window, L_TOKEN);
                        unique.push(ClipSample {
                            len: window.len() as u16,
                            tokens,
                            ctx: context_tokens(&clip_regs, &REGISTER_SPEC),
                            time: 0.0,
                            key,
                            bench: 0,
                        });
                        *e.insert(unique.len() - 1)
                    }
                };
                *counts.entry(slot).or_insert(0) += 1;
                window.clear();
            }
        }
        interval_refs.push(counts.into_iter().collect());
    }

    // batched inference over unique clips only
    let idx: Vec<usize> = (0..unique.len()).collect();
    let preds = predict_all(model, &unique, &idx, time_scale)?;

    let mut interval_cycles = Vec::with_capacity(selected.len());
    let mut clips_total = 0usize;
    for refs in &interval_refs {
        let mut sum = 0.0;
        for &(slot, count) in refs {
            sum += preds[slot] * count as f64;
            clips_total += count as usize;
        }
        interval_cycles.push(sum);
    }

    let weights: Vec<f64> = selected.iter().map(|s| s.weight).collect();
    Ok(CapsimRun {
        total_cycles: extrapolate(&weights, &interval_cycles, n_intervals),
        interval_cycles,
        wall_s: t0.elapsed().as_secs_f64(),
        clips_total,
        clips_unique: unique.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::golden::build_bench_dataset;
    use crate::workloads::{suite, Scale};

    fn test_cfg() -> PipelineConfig {
        let mut c = PipelineConfig::default();
        c.simpoint.interval_insts = 8_000;
        c.simpoint.warmup_insts = 1_000;
        c.simpoint.max_k = 3;
        c.l_min = 24;
        c
    }

    #[test]
    fn gem5_mode_produces_positive_cycles() {
        let benches = suite(Scale::Test);
        let cfg = test_cfg();
        let (_, bp) = build_bench_dataset(0, &benches[0], &cfg);
        let run = gem5_mode(&bp.selected, bp.n_intervals, &cfg);
        assert_eq!(run.interval_cycles.len(), bp.selected.len());
        assert!(run.interval_cycles.iter().all(|&c| c > 0));
        assert!(run.total_cycles > 0.0);
        assert!(run.wall_s > 0.0);
    }

    #[test]
    fn extrapolation_weights_sum() {
        // two intervals, equal weights 0.5 -> mean * n
        let v = extrapolate(&[0.5, 0.5], &[100.0, 300.0], 10);
        assert_eq!(v, 2000.0);
    }

    #[test]
    fn gem5_total_roughly_matches_full_simulation() {
        // For a small uniform benchmark, the SimPoint extrapolation should
        // land within ~35% of simulating the entire program.
        let benches = suite(Scale::Test);
        let cfg = test_cfg();
        let b = &benches[23]; // 999.specrand: near-uniform behaviour
        let (_, bp) = build_bench_dataset(23, b, &cfg);
        let run = gem5_mode(&bp.selected, bp.n_intervals, &cfg);

        let mut cpu = crate::functional::AtomicCpu::load(&b.program);
        let full = cpu.run_trace(5_000_000);
        let mut core = O3Core::new(cfg.o3.clone());
        let golden = core.simulate(&full).stats.cycles as f64;
        let rel = (run.total_cycles - golden).abs() / golden;
        assert!(rel < 0.35, "extrapolation off by {rel:.2}");
    }
}
