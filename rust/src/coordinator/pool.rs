//! A minimal scoped worker pool (no rayon in the offline crate set).
//!
//! `parallel_map` distributes independent jobs over `threads` workers and
//! returns results in input order. With one core (this image) it degrades
//! to sequential execution with identical results — determinism is part of
//! the contract either way.
//!
//! Panic contract: a panicking job re-raises with its **original
//! payload** on the caller thread (not the opaque `PoisonError` a
//! poisoned slot mutex would otherwise produce), and once any worker has
//! observed a panic the remaining workers stop pulling new jobs — a
//! failing run winds down instead of burning through the whole job list.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a mutex, ignoring poison: every slot value here is only read
/// after the panic has been captured separately, so a poisoned guard
/// carries no torn state worth refusing.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Map `f` over `items` using up to `threads` OS threads; results keep
/// input order. `f` must be `Sync` (called concurrently by reference).
/// If a job panics, the first panic payload is re-raised here once the
/// workers have wound down (see the module docs).
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }

    let jobs: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let panicked = AtomicBool::new(false);
    let first_panic: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                if panicked.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = lock_clean(&jobs[i]).take().unwrap();
                match catch_unwind(AssertUnwindSafe(|| f(item))) {
                    Ok(r) => *lock_clean(&results[i]) = Some(r),
                    Err(payload) => {
                        let mut slot = lock_clean(&first_panic);
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                        panicked.store(true, Ordering::Relaxed);
                        break;
                    }
                }
            });
        }
    });

    if let Some(payload) = lock_clean(&first_panic).take() {
        resume_unwind(payload);
    }
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(PoisonError::into_inner)
                .expect("every job completed (no panic was captured)")
        })
        .collect()
}

/// Parse a `CAPSIM_THREADS`-style override: a positive integer.
/// `0`, garbage, and absence all mean "no override".
pub(crate) fn threads_override(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

/// Number of worker threads to use when the config leaves it on auto.
///
/// Precedence, highest first: `--threads N` on the CLI and
/// `pipeline.threads` in TOML both set `PipelineConfig::threads`
/// directly (CLI wins because it is applied after the file), so this
/// function is only consulted when both leave it at `0` = auto. Then
/// the `CAPSIM_THREADS` environment variable applies — useful for CI
/// determinism and for containers whose cgroup CPU limit is lower than
/// what `available_parallelism` reports — and finally the detected core
/// count.
pub fn default_threads() -> usize {
    if let Some(n) = threads_override(std::env::var("CAPSIM_THREADS").ok().as_deref()) {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect(), 4, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_fallback_matches() {
        let a = parallel_map((0..20).collect(), 1, |x: i32| x + 1);
        let b = parallel_map((0..20).collect(), 8, |x: i32| x + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn actually_runs_concurrently_when_possible() {
        // can't assert true parallelism on 1 core; assert all jobs ran
        let out = parallel_map((0..50).collect(), default_threads(), |x: i32| x);
        assert_eq!(out.len(), 50);
    }

    /// A panicking job must surface its own message, not the opaque
    /// `PoisonError` the pre-fix result-collection loop raised when it
    /// hit a slot mutex the dying worker had poisoned.
    #[test]
    fn worker_panic_preserves_the_original_message() {
        let caught = std::panic::catch_unwind(|| {
            parallel_map((0..200).collect::<Vec<i32>>(), 4, |x: i32| {
                if x == 0 {
                    panic!("boom at job zero");
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
                x
            })
        });
        let payload = caught.expect_err("the job panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("<non-str payload>");
        assert_eq!(msg, "boom at job zero");
    }

    #[test]
    fn workers_stop_pulling_jobs_after_a_panic() {
        let executed = AtomicUsize::new(0);
        let caught = std::panic::catch_unwind(|| {
            parallel_map((0..1000).collect::<Vec<i32>>(), 2, |x: i32| {
                if x == 0 {
                    panic!("first job fails");
                }
                executed.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(1));
                x
            })
        });
        assert!(caught.is_err());
        // job 0 panics within microseconds; with 1ms per remaining job the
        // other worker cannot drain the whole list before seeing the flag
        assert!(
            executed.load(Ordering::Relaxed) < 999,
            "remaining jobs must be skipped once a panic is observed"
        );
    }

    #[test]
    fn env_override_parsing() {
        // parse logic is pure so it tests without mutating process env
        // (tests run concurrently; std::env::set_var would race)
        assert_eq!(threads_override(Some("4")), Some(4));
        assert_eq!(threads_override(Some(" 16 ")), Some(16));
        assert_eq!(threads_override(Some("0")), None, "0 keeps auto-detect");
        assert_eq!(threads_override(Some("-2")), None);
        assert_eq!(threads_override(Some("many")), None);
        assert_eq!(threads_override(Some("")), None);
        assert_eq!(threads_override(None), None);
    }
}
