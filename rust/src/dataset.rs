//! Clip datasets: the tokenized, context-annotated, time-labelled samples
//! that train and evaluate the predictor, plus the splits the paper's two
//! evaluation methods need (§VI-B):
//!
//! * **Method 1** — mix all benchmarks, split 80/10/10 train/val/test;
//! * **Method 2** — group by the six Table-II sets, train on one set and
//!   test on another (the 6x6 matrix of Fig. 11).

use std::io::{Read, Write};
use std::path::Path;

use crate::util::Rng;

/// One training/evaluation sample.
#[derive(Clone, Debug, PartialEq)]
pub struct ClipSample {
    /// Standardized tokens, `len * l_token`, row-major.
    pub tokens: Vec<u16>,
    /// Number of instructions in the clip (<= l_clip).
    pub len: u16,
    /// Context-matrix tokens (length M).
    pub ctx: Vec<u16>,
    /// Golden execution time in cycles.
    pub time: f32,
    /// Content key (dedup / Fig. 8).
    pub key: u64,
    /// Benchmark index into the suite.
    pub bench: u16,
}

/// A full dataset with fixed model geometry.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub l_token: usize,
    pub l_clip: usize,
    pub m_rows: usize,
    pub samples: Vec<ClipSample>,
    /// Clips dropped because they exceeded `l_clip` instructions.
    pub dropped_long: usize,
}

impl Dataset {
    pub fn new(l_token: usize, l_clip: usize, m_rows: usize) -> Self {
        Dataset { l_token, l_clip, m_rows, ..Default::default() }
    }

    /// Add a sample; drops clips longer than `l_clip` (counted).
    pub fn push(&mut self, s: ClipSample) {
        debug_assert_eq!(s.ctx.len(), self.m_rows);
        if (s.len as usize) > self.l_clip {
            self.dropped_long += 1;
            return;
        }
        debug_assert_eq!(s.tokens.len(), s.len as usize * self.l_token);
        self.samples.push(s);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Mean golden time — the `time_scale` fed to the AOT model.
    pub fn mean_time(&self) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        self.samples.iter().map(|s| s.time as f64).sum::<f64>()
            / self.samples.len() as f64
    }

    /// Method-1 split: shuffled 80/10/10 (train, val, test) index sets.
    pub fn split(&self, seed: u64) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
        let mut idx: Vec<usize> = (0..self.samples.len()).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut idx);
        let n = idx.len();
        let n_train = n * 8 / 10;
        let n_val = n / 10;
        let train = idx[..n_train].to_vec();
        let val = idx[n_train..n_train + n_val].to_vec();
        let test = idx[n_train + n_val..].to_vec();
        (train, val, test)
    }

    /// Method-2 grouping: indices per Table-II set (1..=6), using a
    /// benchmark-index -> set-number map.
    pub fn by_set(&self, set_of_bench: &[u8]) -> [Vec<usize>; 6] {
        let mut out: [Vec<usize>; 6] = Default::default();
        for (i, s) in self.samples.iter().enumerate() {
            let set = set_of_bench[s.bench as usize];
            debug_assert!((1..=6).contains(&set));
            out[(set - 1) as usize].push(i);
        }
        out
    }

    /// Indices per benchmark (Fig. 10's per-benchmark error bars).
    pub fn by_bench(&self, num_benches: usize) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); num_benches];
        for (i, s) in self.samples.iter().enumerate() {
            out[s.bench as usize].push(i);
        }
        out
    }

    /// Content keys in sample order (sampler / Fig. 8 input).
    pub fn keys(&self) -> Vec<u64> {
        self.samples.iter().map(|s| s.key).collect()
    }

    /// Restrict to a subset of indices (post-sampling dataset).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            l_token: self.l_token,
            l_clip: self.l_clip,
            m_rows: self.m_rows,
            samples: idx.iter().map(|&i| self.samples[i].clone()).collect(),
            dropped_long: 0,
        }
    }

    // ---- binary (de)serialization — caching golden-label generation ----

    const MAGIC: u32 = 0x43415053; // "CAPS"

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(&Self::MAGIC.to_le_bytes())?;
        for v in [self.l_token, self.l_clip, self.m_rows, self.samples.len(),
                  self.dropped_long] {
            w.write_all(&(v as u64).to_le_bytes())?;
        }
        for s in &self.samples {
            w.write_all(&s.len.to_le_bytes())?;
            w.write_all(&s.bench.to_le_bytes())?;
            w.write_all(&s.time.to_le_bytes())?;
            w.write_all(&s.key.to_le_bytes())?;
            for t in &s.tokens {
                w.write_all(&t.to_le_bytes())?;
            }
            for t in &s.ctx {
                w.write_all(&t.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Dataset> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut u32b = [0u8; 4];
        let mut u64b = [0u8; 8];
        r.read_exact(&mut u32b)?;
        if u32::from_le_bytes(u32b) != Self::MAGIC {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad dataset magic",
            ));
        }
        let mut next = |r: &mut dyn Read| -> std::io::Result<u64> {
            r.read_exact(&mut u64b)?;
            Ok(u64::from_le_bytes(u64b))
        };
        let l_token = next(&mut r)? as usize;
        let l_clip = next(&mut r)? as usize;
        let m_rows = next(&mut r)? as usize;
        let count = next(&mut r)? as usize;
        let dropped_long = next(&mut r)? as usize;
        let mut samples = Vec::with_capacity(count);
        for _ in 0..count {
            let mut u16b = [0u8; 2];
            r.read_exact(&mut u16b)?;
            let len = u16::from_le_bytes(u16b);
            r.read_exact(&mut u16b)?;
            let bench = u16::from_le_bytes(u16b);
            r.read_exact(&mut u32b)?;
            let time = f32::from_le_bytes(u32b);
            r.read_exact(&mut u64b)?;
            let key = u64::from_le_bytes(u64b);
            let mut tokens = vec![0u16; len as usize * l_token];
            for t in tokens.iter_mut() {
                r.read_exact(&mut u16b)?;
                *t = u16::from_le_bytes(u16b);
            }
            let mut ctx = vec![0u16; m_rows];
            for t in ctx.iter_mut() {
                r.read_exact(&mut u16b)?;
                *t = u16::from_le_bytes(u16b);
            }
            samples.push(ClipSample { tokens, len, ctx, time, key, bench });
        }
        Ok(Dataset { l_token, l_clip, m_rows, samples, dropped_long })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(len: u16, bench: u16, time: f32, key: u64) -> ClipSample {
        ClipSample {
            tokens: vec![1; len as usize * 4],
            len,
            ctx: vec![7; 9],
            time,
            key,
            bench,
        }
    }

    fn dataset(n: usize) -> Dataset {
        let mut d = Dataset::new(4, 8, 9);
        for i in 0..n {
            d.push(sample(
                4 + (i % 4) as u16,
                (i % 24) as u16,
                10.0 + i as f32,
                (i % 50) as u64,
            ));
        }
        d
    }

    #[test]
    fn push_drops_overlong() {
        let mut d = Dataset::new(4, 8, 9);
        d.push(sample(8, 0, 5.0, 1));
        d.push(sample(9, 0, 5.0, 2));
        assert_eq!(d.len(), 1);
        assert_eq!(d.dropped_long, 1);
    }

    #[test]
    fn split_is_partition() {
        let d = dataset(500);
        let (tr, va, te) = d.split(3);
        assert_eq!(tr.len() + va.len() + te.len(), 500);
        assert_eq!(tr.len(), 400);
        assert_eq!(va.len(), 50);
        let mut all: Vec<usize> = tr.iter().chain(&va).chain(&te).copied().collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 500);
    }

    #[test]
    fn split_deterministic_per_seed() {
        let d = dataset(100);
        assert_eq!(d.split(1).0, d.split(1).0);
        assert_ne!(d.split(1).0, d.split(2).0);
    }

    #[test]
    fn by_set_covers_all() {
        let d = dataset(240);
        // map bench i -> set (i % 6) + 1
        let set_of: Vec<u8> = (0..24).map(|i| (i % 6 + 1) as u8).collect();
        let sets = d.by_set(&set_of);
        let total: usize = sets.iter().map(|s| s.len()).sum();
        assert_eq!(total, 240);
        for s in &sets {
            assert_eq!(s.len(), 40);
        }
    }

    #[test]
    fn mean_time_and_keys() {
        let mut d = Dataset::new(4, 8, 9);
        d.push(sample(4, 0, 10.0, 5));
        d.push(sample(4, 0, 30.0, 5));
        assert_eq!(d.mean_time(), 20.0);
        assert_eq!(d.keys(), vec![5, 5]);
    }

    #[test]
    fn save_load_roundtrip() {
        let d = dataset(50);
        let path = std::env::temp_dir().join("capsim_ds_test.bin");
        d.save(&path).unwrap();
        let d2 = Dataset::load(&path).unwrap();
        assert_eq!(d.samples, d2.samples);
        assert_eq!(d.l_token, d2.l_token);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn subset_picks_exact_rows() {
        let d = dataset(20);
        let s = d.subset(&[3, 7]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.samples[0], d.samples[3]);
        assert_eq!(s.samples[1], d.samples[7]);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("capsim_ds_garbage.bin");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
