//! The 24 benchmarks of Table II, as synthetic PISA analogues.
//!
//! Name, tags (CTRL / COMP / MEM) and set number (1..6) are copied from the
//! paper's Table II; the program behind each name is a seeded composition of
//! the kernels in [`super::kernels`] chosen to realize that benchmark's
//! behavioural mix (e.g. `500.perlbench` = bytecode interpreter = CTRL;
//! `503.bwaves` = FP stencil = COMP+MEM). Most benchmarks are multi-phase so
//! SimPoint has real cluster structure to find.

use crate::isa::asm::Program;
use crate::isa::Assembler;
use crate::util::Rng;

use super::kernels::*;

/// Behaviour tags from Table II.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tag {
    Ctrl,
    Comp,
    Mem,
}

impl Tag {
    pub fn short(&self) -> &'static str {
        match self {
            Tag::Ctrl => "CTRL",
            Tag::Comp => "COMP",
            Tag::Mem => "MEM",
        }
    }
}

/// Workload scale: `Test` keeps unit tests fast; `Full` is the
/// EXPERIMENTS.md configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// ~30-80k dynamic instructions per benchmark.
    Test,
    /// ~0.5-1.5M dynamic instructions per benchmark.
    Full,
}

/// Extra multiplier on full-scale iteration counts, calibrated so each
/// benchmark runs ~5-20M dynamic instructions — enough for several
/// 1M-instruction SimPoint intervals (the EXPERIMENTS.md geometry).
const FULL_BOOST: i32 = 10;

impl Scale {
    /// Multiplier applied to iteration counts.
    fn x(&self, test: i32, full: i32) -> i32 {
        match self {
            Scale::Test => test,
            Scale::Full => full.saturating_mul(FULL_BOOST),
        }
    }
}

/// One Table-II benchmark.
pub struct Benchmark {
    pub name: &'static str,
    pub tags: &'static [Tag],
    /// Cross-generalization set (1..=6), from Table II.
    pub set_no: u8,
    pub program: Program,
}

impl Benchmark {
    pub fn tag_string(&self) -> String {
        self.tags
            .iter()
            .map(|t| t.short())
            .collect::<Vec<_>>()
            .join("+")
    }

    pub fn has_tag(&self, t: Tag) -> bool {
        self.tags.contains(&t)
    }
}

struct Builder {
    a: Assembler,
    rng: Rng,
}

impl Builder {
    fn new(seed: u64) -> Self {
        let mut a = Assembler::new(0x1000);
        let rng = Rng::new(seed);
        fp_constants(&mut a, HEAP2 + 0x20000);
        Builder { a, rng }
    }

    fn finish(mut self) -> Program {
        self.a.halt();
        self.a.finish()
    }
}

macro_rules! bench {
    ($name:literal, $tags:expr, $set:literal, $seed:literal, $s:ident, $body:expr) => {{
        #[allow(unused_mut)]
        let mut b = Builder::new($seed);
        {
            let a = &mut b.a;
            let rng = &mut b.rng;
            let _ = rng;
            let f: &dyn Fn(&mut Assembler, &mut Rng, Scale) = &$body;
            f(a, rng, $s);
        }
        Benchmark { name: $name, tags: $tags, set_no: $set, program: b.finish() }
    }};
}

/// Build the full 24-benchmark suite (Table II order).
pub fn suite(s: Scale) -> Vec<Benchmark> {
    use Tag::*;
    const CTRL: &[Tag] = &[Tag::Ctrl];
    const COMP: &[Tag] = &[Tag::Comp];
    const COMP_MEM: &[Tag] = &[Tag::Comp, Tag::Mem];
    const CTRL_MEM: &[Tag] = &[Tag::Ctrl, Tag::Mem];
    let _ = (Ctrl, Comp, Mem);

    vec![
        // 500.perlbench — bytecode interpreter, CTRL, set 1
        bench!("500.perlbench", CTRL, 1, 500, s, |a, r, s: Scale| {
            random_data(a, HEAP0, 256, r);
            interpreter(a, HEAP0, 256, s.x(2_000, 60_000));
            recursive_search(a, 4, 3, s.x(2, 40));
            interpreter(a, HEAP0, 256, s.x(1_000, 40_000));
        }),
        // 502.gcc — tree walking + interpretation, CTRL, set 2
        bench!("502.gcc", CTRL, 2, 502, s, |a, r, s: Scale| {
            random_data(a, HEAP0, 512, r);
            recursive_search(a, 6, 3, s.x(2, 60));
            interpreter(a, HEAP0, 512, s.x(1_500, 50_000));
        }),
        // 503.bwaves — FP stencil, COMP+MEM, set 1
        bench!("503.bwaves", COMP_MEM, 1, 503, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 48 * 48, r);
            stencil2d(a, HEAP0, 48, 48, s.x(2, 60));
            stream_triad(a, HEAP1, 512, s.x(2, 40));
        }),
        // 505.mcf — pointer chasing + relaxation, COMP+MEM, set 2
        bench!("505.mcf", COMP_MEM, 2, 505, s, |a, r, s: Scale| {
            pointer_ring_data(a, HEAP0, 1024, r);
            pointer_chase(a, HEAP0, s.x(5_000, 250_000));
            random_data(a, HEAP1, 2048, r);
            hash_probe(a, HEAP1, 2047, s.x(2_000, 80_000));
        }),
        // 507.cactuBSSN — big-stencil FP, COMP+MEM, set 3
        bench!("507.cactuBSSN", COMP_MEM, 3, 507, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 64 * 64, r);
            stencil2d(a, HEAP0, 64, 64, s.x(2, 40));
            fp_arrays(a, HEAP1, 4, 256, s.x(2, 60), false);
        }),
        // 508.namd — n-body forces, COMP+MEM, set 4
        bench!("508.namd", COMP_MEM, 4, 508, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 3 * 512, r);
            nbody_forces(a, HEAP0, 512, s.x(4, 140));
        }),
        // 510.parest — sparse solver flavour, COMP+MEM, set 5
        bench!("510.parest", COMP_MEM, 5, 510, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 2048, r);
            fp_arrays(a, HEAP0, 3, 512, s.x(3, 70), true);
            stream_triad(a, HEAP1, 512, s.x(2, 50));
        }),
        // 511.povray — FP + branches, COMP+MEM, set 6
        bench!("511.povray", COMP_MEM, 6, 511, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 1024, r);
            nbody_forces(a, HEAP0, 256, s.x(3, 60));
            random_data(a, HEAP1, 512, r);
            interpreter(a, HEAP1, 512, s.x(1_000, 30_000));
            fp_arrays(a, HEAP0, 2, 256, s.x(2, 40), true);
        }),
        // 519.lbm — lattice update, COMP+MEM, set 1
        bench!("519.lbm", COMP_MEM, 1, 519, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 5 * 1200, r);
            lattice_update(a, HEAP0, 1000, s.x(3, 90));
        }),
        // 520.omnetpp — event simulation, CTRL, set 3
        bench!("520.omnetpp", CTRL, 3, 520, s, |a, r, s: Scale| {
            random_data(a, HEAP0, 1024, r);
            event_heap(a, HEAP0, 1024, s.x(3_000, 120_000));
        }),
        // 521.wrf — multi-array FP, COMP+MEM, set 2
        bench!("521.wrf", COMP_MEM, 2, 521, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 4096, r);
            fp_arrays(a, HEAP0, 4, 768, s.x(2, 50), false);
            stencil2d(a, HEAP1, 40, 40, s.x(2, 30));
        }),
        // 523.xalancbmk — tree/hash traversal, CTRL+MEM, set 4
        bench!("523.xalancbmk", CTRL_MEM, 4, 523, s, |a, r, s: Scale| {
            random_data(a, HEAP0, 4096, r);
            hash_probe(a, HEAP0, 4095, s.x(3_000, 100_000));
            pointer_ring_data(a, HEAP1, 512, r);
            pointer_chase(a, HEAP1, s.x(2_000, 60_000));
        }),
        // 525.x264 — integer block ops, COMP, set 3
        bench!("525.x264", COMP, 3, 525, s, |a, r, s: Scale| {
            random_data(a, HEAP0, 8192, r);
            sad_blocks(a, HEAP0, 512, s.x(4, 120));
            alu_parallel(a, s.x(2_000, 60_000));
        }),
        // 526.blender — FP transform, COMP+MEM, set 4
        bench!("526.blender", COMP_MEM, 4, 526, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 3072, r);
            fp_arrays(a, HEAP0, 4, 512, s.x(3, 70), false);
            lattice_update(a, HEAP1, 400, s.x(2, 40));
        }),
        // 527.cam4 — physics loops, COMP+MEM, set 5
        bench!("527.cam4", COMP_MEM, 5, 527, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 4096, r);
            fp_arrays(a, HEAP0, 4, 640, s.x(2, 45), true);
            stencil2d(a, HEAP1, 32, 32, s.x(2, 40));
            stream_triad(a, HEAP2, 256, s.x(2, 30));
        }),
        // 531.deepsjeng — recursive search, CTRL, set 5
        bench!("531.deepsjeng", CTRL, 5, 531, s, |a, r, s: Scale| {
            recursive_search(a, 7, 3, s.x(2, 50));
            random_data(a, HEAP0, 512, r);
            interpreter(a, HEAP0, 512, s.x(800, 25_000));
        }),
        // 538.imagick — convolution, COMP+MEM, set 6
        bench!("538.imagick", COMP_MEM, 6, 538, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 48 * 48, r);
            stencil2d(a, HEAP0, 48, 48, s.x(2, 50));
            sad_blocks(a, HEAP1, 256, s.x(3, 80));
        }),
        // 541.leela — MCTS-ish walks, CTRL+MEM, set 1
        bench!("541.leela", CTRL_MEM, 1, 541, s, |a, r, s: Scale| {
            random_data(a, HEAP0, 2048, r);
            event_heap(a, HEAP0, 2048, s.x(1_500, 50_000));
            recursive_search(a, 5, 3, s.x(2, 35));
            hash_probe(a, HEAP1, 1023, s.x(1_000, 40_000));
        }),
        // 544.nab — molecular FP, COMP+MEM, set 2
        bench!("544.nab", COMP_MEM, 2, 544, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 3 * 640, r);
            nbody_forces(a, HEAP0, 640, s.x(3, 80));
            fp_arrays(a, HEAP1, 2, 256, s.x(2, 40), false);
        }),
        // 548.exchange2 — backtracking, CTRL+MEM, set 6
        bench!("548.exchange2", CTRL_MEM, 6, 548, s, |a, r, s: Scale| {
            recursive_search(a, 8, 2, s.x(3, 70));
            random_data(a, HEAP0, 1024, r);
            event_heap(a, HEAP0, 1024, s.x(1_000, 40_000));
        }),
        // 549.fotonik3d — FDTD stencil, COMP+MEM, set 3
        bench!("549.fotonik3d", COMP_MEM, 3, 549, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 56 * 56, r);
            stencil2d(a, HEAP0, 56, 56, s.x(2, 45));
            lattice_update(a, HEAP1, 600, s.x(2, 40));
        }),
        // 554.roms — ocean model, COMP+MEM, set 4
        bench!("554.roms", COMP_MEM, 4, 554, s, |a, r, s: Scale| {
            random_f64_data(a, HEAP0, 4096, r);
            fp_arrays(a, HEAP0, 4, 512, s.x(2, 40), true);
            stream_triad(a, HEAP1, 768, s.x(2, 45));
            stencil2d(a, HEAP2, 32, 32, s.x(1, 25));
        }),
        // 557.xz — match finder, COMP+MEM, set 5
        bench!("557.xz", COMP_MEM, 5, 557, s, |a, r, s: Scale| {
            random_data(a, HEAP0, 8192, r);
            match_finder(a, HEAP0, 4096, s.x(3_000, 110_000));
            sad_blocks(a, HEAP1, 256, s.x(2, 40));
        }),
        // 999.specrand — PRNG scatter, COMP+MEM, set 6
        bench!("999.specrand", COMP_MEM, 6, 999, s, |a, _r, s: Scale| {
            prng_scatter(a, HEAP0, 8191, s.x(4_000, 150_000));
            alu_chain(a, s.x(1_000, 30_000));
        }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::AtomicCpu;

    #[test]
    fn suite_matches_table2_shape() {
        let s = suite(Scale::Test);
        assert_eq!(s.len(), 24);
        // six sets, each with 4 benchmarks (Table II)
        for set in 1..=6u8 {
            let n = s.iter().filter(|b| b.set_no == set).count();
            assert_eq!(n, 4, "set {set} must have 4 benchmarks");
        }
        // names unique
        let mut names: Vec<_> = s.iter().map(|b| b.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 24);
    }

    #[test]
    fn every_benchmark_halts_at_test_scale() {
        for b in suite(Scale::Test) {
            let mut cpu = AtomicCpu::load(&b.program);
            cpu.run_with(3_000_000, |_| {});
            assert!(cpu.halted, "{} did not halt", b.name);
            assert!(cpu.icount > 5_000, "{} too short: {}", b.name, cpu.icount);
        }
    }

    #[test]
    fn tags_predict_behaviour() {
        // CTRL-tagged benchmarks should have a clearly higher conditional
        // branch share than pure COMP+MEM ones.
        let mut ctrl_rate = Vec::new();
        let mut comp_rate = Vec::new();
        for b in suite(Scale::Test) {
            let mut cpu = AtomicCpu::load(&b.program);
            let mut branches = 0u64;
            let n = cpu.run_with(200_000, |r| {
                if r.inst.is_cond_branch() {
                    branches += 1;
                }
            });
            let rate = branches as f64 / n as f64;
            if b.has_tag(Tag::Ctrl) {
                ctrl_rate.push(rate);
            } else if !b.has_tag(Tag::Ctrl) {
                comp_rate.push(rate);
            }
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            avg(&ctrl_rate) > avg(&comp_rate),
            "CTRL {:.3} should exceed COMP {:.3}",
            avg(&ctrl_rate),
            avg(&comp_rate)
        );
    }

    #[test]
    fn mem_benchmarks_touch_more_memory() {
        let mut mem_rate = Vec::new();
        let mut nonmem_rate = Vec::new();
        for b in suite(Scale::Test) {
            let mut cpu = AtomicCpu::load(&b.program);
            let mut mems = 0u64;
            let n = cpu.run_with(200_000, |r| {
                if r.inst.is_mem() {
                    mems += 1;
                }
            });
            let rate = mems as f64 / n as f64;
            if b.has_tag(Tag::Mem) {
                mem_rate.push(rate);
            } else {
                nonmem_rate.push(rate);
            }
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(avg(&mem_rate) > avg(&nonmem_rate));
    }

    #[test]
    fn deterministic_programs() {
        let a = suite(Scale::Test);
        let b = suite(Scale::Test);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.program.words, y.program.words, "{}", x.name);
        }
    }
}
