//! Parameterized assembly kernels — the building blocks of the 24
//! Table-II-analog benchmarks.
//!
//! Register conventions used throughout:
//! * `r1`  — stack pointer for call/return kernels (stack at `STACK_TOP`,
//!   grows down);
//! * `r20..r31` — loop bounds / base addresses (long-lived);
//! * `r2..r15` — scratch;
//! * `f0..f31` — FP work.
//!
//! Every kernel appends to a caller-provided [`Assembler`] and leaves the
//! machine in a state where further kernels can run (no dangling stack).

use crate::isa::Assembler;
use crate::util::Rng;

/// Data-segment base addresses (spread across pages so kernels don't alias).
pub const HEAP0: u64 = 0x0010_0000;
pub const HEAP1: u64 = 0x0040_0000;
pub const HEAP2: u64 = 0x0080_0000;
pub const STACK_TOP: u64 = 0x0070_0000;

/// Tight ALU dependency loop (`iters` iterations, ~4 insts each):
/// pure compute, no memory.
pub fn alu_chain(a: &mut Assembler, iters: i32) {
    a.load_imm64(20, iters as u64);
    a.mtctr(20);
    let top = a.here();
    a.addi(2, 2, 3);
    a.mullw(3, 2, 2);
    a.xor(4, 3, 2);
    a.bdnz(top);
}

/// Independent ALU work across 8 registers — high-ILP integer compute.
pub fn alu_parallel(a: &mut Assembler, iters: i32) {
    a.load_imm64(20, iters as u64);
    a.mtctr(20);
    let top = a.here();
    for k in 0..8u8 {
        a.addi(2 + k, 2 + k, (k as i32) + 1);
    }
    a.bdnz(top);
}

/// Sequential streaming over `n` doubles at `base`: triad-style
/// `y[i] = a*x[i] + y[i]` (memory bandwidth + FP).
pub fn stream_triad(a: &mut Assembler, base: u64, n: i32, iters: i32) {
    a.load_imm64(21, base);
    a.load_imm64(22, base + 8 * n as u64);
    a.load_imm64(20, iters as u64);
    a.mtctr(20);
    let outer = a.here();
    a.or(5, 21, 21); // x cursor
    a.or(6, 22, 22); // y cursor
    a.li(7, n);
    let inner_top = a.here();
    a.lfd(1, 0, 5);
    a.lfd(2, 0, 6);
    a.fmadd(2, 1, 3); // y += x * f3
    a.stfd(2, 0, 6);
    a.addi(5, 5, 8);
    a.addi(6, 6, 8);
    a.addi(7, 7, -1);
    a.cmpi(7, 0);
    a.bgt(inner_top);
    a.bdnz(outer);
}

/// Pointer chase through a pseudo-random cycle of `n` 64-byte nodes at
/// `base` — latency-bound memory (mcf/xalancbmk flavour). Requires the
/// ring to be written by [`pointer_ring_data`] first.
pub fn pointer_chase(a: &mut Assembler, base: u64, steps: i32) {
    a.load_imm64(21, base);
    a.or(5, 21, 21);
    a.load_imm64(20, steps as u64);
    a.mtctr(20);
    let top = a.here();
    a.ld(5, 0, 5); // follow next pointer
    a.addi(6, 6, 1);
    a.bdnz(top);
}

/// Build the pointer ring data for [`pointer_chase`]: a random permutation
/// cycle over `n` nodes spaced 64 B apart.
pub fn pointer_ring_data(a: &mut Assembler, base: u64, n: usize, rng: &mut Rng) {
    let mut order: Vec<usize> = (1..n).collect();
    rng.shuffle(&mut order);
    let mut cycle = vec![0usize];
    cycle.extend(order);
    for (i, &node) in cycle.iter().enumerate() {
        let next = cycle[(i + 1) % n];
        a.data_u64(base + (node as u64) * 64, &[base + (next as u64) * 64]);
    }
}

/// 2D 5-point stencil over an `nx` x `ny` f64 grid at `base`, `iters`
/// sweeps (bwaves/cactuBSSN/fotonik3d flavour).
pub fn stencil2d(a: &mut Assembler, base: u64, nx: i32, ny: i32, iters: i32) {
    let row = 8 * nx;
    a.load_imm64(21, base);
    a.load_imm64(20, iters as u64);
    a.mtctr(20);
    let outer = a.here();
    // cursor starts at interior row 1, col 1
    a.addi(5, 21, (row + 8) as i32);
    a.li(6, ny - 2); // rows remaining
    let row_top = a.here();
    a.li(7, nx - 2); // cols remaining
    let col_top = a.here();
    a.lfd(1, 0, 5); // center
    a.lfd(2, -8, 5); // west
    a.lfd(3, 8, 5); // east
    a.lfd(4, -(row as i32), 5); // north
    a.lfd(5, row as i32, 5); // south
    a.fadd(2, 2, 3);
    a.fadd(4, 4, 5);
    a.fadd(2, 2, 4);
    a.fmadd(2, 1, 6); // += c*f6
    a.fmul(2, 2, 7); // *= 0.2-ish in f7
    a.stfd(2, 0, 5);
    a.addi(5, 5, 8);
    a.addi(7, 7, -1);
    a.cmpi(7, 0);
    a.bgt(col_top);
    a.addi(5, 5, 16); // skip boundary cols
    a.addi(6, 6, -1);
    a.cmpi(6, 0);
    a.bgt(row_top);
    a.bdnz(outer);
}

/// Bytecode interpreter (perlbench/gcc flavour): fetch opcode byte from a
/// random program at `base`, dispatch through a chain of compares — heavy
/// data-dependent control flow.
pub fn interpreter(a: &mut Assembler, base: u64, prog_len: i32, steps: i32) {
    a.load_imm64(21, base);
    a.load_imm64(22, prog_len as u64 * 8); // wrap bound (may exceed imm14)
    a.li(6, 0); // vm accumulator
    a.li(8, 0); // pc
    a.load_imm64(20, steps as u64);
    a.mtctr(20);
    let top = a.here();
    a.ldx(2, 21, 8); // fetch 8 "bytecodes" at once; use low byte
    a.andi(3, 2, 0x7);
    // dispatch: chain of cmpi/beq (unpredictable)
    let done = a.label();
    let c1 = a.label();
    let c2 = a.label();
    let c3 = a.label();
    a.cmpi(3, 0);
    a.bne(c1);
    a.addi(6, 6, 1);
    a.b(done);
    a.bind(c1);
    a.cmpi(3, 1);
    a.bne(c2);
    a.sub(6, 6, 3);
    a.b(done);
    a.bind(c2);
    a.cmpi(3, 2);
    a.bne(c3);
    a.mullw(6, 6, 2);
    a.b(done);
    a.bind(c3);
    a.xor(6, 6, 2);
    a.bind(done);
    // advance vm pc pseudo-randomly within the bytecode array
    a.addi(8, 8, 8);
    a.cmp(8, 22);
    let nowrap = a.label();
    a.blt(nowrap);
    a.li(8, 0);
    a.bind(nowrap);
    a.bdnz(top);
}

/// Recursive search (deepsjeng/exchange2 flavour): depth-first walk with
/// data-dependent pruning, exercising bl/blr + the RAS + stack memory.
/// Recursion depth is bounded by `depth`; `width` children per node.
pub fn recursive_search(a: &mut Assembler, depth: i32, width: i32, reps: i32) {
    // r1 = sp; f(depth): if depth==0 return; loop width times: recurse
    a.load_imm64(1, STACK_TOP);
    a.load_imm64(20, reps as u64);
    a.mtctr(20);
    let rep_top = a.here();
    let func = a.label();
    let after = a.label();
    a.li(25, depth);
    a.bl(func);
    a.b(after);

    a.bind(func);
    // prologue: push lr, r25, r26
    a.mflr(9);
    a.std(9, -8, 1);
    a.std(25, -16, 1);
    a.std(26, -24, 1);
    a.addi(1, 1, -32);
    let ret = a.label();
    a.cmpi(25, 0);
    a.ble(ret);
    a.li(26, width);
    let child_top = a.here();
    // prune on a cheap hash of (depth, child): skip some subtrees
    a.xor(10, 25, 26);
    a.andi(10, 10, 0x3);
    a.cmpi(10, 0);
    let skip = a.label();
    a.beq(skip);
    a.addi(25, 25, -1);
    a.bl(func);
    a.addi(25, 25, 1);
    a.bind(skip);
    a.addi(26, 26, -1);
    a.cmpi(26, 0);
    a.bgt(child_top);
    a.bind(ret);
    // epilogue
    a.addi(1, 1, 32);
    a.ld(26, -24, 1);
    a.ld(25, -16, 1);
    a.ld(9, -8, 1);
    a.mtlr(9);
    a.blr();

    a.bind(after);
    a.bdnz(rep_top);
}

/// Hash-table probe loop (xalancbmk/leela flavour): hash a counter,
/// load a bucket, compare, branch — mixes MEM and CTRL.
pub fn hash_probe(a: &mut Assembler, base: u64, mask: i32, steps: i32) {
    a.load_imm64(21, base);
    a.li(5, 12345);
    a.li(11, 0);
    a.load_imm64(20, steps as u64);
    a.mtctr(20);
    let top = a.here();
    // xorshift hash
    a.sldi(6, 5, 13);
    a.xor(5, 5, 6);
    a.srdi(6, 5, 7);
    a.xor(5, 5, 6);
    a.sldi(6, 5, 17);
    a.xor(5, 5, 6);
    a.andi(7, 5, mask);
    a.sldi(7, 7, 3);
    a.ldx(8, 21, 7); // bucket
    a.cmp(8, 5);
    let miss = a.label();
    a.bne(miss);
    a.addi(11, 11, 1); // hit counter (rare)
    a.bind(miss);
    a.stdx(5, 21, 7); // insert
    a.bdnz(top);
}

/// Dense FP multi-array loops (wrf/cam4/roms flavour): `arrays` interleaved
/// f64 arrays of length `n`, combined with mixed fmadd/fdiv work.
pub fn fp_arrays(a: &mut Assembler, base: u64, arrays: i32, n: i32, iters: i32, with_div: bool) {
    a.load_imm64(21, base);
    a.load_imm64(20, iters as u64);
    a.mtctr(20);
    let outer = a.here();
    a.or(5, 21, 21);
    a.li(6, n);
    let inner = a.here();
    for k in 0..arrays.min(4) {
        a.lfd(1 + k as u8, (k * 8) as i32, 5);
    }
    a.fadd(10, 1, 2);
    a.fmadd(10, 1, 2);
    if arrays >= 3 {
        a.fmul(11, 3, 10);
    } else {
        a.fmul(11, 10, 10);
    }
    if with_div {
        a.fdiv(12, 10, 11);
        a.stfd(12, 0, 5);
    } else {
        a.stfd(11, 0, 5);
    }
    a.addi(5, 5, arrays.min(4) * 8);
    a.addi(6, 6, -1);
    a.cmpi(6, 0);
    a.bgt(inner);
    a.bdnz(outer);
}

/// Integer block ops (x264 SAD flavour): absolute-difference accumulation
/// over byte blocks, mostly ALU with regular loads.
pub fn sad_blocks(a: &mut Assembler, base: u64, blocks: i32, iters: i32) {
    a.load_imm64(21, base);
    a.load_imm64(22, base + 0x8000);
    a.load_imm64(20, iters as u64);
    a.mtctr(20);
    let outer = a.here();
    a.li(6, blocks);
    a.li(12, 0); // sad accumulator
    a.or(5, 21, 21);
    a.or(7, 22, 22);
    let inner = a.here();
    a.ld(2, 0, 5);
    a.ld(3, 0, 7);
    a.sub(4, 2, 3);
    a.sradi(8, 4, 63); // sign mask
    a.xor(4, 4, 8);
    a.sub(4, 4, 8); // |diff|
    a.add(12, 12, 4);
    a.addi(5, 5, 8);
    a.addi(7, 7, 8);
    a.addi(6, 6, -1);
    a.cmpi(6, 0);
    a.bgt(inner);
    a.bdnz(outer);
}

/// LZ-style match finder (xz flavour): scan a byte window comparing
/// against a lagged copy, with data-dependent match-extension loops.
pub fn match_finder(a: &mut Assembler, base: u64, window: i32, steps: i32) {
    a.load_imm64(21, base);
    a.load_imm64(22, window as u64 * 8); // wrap bound
    a.li(9, 0); // position
    a.li(11, 0); // match count
    a.load_imm64(20, steps as u64);
    a.mtctr(20);
    let top = a.here();
    a.ldx(2, 21, 9); // current
    a.addi(10, 9, 256); // lag offset
    a.ldx(3, 21, 10);
    a.cmp(2, 3);
    let nomatch = a.label();
    a.bne(nomatch);
    // extend match (bounded short loop)
    a.li(6, 4);
    let ext = a.here();
    a.addi(9, 9, 8);
    a.ldx(2, 21, 9);
    a.addi(6, 6, -1);
    a.cmpi(6, 0);
    a.bgt(ext);
    a.addi(11, 11, 1);
    a.bind(nomatch);
    a.addi(9, 9, 8);
    // wrap window
    a.cmp(9, 22);
    let nowrap = a.label();
    a.blt(nowrap);
    a.li(9, 0);
    a.bind(nowrap);
    a.bdnz(top);
}

/// Lattice-update kernel (lbm flavour): structured grid, load a
/// neighbourhood of 4, weighted combine, store back with stride.
pub fn lattice_update(a: &mut Assembler, base: u64, cells: i32, iters: i32) {
    a.load_imm64(21, base);
    a.load_imm64(20, iters as u64);
    a.mtctr(20);
    let outer = a.here();
    a.or(5, 21, 21);
    a.li(6, cells);
    let inner = a.here();
    a.lfd(1, 0, 5);
    a.lfd(2, 8, 5);
    a.lfd(3, 16, 5);
    a.lfd(4, 24, 5);
    a.fadd(10, 1, 2);
    a.fadd(11, 3, 4);
    a.fadd(10, 10, 11);
    a.fmul(10, 10, 8); // f8 = 0.25
    a.stfd(10, 0, 5);
    a.stfd(10, 32, 5);
    a.addi(5, 5, 40);
    a.addi(6, 6, -1);
    a.cmpi(6, 0);
    a.bgt(inner);
    a.bdnz(outer);
}

/// Event-queue simulation (omnetpp flavour): binary-heap sift operations
/// driven by a PRNG — pointer arithmetic + hard-to-predict compares.
pub fn event_heap(a: &mut Assembler, base: u64, heap_elems: i32, steps: i32) {
    a.load_imm64(21, base);
    a.li(5, 98765); // prng state
    a.load_imm64(20, steps as u64);
    a.mtctr(20);
    let top = a.here();
    // prng
    a.sldi(6, 5, 13);
    a.xor(5, 5, 6);
    a.srdi(6, 5, 7);
    a.xor(5, 5, 6);
    // i = prng % heap_elems (approx via mask)
    a.andi(7, 5, heap_elems - 1);
    // sift: while i>0 { parent=(i-1)/2; if h[p] <= h[i] break; swap }
    let sift = a.here();
    a.cmpi(7, 0);
    let done = a.label();
    a.ble(done);
    a.addi(8, 7, -1);
    a.srdi(8, 8, 1); // parent
    a.sldi(9, 7, 3);
    a.sldi(10, 8, 3);
    a.ldx(2, 21, 9);
    a.ldx(3, 21, 10);
    a.cmp(3, 2);
    a.ble(done);
    a.stdx(2, 21, 10); // swap
    a.stdx(3, 21, 9);
    a.or(7, 8, 8); // i = parent
    a.b(sift);
    a.bind(done);
    // push new key = prng at random slot
    a.andi(7, 5, heap_elems - 1);
    a.sldi(9, 7, 3);
    a.stdx(5, 21, 9);
    a.bdnz(top);
}

/// N-body-ish force loop (namd/nab flavour): inner loop of FP with
/// divides (softened inverse square).
pub fn nbody_forces(a: &mut Assembler, base: u64, n: i32, iters: i32) {
    a.load_imm64(21, base);
    a.load_imm64(20, iters as u64);
    a.mtctr(20);
    let outer = a.here();
    a.or(5, 21, 21);
    a.li(6, n);
    let inner = a.here();
    a.lfd(1, 0, 5); // xi
    a.lfd(2, 8, 5); // xj
    a.fsub(3, 1, 2); // dx
    a.fmul(4, 3, 3); // dx^2
    a.fadd(4, 4, 9); // + eps  (f9)
    a.fdiv(10, 3, 4); // force ~ dx / (dx^2+eps)
    a.lfd(11, 16, 5);
    a.fadd(11, 11, 10);
    a.stfd(11, 16, 5);
    a.addi(5, 5, 24);
    a.addi(6, 6, -1);
    a.cmpi(6, 0);
    a.bgt(inner);
    a.bdnz(outer);
}

/// PRNG + scatter stores (specrand flavour).
pub fn prng_scatter(a: &mut Assembler, base: u64, mask: i32, steps: i32) {
    a.load_imm64(21, base);
    a.load_imm64(5, 424242);
    a.load_imm64(20, steps as u64);
    a.mtctr(20);
    let top = a.here();
    a.sldi(6, 5, 13);
    a.xor(5, 5, 6);
    a.srdi(6, 5, 7);
    a.xor(5, 5, 6);
    a.sldi(6, 5, 17);
    a.xor(5, 5, 6);
    a.andi(7, 5, mask);
    a.sldi(7, 7, 3);
    a.stdx(5, 21, 7);
    a.bdnz(top);
}

/// Fill a data region with pseudo-random u64s (initial heap contents).
pub fn random_data(a: &mut Assembler, base: u64, words: usize, rng: &mut Rng) {
    let vals: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    a.data_u64(base, &vals);
}

/// Fill a data region with pseudo-random f64s in [0.5, 1.5).
pub fn random_f64_data(a: &mut Assembler, base: u64, count: usize, rng: &mut Rng) {
    let vals: Vec<f64> = (0..count).map(|_| 0.5 + rng.f64()).collect();
    a.data_f64(base, &vals);
}

/// Set up the commonly-used FP constants f3=1.5, f6=0.3, f7=0.2, f8=0.25,
/// f9=1e-3 from a constant pool.
pub fn fp_constants(a: &mut Assembler, pool: u64) {
    a.data_f64(pool, &[1.5, 0.3, 0.2, 0.25, 1e-3]);
    a.load_imm64(15, pool);
    a.lfd(3, 0, 15);
    a.lfd(6, 8, 15);
    a.lfd(7, 16, 15);
    a.lfd(8, 24, 15);
    a.lfd(9, 32, 15);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::AtomicCpu;

    fn run_kernel(build: impl FnOnce(&mut Assembler, &mut Rng)) -> AtomicCpu {
        let mut a = Assembler::new(0x1000);
        let mut rng = Rng::new(7);
        fp_constants(&mut a, HEAP2 + 0x10000);
        build(&mut a, &mut rng);
        a.halt();
        let mut cpu = AtomicCpu::load(&a.finish());
        let n = cpu.run_with(5_000_000, |_| {});
        assert!(cpu.halted, "kernel must halt (ran {n} insts)");
        cpu
    }

    #[test]
    fn alu_kernels_halt() {
        run_kernel(|a, _| alu_chain(a, 500));
        run_kernel(|a, _| alu_parallel(a, 500));
    }

    #[test]
    fn stream_triad_touches_memory() {
        let cpu = run_kernel(|a, r| {
            random_f64_data(a, HEAP0, 256, r);
            random_f64_data(a, HEAP0 + 8 * 256, 256, r);
            stream_triad(a, HEAP0, 256, 3);
        });
        assert!(cpu.mem.read_f64(HEAP0 + 8 * 256) != 0.0);
    }

    #[test]
    fn pointer_chase_visits_ring() {
        let cpu = run_kernel(|a, r| {
            pointer_ring_data(a, HEAP0, 64, r);
            pointer_chase(a, HEAP0, 500);
        });
        assert_eq!(cpu.regs.gpr[6], 500);
        // cursor must still be inside the ring
        let p = cpu.regs.gpr[5];
        assert!(p >= HEAP0 && p < HEAP0 + 64 * 64);
    }

    #[test]
    fn stencil_and_lattice_halt_and_write() {
        let cpu = run_kernel(|a, r| {
            random_f64_data(a, HEAP0, 32 * 32, r);
            stencil2d(a, HEAP0, 32, 32, 2);
        });
        assert!(cpu.icount > 5_000);
        run_kernel(|a, r| {
            random_f64_data(a, HEAP1, 600, r);
            lattice_update(a, HEAP1, 100, 3);
        });
    }

    #[test]
    fn interpreter_exercises_branches() {
        let mut a = Assembler::new(0x1000);
        let mut rng = Rng::new(9);
        random_data(&mut a, HEAP0, 128, &mut rng);
        interpreter(&mut a, HEAP0, 128, 2_000);
        a.halt();
        let mut cpu = AtomicCpu::load(&a.finish());
        let trace = cpu.run_trace(5_000_000);
        assert!(cpu.halted);
        let branches = trace.iter().filter(|r| r.inst.is_cond_branch()).count();
        assert!(branches as f64 / trace.len() as f64 > 0.15,
                "interpreter should be branch-heavy");
    }

    #[test]
    fn recursive_search_balances_stack() {
        let cpu = run_kernel(|a, _| recursive_search(a, 5, 3, 2));
        assert_eq!(cpu.regs.gpr[1], STACK_TOP, "stack must be balanced");
    }

    #[test]
    fn hash_and_heap_and_match_halt() {
        run_kernel(|a, r| {
            random_data(a, HEAP0, 1024, r);
            hash_probe(a, HEAP0, 1023, 2_000);
        });
        run_kernel(|a, r| {
            random_data(a, HEAP1, 256, r);
            event_heap(a, HEAP1, 256, 1_000);
        });
        run_kernel(|a, r| {
            random_data(a, HEAP0, 4096, r);
            match_finder(a, HEAP0, 2048, 1_500);
        });
    }

    #[test]
    fn fp_kernels_halt_with_finite_results() {
        let cpu = run_kernel(|a, r| {
            random_f64_data(a, HEAP0, 1024, r);
            fp_arrays(a, HEAP0, 4, 128, 3, true);
        });
        assert!(cpu.regs.fpr[12].is_finite());
        run_kernel(|a, r| {
            random_f64_data(a, HEAP1, 512, r);
            nbody_forces(a, HEAP1, 128, 3);
        });
    }

    #[test]
    fn sad_and_prng_halt() {
        run_kernel(|a, r| {
            random_data(a, HEAP0, 8192, r);
            sad_blocks(a, HEAP0, 256, 4);
        });
        run_kernel(|a, _| prng_scatter(a, HEAP1, 4095, 3_000));
    }
}
