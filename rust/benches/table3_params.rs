//! **Table III** — average error under five O3 parameter configurations
//! (FetchWidth / IssueWidth / CommitWidth / ROBEntry), fine-tuning each
//! variant from the pre-trained baseline exactly as §VI-D describes.
//! Paper errors: 12.0 / 12.2 / 12.9 / 12.5 / 12.8 %.

#[path = "common.rs"]
mod common;

use capsim::coordinator::build_dataset;
use capsim::o3::O3Config;
use capsim::predictor::{evaluate, train, TrainParams};
use capsim::report::Table;
use capsim::workloads::suite;

fn main() -> anyhow::Result<()> {
    let cfg = common::pipeline_config();
    let rt = common::runtime(&cfg);
    let base_steps = common::train_steps(150, 600);
    let tune_steps = base_steps / 2;

    // a representative subset keeps the 5 per-config golden rebuilds
    // affordable (each configuration needs fresh labels)
    let benches: Vec<_> = suite(cfg.scale)
        .into_iter()
        .enumerate()
        .filter(|(i, _)| i % 4 == 0 || (common::is_full() && i % 3 == 0))
        .map(|(_, b)| b)
        .collect();
    let mut t = Table::new(
        "Table III — average error with different simulator parameters",
        &["FetchWidth", "IssueWidth", "CommitWidth", "ROBEntry", "Error %", "paper %"],
    );
    let paper = [12.0, 12.2, 12.9, 12.5, 12.8];

    let mut base_params: Option<Vec<f32>> = None;
    for ((label, o3), paper_err) in O3Config::table3_rows().into_iter().zip(paper) {
        let mut run_cfg = cfg.clone();
        run_cfg.o3 = o3;
        let (ds, _) = build_dataset(&benches, &run_cfg, run_cfg.effective_threads());
        let (tr, va, te) = ds.split(run_cfg.seed);

        let mut model = rt.load_variant("capsim")?;
        let steps = match &base_params {
            None => {
                model.init_params(run_cfg.seed as u32)?;
                base_steps
            }
            Some(p) => {
                model.set_params(p)?;
                tune_steps
            }
        };
        let log = train(
            &mut model,
            &ds,
            &tr,
            &va,
            &TrainParams { steps, lr: 1e-3, eval_every: 50, seed: 1, patience: 10_000 },
        )?;
        let ev = evaluate(&model, &ds, &te, log.time_scale)?;
        if base_params.is_none() {
            base_params = Some(model.params_vec()?);
        }
        let p: Vec<&str> = label.split('/').collect();
        t.row(vec![
            p[0].into(),
            p[1].into(),
            p[2].into(),
            p[3].into(),
            format!("{:.1}", 100.0 * ev.mape),
            format!("{paper_err:.1}"),
        ]);
    }
    t.emit("table3_params");
    Ok(())
}
