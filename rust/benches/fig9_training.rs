//! **Fig. 9** — training loss vs validation loss of the attention
//! predictor (paper: SGD lr 1e-3 momentum 0.9, converging by ~epoch 128).

#[path = "common.rs"]
mod common;

use capsim::report::Series;

fn main() -> anyhow::Result<()> {
    let cfg = common::pipeline_config();
    let (_, ds) = common::golden_cached(&cfg);
    let rt = common::runtime(&cfg);
    let steps = common::train_steps(200, 800);
    let (_, log, _) = common::train_variant(&rt, "capsim", &ds, steps, cfg.seed)?;

    let mut tr = Series::new("training loss (MAPE)");
    for (s, l) in log.smoothed_train(10) {
        tr.push(s as f64, l);
    }
    tr.emit("fig9_train");

    let mut va = Series::new("validation loss (MAPE)");
    for (s, l) in &log.val_loss {
        va.push(*s as f64, *l);
    }
    va.emit("fig9_val");

    let first = log.smoothed_train(10).first().map(|p| p.1).unwrap_or(0.0);
    let last = log.smoothed_train(10).last().map(|p| p.1).unwrap_or(0.0);
    println!(
        "train loss {first:.3} -> {last:.3} over {} steps; final val MAPE {:.3}",
        log.steps_run,
        log.val_loss.last().map(|p| p.1).unwrap_or(f64::NAN)
    );
    // the paper's qualitative claims: both curves decrease, no divergence
    assert!(last < first, "training loss must decrease");
    Ok(())
}
