//! Shared setup for the paper-reproduction benches.
//!
//! Scale control: `CAPSIM_BENCH_FULL=1` switches to the EXPERIMENTS.md
//! configuration (much longer); the default keeps `cargo bench` tractable
//! on one core while preserving every qualitative shape.

#![allow(dead_code)]

use std::path::Path;

use capsim::config::PipelineConfig;
use capsim::coordinator::{build_dataset, BenchProfile};
use capsim::dataset::Dataset;
use capsim::predictor::{train, TrainLog, TrainParams};
use capsim::runtime::{Backend, ModelHandle, Predictor, Runtime};
use capsim::workloads::{suite, Benchmark, Scale};

pub fn is_full() -> bool {
    std::env::var("CAPSIM_BENCH_FULL").map_or(false, |v| v == "1")
}

pub fn pipeline_config() -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    if is_full() {
        cfg.scale = Scale::Full;
        cfg.simpoint.interval_insts = 1_000_000;
        cfg.simpoint.warmup_insts = 50_000;
        cfg.simpoint.max_k = 6;
    } else {
        cfg.simpoint.interval_insts = 10_000;
        cfg.simpoint.warmup_insts = 1_000;
        cfg.simpoint.max_k = 4;
    }
    cfg
}

pub fn train_steps(default_small: usize, default_full: usize) -> usize {
    if let Ok(v) = std::env::var("CAPSIM_BENCH_STEPS") {
        if let Ok(n) = v.parse() {
            return n;
        }
    }
    if is_full() {
        default_full
    } else {
        default_small
    }
}

/// Suite + golden dataset + profiles under the bench config.
pub fn golden(cfg: &PipelineConfig) -> (Vec<Benchmark>, Dataset, Vec<BenchProfile>) {
    let benches = suite(cfg.scale);
    let (ds, profiles) = build_dataset(&benches, cfg, cfg.effective_threads());
    (benches, ds, profiles)
}

/// Like [`golden`] but caches the dataset on disk so the bench suite does
/// not regenerate identical golden labels six times over (`cargo bench`
/// runs each bench as its own process). Profiles are NOT cached
/// (checkpoints embed memory images); benches that need them use
/// [`golden`].
pub fn golden_cached(cfg: &PipelineConfig) -> (Vec<Benchmark>, Dataset) {
    let benches = suite(cfg.scale);
    let tag = if is_full() { "full" } else { "test" };
    let path = std::path::PathBuf::from(format!("target/capsim_ds_{tag}.bin"));
    if let Ok(ds) = Dataset::load(&path) {
        eprintln!("[common] using cached dataset {path:?} ({} clips)", ds.len());
        return (benches, ds);
    }
    let (ds, _) = build_dataset(&benches, cfg, cfg.effective_threads());
    let _ = ds.save(&path);
    (benches, ds)
}

/// Load the PJRT runtime; exits with a clear message if artifacts are
/// missing (benches are meaningless without them).
pub fn runtime(cfg: &PipelineConfig) -> Runtime {
    match Runtime::load(Path::new(&cfg.artifacts)) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            std::process::exit(0); // don't fail `cargo bench` on a clean tree
        }
    }
}

/// Build the configured backend (`cfg.backend`, the runtime registry)
/// ready for comparison runs. A `pjrt` request that fails (clean tree,
/// no `make artifacts`) falls back to the native analytic backend so
/// the speed benches always run end-to-end. Returns the boxed backend,
/// its time scale and the backend name for reports.
pub fn predictor_for(
    cfg: &PipelineConfig,
    ds: &Dataset,
    steps: usize,
) -> anyhow::Result<(Box<dyn Predictor>, f32, &'static str)> {
    let backend = cfg.backend;
    if backend.requires_artifacts() {
        match backend.build_trained(cfg, ds, steps, "capsim") {
            Ok((model, ts)) => Ok((model, ts, backend.name())),
            Err(e) => {
                eprintln!("[common] {backend} backend unavailable ({e}); using native");
                let (model, ts) = Backend::Native.build_trained(cfg, ds, steps, "capsim")?;
                Ok((model, ts, Backend::Native.name()))
            }
        }
    } else {
        let (model, ts) = backend.build_trained(cfg, ds, steps, "capsim")?;
        Ok((model, ts, backend.name()))
    }
}

/// Init + train one variant on a Method-1 split of `ds`.
pub fn train_variant(
    rt: &Runtime,
    variant: &str,
    ds: &Dataset,
    steps: usize,
    seed: u64,
) -> anyhow::Result<(ModelHandle, TrainLog, Vec<usize>)> {
    let mut model = rt.load_variant(variant)?;
    model.init_params(seed as u32)?;
    let (tr, va, te) = ds.split(seed);
    let log = train(
        &mut model,
        ds,
        &tr,
        &va,
        &TrainParams { steps, lr: 1e-3, eval_every: 25, seed, patience: 10_000 },
    )?;
    Ok((model, log, te))
}
