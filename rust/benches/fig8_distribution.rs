//! **Fig. 8** — the clip-occurrence distribution of one interval of the
//! bwaves analog: (a) in first-appearance order, (b) sorted descending —
//! the two-population shape that justifies the Fig.-3 sampler. Also prints
//! the §VI-A sampler-compression numbers (threshold 200, coefficient 0.02).

#[path = "common.rs"]
mod common;

use capsim::coordinator::build_bench_dataset;
use capsim::report::{Series, Table};
use capsim::sampler::{occurrence_distribution, sample, SamplerConfig};
use capsim::workloads::suite;

fn main() {
    let cfg = common::pipeline_config();
    let benches = suite(cfg.scale);
    // 503.bwaves analog (paper uses its second interval)
    let bwaves = benches.iter().position(|b| b.name == "503.bwaves").unwrap();
    let (ds, prof) = build_bench_dataset(bwaves, &benches[bwaves], &cfg);
    println!(
        "503.bwaves analog: {} clips from {} checkpoints",
        ds.len(),
        prof.selected.len()
    );

    let keys = ds.keys();
    let (orig, sorted) = occurrence_distribution(&keys);
    let mut a = Series::new("occurrences (appearance order)");
    for (i, &c) in orig.iter().enumerate() {
        a.push(i as f64, c as f64);
    }
    a.emit("fig8a_original");
    let mut b = Series::new("occurrences (sorted desc)");
    for (i, &c) in sorted.iter().enumerate() {
        b.push(i as f64, c as f64);
    }
    b.emit("fig8b_sorted");

    let head: u64 = sorted.iter().take(5).sum();
    let total: u64 = sorted.iter().sum();
    println!(
        "unique clips {}  total {}  top-5 categories carry {:.0}% of all clips",
        sorted.len(),
        total,
        100.0 * head as f64 / total as f64
    );

    // §VI-A: sampler compression at the paper's parameters
    let mut t = Table::new(
        "Sampler compression (threshold/coefficient sweep)",
        &["threshold", "coefficient", "clips in", "clips out", "ratio"],
    );
    for (th, co) in [(200u64, 0.02f64), (200, 0.1), (50, 0.02), (10, 0.2)] {
        let sel = sample(&keys, &SamplerConfig { threshold: th, coefficient: co });
        t.row(vec![
            th.to_string(),
            format!("{co}"),
            keys.len().to_string(),
            sel.len().to_string(),
            format!("{:.1}%", 100.0 * sel.len() as f64 / keys.len() as f64),
        ]);
    }
    t.emit("fig8_sampler");
}
