//! **Ablation** — the Fig.-3 sampler's accuracy/cost trade-off (paper
//! §VI-A: threshold 200 + coefficient 0.02 cut training from 300 h to
//! ~10 h without hurting accuracy). We sweep the coefficient and report
//! dataset size, wall-clock per 50 steps, and held-out MAPE.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use capsim::predictor::{evaluate, train, TrainParams};
use capsim::report::Table;
use capsim::sampler::{sample, SamplerConfig};

fn main() -> anyhow::Result<()> {
    let cfg = common::pipeline_config();
    let (_, ds) = common::golden_cached(&cfg);
    let rt = common::runtime(&cfg);
    let steps = common::train_steps(100, 300);

    // shared held-out set from the UNsampled corpus
    let (_, _, test_idx) = ds.split(cfg.seed);
    let test_ds = ds.subset(&test_idx);
    let test_all: Vec<usize> = (0..test_ds.len()).collect();

    let mut t = Table::new(
        "Sampler ablation — training cost vs accuracy",
        &["sampler", "train clips", "s / step", "test MAPE %"],
    );

    let mut configs: Vec<(String, Option<SamplerConfig>)> = vec![
        ("none (full corpus)".into(), None),
    ];
    for co in [0.02, 0.1, 0.5] {
        configs.push((
            format!("threshold 200, coeff {co}"),
            Some(SamplerConfig { threshold: 200, coefficient: co }),
        ));
    }

    for (label, sampler) in configs {
        let train_ds = match &sampler {
            None => ds.clone(),
            Some(sc) => {
                let sel = sample(&ds.keys(), sc);
                ds.subset(&sel)
            }
        };
        if train_ds.len() < 64 {
            t.row(vec![label, train_ds.len().to_string(), "-".into(), "-".into()]);
            continue;
        }
        let mut model = rt.load_variant("capsim")?;
        model.init_params(cfg.seed as u32)?;
        let idx: Vec<usize> = (0..train_ds.len()).collect();
        let t0 = Instant::now();
        let log = train(
            &mut model,
            &train_ds,
            &idx,
            &[],
            &TrainParams { steps, lr: 1e-3, eval_every: 1_000, seed: 3, patience: 10_000 },
        )?;
        let per_step = t0.elapsed().as_secs_f64() / log.steps_run as f64;
        let ev = evaluate(&model, &test_ds, &test_all, log.time_scale)?;
        t.row(vec![
            label,
            train_ds.len().to_string(),
            format!("{per_step:.3}"),
            format!("{:.1}", 100.0 * ev.mape),
        ]);
    }
    t.emit("ablation_sampler");
    Ok(())
}
