//! **Fig. 11** — cross-generalization: train on one of the six Table-II
//! benchmark sets, evaluate on every set — the 6x6 accuracy matrix
//! (Method 2, §VI-D; paper: 91.3% on the training set, 88.3% overall).

#[path = "common.rs"]
mod common;

use capsim::predictor::{evaluate, train, TrainParams};
use capsim::report::Table;
use capsim::util::stats;

fn main() -> anyhow::Result<()> {
    let cfg = common::pipeline_config();
    let (benches, ds) = common::golden_cached(&cfg);
    let rt = common::runtime(&cfg);
    let steps = common::train_steps(120, 500);

    let set_of: Vec<u8> = benches.iter().map(|b| b.set_no).collect();
    let mut sets = ds.by_set(&set_of);
    // cap per-set evaluation size (36 evaluations; MAPE stabilizes well
    // below this many clips)
    let cap = if common::is_full() { 2_000 } else { 500 };
    for s in sets.iter_mut() {
        if s.len() > cap {
            let stride = s.len() / cap;
            *s = s.iter().step_by(stride.max(1)).copied().take(cap).collect();
        }
    }

    let mut t = Table::new(
        "Fig. 11 — 6x6 train/test accuracy (%) over the Table-II sets",
        &["train\\test", "Set1", "Set2", "Set3", "Set4", "Set5", "Set6"],
    );
    let mut diag = Vec::new();
    let mut off = Vec::new();
    for train_set in 0..6 {
        let mut model = rt.load_variant("capsim")?;
        model.init_params(cfg.seed as u32)?;
        let idx = &sets[train_set];
        if idx.is_empty() {
            continue;
        }
        // hold out 10% of the training set as validation
        let n_val = (idx.len() / 10).max(1);
        let (va, tr) = idx.split_at(n_val);
        let log = train(
            &mut model,
            &ds,
            tr,
            va,
            &TrainParams { steps, lr: 1e-3, eval_every: 50, seed: cfg.seed, patience: 10_000 },
        )?;

        let mut row = vec![format!("Set{}", train_set + 1)];
        for (test_set, test_idx) in sets.iter().enumerate() {
            let acc = if test_idx.is_empty() {
                f64::NAN
            } else {
                evaluate(&model, &ds, test_idx, log.time_scale)?.accuracy_pct
            };
            if test_set == train_set {
                diag.push(acc);
            } else {
                off.push(acc);
            }
            row.push(format!("{acc:.1}"));
        }
        t.row(row);
    }
    t.emit("fig11_crossgen");
    println!(
        "train-set accuracy {:.1}% (paper 91.3%)  overall {:.1}% (paper 88.3%)",
        stats::mean(&diag),
        stats::mean(&diag.iter().chain(&off).copied().collect::<Vec<_>>()),
    );
    Ok(())
}
