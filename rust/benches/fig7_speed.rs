//! **Fig. 7** — per-benchmark restore time: gem5 mode (serial O3 restore)
//! vs CAPSim (functional trace + batched attention inference), plus the
//! headline speedup (paper: 2.2–8.3x, arithmetic mean 4.9x).
//!
//! Engine sections on top of the paper's figure:
//!
//! * **cross-benchmark clip dedup** — unique clips sent to the model with
//!   one shared `ClipCache` across the suite vs the per-benchmark dedup
//!   baseline (strictly fewer whenever workloads share kernels);
//! * **pipeline overlap / thread scaling** — the streaming
//!   stage-pipelined engine per thread count (`threads = 1, 2, 4, 8`):
//!   scan-wall (summed worker busy seconds) vs predict-wall (inference
//!   busy seconds) vs total-wall, plus the overlap factor
//!   `(scan + predict) / wall` — results are bit-identical across
//!   counts; only the wall clock moves;
//! * **persistent clip cache** — a second run warm-started from the
//!   on-disk cache must resolve every clip without inference
//!   (warm-start hit rate > 0, zero new predictions).
//!
//! Runs against the trained PJRT model when `make artifacts` has been
//! run, else against the deterministic native analytic backend.

#[path = "common.rs"]
mod common;

use capsim::coordinator::{
    capsim_mode, capsim_suite, gem5_mode, gem5_suite_streamed, ClipCache, SuiteBatching,
};
use capsim::report::Table;
use capsim::runtime::Predictor;
use capsim::util::stats;

fn main() -> anyhow::Result<()> {
    let cfg = common::pipeline_config();
    let (benches, ds, profiles) = common::golden(&cfg);
    let steps = common::train_steps(150, 600);
    let (model, time_scale, backend) = common::predictor_or_native(&cfg, &ds, steps)?;

    // ---- per-benchmark comparison, paper methodology: no cache, each
    // benchmark stands alone (engine effects are reported separately) ----
    let mut t = Table::new(
        "Fig. 7 — speed comparison: simulator (gem5 mode) vs predictor (CAPSim)",
        &["Benchmark", "CKPs", "gem5 s", "CAPSim s", "Speedup", "CyclesErr %", "uniq/total"],
    );
    let mut speedups = Vec::new();
    let mut isolated_unique = 0usize;
    let mut clips_total = 0usize;
    for (b, p) in benches.iter().zip(&profiles) {
        let g = gem5_mode(&p.selected, p.n_intervals, &cfg);
        let c = capsim_mode(
            &p.selected,
            p.n_intervals,
            &cfg,
            model.as_ref(),
            time_scale,
            None,
        )?;
        let speedup = g.wall_s / c.wall_s.max(1e-9);
        speedups.push(speedup);
        isolated_unique += c.clips_unique;
        clips_total += c.clips_total;
        let err = 100.0 * (c.total_cycles - g.total_cycles).abs() / g.total_cycles;
        t.row(vec![
            b.name.into(),
            p.selected.len().to_string(),
            format!("{:.3}", g.wall_s),
            format!("{:.3}", c.wall_s),
            format!("{:.2}x", speedup),
            format!("{:.1}", err),
            format!("{}/{}", c.clips_unique, c.clips_total),
        ]);
    }
    t.emit("fig7_speed");
    println!(
        "speedup: mean {:.2}x (paper 4.9x)  max {:.2}x (paper 8.3x)  min {:.2}x (paper 2.2x)",
        stats::mean(&speedups),
        speedups.iter().cloned().fold(0.0, f64::max),
        speedups.iter().cloned().fold(f64::INFINITY, f64::min),
    );

    // ---- cross-benchmark dedup vs that per-benchmark baseline ----
    let shared = capsim_suite(
        &profiles,
        &cfg,
        model.as_ref(),
        time_scale,
        &ClipCache::new(),
        SuiteBatching::CrossBench,
    )?;
    println!(
        "clip dedup [{backend}]: {clips_total} clip occurrences; per-benchmark dedup \
         predicts {isolated_unique} unique clips, cross-benchmark cache predicts {} \
         ({} resolved across benchmarks)",
        shared.clips_unique, shared.cache_hits
    );

    // ---- streaming engine: overlap + thread scaling (cold cache per
    // row). scan s / predict s are stage busy times; overlap > 1 means
    // the stages genuinely ran concurrently ----
    let mut scaling = Table::new(
        "Engine scaling — streamed suite, scan/predict/total wall per thread count",
        &[
            "Threads", "gem5 s", "CAPSim s", "scan s", "predict s", "overlap", "Speedup",
            "uniq clips",
        ],
    );
    for threads in [1usize, 2, 4, 8] {
        let mut run_cfg = cfg.clone();
        run_cfg.threads = threads;
        let t0 = std::time::Instant::now();
        let _g = gem5_suite_streamed(&profiles, &run_cfg);
        let gem5_s = t0.elapsed().as_secs_f64();
        let c = capsim_suite(
            &profiles,
            &run_cfg,
            model.as_ref(),
            time_scale,
            &ClipCache::new(),
            SuiteBatching::Streamed,
        )?;
        let st = c.stages.unwrap_or_default();
        scaling.row(vec![
            threads.to_string(),
            format!("{gem5_s:.3}"),
            format!("{:.3}", c.wall_s),
            format!("{:.3}", st.scan_busy_s),
            format!("{:.3}", st.predict_busy_s),
            format!("{:.2}x", st.overlap()),
            format!("{:.2}x", gem5_s / c.wall_s.max(1e-9)),
            c.clips_unique.to_string(),
        ]);
    }
    scaling.emit("fig7_engine_scaling");

    // ---- persistent clip cache: cold run -> save -> load -> warm run ----
    let cache_path = std::path::PathBuf::from("target/capsim_fig7_clip_cache.bin");
    let fp = model.fingerprint();
    let cold_cache = ClipCache::new();
    let cold = capsim_suite(
        &profiles,
        &cfg,
        model.as_ref(),
        time_scale,
        &cold_cache,
        SuiteBatching::Streamed,
    )?;
    cold_cache.save(&cache_path, fp, time_scale)?;
    let (warm_cache, warm_loaded) = ClipCache::load_or_cold(&cache_path, fp, time_scale);
    let warm = capsim_suite(
        &profiles,
        &cfg,
        model.as_ref(),
        time_scale,
        &warm_cache,
        SuiteBatching::Streamed,
    )?;
    let wst = warm_cache.stats();
    println!(
        "persistent cache [{backend}]: {} clips saved; warm start loaded={warm_loaded}, \
         hit rate {:.1}% ({} hits), {} new clips predicted (cold run predicted {})",
        cold_cache.len(),
        100.0 * wst.hit_rate(),
        wst.hits,
        warm.clips_unique,
        cold.clips_unique,
    );
    assert!(warm_loaded, "persisted cache must reload under the same key");
    assert!(wst.hit_rate() > 0.0, "warm start must report cache hits");
    assert_eq!(warm.clips_unique, 0, "warm start predicts nothing new");
    let _ = std::fs::remove_file(&cache_path);
    Ok(())
}
