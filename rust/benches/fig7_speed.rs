//! **Fig. 7** — per-benchmark restore time: gem5 mode (serial O3 restore)
//! vs CAPSim (functional trace + batched attention inference), plus the
//! headline speedup (paper: 2.2–8.3x, arithmetic mean 4.9x).

#[path = "common.rs"]
mod common;

use capsim::coordinator::{capsim_mode, gem5_mode};
use capsim::report::Table;
use capsim::util::stats;

fn main() -> anyhow::Result<()> {
    let cfg = common::pipeline_config();
    let (benches, ds, profiles) = common::golden(&cfg);
    let rt = common::runtime(&cfg);
    let steps = common::train_steps(150, 600);
    let (model, log, _) = common::train_variant(&rt, "capsim", &ds, steps, cfg.seed)?;

    let mut t = Table::new(
        "Fig. 7 — speed comparison: simulator (gem5 mode) vs predictor (CAPSim)",
        &["Benchmark", "CKPs", "gem5 s", "CAPSim s", "Speedup", "CyclesErr %"],
    );
    let mut speedups = Vec::new();
    for (b, p) in benches.iter().zip(&profiles) {
        let g = gem5_mode(&p.selected, p.n_intervals, &cfg);
        let c = capsim_mode(&p.selected, p.n_intervals, &cfg, &model, log.time_scale)?;
        let speedup = g.wall_s / c.wall_s.max(1e-9);
        speedups.push(speedup);
        let err = 100.0 * (c.total_cycles - g.total_cycles).abs() / g.total_cycles;
        t.row(vec![
            b.name.into(),
            p.selected.len().to_string(),
            format!("{:.3}", g.wall_s),
            format!("{:.3}", c.wall_s),
            format!("{:.2}x", speedup),
            format!("{:.1}", err),
        ]);
    }
    t.emit("fig7_speed");
    println!(
        "speedup: mean {:.2}x (paper 4.9x)  max {:.2}x (paper 8.3x)  min {:.2}x (paper 2.2x)",
        stats::mean(&speedups),
        speedups.iter().cloned().fold(0.0, f64::max),
        speedups.iter().cloned().fold(f64::INFINITY, f64::min),
    );
    Ok(())
}
