//! **Fig. 7** — per-benchmark restore time: gem5 mode (serial O3 restore)
//! vs CAPSim (functional trace + batched attention inference), plus the
//! headline speedup (paper: 2.2–8.3x, arithmetic mean 4.9x).
//!
//! Engine sections on top of the paper's figure, each reported **per
//! backend** (`native` — the analytic stand-in whose inference is nearly
//! free — vs `attention` — the pure-Rust transformer, a realistic model
//! cost in the measured loop):
//!
//! * **cross-benchmark clip dedup** — unique clips sent to the model with
//!   one shared `ClipCache` across the suite vs the per-benchmark dedup
//!   baseline (strictly fewer whenever workloads share kernels);
//! * **pipeline overlap / thread scaling** — the streaming
//!   stage-pipelined engine per thread count (`threads = 1, 2, 4, 8`):
//!   scan-wall (summed worker busy seconds) vs predict-wall (inference
//!   busy seconds) vs total-wall, plus the overlap factor
//!   `(scan + predict) / wall` — results are bit-identical across
//!   counts; only the wall clock moves. The attention rows are the
//!   interesting ones: with a real model cost the predict stage is no
//!   longer negligible, so overlap shows whether the pipeline actually
//!   hides it;
//! * **persistent clip cache** — a second run warm-started from the
//!   on-disk cache must resolve every clip without inference
//!   (warm-start hit rate > 0, zero new predictions);
//! * **serve latency** — p50/p99/mean per session layer (epoll event
//!   loop vs thread-per-connection, where the host has both) and client
//!   concurrency against a `capsim serve` daemon (attention backend),
//!   with the per-sweep batch fill showing cross-request batching
//!   engage as concurrency rises. Machine-readable copy lands in
//!   `CAPSIM_SERVE_OUT` (default `BENCH_serve.json`);
//! * **serve replica throughput** — the same fixed burst against daemons
//!   at `predict_loops` ∈ {1, 2, 4}: wall time → clips/s plus the
//!   per-loop batch split (row-locality keeps the answers bit-identical,
//!   so only throughput may move);
//! * **persist load wall time** — `CPIM` image load at two cache sizes
//!   100x apart, mmap-frozen vs heap-copied: the mmap path only parses
//!   and checksums the fixed header, so its wall time must stay flat
//!   while the heap path grows with the payload. Machine-readable copy
//!   lands in `CAPSIM_PERSIST_OUT` (default `BENCH_persist.json`).
//!
//! The per-benchmark paper table runs on the configured backend
//! (`pipeline.backend`, default pjrt → trained PJRT model when
//! `make artifacts` has run, else the native fallback).

#[path = "common.rs"]
mod common;

use capsim::coordinator::{
    capsim_mode, capsim_suite, gem5_mode, gem5_suite_streamed, ClipCache, SuiteBatching,
};
use capsim::report::Table;
use capsim::runtime::{Backend, Predictor};
use capsim::util::stats;

fn main() -> anyhow::Result<()> {
    let cfg = common::pipeline_config();
    let (benches, ds, profiles) = common::golden(&cfg);
    let steps = common::train_steps(150, 600);
    let (model, time_scale, backend) = common::predictor_for(&cfg, &ds, steps)?;

    // ---- per-benchmark comparison, paper methodology: no cache, each
    // benchmark stands alone (engine effects are reported separately) ----
    let mut t = Table::new(
        "Fig. 7 — speed comparison: simulator (gem5 mode) vs predictor (CAPSim)",
        &["Benchmark", "CKPs", "gem5 s", "CAPSim s", "Speedup", "CyclesErr %", "uniq/total"],
    );
    let mut speedups = Vec::new();
    let mut isolated_unique = 0usize;
    let mut clips_total = 0usize;
    for (b, p) in benches.iter().zip(&profiles) {
        let g = gem5_mode(&p.selected, p.n_intervals, &cfg);
        let c = capsim_mode(
            &p.selected,
            p.n_intervals,
            &cfg,
            model.as_ref(),
            time_scale,
            None,
        )?;
        let speedup = g.wall_s / c.wall_s.max(1e-9);
        speedups.push(speedup);
        isolated_unique += c.clips_unique;
        clips_total += c.clips_total;
        let err = 100.0 * (c.total_cycles - g.total_cycles).abs() / g.total_cycles;
        t.row(vec![
            b.name.into(),
            p.selected.len().to_string(),
            format!("{:.3}", g.wall_s),
            format!("{:.3}", c.wall_s),
            format!("{:.2}x", speedup),
            format!("{:.1}", err),
            format!("{}/{}", c.clips_unique, c.clips_total),
        ]);
    }
    t.emit("fig7_speed");
    println!(
        "backend [{backend}] speedup: mean {:.2}x (paper 4.9x)  max {:.2}x (paper 8.3x)  \
         min {:.2}x (paper 2.2x)",
        stats::mean(&speedups),
        speedups.iter().cloned().fold(0.0, f64::max),
        speedups.iter().cloned().fold(f64::INFINITY, f64::min),
    );

    // ---- engine sections per dependency-free backend: the analytic
    // stand-in vs the pure-Rust attention model (a real inference cost;
    // unique-clip counts are content-keyed and thus backend-independent,
    // only the wall times move) ----
    let mut scaling = Table::new(
        "Engine scaling — streamed suite, scan/predict/total wall per backend and threads",
        &[
            "Backend", "Threads", "gem5 s", "CAPSim s", "scan s", "predict s", "overlap",
            "Speedup", "uniq clips",
        ],
    );
    // gem5 baselines are backend-independent: measure once per thread
    // count and reuse across both backend sections
    let thread_counts = [1usize, 2, 4, 8];
    let mut gem5_wall = Vec::with_capacity(thread_counts.len());
    for &threads in &thread_counts {
        let mut run_cfg = cfg.clone();
        run_cfg.threads = threads;
        let t0 = std::time::Instant::now();
        let _g = gem5_suite_streamed(&profiles, &run_cfg);
        gem5_wall.push(t0.elapsed().as_secs_f64());
    }
    for be in [Backend::Native, Backend::Attention] {
        let (m, ts) = be.build_trained(&cfg, &ds, 0, "capsim")?;

        // cross-benchmark dedup vs the per-benchmark baseline
        let shared = capsim_suite(
            &profiles,
            &cfg,
            m.as_ref(),
            ts,
            &ClipCache::bounded(cfg.cache_max_entries),
            SuiteBatching::CrossBench,
        )?;
        println!(
            "clip dedup [{be}]: {clips_total} clip occurrences; per-benchmark dedup \
             predicts {isolated_unique} unique clips, cross-benchmark cache predicts {} \
             ({} resolved across benchmarks)",
            shared.clips_unique, shared.cache_hits
        );

        // streaming engine: overlap + thread scaling (cold cache per
        // row). scan s / predict s are stage busy times; overlap > 1
        // means the stages genuinely ran concurrently
        for (&threads, &gem5_s) in thread_counts.iter().zip(&gem5_wall) {
            let mut run_cfg = cfg.clone();
            run_cfg.threads = threads;
            let c = capsim_suite(
                &profiles,
                &run_cfg,
                m.as_ref(),
                ts,
                &ClipCache::bounded(run_cfg.cache_max_entries),
                SuiteBatching::Streamed,
            )?;
            let st = c.stages.unwrap_or_default();
            scaling.row(vec![
                be.name().to_string(),
                threads.to_string(),
                format!("{gem5_s:.3}"),
                format!("{:.3}", c.wall_s),
                format!("{:.3}", st.scan_busy_s),
                format!("{:.3}", st.predict_busy_s),
                format!("{:.2}x", st.overlap()),
                format!("{:.2}x", gem5_s / c.wall_s.max(1e-9)),
                c.clips_unique.to_string(),
            ]);
        }

        // persistent clip cache: cold run -> save -> load -> warm run
        let cache_path =
            std::path::PathBuf::from(format!("target/capsim_fig7_clip_cache_{be}.bin"));
        let fp = m.fingerprint();
        let cold_cache = ClipCache::bounded(cfg.cache_max_entries);
        let cold = capsim_suite(
            &profiles,
            &cfg,
            m.as_ref(),
            ts,
            &cold_cache,
            SuiteBatching::Streamed,
        )?;
        cold_cache.save(&cache_path, fp, ts)?;
        let (warm_cache, warm_loaded) =
            ClipCache::load_or_cold_bounded(&cache_path, fp, ts, cfg.cache_max_entries);
        let warm = capsim_suite(
            &profiles,
            &cfg,
            m.as_ref(),
            ts,
            &warm_cache,
            SuiteBatching::Streamed,
        )?;
        let wst = warm_cache.stats();
        println!(
            "persistent cache [{be}]: {} clips saved; warm start loaded={warm_loaded}, \
             hit rate {}, {} new clips predicted (cold run predicted {})",
            cold_cache.len(),
            wst.hit_line(),
            warm.clips_unique,
            cold.clips_unique,
        );
        assert!(warm_loaded, "persisted cache must reload under the same key");
        assert!(wst.hit_rate() > 0.0, "warm start must report cache hits");
        assert_eq!(warm.clips_unique, 0, "warm start predicts nothing new");
        assert_eq!(wst.evictions, 0, "default bound must not evict at suite scale");
        let _ = std::fs::remove_file(&cache_path);
    }
    scaling.emit("fig7_engine_scaling");

    // ---- serve latency: p50/p99 per client concurrency against the
    // daemon (attention backend — a real model cost in the hot path).
    // Stats deltas between sweeps isolate each concurrency level's
    // batches; rising mean fill with concurrency is the cross-request
    // batching paying off ----
    serve_latency_sweep(&cfg)?;

    // ---- serve throughput per replica count: one shared weight set,
    // N predict loops ----
    serve_replica_sweep(&cfg)?;

    // ---- persistence: image load wall time at two sizes 100x apart ----
    persist_load_bench()?;
    Ok(())
}

/// Time `ClipCache` image loads at two sizes a factor of 100 apart:
/// the mmap-frozen path (header parse only — payload verification is
/// deferred to first lookup) against the heap path (eager digest over
/// the whole payload plus per-entry inserts). The frozen load must stay
/// flat across the size spread; the generous bound below only fails
/// when an O(payload) cost sneaks back into the frozen load path.
fn persist_load_bench() -> anyhow::Result<()> {
    use capsim::util::json::Json;

    const FP: u64 = 0xF1C7_CA5E;
    const TS: f32 = 40.0;
    let sizes = [1_000usize, 100_000];
    let mut rows = Vec::new();
    let mut mmap_mins = Vec::new();
    for &n in &sizes {
        let cache = ClipCache::new();
        for k in 0..n as u64 {
            cache.insert(k.wrapping_mul(0x9E37_79B9_7F4A_7C15), (k % 977) as f64 * 0.25);
        }
        let path = std::path::PathBuf::from(format!("target/capsim_fig7_persist_{n}.bin"));
        cache.save(&path, FP, TS)?;
        let bytes = std::fs::metadata(&path)?.len();

        // min-of-N wall times: the page cache is warm after the first
        // iteration, so the min isolates the code path from disk noise
        let mut mmap_s = f64::INFINITY;
        for _ in 0..24 {
            let t0 = std::time::Instant::now();
            let c = ClipCache::load_bounded(&path, FP, TS, 0)?;
            mmap_s = mmap_s.min(t0.elapsed().as_secs_f64());
            assert_eq!(c.frozen_len(), n, "frozen tier must expose every record");
        }
        let mut heap_s = f64::INFINITY;
        for _ in 0..8 {
            let t0 = std::time::Instant::now();
            let c = ClipCache::load_heap_bounded(&path, FP, TS, 0)?;
            heap_s = heap_s.min(t0.elapsed().as_secs_f64());
            assert_eq!(c.len(), n, "heap tier must copy every record");
        }
        println!(
            "persist load [{n} clips, {bytes} bytes]: mmap {:.1} us, heap {:.1} us ({:.1}x)",
            mmap_s * 1e6,
            heap_s * 1e6,
            heap_s / mmap_s.max(1e-9),
        );
        mmap_mins.push(mmap_s);
        rows.push(Json::obj(vec![
            ("clips", Json::num(n as f64)),
            ("bytes", Json::num(bytes as f64)),
            ("mmap_load_us", Json::num(mmap_s * 1e6)),
            ("heap_load_us", Json::num(heap_s * 1e6)),
        ]));
        let _ = std::fs::remove_file(&path);
    }
    assert!(
        mmap_mins[1] <= mmap_mins[0] * 64.0 + 1e-3,
        "mmap load must stay flat across a 100x size spread: {:.1} us -> {:.1} us",
        mmap_mins[0] * 1e6,
        mmap_mins[1] * 1e6,
    );

    let out = std::env::var("CAPSIM_PERSIST_OUT").unwrap_or_else(|_| "BENCH_persist.json".into());
    let doc = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("image_version", Json::num(capsim::util::image::IMAGE_VERSION as f64)),
        ("loads", Json::arr(rows)),
    ]);
    std::fs::write(&out, doc.dump_pretty())?;
    println!("wrote {out}");
    Ok(())
}

fn serve_latency_sweep(cfg: &capsim::config::PipelineConfig) -> anyhow::Result<()> {
    use capsim::serve::{burst, BurstSpec, Client, Server, ServeOptions, ServeSummary, SessionLayer};
    use capsim::util::json::Json;

    // one sweep per session layer this host can run: the daemon restarts
    // per layer, so every row starts from a cold daemon and the layers
    // see identical deterministic bursts (same seeds)
    let layers: &[SessionLayer] = if capsim::util::epoll::available() {
        &[SessionLayer::Epoll, SessionLayer::Threads]
    } else {
        &[SessionLayer::Threads]
    };
    let g = capsim::runtime::default_geometry();
    let mut t = Table::new(
        "Serve latency — p50/p99 per session layer and client concurrency (attention daemon)",
        &["Layer", "Clients", "Requests", "p50 ms", "p99 ms", "mean ms", "fill", "x-req batches"],
    );
    let mut rows = Vec::new();
    for &layer in layers {
        let opts = ServeOptions {
            listen: "127.0.0.1:0".into(),
            linger_us: 500,
            queue_depth: cfg.effective_queue_depth(),
            predict_loops: 1,
            time_scale: 40.0,
            cache_path: None,
            cache_max_entries: cfg.cache_max_entries,
            cache_mmap: true,
            session_layer: layer,
            idle_timeout_ms: 60_000,
        };
        let server = Server::bind(opts)?;
        let addr = server.addr();
        let seed_cfg = cfg.clone();
        let daemon = std::thread::spawn(move || -> anyhow::Result<ServeSummary> {
            let model = Backend::Attention.build_shared(&seed_cfg)?;
            server.run(model.as_ref())
        });

        let mut prev_clips = 0u64;
        let mut prev_batches = 0u64;
        let mut prev_cross = 0u64;
        for (i, &clients) in [1usize, 2, 4, 8].iter().enumerate() {
            let spec = BurstSpec {
                clients,
                requests: 24,
                clips: 6,
                use_cache: false,
                seed: 0xF16_5EED + i as u64,
                workers: 0,
            };
            let t0 = std::time::Instant::now();
            let report = burst(addr, &g, &spec)?;
            let wall = t0.elapsed().as_secs_f64();
            let clips_d = report.stats.predicted_clips - prev_clips;
            let batches_d = report.stats.batches - prev_batches;
            let cross_d = report.stats.cross_batches - prev_cross;
            prev_clips = report.stats.predicted_clips;
            prev_batches = report.stats.batches;
            prev_cross = report.stats.cross_batches;
            let fill = if batches_d == 0 { 0.0 } else { clips_d as f64 / batches_d as f64 };
            let n_requests = clients * spec.requests;
            t.row(vec![
                layer.to_string(),
                clients.to_string(),
                n_requests.to_string(),
                format!("{:.3}", report.p50_ms()),
                format!("{:.3}", report.p99_ms()),
                format!("{:.3}", report.mean_ms()),
                format!("{fill:.2}"),
                cross_d.to_string(),
            ]);
            rows.push(Json::obj(vec![
                ("layer", Json::str(layer.to_string())),
                ("clients", Json::num(clients as f64)),
                ("requests", Json::num(n_requests as f64)),
                ("p50_ms", Json::num(report.p50_ms())),
                ("p99_ms", Json::num(report.p99_ms())),
                ("mean_ms", Json::num(report.mean_ms())),
                ("throughput_rps", Json::num(n_requests as f64 / wall.max(1e-9))),
            ]));
        }

        Client::connect(addr)?.shutdown()?;
        let summary = daemon.join().expect("serve daemon panicked")?;
        println!(
            "serve [{layer}] drained: {} requests, {} batches, mean fill {:.2}, {} rejected",
            summary.stats.requests,
            summary.stats.batches,
            summary.stats.mean_fill(),
            summary.stats.rejected
        );
    }
    t.emit("fig7_serve_latency");

    // machine-readable trajectory, uploaded like BENCH_kernels.json so
    // perf PRs can diff p50/p99/throughput per layer and concurrency
    let out = std::env::var("CAPSIM_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    let doc = Json::obj(vec![("schema", Json::num(1.0)), ("sweeps", Json::arr(rows))]);
    std::fs::write(&out, doc.dump_pretty())?;
    println!("wrote {out}");
    Ok(())
}

/// Throughput per replica count: the same fixed no-cache burst against
/// daemons at `predict_loops` ∈ {1, 2, 4} (one weight set shared
/// read-only by all loops). Row-locality pins the answers, so the only
/// thing allowed to move across rows is the wall clock — and the
/// per-loop batch split shows whether the replicas actually share load.
fn serve_replica_sweep(cfg: &capsim::config::PipelineConfig) -> anyhow::Result<()> {
    use capsim::serve::{burst, BurstSpec, Client, Server, ServeOptions, ServeSummary, SessionLayer};

    let g = capsim::runtime::default_geometry();
    let mut t = Table::new(
        "Serve throughput — replicated predict loops (attention daemon, fixed burst)",
        &["Loops", "Clips", "wall s", "clips/s", "fill", "per-loop batches"],
    );
    for &n_loops in &[1usize, 2, 4] {
        let opts = ServeOptions {
            listen: "127.0.0.1:0".into(),
            linger_us: 500,
            queue_depth: cfg.effective_queue_depth().max(8),
            predict_loops: n_loops,
            time_scale: 40.0,
            cache_path: None,
            cache_max_entries: cfg.cache_max_entries,
            cache_mmap: true,
            session_layer: SessionLayer::Auto,
            idle_timeout_ms: 60_000,
        };
        let server = Server::bind(opts)?;
        let addr = server.addr();
        let seed_cfg = cfg.clone();
        let daemon = std::thread::spawn(move || -> anyhow::Result<ServeSummary> {
            let model = Backend::Attention.build_shared(&seed_cfg)?;
            server.run(model.as_ref())
        });

        // same burst every row (same seed): only the replica count moves
        let spec = BurstSpec {
            clients: 8,
            requests: 16,
            clips: 6,
            use_cache: false,
            seed: 0x2E9_11CA,
            workers: 0,
        };
        let clips = (spec.clients * spec.requests * spec.clips) as f64;
        let t0 = std::time::Instant::now();
        burst(addr, &g, &spec)?;
        let wall = t0.elapsed().as_secs_f64();

        Client::connect(addr)?.shutdown()?;
        let summary = daemon.join().expect("serve daemon panicked")?;
        assert_eq!(summary.stats.per_loop.len(), n_loops);
        let per_loop = summary
            .stats
            .per_loop
            .iter()
            .map(|l| l.batches.to_string())
            .collect::<Vec<_>>()
            .join("/");
        t.row(vec![
            n_loops.to_string(),
            format!("{clips:.0}"),
            format!("{wall:.3}"),
            format!("{:.0}", clips / wall.max(1e-9)),
            format!("{:.2}", summary.stats.mean_fill()),
            per_loop,
        ]);
    }
    t.emit("fig7_serve_replicas");
    Ok(())
}
